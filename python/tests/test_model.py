"""L2 correctness: model shapes, loss descent, flat-param plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def _params(rng, spec, scale=0.05):
    return jnp.array(rng.normal(0, scale, spec.total).astype(np.float32))


# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


def test_param_spec_layout_roundtrip(rng):
    spec = M.ParamSpec([("a", (3, 4)), ("b", (5,)), ("c", (2, 2, 2))])
    assert spec.total == 12 + 5 + 8
    flat = jnp.arange(spec.total, dtype=jnp.float32)
    a = spec.get(flat, "a")
    b = spec.get(flat, "b")
    c = spec.get(flat, "c")
    assert a.shape == (3, 4) and float(a[0, 0]) == 0.0
    assert b.shape == (5,) and float(b[0]) == 12.0
    assert c.shape == (2, 2, 2) and float(c[0, 0, 0]) == 17.0


def test_param_spec_manifest():
    spec = M.mlp_spec([4, 3, 2])
    man = spec.manifest()
    assert man["total"] == 4 * 3 + 3 + 3 * 2 + 2
    assert man["tensors"][0] == {"name": "l0.w", "shape": [4, 3]}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def test_mlp_shapes(rng):
    spec, loss_fn, fwd = M.make_mlp([20, 16, 10])
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(7, 20)).astype(np.float32))
    assert fwd(p, x).shape == (7, 10)


def test_mlp_loss_decreases(rng):
    spec, loss_fn, fwd = M.make_mlp([20, 32, 5])
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(16, 20)).astype(np.float32))
    y = jnp.array(rng.integers(0, 5, 16).astype(np.int32))
    step = jax.jit(M.make_sgd_step(loss_fn))
    p, l0 = step(p, x, y, jnp.float32(0.1))
    for _ in range(15):
        p, l = step(p, x, y, jnp.float32(0.1))
    assert float(l) < float(l0)


def test_mlp_eval_counts_correct(rng):
    spec, loss_fn, fwd = M.make_mlp([8, 4])
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(10, 8)).astype(np.float32))
    logits = fwd(p, x)
    y = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    ev = M.make_eval(fwd)
    loss, correct = ev(p, x, y)
    assert float(correct) == 10.0


def test_grad_fn_matches_step(rng):
    spec, loss_fn, _ = M.make_mlp([6, 5, 3])
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(4, 6)).astype(np.float32))
    y = jnp.array(rng.integers(0, 3, 4).astype(np.int32))
    g, l1 = M.make_grad_fn(loss_fn)(p, x, y)
    p2, l2 = M.make_sgd_step(loss_fn)(p, x, y, jnp.float32(0.5))
    np.testing.assert_allclose(np.array(p2), np.array(p - 0.5 * g),
                               rtol=1e-5, atol=1e-6)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------


def test_cnn_shapes_mnist_like(rng):
    spec, loss_fn, fwd = M.make_cnn(in_ch=1, img=28, c1=4, c2=8, fc=32,
                                    classes=10)
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(3, 784)).astype(np.float32))
    assert fwd(p, x).shape == (3, 10)


def test_cnn_shapes_cifar_like(rng):
    spec, loss_fn, fwd = M.make_cnn(in_ch=3, img=32, c1=4, c2=8, fc=32,
                                    classes=10)
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(2, 3 * 32 * 32)).astype(np.float32))
    assert fwd(p, x).shape == (2, 10)


def test_cnn_loss_decreases(rng):
    spec, loss_fn, fwd = M.make_cnn(in_ch=1, img=28, c1=2, c2=4, fc=16,
                                    classes=4)
    p = _params(rng, spec)
    x = jnp.array(rng.normal(size=(8, 784)).astype(np.float32))
    y = jnp.array(rng.integers(0, 4, 8).astype(np.int32))
    step = jax.jit(M.make_sgd_step(loss_fn))
    p, l0 = step(p, x, y, jnp.float32(0.05))
    for _ in range(10):
        p, l = step(p, x, y, jnp.float32(0.05))
    assert float(l) < float(l0)


# ---------------------------------------------------------------------------
# Transformer
# ---------------------------------------------------------------------------


def test_transformer_shapes(rng):
    spec, loss_fn = M.make_transformer(vocab=32, d=16, layers=1, heads=2,
                                       dff=32)
    p = _params(rng, spec)
    tok = jnp.array(rng.integers(0, 32, (2, 9)).astype(np.int32))
    logits = M.transformer_forward(spec, 32, 16, 1, 2, p, tok[:, :-1])
    assert logits.shape == (2, 8, 32)


def test_transformer_causality(rng):
    """Changing a future token must not change past logits."""
    spec, _ = M.make_transformer(vocab=16, d=8, layers=1, heads=1, dff=16)
    p = _params(rng, spec)
    tok = jnp.array(rng.integers(0, 16, (1, 8)).astype(np.int32))
    tok2 = tok.at[0, 7].set((int(tok[0, 7]) + 1) % 16)
    l1 = M.transformer_forward(spec, 16, 8, 1, 1, p, tok)
    l2 = M.transformer_forward(spec, 16, 8, 1, 1, p, tok2)
    np.testing.assert_allclose(np.array(l1[0, :7]), np.array(l2[0, :7]),
                               rtol=1e-5, atol=1e-5)


def test_transformer_loss_decreases(rng):
    spec, loss_fn = M.make_transformer(vocab=16, d=16, layers=1, heads=2,
                                       dff=32)
    p = _params(rng, spec)
    # a memorizable repeating sequence
    seq = np.tile(np.arange(8), 3)[:17]
    tok = jnp.array(np.stack([seq, seq]).astype(np.int32))
    step = jax.jit(M.make_lm_step(loss_fn))
    p, l0 = step(p, tok, jnp.float32(0.1))
    for _ in range(30):
        p, l = step(p, tok, jnp.float32(0.1))
    assert float(l) < float(l0)


def test_lm_eval_matches_loss(rng):
    spec, loss_fn = M.make_transformer(vocab=16, d=8, layers=1, heads=1,
                                       dff=16)
    p = _params(rng, spec)
    tok = jnp.array(rng.integers(0, 16, (2, 9)).astype(np.int32))
    (le,) = M.make_lm_eval(loss_fn)(p, tok)
    assert float(le) == pytest.approx(float(loss_fn(p, tok)), rel=1e-6)
