"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes, level counts and value distributions;
assert_allclose against ref.py. This is the core L1 signal.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lm_quant as LQ
from compile.kernels import matmul as MM
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _uniform_partition(s):
    bnd = jnp.linspace(0.0, 1.0, s + 1).astype(jnp.float32)
    lev = 0.5 * (bnd[:-1] + bnd[1:])
    return lev, bnd


def _rand_partition(rng, s):
    """Random strictly-increasing boundaries in [0, 1] with valid levels."""
    cuts = np.sort(rng.uniform(0.01, 0.99, size=s - 1)).astype(np.float32)
    bnd = np.concatenate([[0.0], cuts, [1.0]]).astype(np.float32)
    lev = (0.5 * (bnd[:-1] + bnd[1:])).astype(np.float32)
    return jnp.array(lev), jnp.array(bnd)


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.array(MM.matmul_pallas(jnp.array(a), jnp.array(b)))
    want = np.array(ref.matmul_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_exact_block_multiple():
    rng = np.random.default_rng(7)
    a = rng.normal(size=(256, 128)).astype(np.float32)
    b = rng.normal(size=(128, 384)).astype(np.float32)
    got = np.array(MM.matmul_pallas(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_jnp():
    import jax

    rng = np.random.default_rng(3)
    a = jnp.array(rng.normal(size=(17, 33)).astype(np.float32))
    b = jnp.array(rng.normal(size=(33, 9)).astype(np.float32))

    def f_pallas(a, b):
        return jnp.sum(MM.matmul(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum(jnp.matmul(a, b) ** 2)

    ga = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    gr = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.array(ga[0]), np.array(gr[0]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(ga[1]), np.array(gr[1]),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# LM quantizer kernels
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d=st.integers(1, 5000),
    s=st.sampled_from([2, 4, 8, 16, 50, 256]),
    seed=st.integers(0, 2**31 - 1),
    uniform=st.booleans(),
)
def test_lm_assign_matches_ref(d, s, seed, uniform):
    rng = np.random.default_rng(seed)
    r = jnp.array(rng.uniform(0, 1, d).astype(np.float32))
    lev, bnd = (_uniform_partition(s) if uniform
                else _rand_partition(rng, s))
    got = np.array(LQ.lm_assign(r, lev, bnd))
    want = np.array(ref.lm_assign_ref(r, lev, bnd))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@settings(**SETTINGS)
@given(
    d=st.integers(1, 5000),
    s=st.sampled_from([2, 4, 16, 50]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lm_stats_matches_ref(d, s, seed):
    rng = np.random.default_rng(seed)
    r = jnp.array(rng.uniform(0, 1, d).astype(np.float32))
    lev, bnd = _rand_partition(rng, s)
    gs, gc = LQ.lm_stats(r, bnd, s)
    ws, wc = ref.lm_stats_ref(r, bnd, s)
    np.testing.assert_allclose(np.array(gs), np.array(ws),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.array(gc), np.array(wc),
                               rtol=0, atol=0.5)


def test_lm_stats_counts_total():
    rng = np.random.default_rng(0)
    d, s = 3333, 16
    r = jnp.array(rng.uniform(0, 1, d).astype(np.float32))
    lev, bnd = _uniform_partition(s)
    _, cnt = LQ.lm_stats(r, bnd, s)
    assert float(jnp.sum(cnt)) == pytest.approx(d)


@settings(**SETTINGS)
@given(
    d=st.integers(2, 4000),
    s=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_lm_quantize_matches_ref(d, s, seed, scale):
    rng = np.random.default_rng(seed)
    v = jnp.array((rng.normal(size=d) * scale).astype(np.float32))
    lev, bnd = _uniform_partition(s)
    gq, gd = LQ.lm_quantize(v, lev, bnd)
    wq, wd = ref.lm_quantize_ref(v, lev, bnd)
    np.testing.assert_allclose(np.array(gq), np.array(wq),
                               rtol=1e-5, atol=1e-5 * scale)
    np.testing.assert_allclose(float(gd), float(wd), rtol=1e-3, atol=1e-6)


@settings(**SETTINGS)
@given(
    d=st.integers(100, 5000),
    s=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lloyd_iter_matches_ref_and_reduces_distortion(d, s, seed):
    rng = np.random.default_rng(seed)
    r = jnp.array(np.abs(rng.normal(size=d)).astype(np.float32))
    r = r / jnp.max(r)
    lev, bnd = _uniform_partition(s)
    glev, gbnd = LQ.lloyd_iter(r, bnd, s)
    wlev, wbnd = ref.lloyd_iter_ref(r, bnd, s)
    np.testing.assert_allclose(np.array(glev), np.array(wlev),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(gbnd), np.array(wbnd),
                               rtol=1e-4, atol=1e-4)
    # Lloyd-Max iterations are monotone in distortion (Lemma 1)
    def distortion(lev, bnd):
        q = ref.lm_assign_ref(r, lev, bnd)
        return float(jnp.sum((q - r) ** 2))

    lev0 = 0.5 * (bnd[:-1] + bnd[1:])
    d0 = distortion(lev0, bnd)
    d1 = distortion(glev, gbnd)
    assert d1 <= d0 * (1 + 1e-4)


def test_lloyd_fixed_point_levels_are_centroids():
    """After many iterations levels ~ bin centroids (Eq. 16-17)."""
    rng = np.random.default_rng(1)
    s = 8
    r = jnp.array(rng.beta(2, 5, 20000).astype(np.float32))
    lev, bnd = _uniform_partition(s)
    for _ in range(40):
        lev, bnd = LQ.lloyd_iter(r, bnd, s)
    # levels at return are centroids of the PREVIOUS boundaries, so the
    # fixed point is only approached (quadratically); allow ~1% slack.
    ws, wc = ref.lm_stats_ref(r, bnd, s)
    cent = np.array(ws) / np.maximum(np.array(wc), 1)
    np.testing.assert_allclose(np.array(lev), cent, rtol=0.02, atol=0.01)
    inner = 0.5 * (np.array(lev)[:-1] + np.array(lev)[1:])
    np.testing.assert_allclose(np.array(bnd)[1:-1], inner,
                               rtol=0.02, atol=0.01)
