"""L2: jax models (fwd/bwd) over a FLAT parameter vector.

Every model here exposes the same interface so the Rust coordinator can
drive any of them through one code path:

    sgd_step(params[P], x, y, lr[]) -> (params'[P], loss[])
    evaluate(params[P], x, y)       -> (loss[], correct[])

Parameters live in a single flat f32 vector because the paper's quantizers
(and the Rust L3 engine) operate on the flat exchanged buffer — the model
unflattens internally with static slices. Dense layers route through the
L1 Pallas matmul kernel (kernels/matmul.py) so the AOT-lowered HLO step
contains the Pallas compute in both forward and backward.

These functions are lowered ONCE by aot.py to artifacts/*.hlo.txt; python
never runs on the training path.
"""

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.matmul import matmul

# ---------------------------------------------------------------------------
# Flat parameter plumbing
# ---------------------------------------------------------------------------


class ParamSpec:
    """Named tensor layout inside the flat parameter vector."""

    def __init__(self, entries: Sequence[Tuple[str, Tuple[int, ...]]]):
        self.entries: List[Tuple[str, Tuple[int, ...]]] = list(entries)
        self.offsets: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
        off = 0
        for name, shape in self.entries:
            size = 1
            for dim in shape:
                size *= dim
            self.offsets[name] = (off, shape)
            off += size
        self.total = off

    def get(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        off, shape = self.offsets[name]
        size = 1
        for dim in shape:
            size *= dim
        return flat[off:off + size].reshape(shape)

    def manifest(self) -> dict:
        return {
            "total": self.total,
            "tensors": [
                {"name": n, "shape": list(s)} for n, s in self.entries
            ],
        }


def _dense(spec: ParamSpec, flat: jnp.ndarray, name: str,
           x: jnp.ndarray) -> jnp.ndarray:
    """x @ W + b through the Pallas matmul kernel."""
    w = spec.get(flat, name + ".w")
    b = spec.get(flat, name + ".b")
    return matmul(x, w) + b[None, :]


def _xent(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy with integer labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


# ---------------------------------------------------------------------------
# MLP (paper's MNIST-class workload, fast sweep model)
# ---------------------------------------------------------------------------


def mlp_spec(dims: Sequence[int]) -> ParamSpec:
    entries = []
    for i in range(len(dims) - 1):
        entries.append((f"l{i}.w", (dims[i], dims[i + 1])))
        entries.append((f"l{i}.b", (dims[i + 1],)))
    return ParamSpec(entries)


def mlp_forward(spec: ParamSpec, dims: Sequence[int], flat: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    h = x
    nlayer = len(dims) - 1
    for i in range(nlayer):
        h = _dense(spec, flat, f"l{i}", h)
        if i + 1 < nlayer:
            h = jax.nn.relu(h)
    return h


def make_mlp(dims: Sequence[int]):
    spec = mlp_spec(dims)

    def loss_fn(flat, x, y):
        return _xent(mlp_forward(spec, dims, flat, x), y)

    return spec, loss_fn, lambda flat, x: mlp_forward(spec, dims, flat, x)


# ---------------------------------------------------------------------------
# CNN (paper section VI: "two different CNNs" for MNIST / CIFAR-10)
# ---------------------------------------------------------------------------


def cnn_spec(in_ch: int, img: int, c1: int, c2: int, fc: int,
             classes: int) -> ParamSpec:
    side = img // 4  # two 2x2 max-pools
    return ParamSpec([
        ("conv1.w", (c1, in_ch, 5, 5)),
        ("conv1.b", (c1,)),
        ("conv2.w", (c2, c1, 5, 5)),
        ("conv2.b", (c2,)),
        ("fc1.w", (c2 * side * side, fc)),
        ("fc1.b", (fc,)),
        ("fc2.w", (fc, classes)),
        ("fc2.b", (classes,)),
    ])


def _conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """NCHW same-padding conv + bias."""
    out = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out + b[None, :, None, None]


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def cnn_forward(spec: ParamSpec, in_ch: int, img: int, flat: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, in_ch*img*img) flat image rows -> logits."""
    bsz = x.shape[0]
    h = x.reshape(bsz, in_ch, img, img)
    h = jax.nn.relu(_conv(h, spec.get(flat, "conv1.w"),
                          spec.get(flat, "conv1.b")))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, spec.get(flat, "conv2.w"),
                          spec.get(flat, "conv2.b")))
    h = _maxpool2(h)
    h = h.reshape(bsz, -1)
    h = jax.nn.relu(_dense(spec, flat, "fc1", h))
    return _dense(spec, flat, "fc2", h)


def make_cnn(in_ch: int, img: int, c1: int, c2: int, fc: int, classes: int):
    spec = cnn_spec(in_ch, img, c1, c2, fc, classes)

    def loss_fn(flat, x, y):
        return _xent(cnn_forward(spec, in_ch, img, flat, x), y)

    return spec, loss_fn, lambda flat, x: cnn_forward(spec, in_ch, img,
                                                      flat, x)


# ---------------------------------------------------------------------------
# Tiny decoder-only transformer LM (end-to-end driver workload)
# ---------------------------------------------------------------------------


def transformer_spec(vocab: int, d: int, layers: int, dff: int) -> ParamSpec:
    entries = [("embed", (vocab, d)), ("pos", (1024, d))]
    for i in range(layers):
        entries += [
            (f"blk{i}.ln1.g", (d,)), (f"blk{i}.ln1.b", (d,)),
            (f"blk{i}.qkv.w", (d, 3 * d)), (f"blk{i}.qkv.b", (3 * d,)),
            (f"blk{i}.proj.w", (d, d)), (f"blk{i}.proj.b", (d,)),
            (f"blk{i}.ln2.g", (d,)), (f"blk{i}.ln2.b", (d,)),
            (f"blk{i}.ff1.w", (d, dff)), (f"blk{i}.ff1.b", (dff,)),
            (f"blk{i}.ff2.w", (dff, d)), (f"blk{i}.ff2.b", (d,)),
        ]
    entries += [("lnf.g", (d,)), ("lnf.b", (d,)), ("head", (d, vocab))]
    return ParamSpec(entries)


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _mm2(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) x (d, e) through the Pallas kernel via a 2D reshape."""
    bsz, s, d = x.shape
    out = matmul(x.reshape(bsz * s, d), w) + b[None, :]
    return out.reshape(bsz, s, -1)


def transformer_forward(spec: ParamSpec, vocab: int, d: int, layers: int,
                        heads: int, flat: jnp.ndarray,
                        tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32 -> logits (B, S, vocab); causal attention."""
    bsz, s = tokens.shape
    hd = d // heads
    h = spec.get(flat, "embed")[tokens] + spec.get(flat, "pos")[None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(layers):
        pre = _layernorm(h, spec.get(flat, f"blk{i}.ln1.g"),
                         spec.get(flat, f"blk{i}.ln1.b"))
        qkv = _mm2(pre, spec.get(flat, f"blk{i}.qkv.w"),
                   spec.get(flat, f"blk{i}.qkv.b"))
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def split_heads(t):
            return t.reshape(bsz, s, heads, hd).transpose(0, 2, 1, 3)

        q, k, v = split_heads(q), split_heads(k), split_heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz, s, d)
        h = h + _mm2(ctx, spec.get(flat, f"blk{i}.proj.w"),
                     spec.get(flat, f"blk{i}.proj.b"))
        pre = _layernorm(h, spec.get(flat, f"blk{i}.ln2.g"),
                         spec.get(flat, f"blk{i}.ln2.b"))
        ff = jax.nn.gelu(_mm2(pre, spec.get(flat, f"blk{i}.ff1.w"),
                              spec.get(flat, f"blk{i}.ff1.b")))
        h = h + _mm2(ff, spec.get(flat, f"blk{i}.ff2.w"),
                     spec.get(flat, f"blk{i}.ff2.b"))
    h = _layernorm(h, spec.get(flat, "lnf.g"), spec.get(flat, "lnf.b"))
    bszs = bsz * s
    logits = matmul(h.reshape(bszs, d), spec.get(flat, "head"))
    return logits.reshape(bsz, s, vocab)


def make_transformer(vocab: int, d: int, layers: int, heads: int, dff: int):
    spec = transformer_spec(vocab, d, layers, dff)

    def loss_fn(flat, tokens, _y_unused=None):
        """Next-token prediction over (B, S+1) token rows."""
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits = transformer_forward(spec, vocab, d, layers, heads, flat,
                                     inp)
        bsz, s, _ = logits.shape
        return _xent(logits.reshape(bsz * s, vocab), tgt.reshape(bsz * s))

    return spec, loss_fn


# ---------------------------------------------------------------------------
# Shared step / eval wrappers (these are what aot.py lowers)
# ---------------------------------------------------------------------------


def make_sgd_step(loss_fn):
    """(params, x, y, lr) -> (params', loss): one local SGD step, Eq. (3)."""

    def step(params, x, y, lr):
        loss, grad = jax.value_and_grad(loss_fn)(params, x, y)
        return params - lr * grad, loss

    return step


def make_grad_fn(loss_fn):
    """(params, x, y) -> (grad, loss): for gradient-exchange variants."""

    def gradf(params, x, y):
        loss, grad = jax.value_and_grad(loss_fn)(params, x, y)
        return grad, loss

    return gradf


def make_eval(forward):
    """(params, x, y) -> (loss, correct-count) on one batch."""

    def ev(params, x, y):
        logits = forward(params, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        loss = jnp.mean(logz - picked)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y)
                          .astype(jnp.float32))
        return loss, correct

    return ev


def make_lm_step(loss_fn):
    """(params, tokens, lr) -> (params', loss) for the transformer LM."""

    def step(params, tokens, lr):
        loss, grad = jax.value_and_grad(loss_fn)(params, tokens)
        return params - lr * grad, loss

    return step


def make_lm_eval(loss_fn):
    def ev(params, tokens):
        return (loss_fn(params, tokens),)

    return ev
