"""AOT compile path: lower every L2 entry point to HLO TEXT artifacts.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Emits artifacts/<name>.hlo.txt plus artifacts/manifest.json describing every
artifact's I/O shapes so the Rust runtime can bind buffers without any
Python at run time.

HLO *text* (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo/ and gen_hlo.py there.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import lm_quant as LQ

# ---------------------------------------------------------------------------
# Presets — baked shapes. The Rust side reads these from manifest.json.
# ---------------------------------------------------------------------------

BATCH = 32
MLP_DIMS = [784, 256, 128, 10]
CNN_MNIST = dict(in_ch=1, img=28, c1=8, c2=16, fc=128, classes=10)
CNN_CIFAR = dict(in_ch=3, img=32, c1=16, c2=32, fc=256, classes=10)
TRANSFORMER = dict(vocab=256, d=128, layers=2, heads=4, dff=512,
                   batch=8, seq=64)
QUANT_D = 65536            # flat-vector length for the LM quantizer artifacts
QUANT_S = [16, 64]         # level counts baked into quantizer artifacts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _shape_entry(name, arr_spec):
    return {
        "name": name,
        "shape": list(arr_spec.shape),
        "dtype": str(arr_spec.dtype),
    }


class Emitter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.manifest = {"artifacts": {}}

    def emit(self, name: str, fn, specs, meta: dict, out_names=None):
        """Lower fn(*specs) and write <name>.hlo.txt + manifest entry."""
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        outs = jax.tree_util.tree_leaves(out_avals)
        entry = {
            "file": fname,
            "inputs": [_shape_entry(n, s) for n, s in specs],
            "outputs": [
                _shape_entry(
                    out_names[i] if out_names else f"out{i}", o)
                for i, o in enumerate(outs)
            ],
        }
        entry.update(meta)
        self.manifest["artifacts"][name] = entry
        print(f"  wrote {fname} ({len(text)} chars)")

    def finish(self):
        path = os.path.join(self.outdir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"  wrote manifest.json ({len(self.manifest['artifacts'])} "
              "artifacts)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def emit_classifier(em: Emitter, name: str, spec, loss_fn, forward,
                    feat: int, meta: dict):
    step = M.make_sgd_step(loss_fn)
    ev = M.make_eval(forward)
    gradf = M.make_grad_fn(loss_fn)
    p = spec.total
    io = [("params", f32(p)), ("x", f32(BATCH, feat)), ("y", i32(BATCH))]
    meta = dict(meta, params=p, batch=BATCH, features=feat)
    em.emit(f"{name}_step", step, io + [("lr", f32())],
            dict(meta, kind="step"), out_names=["params", "loss"])
    em.emit(f"{name}_eval", ev, io, dict(meta, kind="eval"),
            out_names=["loss", "correct"])
    em.emit(f"{name}_grad", gradf, io, dict(meta, kind="grad"),
            out_names=["grad", "loss"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-group filter "
                         "(mlp,cnn_mnist,cnn_cifar,transformer,quant)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    def want(group):
        return only is None or group in only

    em = Emitter(args.out)

    if want("mlp"):
        print("lowering MLP (synth-MNIST sweep model)")
        spec, loss_fn, fwd = M.make_mlp(MLP_DIMS)
        emit_classifier(em, "mlp_mnist", spec, loss_fn, fwd, MLP_DIMS[0],
                        {"model": "mlp", "dims": MLP_DIMS,
                         "tensors": spec.manifest()["tensors"]})

    if want("cnn_mnist"):
        print("lowering CNN / synth-MNIST")
        c = CNN_MNIST
        spec, loss_fn, fwd = M.make_cnn(**c)
        emit_classifier(em, "cnn_mnist", spec, loss_fn, fwd,
                        c["in_ch"] * c["img"] ** 2,
                        {"model": "cnn", "cnn": c,
                         "tensors": spec.manifest()["tensors"]})

    if want("cnn_cifar"):
        print("lowering CNN / synth-CIFAR")
        c = CNN_CIFAR
        spec, loss_fn, fwd = M.make_cnn(**c)
        emit_classifier(em, "cnn_cifar", spec, loss_fn, fwd,
                        c["in_ch"] * c["img"] ** 2,
                        {"model": "cnn", "cnn": c,
                         "tensors": spec.manifest()["tensors"]})

    if want("transformer"):
        print("lowering transformer LM (e2e driver)")
        t = TRANSFORMER
        spec, loss_fn = M.make_transformer(
            t["vocab"], t["d"], t["layers"], t["heads"], t["dff"])
        step = M.make_lm_step(loss_fn)
        ev = M.make_lm_eval(loss_fn)
        p = spec.total
        tok = i32(t["batch"], t["seq"] + 1)
        meta = {"model": "transformer", "transformer": t, "params": p}
        em.emit("transformer_step", step,
                [("params", f32(p)), ("tokens", tok), ("lr", f32())],
                dict(meta, kind="lm_step"), out_names=["params", "loss"])
        em.emit("transformer_eval", ev,
                [("params", f32(p)), ("tokens", tok)],
                dict(meta, kind="lm_eval"), out_names=["loss"])

    if want("quant"):
        for s in QUANT_S:
            print(f"lowering LM quantizer kernels (s={s}, d={QUANT_D})")
            em.emit(
                f"lm_quantize_s{s}",
                lambda v, lev, bnd: LQ.lm_quantize(v, lev, bnd),
                [("v", f32(QUANT_D)), ("levels", f32(s)),
                 ("boundaries", f32(s + 1))],
                {"kind": "lm_quantize", "s": s, "d": QUANT_D},
                out_names=["q", "distortion"])
            em.emit(
                f"lloyd_iter_s{s}",
                lambda r, bnd, s=s: LQ.lloyd_iter(r, bnd, s),
                [("r", f32(QUANT_D)), ("boundaries", f32(s + 1))],
                {"kind": "lloyd_iter", "s": s, "d": QUANT_D},
                out_names=["levels", "boundaries"])

    em.finish()


if __name__ == "__main__":
    main()
