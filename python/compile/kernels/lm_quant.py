"""L1 Pallas kernels for the Lloyd-Max quantizer (the paper's hot spot).

Two kernels, both tiled over the flat parameter-difference vector:

* `lm_assign` — bucketize each normalized magnitude r_i into its Lloyd-Max
  bin and emit the dequantized level (Algorithm 1 step 8). The per-element
  bin search is expressed as a broadcast compare against the interior
  boundaries followed by a row-sum — an O(s) chain of VPU compare+adds,
  which on TPU vectorizes across the (8, 128) lanes; no gather is needed
  because the level lookup is a one-hot contraction that maps to the MXU.

* `lm_stats` — per-bin sum and count of r (the sufficient statistics for
  one empirical Lloyd-Max centroid iteration, Eq. 17). Grid-sequential
  accumulation into the output ref (TPU "arbitrary" grid semantics): each
  chunk adds its partial histogram.

Both run `interpret=True` (CPU PJRT cannot run Mosaic custom-calls) and are
validated against `ref.py` oracles by pytest/hypothesis sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk of the flat vector processed per grid step. At s <= 256 the
# (CHUNK, s) compare matrix is CHUNK*s*4 bytes = 1 MiB @ s=256 — the
# working set that has to fit VMEM alongside levels/boundaries.
CHUNK = 1024


def _assign_kernel(r_ref, inner_ref, levels_ref, o_ref):
    r = r_ref[...]                      # (CHUNK,)
    inner = inner_ref[...]              # (s-1,) interior boundaries
    levels = levels_ref[...]            # (s,)
    s = levels.shape[0]
    # idx_i = #{m : r_i > inner_m}  ==  bin index in [0, s)
    cmp = (r[:, None] > inner[None, :]).astype(jnp.int32)
    idx = jnp.sum(cmp, axis=1)
    # one-hot contraction instead of gather: MXU-friendly
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, s), 1))
    o_ref[...] = jnp.sum(onehot.astype(jnp.float32) * levels[None, :], axis=1)


def _stats_kernel(r_ref, inner_ref, sum_ref, cnt_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    r = r_ref[...]
    inner = inner_ref[...]
    s = sum_ref.shape[0]
    cmp = (r[:, None] > inner[None, :]).astype(jnp.int32)
    idx = jnp.sum(cmp, axis=1)
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, s), 1))
    oh = onehot.astype(jnp.float32)
    sum_ref[...] += jnp.sum(oh * r[:, None], axis=0)
    cnt_ref[...] += jnp.sum(oh, axis=0)


def _pad1(x: jnp.ndarray, mult: int, value: float) -> jnp.ndarray:
    p = (-x.shape[0]) % mult
    if p == 0:
        return x
    return jnp.pad(x, (0, p), constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lm_assign(r: jnp.ndarray, levels: jnp.ndarray, boundaries: jnp.ndarray,
              interpret: bool = True) -> jnp.ndarray:
    """Dequantized Lloyd-Max assignment of (d,) magnitudes r in [0,1]."""
    d = r.shape[0]
    rp = _pad1(r.astype(jnp.float32), CHUNK, 0.0)
    inner = boundaries[1:-1].astype(jnp.float32)
    out = pl.pallas_call(
        _assign_kernel,
        grid=(rp.shape[0] // CHUNK,),
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec(inner.shape, lambda i: (0,)),
            pl.BlockSpec(levels.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((CHUNK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(rp.shape, jnp.float32),
        interpret=interpret,
    )(rp, inner, levels.astype(jnp.float32))
    return out[:d]


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def lm_stats(r: jnp.ndarray, boundaries: jnp.ndarray, s: int,
             interpret: bool = True):
    """Per-bin (sum, count) of (d,) magnitudes r under `boundaries`.

    Padding: tail elements are set to 2.0 — every interior boundary is
    <= 1, so all npad phantom elements land deterministically in the last
    bin; the wrapper subtracts exactly (2.0 * npad, npad) from bin s-1,
    making the result exact for any d.
    """
    d = r.shape[0]
    rp = _pad1(r.astype(jnp.float32), CHUNK, 2.0)
    npad = rp.shape[0] - d
    inner = boundaries[1:-1].astype(jnp.float32)
    bin_sum, bin_cnt = pl.pallas_call(
        _stats_kernel,
        grid=(rp.shape[0] // CHUNK,),
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec(inner.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((s,), lambda i: (0,)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=interpret,
    )(rp, inner)
    # Correct the phantom tail: padded values (2.0) all fell in the last bin.
    correction_cnt = jnp.zeros((s,), jnp.float32).at[s - 1].set(float(npad))
    correction_sum = jnp.zeros((s,), jnp.float32).at[s - 1].set(2.0 * npad)
    return bin_sum - correction_sum, bin_cnt - correction_cnt


def lloyd_iter(r: jnp.ndarray, boundaries: jnp.ndarray, s: int,
               interpret: bool = True):
    """One Lloyd-Max iteration (Algorithm 1 steps 4-5) on empirical data.

    Kernel for the stats, plain jnp for the tiny (s,)-sized centroid /
    midpoint arithmetic.
    """
    bin_sum, bin_cnt = lm_stats(r, boundaries, s, interpret=interpret)
    mid = 0.5 * (boundaries[:-1] + boundaries[1:])
    levels = jnp.where(bin_cnt > 0, bin_sum / jnp.maximum(bin_cnt, 1.0), mid)
    inner = 0.5 * (levels[:-1] + levels[1:])
    new_bounds = jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), inner, jnp.ones((1,), jnp.float32)])
    return levels, new_bounds


def lm_quantize(v: jnp.ndarray, levels: jnp.ndarray, boundaries: jnp.ndarray,
                interpret: bool = True):
    """Full LM vector quantizer (paper III-C3): norm + signs + levels.

    Returns (q, distortion). This is the function AOT-lowered into
    artifacts/lm_quantize_*.hlo.txt and benched against the Rust-native
    quantizer.
    """
    norm = jnp.linalg.norm(v)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(v) / safe
    sign = jnp.where(v < 0, -1.0, 1.0)
    q = norm * sign * lm_assign(r, levels, boundaries, interpret=interpret)
    distortion = jnp.sum((q - v) ** 2)
    return q, distortion
