"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (python/tests/) asserts
allclose between kernel and oracle across shape/dtype sweeps (hypothesis).
This is the CORE correctness signal for layer 1.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul oracle, fp32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def lm_assign_ref(r: jnp.ndarray, levels: jnp.ndarray,
                  boundaries: jnp.ndarray) -> jnp.ndarray:
    """Lloyd-Max assignment oracle.

    r:          (d,) normalized magnitudes in [0, 1]
    levels:     (s,) quantization levels, ascending
    boundaries: (s+1,) bin edges, boundaries[0] = 0, boundaries[s] = 1

    Element r_i is mapped to levels[j] where r_i falls in bin
    (boundaries[j], boundaries[j+1]]  (r = 0 maps to the first level),
    exactly the rule of Algorithm 1 step 8 in the paper.
    """
    s = levels.shape[0]
    # index = number of interior boundaries strictly below r
    idx = jnp.sum(r[:, None] > boundaries[None, 1:s], axis=1)
    return levels[idx]


def lm_stats_ref(r: jnp.ndarray, boundaries: jnp.ndarray, s: int):
    """Per-bin (sum, count) oracle for one Lloyd-Max centroid step.

    Returns (bin_sum[s], bin_cnt[s]) with the same binning rule as
    lm_assign_ref. The centroid update of Eq. (17) on an empirical
    distribution is then levels[j] = bin_sum[j] / max(bin_cnt[j], 1).
    """
    idx = jnp.sum(r[:, None] > boundaries[None, 1:s], axis=1)
    onehot = (idx[:, None] == jnp.arange(s)[None, :]).astype(jnp.float32)
    bin_sum = jnp.sum(onehot * r[:, None], axis=0)
    bin_cnt = jnp.sum(onehot, axis=0)
    return bin_sum, bin_cnt


def lloyd_iter_ref(r: jnp.ndarray, boundaries: jnp.ndarray, s: int):
    """One full Lloyd-Max iteration oracle (Algorithm 1 steps 4-5).

    levels[j]  = centroid of bin j            (Eq. 17, empirical)
    bounds[j]  = (levels[j] + levels[j+1])/2  (Eq. 16)
    Empty bins keep their midpoint as the level so the sequence stays
    monotone.
    """
    bin_sum, bin_cnt = lm_stats_ref(r, boundaries, s)
    mid = 0.5 * (boundaries[:-1] + boundaries[1:])
    levels = jnp.where(bin_cnt > 0, bin_sum / jnp.maximum(bin_cnt, 1.0), mid)
    inner = 0.5 * (levels[:-1] + levels[1:])
    new_bounds = jnp.concatenate(
        [jnp.zeros((1,), r.dtype), inner, jnp.ones((1,), r.dtype)])
    return levels, new_bounds


def lm_quantize_ref(v: jnp.ndarray, levels: jnp.ndarray,
                    boundaries: jnp.ndarray):
    """LM vector quantizer oracle (paper section III-C3).

    Decomposes v into (norm, signs, normalized magnitudes), assigns each
    magnitude to its Lloyd-Max level, and reconstructs the dequantized
    vector. Returns (q, distortion) with distortion = ||q - v||^2.
    """
    norm = jnp.linalg.norm(v)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(v) / safe
    sign = jnp.where(v < 0, -1.0, 1.0)
    q = norm * sign * lm_assign_ref(r, levels, boundaries)
    distortion = jnp.sum((q - v) ** 2)
    return q, distortion
