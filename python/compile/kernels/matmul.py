"""L1 Pallas kernel: tiled matmul with a Pallas backward pass.

The dense layers of every L2 model route through `matmul()` below, so the
Pallas kernel lowers into the same HLO artifact as the surrounding jax
computation — forward AND backward (the custom_vjp's two gradient matmuls
are the same kernel).

TPU mapping (see DESIGN.md §Hardware-Adaptation): 128x128 blocks match the
MXU systolic array; the k-loop is the innermost grid dimension so each
(i, j) output tile accumulates in VMEM scratch across k steps — the
BlockSpec index maps express the HBM<->VMEM schedule the paper's CPU/PyTorch
substrate left to the BLAS library. `interpret=True` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes. 128 matches both the MXU tile and the f32 VPU lane layout
# (8, 128). Inputs not divisible by the block are padded by the wrapper.
BM = 128
BK = 128
BN = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (BM, BN) output tile; accumulate over the k grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    pm = (-x.shape[0]) % m
    pn = (-x.shape[1]) % n
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray,
                  interpret: bool = True) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) via the tiled Pallas kernel.

    Arbitrary shapes: inputs are zero-padded up to the block grid and the
    result is sliced back. fp32 accumulation regardless of input dtype.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    ap = _pad_to(a.astype(jnp.float32), BM, BK)
    bp = _pad_to(b.astype(jnp.float32), BK, BN)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // BM, np_ // BN, kp // BK)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul used by the L2 model dense layers."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # dA = g @ B^T ; dB = A^T @ g — both through the same Pallas kernel, so
    # the backward pass of the AOT-lowered training step is also Pallas.
    return matmul_pallas(g, b.T), matmul_pallas(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
