//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline, so instead
//! of pulling `anyhow` from crates.io this workspace vendors the small
//! subset the `lmdfl` crate actually uses:
//!
//! * [`Error`] — an opaque boxed error with `Display`/`Debug`
//! * [`Result`] — `Result<T, Error>` alias with the same defaulted form
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros
//! * a blanket `From<E: std::error::Error>` so `?` lifts concrete errors
//!
//! Context chains (`.context(...)`) and downcasting are intentionally not
//! implemented; nothing in the workspace uses them. If the real crate ever
//! becomes available, swapping the path dependency back to the registry
//! version is a one-line change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// Opaque error: a boxed `std::error::Error` (or a formatted message).
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>` with the error type defaulted, matching the
/// real crate's signature.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A plain-message error payload (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error(Box::new(error))
    }

    /// Borrow the underlying error object.
    pub fn as_std(&self) -> &(dyn StdError + Send + Sync + 'static) {
        self.0.as_ref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` in the real crate prints the context chain; this stand-in
        // carries no context, so both forms print the root message.
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

// `Error` itself deliberately does NOT implement `std::error::Error`: that
// is what makes this blanket conversion coherent (same trick as the real
// crate), and it is what `?` uses to lift concrete error types.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

/// Construct an [`Error`] from a format string (inline captures work) or
/// from any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let x = 7;
        let e = anyhow!("bad value {x} in {}", "ctx");
        assert_eq!(e.to_string(), "bad value 7 in ctx");
        // alternate form prints the same (no context chain here)
        assert_eq!(format!("{e:#}"), "bad value 7 in ctx");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted ok, got {ok}");
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("always fails");
        }
        assert_eq!(f(true).unwrap(), 1);
        assert!(f(false).unwrap_err().to_string().contains("wanted ok"));
        assert!(g().is_err());
        fn bare(x: u32) -> Result<u32> {
            ensure!(x > 2);
            Ok(x)
        }
        assert!(bare(1).unwrap_err().to_string().contains("x > 2"));
        assert_eq!(bare(3).unwrap(), 3);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
