//! Fig. 6 reproduction driver: LM-DFL vs no-quant / ALQ / QSGD on
//! synth-MNIST and synth-CIFAR — all four panels per dataset, CSVs written
//! to results/fig6_*.csv.
//!
//!   cargo run --release --example lm_vs_baselines [-- --full] [--cifar]

use lmdfl::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "--full") {
        Scale::Full
    } else {
        Scale::from_env()
    };
    let cifar = args.iter().any(|a| a == "--cifar");

    let (tag, curves) = if cifar {
        ("cifar", fig6::run_cifar(scale)?)
    } else {
        ("mnist", fig6::run_mnist(scale)?)
    };

    println!("{}", fig6::render_panels(&curves, 100e6));

    std::fs::create_dir_all("results")?;
    for c in &curves {
        let safe = c.label.replace('/', "_");
        let path = format!("results/fig6_{tag}_{safe}.csv");
        c.log.write_csv(std::path::Path::new(&path))?;
        println!("wrote {path}");
    }

    // headline check, mirroring the paper's §VI-B1 narrative
    let last = |label: &str| {
        curves
            .iter()
            .find(|c| c.label.ends_with(label))
            .map(|c| c.log.records.last().unwrap().clone())
            .unwrap()
    };
    let lm = last("LM-DFL");
    let qsgd = last("QSGD");
    let alq = last("ALQ");
    println!(
        "\nfinal distortion: LM-DFL {:.4}  ALQ {:.4}  QSGD {:.4}  \
         (expect LM lowest)",
        lm.distortion, alq.distortion, qsgd.distortion
    );
    println!(
        "final loss      : LM-DFL {:.4}  ALQ {:.4}  QSGD {:.4}",
        lm.loss, alq.loss, qsgd.loss
    );
    Ok(())
}
