//! Quickstart: train a small model with LM-DFL on synth-MNIST and compare
//! against unquantized DFL — the 60-second tour of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! If `make artifacts` has been run, the same training is repeated on the
//! AOT-compiled HLO backend (PJRT) to show the production path.

use lmdfl::prelude::*;

fn base_config() -> ExperimentConfig {
    ExperimentConfig {
        name: "quickstart".into(),
        seed: 1,
        nodes: 10,
        tau: 4,
        rounds: 25,
        batch_size: 32,
        lr: LrSchedule::fixed(0.02),
        topology: TopologyKind::Ring, // zeta ~ 0.87, the paper's setup
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 12 },
        dataset: DatasetKind::SynthMnist { train: 1500, test: 400 },
        backend: BackendKind::RustMlp { hidden: vec![64] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism: Parallelism::Auto,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
    }
}

fn main() -> anyhow::Result<()> {
    println!("== LM-DFL (Lloyd-Max quantizer, s=16) ==");
    let lm_log = Trainer::build(&base_config())?.run()?;
    report(&lm_log);

    println!("\n== DFL without quantization (baseline) ==");
    let mut cfg = base_config();
    cfg.quantizer = QuantizerKind::Full;
    let full_log = Trainer::build(&cfg)?.run()?;
    report(&full_log);

    let lm_bits = lm_log.total_bits() as f64;
    let full_bits = full_log.total_bits() as f64;
    println!(
        "\nLM-DFL used {:.1}x fewer bits per link ({:.2} vs {:.2} Mbit) \
         for final loss {} vs {}",
        full_bits / lm_bits,
        lm_bits / 1e6,
        full_bits / 1e6,
        fnum(lm_log.last_loss().unwrap()),
        fnum(full_log.last_loss().unwrap()),
    );

    // production path: same algorithm, local updates on the AOT HLO model
    if artifacts_available() {
        println!("\n== LM-DFL on the PJRT HLO backend (mlp_mnist) ==");
        let mut cfg = base_config();
        cfg.name = "quickstart-hlo".into();
        cfg.nodes = 4; // keep PJRT compile time short in the demo
        cfg.rounds = 6;
        cfg.dataset = DatasetKind::SynthMnist { train: 600, test: 200 };
        cfg.backend = BackendKind::Hlo { artifact: "mlp_mnist".into() };
        let log = Trainer::build(&cfg)?.run()?;
        report(&log);
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` to demo the \
                  PJRT HLO backend)");
    }
    Ok(())
}

fn report(log: &RunLog) {
    let first = log.records.first().unwrap();
    let last = log.records.last().unwrap();
    println!(
        "rounds {:3}: loss {} -> {}, accuracy {}, bits/link {}, \
         mean distortion {}",
        log.records.len(),
        fnum(first.loss),
        fnum(last.loss),
        fnum(log.final_accuracy().unwrap_or(f64::NAN)),
        last.bits_per_link,
        fnum(
            log.records.iter().map(|r| r.distortion).sum::<f64>()
                / log.records.len() as f64
        ),
    );
}
