//! Fig. 4 + Fig. 8 reproduction driver: doubly-adaptive DFL (ascending
//! s_k per Eq. 37) vs fixed-level baselines, under fixed and variable
//! learning rates. CSVs written to results/.
//!
//!   cargo run --release --example doubly_adaptive [-- --full] [--cifar]

use lmdfl::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "--full") {
        Scale::Full
    } else {
        Scale::from_env()
    };
    let cifar = args.iter().any(|a| a == "--cifar");
    std::fs::create_dir_all("results")?;

    // ---- Fig. 4: adaptive vs fixed vs descending s (loss vs bits) ------
    println!("===== Fig. 4: ascending vs fixed s =====");
    let f4 = fig4::run_mnist(scale)?;
    println!("{}", fig8::render_loss_vs_bits(&f4));
    for c in &f4 {
        let path = format!("results/fig4_{}.csv", c.label);
        c.log.write_csv(std::path::Path::new(&path))?;
    }

    // ---- Fig. 8: doubly-adaptive vs QSGD 2/4/8-bit ----------------------
    for variable_lr in [false, true] {
        let tag = if variable_lr { "var-lr" } else { "fixed-lr" };
        println!("\n===== Fig. 8 ({tag}) =====");
        let curves = if cifar {
            fig8::run_cifar(scale, variable_lr)?
        } else {
            fig8::run_mnist(scale, variable_lr)?
        };
        println!("{}", fig8::render_loss_vs_bits(&curves));
        println!("{}", fig8::render_bits_per_element(&curves));
        // bits to reach a shared mid-training target
        let target = curves
            .iter()
            .map(|c| c.log.records.last().unwrap().loss)
            .fold(f64::MIN, f64::max)
            * 1.1;
        println!("{}", fig8::bits_to_target(&curves, target));
        for c in &curves {
            let safe = c.label.replace('/', "_");
            let path = format!("results/fig8_{safe}.csv");
            c.log.write_csv(std::path::Path::new(&path))?;
        }
    }
    Ok(())
}
