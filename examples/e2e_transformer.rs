//! End-to-end driver (DESIGN.md §E2E): decentralized training of the
//! AOT-compiled transformer LM over the full three-layer stack.
//!
//! * L1/L2: `artifacts/transformer_step.hlo.txt` — jax transformer whose
//!   dense layers are the Pallas matmul kernel, lowered once at build time.
//! * L3: this driver — N Rust nodes, ring topology, LM-DFL differential
//!   quantized gossip (Algorithm 2), real bit accounting; Python never runs.
//!
//! Workload: next-byte prediction on a synthetic corpus (deterministic
//! pseudo-English markov text). Logs the global loss curve to
//! results/e2e_transformer.csv — the EXPERIMENTS.md §E2E record.
//!
//!   make artifacts && cargo run --release --example e2e_transformer
//!   (flags: --rounds N --nodes N --tau N --s N --lr F)

use lmdfl::prelude::*;

/// Deterministic pseudo-text corpus: sampled words with punctuation —
/// structured enough that a byte LM's loss falls quickly.
fn synth_corpus(len: usize, seed: u64) -> Vec<u8> {
    const WORDS: [&str; 12] = [
        "the", "model", "gossip", "quantize", "level", "node", "learn",
        "bits", "adapt", "lloyd", "max", "converge",
    ];
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len + 16);
    while out.len() < len {
        let w = WORDS[rng.below(WORDS.len())];
        out.extend_from_slice(w.as_bytes());
        out.push(b' ');
        if rng.uniform() < 0.12 {
            out.extend_from_slice(b". ");
        }
    }
    out.truncate(len);
    out
}

struct LmNode {
    /// x_k (params after mixing — round start)
    params: Vec<f32>,
    /// x̂ (globally consistent estimate; deterministic LM quantizer)
    hat: Vec<f32>,
    quantizer: LloydMaxQuantizer,
    rng: Rng,
    /// corpus shard (offset, len) — non-IID by position
    shard: (usize, usize),
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.get_usize("nodes", 4)?;
    let rounds = args.get_usize("rounds", 60)?;
    let tau = args.get_usize("tau", 2)?;
    let s = args.get_usize("s", 32)?;
    let lr = args.get_f64("lr", 0.25)? as f32;

    let dir = artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let info = manifest.get("transformer_step")?.clone();
    let eval_info = manifest.get("transformer_eval")?.clone();
    let p = info.params.expect("manifest params");
    let tok_spec = info.input("tokens").expect("tokens input").clone();
    let (batch, seq1) = (tok_spec.shape[0], tok_spec.shape[1]);
    println!(
        "transformer artifact: {p} params, batch {batch}, seq {} (+1 label)",
        seq1 - 1
    );

    println!("compiling PJRT executables...");
    let client = xla::PjRtClient::cpu()
        .map_err(|e| anyhow::anyhow!("pjrt: {e:?}"))?;
    let step = HloExecutor::compile(&client, info)?;
    let eval = HloExecutor::compile(&client, eval_info)?;

    let corpus = synth_corpus(200_000, 99);
    let shard_len = corpus.len() / nodes;

    let topo = Topology::build(&TopologyKind::Ring, nodes, 0);
    println!(
        "topology: ring, zeta = {:.4}; LM-DFL s = {s}, tau = {tau}, lr = {lr}",
        topo.zeta
    );

    let mut root_rng = Rng::new(7);
    let mut init = vec![0.0f32; p];
    root_rng.fill_normal(&mut init, 0.0, 0.02);
    let mut node_v: Vec<LmNode> = (0..nodes)
        .map(|i| LmNode {
            params: init.clone(),
            hat: vec![0.0; p],
            quantizer: LloydMaxQuantizer::new(s, 12),
            rng: root_rng.split(i as u64),
            shard: (i * shard_len, shard_len),
        })
        .collect();

    // held-out eval windows from across the whole corpus
    let eval_toks: Vec<i32> = {
        let mut rng = Rng::new(12345);
        let mut t = Vec::with_capacity(batch * seq1);
        for _ in 0..batch {
            let start = rng.below(corpus.len() - seq1 - 1);
            t.extend(corpus[start..start + seq1].iter().map(|&b| b as i32));
        }
        t
    };

    let mut log = RunLog::new("e2e_transformer");
    let mut cum_bits = 0u64;
    let mut cum_wire = 0u64;
    let mut diff = vec![0.0f32; p];
    let mut dq = vec![0.0f32; p];
    let mut q1_all: Vec<Vec<f32>> = vec![vec![0.0; p]; nodes];

    for k in 0..rounds {
        let t0 = std::time::Instant::now();
        let mut round_bits = 0u64;
        let mut round_wire = 0u64;
        let mut round_dist = 0.0f64;

        // ---- Eq. 22 (estimate-referenced): x̂ += γ·Q(x_k − x̂) ----------
        for (i, node) in node_v.iter_mut().enumerate() {
            for j in 0..p {
                diff[j] = node.params[j] - node.hat[j];
            }
            let (msg, _) = quantize_damped(
                &mut node.quantizer, &diff, &mut node.rng, &mut dq);
            round_bits += msg.paper_bits();
            // matrix-engine convention: encoded size × out-degree
            round_wire +=
                msg.wire_message_bytes() * topo.adj[i].len() as u64;
            for j in 0..p {
                node.hat[j] += dq[j];
            }
        }

        // ---- τ local SGD steps through the AOT executable ---------------
        let mut mean_local_loss = 0.0f64;
        for node in node_v.iter_mut() {
            for _ in 0..tau {
                let (off, len) = node.shard;
                let mut toks = Vec::with_capacity(batch * seq1);
                for _ in 0..batch {
                    let start = off + node.rng.below(len - seq1 - 1);
                    toks.extend(
                        corpus[start..start + seq1]
                            .iter()
                            .map(|&b| b as i32),
                    );
                }
                let outs = step.run(&[
                    literal_f32(&node.params, &[p])?,
                    literal_i32(&toks, &[batch, seq1])?,
                    literal_f32(&[lr], &[])?,
                ])?;
                let newp = outs[0]
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?;
                node.params.copy_from_slice(&newp);
                mean_local_loss += outs[1]
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("{e:?}"))?[0]
                    as f64;
            }
        }
        mean_local_loss /= (nodes * tau) as f64;

        // ---- q1 = Q(x_{k,τ} − x̂): x̂ += γ·q1 ---------------------------
        for (i, node) in node_v.iter_mut().enumerate() {
            for j in 0..p {
                diff[j] = node.params[j] - node.hat[j];
            }
            let (msg, omega) = quantize_damped(
                &mut node.quantizer, &diff, &mut node.rng,
                &mut q1_all[i]);
            round_bits += msg.paper_bits();
            round_wire +=
                msg.wire_message_bytes() * topo.adj[i].len() as u64;
            round_dist += omega;
            for j in 0..p {
                node.hat[j] += q1_all[i][j];
            }
        }

        // ---- Eq. 21 mixing as consensus correction on true params ------
        // x += (X̂C)_i − x̂_i   (== X̂C when estimates are exact)
        let mut mixed: Vec<Vec<f32>> = vec![vec![0.0f32; p]; nodes];
        for i in 0..nodes {
            for j in 0..nodes {
                let w = topo.c[(j, i)] as f32;
                if w == 0.0 {
                    continue;
                }
                let hat = &node_v[j].hat;
                let out = &mut mixed[i];
                for x in 0..p {
                    out[x] += w * hat[x];
                }
            }
        }
        for (node, m) in node_v.iter_mut().zip(mixed) {
            for x in 0..p {
                node.params[x] += m[x] - node.hat[x];
            }
        }

        // ---- evaluate the averaged model on held-out windows ------------
        let mut avg = vec![0.0f32; p];
        for node in &node_v {
            for (a, &v) in avg.iter_mut().zip(&node.params) {
                *a += v;
            }
        }
        avg.iter_mut().for_each(|x| *x /= nodes as f32);
        let outs = eval.run(&[
            literal_f32(&avg, &[p])?,
            literal_i32(&eval_toks, &[batch, seq1])?,
        ])?;
        let eval_loss = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?[0] as f64;

        cum_bits += round_bits / nodes as u64;
        cum_wire += round_wire;
        let rec = RoundRecord {
            round: k + 1,
            loss: eval_loss,
            accuracy: f64::NAN,
            bits_per_link: cum_bits,
            distortion: round_dist / nodes as f64,
            levels: s,
            lr: lr as f64,
            wall_secs: t0.elapsed().as_secs_f64(),
            virtual_secs: 0.0,
            straggler_wait_secs: 0.0,
            wire_bytes: cum_wire,
        };
        println!(
            "round {:3}  eval-loss {:.4}  local-loss {:.4}  \
             {:6.2} Mbit/link  dist {:.5}  {:.2}s",
            rec.round,
            rec.loss,
            mean_local_loss,
            cum_bits as f64 / 1e6,
            rec.distortion,
            rec.wall_secs
        );
        log.push(rec);
    }

    std::fs::create_dir_all("results")?;
    log.write_csv(std::path::Path::new("results/e2e_transformer.csv"))?;
    println!("\nwrote results/e2e_transformer.csv");
    println!(
        "final loss {} after {} rounds, {:.2} Mbit/link",
        fnum(log.last_loss().unwrap_or(f64::NAN)),
        log.records.len(),
        log.total_bits() as f64 / 1e6
    );
    Ok(())
}
