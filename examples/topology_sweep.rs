//! Fig. 7 reproduction driver: LM-DFL convergence under different network
//! topologies (ζ = 0 / 0.87 / 1) plus an extended sweep over star, torus
//! and random graphs with their measured spectral gaps.
//!
//!   cargo run --release --example topology_sweep [-- --full]

use lmdfl::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "--full") {
        Scale::Full
    } else {
        Scale::from_env()
    };

    println!("measured spectral gaps at N = 10:");
    for (label, zeta) in fig7::zetas(10) {
        println!(
            "  {label:<24} zeta = {zeta:.4}  alpha = {:.3}",
            alpha_of_zeta(zeta)
        );
    }

    println!("\n===== Fig. 7: accuracy vs iteration =====");
    let curves = fig7::run(scale)?;
    println!("{}", fig7::render(&curves));

    std::fs::create_dir_all("results")?;
    for c in &curves {
        let safe = c.label.replace(['/', ' ', '(', ')', '=', '~'], "_");
        c.log
            .write_csv(std::path::Path::new(&format!(
                "results/fig7_{safe}.csv"
            )))?;
    }

    // extension: richer topology sweep (beyond the paper's three)
    println!("\n===== extension: star / torus / random topologies =====");
    let base = paper_base_config(scale);
    for kind in [
        TopologyKind::Star,
        TopologyKind::Torus,
        TopologyKind::Random { p: 0.3 },
    ] {
        let t = Topology::build(&kind, base.nodes, base.seed);
        let mut cfg = base.clone();
        cfg.topology = kind.clone();
        let label = format!("{} (zeta={:.3})", kind.name(), t.zeta);
        let c = run_labeled(cfg, &label)?;
        println!(
            "  {label:<28} final loss {:.4}  accuracy {:.3}",
            c.log.last_loss().unwrap(),
            c.log.final_accuracy().unwrap_or(f64::NAN)
        );
    }
    Ok(())
}
