//! Integration tests for the PJRT HLO runtime — gated on `make artifacts`
//! having run (they skip cleanly otherwise, so `cargo test` works before
//! the python compile path).

use lmdfl::dfl::backend::{LocalUpdate, RustMlpBackend};
use lmdfl::runtime::{
    artifacts_available, artifacts_dir, literal_f32, HloBackend,
    HloExecutor, Manifest,
};
use lmdfl::util::rng::Rng;
use lmdfl::xla;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifest_lists_expected_artifacts() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    for name in [
        "mlp_mnist_step",
        "mlp_mnist_eval",
        "mlp_mnist_grad",
        "cnn_mnist_step",
        "cnn_cifar_step",
        "transformer_step",
        "transformer_eval",
        "lm_quantize_s16",
        "lloyd_iter_s16",
    ] {
        assert!(m.get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn hlo_mlp_step_decreases_loss() {
    require_artifacts!();
    let mut b = HloBackend::load(&artifacts_dir(), "mlp_mnist", 784, 10)
        .unwrap();
    let mut rng = Rng::new(0);
    let mut params = b.init_params(&mut rng);
    let x: Vec<f32> =
        (0..32 * 784).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<u32> = (0..32).map(|i| (i % 10) as u32).collect();
    let l0 = b.step(&mut params, &x, &y, 0.2).unwrap();
    let mut l = l0;
    for _ in 0..40 {
        l = b.step(&mut params, &x, &y, 0.2).unwrap();
    }
    assert!(l < l0 * 0.7, "HLO loss {l0} -> {l}");
}

#[test]
fn hlo_and_rust_backends_agree_on_gradient_direction() {
    // identical math (same layout, same loss): one step from the same
    // params on the same batch must produce very similar parameters.
    require_artifacts!();
    let mut hlo = HloBackend::load(&artifacts_dir(), "mlp_mnist", 784, 10)
        .unwrap();
    let mut rust = RustMlpBackend::new(784, &[256, 128], 10);
    assert_eq!(hlo.param_count(), rust.param_count());
    let mut rng = Rng::new(3);
    let params0 = hlo.init_params(&mut rng);
    let x: Vec<f32> =
        (0..32 * 784).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<u32> = (0..32).map(|_| rng.below(10) as u32).collect();

    let mut p_hlo = params0.clone();
    let loss_hlo = hlo.step(&mut p_hlo, &x, &y, 0.1).unwrap();
    let mut p_rust = params0.clone();
    let loss_rust = rust.step(&mut p_rust, &x, &y, 0.1).unwrap();

    assert!(
        (loss_hlo - loss_rust).abs() < 1e-3 * (1.0 + loss_rust.abs()),
        "losses diverge: hlo {loss_hlo} rust {loss_rust}"
    );
    // parameter updates nearly identical
    let mut max_diff = 0.0f32;
    for (a, b) in p_hlo.iter().zip(&p_rust) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 5e-3, "param max diff {max_diff}");
}

#[test]
fn hlo_eval_matches_rust_eval() {
    require_artifacts!();
    let mut hlo = HloBackend::load(&artifacts_dir(), "mlp_mnist", 784, 10)
        .unwrap();
    let mut rust = RustMlpBackend::new(784, &[256, 128], 10);
    let mut rng = Rng::new(5);
    let params = hlo.init_params(&mut rng);
    // exact multiple of the baked batch (32) → no padding approximation
    let n = 64;
    let x: Vec<f32> =
        (0..n * 784).map(|_| rng.normal() as f32 * 0.3).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(10) as u32).collect();
    let (lh, ch) = hlo.evaluate(&params, &x, &y).unwrap();
    let (lr, cr) = rust.evaluate(&params, &x, &y).unwrap();
    assert!((lh - lr).abs() < 1e-3 * (1.0 + lr.abs()), "{lh} vs {lr}");
    assert_eq!(ch, cr, "correct counts differ");
}

#[test]
fn hlo_lm_quantize_matches_rust_quantizer_tables() {
    // run the AOT Pallas LM-quantize kernel and compare against the native
    // Rust assignment with the same levels/boundaries
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let info = m.get("lm_quantize_s16").unwrap().clone();
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = HloExecutor::compile(&client, info.clone()).unwrap();
    let d = info.input("v").unwrap().elements();
    let s = 16usize;
    let mut rng = Rng::new(9);
    let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let bnd: Vec<f32> = (0..=s).map(|j| j as f32 / s as f32).collect();
    let lev: Vec<f32> =
        (0..s).map(|j| (j as f32 + 0.5) / s as f32).collect();
    let outs = exe
        .run(&[
            literal_f32(&v, &[d]).unwrap(),
            literal_f32(&lev, &[s]).unwrap(),
            literal_f32(&bnd, &[s + 1]).unwrap(),
        ])
        .unwrap();
    let q_hlo = outs[0].to_vec::<f32>().unwrap();
    let dist_hlo = outs[1].to_vec::<f32>().unwrap()[0] as f64;

    // native reference with the same fixed tables
    let norm = lmdfl::util::stats::l2_norm(&v) as f32;
    let mut q_ref = Vec::with_capacity(d);
    for &x in &v {
        let r = x.abs() / norm;
        // bin index = #\{interior boundaries < r\}
        let mut idx = 0usize;
        for &bv in &bnd[1..s] {
            if bv < r {
                idx += 1;
            }
        }
        let mag = norm * lev[idx];
        q_ref.push(if x < 0.0 { -mag } else { mag });
    }
    let mut max_diff = 0.0f32;
    for (a, b) in q_hlo.iter().zip(&q_ref) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-4 * norm, "pallas vs native max diff {max_diff}");
    let dist_ref = lmdfl::util::stats::sq_dist(&q_ref, &v);
    assert!(
        (dist_hlo - dist_ref).abs() < 1e-2 * (1.0 + dist_ref),
        "distortion {dist_hlo} vs {dist_ref}"
    );
}

#[test]
fn hlo_lloyd_iter_reduces_distortion() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let info = m.get("lloyd_iter_s16").unwrap().clone();
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = HloExecutor::compile(&client, info.clone()).unwrap();
    let d = info.input("r").unwrap().elements();
    let s = 16usize;
    let mut rng = Rng::new(11);
    let r: Vec<f32> =
        (0..d).map(|_| (rng.uniform() as f32).powi(2)).collect();
    let mut bnd: Vec<f32> = (0..=s).map(|j| j as f32 / s as f32).collect();
    let mut lev: Vec<f32> =
        (0..s).map(|j| (j as f32 + 0.5) / s as f32).collect();

    let dist = |lev: &[f32], bnd: &[f32]| -> f64 {
        let mut acc = 0.0f64;
        for &x in &r {
            let mut idx = 0usize;
            for &bv in &bnd[1..s] {
                if bv < x {
                    idx += 1;
                }
            }
            let dd = (x - lev[idx]) as f64;
            acc += dd * dd;
        }
        acc
    };
    let d0 = dist(&lev, &bnd);
    for _ in 0..5 {
        let outs = exe
            .run(&[
                literal_f32(&r, &[d]).unwrap(),
                literal_f32(&bnd, &[s + 1]).unwrap(),
            ])
            .unwrap();
        lev = outs[0].to_vec::<f32>().unwrap();
        bnd = outs[1].to_vec::<f32>().unwrap();
    }
    let d5 = dist(&lev, &bnd);
    assert!(d5 < d0, "lloyd iterations did not reduce distortion: {d0} -> {d5}");
}

#[test]
fn dfl_training_on_hlo_backend_converges() {
    require_artifacts!();
    use lmdfl::config::*;
    let cfg = ExperimentConfig {
        name: "hlo-dfl".into(),
        seed: 2,
        nodes: 3,
        tau: 2,
        rounds: 4,
        batch_size: 32,
        lr: LrSchedule::fixed(0.05),
        topology: TopologyKind::Ring,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 8 },
        dataset: DatasetKind::SynthMnist { train: 400, test: 100 },
        backend: BackendKind::Hlo { artifact: "mlp_mnist".into() },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism: Parallelism::Auto,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    };
    let log = lmdfl::dfl::Trainer::build(&cfg).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 4);
    let first = log.records.first().unwrap().loss;
    let last = log.records.last().unwrap().loss;
    assert!(last < first, "HLO DFL did not learn: {first} -> {last}");
}
