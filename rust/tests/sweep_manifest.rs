//! Sweep harness contract: manifests are deterministic modulo timing,
//! config hashes are pinned by a golden fixture, resume skips
//! completed cells, traced sweep cells match equivalent standalone
//! runs, and `analyse` emits the tidy CSVs downstream tooling greps.
//!
//! Every sweep here runs real `lmdfl train` subprocesses, so the
//! tests skip (like `integration_cli.rs`) when the binary isn't
//! built — `cargo test` after `cargo build` exercises everything.

use std::path::{Path, PathBuf};

use lmdfl::config::{DatasetKind, ExperimentConfig, QuantizerKind};
use lmdfl::metrics::{CsvStream, RunLog};
use lmdfl::prelude::{Grid, SweepOptions, SWEEP_SCHEMA};
use lmdfl::sweep;

fn lmdfl_bin() -> Option<PathBuf> {
    // cargo puts test binaries next to the main binary
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // test binary name
    if path.ends_with("deps") {
        path.pop();
    }
    let bin = path.join("lmdfl");
    bin.exists().then_some(bin)
}

macro_rules! require_bin {
    () => {
        match lmdfl_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: lmdfl binary not built");
                return;
            }
        }
    };
}

/// Tiny ideal-network sync base: fast enough to run several times
/// per test binary.
fn tiny_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "sweep-test".into();
    cfg.seed = 17;
    cfg.nodes = 4;
    cfg.tau = 1;
    cfg.rounds = 3;
    cfg.batch_size = 8;
    cfg.dataset = DatasetKind::Blobs {
        train: 80,
        test: 40,
        dim: 6,
        classes: 3,
    };
    cfg.quantizer = QuantizerKind::LloydMax { s: 8, iters: 4 };
    cfg
}

fn tiny_grid(base: &ExperimentConfig) -> Grid {
    let mut grid = Grid::from_base(base);
    grid.set_quantizers("lloyd_max,qsgd").unwrap();
    grid
}

fn opts(bin: &Path, out: &Path) -> SweepOptions {
    SweepOptions {
        out_dir: out.to_path_buf(),
        slots: 2,
        binary: Some(bin.to_path_buf()),
        ..Default::default()
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("lmdfl-sweep-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn manifests_are_deterministic_modulo_timing() {
    let bin = require_bin!();
    let base = tiny_base();
    let grid = tiny_grid(&base);
    let (d1, d2) = (temp_dir("det-a"), temp_dir("det-b"));
    let m1 = sweep::run_sweep(&base, &grid, &opts(&bin, &d1)).unwrap();
    let m2 = sweep::run_sweep(&base, &grid, &opts(&bin, &d2)).unwrap();
    assert_eq!(m1.cells.len(), 2);
    assert!(m1.cells.iter().all(|c| c.ok()), "{m1:?}");
    assert_eq!(
        m1.determinism_key(),
        m2.determinism_key(),
        "same sweep, different manifests (beyond timing)"
    );
    // the saved manifest loads back to the same key
    let loaded =
        sweep::SweepManifest::load(&d1.join("manifest.json")).unwrap();
    assert_eq!(loaded.schema, SWEEP_SCHEMA);
    assert_eq!(loaded.determinism_key(), m1.determinism_key());
    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d2).ok();
}

/// The golden config-hash fixture: cells/<hash> directory names are
/// part of the resume contract, so an accidental change to the
/// identity JSON (or the hash) must fail loudly. The fixture
/// self-blesses on first run (or with LMDFL_BLESS=1) and is compared
/// verbatim afterwards.
#[test]
fn config_hash_matches_golden_fixture() {
    let fixture = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/sweep_config_hash.txt");
    let base = tiny_base();
    let lines: String = tiny_grid(&base)
        .cells()
        .iter()
        .map(|cell| {
            let cfg = cell.apply_to(&base);
            format!("{} {}\n", sweep::config_hash(&cfg), cell.id())
        })
        .collect();
    // observe: must never reach the hash (trace paths differ per dir)
    let mut traced = base.clone();
    traced.observe = Some(lmdfl::obs::ObserveConfig {
        trace_path: Some("anywhere.jsonl".into()),
        chrome_path: None,
    });
    assert_eq!(
        sweep::config_hash(&traced),
        sweep::config_hash(&base)
    );
    let bless = std::env::var("LMDFL_BLESS").is_ok();
    if bless || !fixture.exists() {
        std::fs::create_dir_all(fixture.parent().unwrap()).unwrap();
        std::fs::write(&fixture, &lines).unwrap();
        eprintln!("blessed {}", fixture.display());
        return;
    }
    let want = std::fs::read_to_string(&fixture).unwrap();
    assert_eq!(
        lines,
        want,
        "config hashes changed; if intentional, re-bless with \
         LMDFL_BLESS=1"
    );
}

#[test]
fn resume_skips_completed_cells() {
    let bin = require_bin!();
    let base = tiny_base();
    let grid = tiny_grid(&base);
    let dir = temp_dir("resume");
    let o = opts(&bin, &dir);
    let first = sweep::run_sweep(&base, &grid, &o).unwrap();
    assert!(first.cells.iter().all(|c| !c.timing.cached));
    let second = sweep::run_sweep(&base, &grid, &o).unwrap();
    assert!(
        second.cells.iter().all(|c| c.timing.cached),
        "resume re-ran completed cells: {second:?}"
    );
    assert_eq!(
        first.determinism_key(),
        second.determinism_key(),
        "resume changed the manifest (beyond timing)"
    );
    // a missing artifact invalidates just that cell
    let victim = &second.cells[0];
    std::fs::remove_file(dir.join(&victim.trace)).unwrap();
    let third = sweep::run_sweep(&base, &grid, &o).unwrap();
    assert!(!third.cells[0].timing.cached, "gone trace, still cached");
    assert!(third.cells[1].timing.cached);
    assert_eq!(third.determinism_key(), first.determinism_key());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_cells_match_equivalent_standalone_runs() {
    let bin = require_bin!();
    let base = tiny_base();
    let grid = tiny_grid(&base);
    let dir = temp_dir("parity");
    let m = sweep::run_sweep(&base, &grid, &opts(&bin, &dir)).unwrap();
    // zero the one real-time column on both sides before comparing
    let normalize = |name: &str, text: &str| -> String {
        let mut log = RunLog::from_csv(name, text).unwrap();
        for r in &mut log.records {
            r.wall_secs = 0.0;
        }
        log.to_csv()
    };
    for (cell, result) in grid.cells().iter().zip(&m.cells) {
        assert!(result.ok());
        let cfg = cell.apply_to(&base);
        let mut sink = CsvStream::new(Vec::new()).unwrap();
        lmdfl::dfl::Trainer::run_streamed(&cfg, &mut sink).unwrap();
        let standalone =
            String::from_utf8(sink.finish().unwrap()).unwrap();
        let from_sweep = std::fs::read_to_string(
            dir.join(&result.rounds_csv),
        )
        .unwrap();
        assert_eq!(
            normalize(&result.id, &from_sweep),
            normalize(&result.id, &standalone),
            "cell {} diverged from its standalone run",
            result.id
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn analyse_emits_tidy_csvs_and_fig_time_consumes_them() {
    let bin = require_bin!();
    let base = tiny_base();
    let grid = tiny_grid(&base);
    let dir = temp_dir("analyse");
    let m = sweep::run_sweep(&base, &grid, &opts(&bin, &dir)).unwrap();
    let manifest_path = dir.join("manifest.json");
    let out = dir.join("analysis");
    let written =
        sweep::analyse::analyse(&manifest_path, &out).unwrap();
    assert_eq!(written.len(), 4);

    let cells = std::fs::read_to_string(out.join("cells.csv")).unwrap();
    let rows: Vec<&str> = cells.lines().collect();
    assert_eq!(rows.len(), 1 + m.cells.len());
    assert!(
        rows[0].starts_with(
            "cell,hash,quantizer,topology,net,mode,seed,status"
        ),
        "{}",
        rows[0]
    );
    for cell in &m.cells {
        assert!(cells.contains(&cell.hash), "missing {}", cell.id);
    }
    let spans = std::fs::read_to_string(out.join("spans.csv")).unwrap();
    assert!(
        spans.lines().count() > 1,
        "no span aggregates: {spans}"
    );
    let hists = std::fs::read_to_string(out.join("hists.csv")).unwrap();
    assert!(hists.starts_with(
        "cell,hash,histogram,count,mean,p50_le,p90_le,p99_le"
    ));

    // fig-time --from-sweep consumes the same manifest
    let curves = lmdfl::experiments::fig_time::curves_from_sweep(
        &manifest_path,
    )
    .unwrap();
    assert_eq!(curves.len(), m.cells.len());
    for (curve, cell) in curves.iter().zip(&m.cells) {
        assert_eq!(curve.label, cell.id);
        assert_eq!(curve.log.records.len(), cell.rounds);
    }
    std::fs::remove_dir_all(&dir).ok();
}
