//! Equivalence gates for the parallel round executor and the
//! allocation-free quantizer path:
//!
//! * the parallel engine's `RunLog` must be **bit-identical** to the
//!   sequential engine's for a fixed seed, across quantizers and worker
//!   counts (the engine's core determinism contract), and
//! * `Quantizer::quantize_into` must match the allocating `quantize`
//!   exactly — same message, same RNG draw sequence — including when the
//!   output buffer is dirty from a previous (differently-sized) message.

use lmdfl::config::{
    BackendKind, DatasetKind, ExperimentConfig, LrSchedule, Parallelism,
    QuantizerKind, TopologyKind,
};
use lmdfl::dfl::Trainer;
use lmdfl::metrics::RunLog;
use lmdfl::quant::{
    AlqQuantizer, FullPrecision, LloydMaxQuantizer, NaturalQuantizer,
    QsgdQuantizer, QuantizedVector, Quantizer, TernGradQuantizer,
};
use lmdfl::util::proptest::check;
use lmdfl::util::rng::Rng;

fn cfg(quant: QuantizerKind, parallelism: Parallelism) -> ExperimentConfig {
    ExperimentConfig {
        name: "engine-parallel".into(),
        seed: 1234,
        nodes: 6,
        tau: 2,
        rounds: 8,
        batch_size: 16,
        lr: LrSchedule::fixed(0.1),
        topology: TopologyKind::Ring,
        quantizer: quant,
        dataset: DatasetKind::Blobs {
            train: 300,
            test: 90,
            dim: 10,
            classes: 3,
        },
        backend: BackendKind::RustMlp { hidden: vec![20] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

fn run(quant: QuantizerKind, parallelism: Parallelism) -> RunLog {
    Trainer::build(&cfg(quant, parallelism))
        .unwrap()
        .run()
        .unwrap()
}

/// Field-by-field bit equality (wall_secs excluded: it is the only
/// measurement, not a computation).
fn assert_logs_bit_identical(a: &RunLog, b: &RunLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: round count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round, "{label}");
        assert_eq!(
            ra.loss.to_bits(),
            rb.loss.to_bits(),
            "{label} round {}: loss {} vs {}",
            ra.round,
            ra.loss,
            rb.loss
        );
        assert_eq!(
            ra.accuracy.to_bits(),
            rb.accuracy.to_bits(),
            "{label} round {}: accuracy",
            ra.round
        );
        assert_eq!(
            ra.bits_per_link, rb.bits_per_link,
            "{label} round {}: bits",
            ra.round
        );
        assert_eq!(
            ra.distortion.to_bits(),
            rb.distortion.to_bits(),
            "{label} round {}: distortion",
            ra.round
        );
        assert_eq!(ra.levels, rb.levels, "{label} round {}", ra.round);
        assert_eq!(
            ra.lr.to_bits(),
            rb.lr.to_bits(),
            "{label} round {}",
            ra.round
        );
    }
}

#[test]
fn parallel_engine_bit_identical_across_quantizers() {
    for quant in [
        QuantizerKind::LloydMax { s: 16, iters: 8 },
        QuantizerKind::Qsgd { s: 16 },
        QuantizerKind::Natural { s: 16 },
    ] {
        let label = format!("{quant:?}");
        let seq = run(quant.clone(), Parallelism::Off);
        let par = run(quant.clone(), Parallelism::Fixed(4));
        assert_logs_bit_identical(&seq, &par, &label);
    }
}

#[test]
fn parallel_engine_bit_identical_for_any_worker_count() {
    let quant = QuantizerKind::LloydMax { s: 8, iters: 5 };
    let seq = run(quant.clone(), Parallelism::Off);
    for workers in [2usize, 3, 6, 16] {
        let par = run(quant.clone(), Parallelism::Fixed(workers));
        assert_logs_bit_identical(&seq, &par, &format!("w={workers}"));
    }
    let auto = run(quant, Parallelism::Auto);
    assert_logs_bit_identical(&seq, &auto, "auto");
}

#[test]
fn doubly_adaptive_schedule_survives_parallelism() {
    // the adaptive level controller feeds on per-node local loss; its
    // trajectory must not depend on the worker count either
    let quant = QuantizerKind::DoublyAdaptive { s1: 4, iters: 6, s_max: 64 };
    let seq = run(quant.clone(), Parallelism::Off);
    let par = run(quant, Parallelism::Fixed(3));
    assert_logs_bit_identical(&seq, &par, "doubly_adaptive");
}

// ---- quantize_into == quantize ---------------------------------------------

/// Run both paths from identical quantizer + rng clones and compare.
fn assert_into_matches<Q: Quantizer + Clone>(
    proto: &Q,
    v: &[f32],
    seed: u64,
    dirty: Option<&QuantizedVector>,
    label: &str,
) {
    let mut q_alloc = proto.clone();
    let mut rng_alloc = Rng::new(seed);
    let want = q_alloc.quantize(v, &mut rng_alloc);

    let mut q_into = proto.clone();
    let mut rng_into = Rng::new(seed);
    let mut got = dirty.cloned().unwrap_or_default();
    q_into.quantize_into(v, &mut rng_into, &mut got);

    assert_eq!(want, got, "{label}: message mismatch");
    // the rng streams must stay in lockstep (same number of draws)
    assert_eq!(
        rng_alloc.next_u64(),
        rng_into.next_u64(),
        "{label}: rng stream diverged"
    );
}

#[test]
fn prop_quantize_into_matches_quantize() {
    check("quantize_into == quantize", 60, |g| {
        let v = g.vec_normal(1..500, 1.5);
        let s = *g.pick(&[2usize, 3, 8, 16, 64]);
        let seed = g.seed;
        // a dirty buffer from a previous, differently-shaped message must
        // not leak into the next fill
        let dirty = QuantizedVector {
            norm: 9.0,
            negative: vec![true; 7],
            indices: vec![1; 7],
            levels: vec![0.5; 3],
            implied_table: true,
        };
        assert_into_matches(
            &LloydMaxQuantizer::new(s, 6), &v, seed, Some(&dirty),
            "lloyd_max");
        assert_into_matches(
            &QsgdQuantizer::new(s), &v, seed, Some(&dirty), "qsgd");
        assert_into_matches(
            &NaturalQuantizer::new(s), &v, seed, Some(&dirty), "natural");
        assert_into_matches(
            &AlqQuantizer::new(s), &v, seed, Some(&dirty), "alq");
        assert_into_matches(
            &FullPrecision::new(), &v, seed, Some(&dirty), "full");
    });
}

#[test]
fn prop_quantize_into_degenerate_inputs() {
    check("quantize_into degenerate", 20, |g| {
        let seed = g.seed;
        for v in [vec![0.0f32; 16], vec![5.0f32], vec![-3.0f32; 4]] {
            assert_into_matches(
                &LloydMaxQuantizer::new(4, 3), &v, seed, None, "lm-deg");
            assert_into_matches(
                &QsgdQuantizer::new(4), &v, seed, None, "qsgd-deg");
            assert_into_matches(
                &NaturalQuantizer::new(4), &v, seed, None, "natural-deg");
        }
    });
}

#[test]
fn default_quantize_into_delegates() {
    // quantizers without an override (e.g. TernGrad) fall back to the
    // allocating path through the trait default — same contract
    let mut a = TernGradQuantizer::new();
    let mut b = TernGradQuantizer::new();
    let v: Vec<f32> = (0..200).map(|i| ((i * 37 % 97) as f32) - 48.0).collect();
    let mut r1 = Rng::new(7);
    let mut r2 = Rng::new(7);
    let want = a.quantize(&v, &mut r1);
    let mut got = QuantizedVector::empty();
    b.quantize_into(&v, &mut r2, &mut got);
    assert_eq!(want, got);
}
