//! Integration tests for the CLI binary surface and the config system as a
//! user would exercise them.

use std::process::Command;

fn lmdfl_bin() -> Option<std::path::PathBuf> {
    // cargo puts test binaries next to the main binary
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // test binary name
    if path.ends_with("deps") {
        path.pop();
    }
    let bin = path.join("lmdfl");
    bin.exists().then_some(bin)
}

macro_rules! require_bin {
    () => {
        match lmdfl_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: lmdfl binary not built");
                return;
            }
        }
    };
}

#[test]
fn no_args_prints_usage() {
    let bin = require_bin!();
    let out = Command::new(&bin).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("lmdfl <command>"), "{text}");
}

#[test]
fn topo_command_reports_ring_zeta() {
    let bin = require_bin!();
    let out = Command::new(&bin)
        .args(["topo", "--kind", "ring", "--nodes", "10"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("zeta=0.87"), "{text}");
    assert!(text.contains("connected=true"), "{text}");
}

#[test]
fn quant_command_prints_bounds_table() {
    let bin = require_bin!();
    let out = Command::new(&bin)
        .args(["quant", "--d", "1000"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LM bound"), "{text}");
    assert!(text.contains("16384"), "{text}");
}

#[test]
fn train_inline_runs_and_writes_csv() {
    let bin = require_bin!();
    let csv = std::env::temp_dir().join("lmdfl_cli_train.csv");
    let _ = std::fs::remove_file(&csv);
    let out = Command::new(&bin)
        .args([
            "train",
            "--nodes", "3",
            "--rounds", "3",
            "--tau", "2",
            "--quantizer", "lm",
            "--s", "8",
            "--dataset", "blobs",
            "--train", "120",
            "--test", "40",
            "--dim", "8",
            "--classes", "3",
            "--lr", "0.1",
            "--csv",
        ])
        .arg(&csv)
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}",
            String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("final:"), "{text}");
    let content = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(content.lines().count(), 4, "{content}");
    let _ = std::fs::remove_file(&csv);
}

#[test]
fn train_from_config_file() {
    let bin = require_bin!();
    let cfg_path = std::env::temp_dir().join("lmdfl_cli_cfg.json");
    let mut cfg = lmdfl::config::ExperimentConfig::default();
    cfg.nodes = 3;
    cfg.rounds = 2;
    cfg.dataset = lmdfl::config::DatasetKind::Blobs {
        train: 90,
        test: 30,
        dim: 6,
        classes: 3,
    };
    std::fs::write(&cfg_path, cfg.to_json().to_pretty()).unwrap();
    let out = Command::new(&bin)
        .args(["train", "--config"])
        .arg(&cfg_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}",
            String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_file(&cfg_path);
}

#[test]
fn train_simulated_reports_virtual_time() {
    let bin = require_bin!();
    let out = Command::new(&bin)
        .args([
            "train",
            "--nodes", "4",
            "--rounds", "3",
            "--tau", "2",
            "--quantizer", "qsgd",
            "--s", "8",
            "--dataset", "blobs",
            "--train", "120",
            "--test", "40",
            "--dim", "8",
            "--classes", "3",
            "--lr", "0.1",
            "--net-bandwidth-bps", "1e6",
            "--net-latency-s", "0.002",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}",
            String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // the config echo contains the network section and the summary the
    // simnet line
    assert!(text.contains("\"network\""), "{text}");
    assert!(text.contains("simnet: virtual time"), "{text}");
}

#[test]
fn unknown_quantizer_fails_with_message() {
    let bin = require_bin!();
    let out = Command::new(&bin)
        .args(["train", "--quantizer", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown quantizer"), "{text}");
}
