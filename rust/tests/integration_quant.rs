//! Integration tests across the quant stack: quantize → encode → wire →
//! decode → dequantize, distortion orderings, and paper-bound conformance.

use lmdfl::config::QuantizerKind;
use lmdfl::quant::distortion::{
    lm_bound, normalized_distortion, qsgd_bound,
};
use lmdfl::quant::{
    build_quantizer, codec, FullPrecision, NaturalQuantizer, QsgdQuantizer,
};
use lmdfl::util::proptest::check;
use lmdfl::util::rng::Rng;
use lmdfl::util::stats::l2_norm;

fn all_kinds(s: usize) -> Vec<QuantizerKind> {
    vec![
        QuantizerKind::Full,
        QuantizerKind::Qsgd { s },
        QuantizerKind::Natural { s },
        QuantizerKind::Alq { s },
        QuantizerKind::LloydMax { s, iters: 10 },
    ]
}

fn implied(kind: &QuantizerKind, s: usize) -> Vec<f32> {
    match kind {
        QuantizerKind::Qsgd { .. } => QsgdQuantizer::level_table(s),
        QuantizerKind::Natural { .. } => NaturalQuantizer::level_table(s),
        QuantizerKind::Full => FullPrecision::level_table(s),
        _ => Vec::new(),
    }
}

#[test]
fn wire_roundtrip_preserves_dequantization_for_all_quantizers() {
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..3000).map(|_| rng.normal() as f32).collect();
    for kind in all_kinds(16) {
        let mut q = build_quantizer(&kind);
        let msg = q.quantize(&v, &mut rng);
        let bytes = codec::encode(&msg);
        let back = codec::decode(&bytes, |s| implied(&kind, s)).unwrap();
        assert_eq!(
            back.dequantize(),
            msg.dequantize(),
            "{kind:?} wire roundtrip changed values"
        );
    }
}

#[test]
fn distortion_ordering_lm_best_on_gaussian() {
    let mut rng = Rng::new(2);
    let v: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
    let mut results = Vec::new();
    for kind in all_kinds(16) {
        let mut q = build_quantizer(&kind);
        let dq = q.quantize(&v, &mut rng).dequantize();
        results.push((kind, normalized_distortion(&v, &dq)));
    }
    let get = |name: &str| {
        results
            .iter()
            .find(|(k, _)| format!("{k:?}").contains(name))
            .unwrap()
            .1
    };
    // d * step^2 / 12 ≈ 1.6e-5 at d = 50k, s = 16384
    assert!(get("Full") < 1e-4);
    let lm = get("LloydMax");
    assert!(lm < get("Qsgd"), "LM {lm} !< QSGD {}", get("Qsgd"));
    assert!(lm < get("Natural"));
    assert!(lm < get("Alq") * 1.05);
}

#[test]
fn lm_bound_holds_across_scales_and_distributions() {
    check("lm theorem-2 bound", 40, |g| {
        let scale = g.f64_in(1e-4..1e4) as f32;
        let mut v = if g.bool() {
            g.vec_normal(200..3000, 1.0)
        } else {
            g.vec_laplace(200..3000, 0.4)
        };
        v.iter_mut().for_each(|x| *x *= scale);
        if l2_norm(&v) == 0.0 {
            return;
        }
        let s = *g.pick(&[4usize, 16, 64]);
        let mut q = build_quantizer(
            &QuantizerKind::LloydMax { s, iters: 25 });
        let mut rng = Rng::new(g.seed);
        let dq = q.quantize(&v, &mut rng).dequantize();
        let nd = normalized_distortion(&v, &dq);
        let bound = lm_bound(v.len(), s);
        assert!(nd <= bound * 1.5 + 1e-9, "nd {nd} bound {bound} s={s}");
    });
}

#[test]
fn lm_needs_fewer_levels_than_qsgd_for_same_distortion() {
    // Table I discussion: "LM-DFL uses only 0.29 s levels" — check that
    // LM at s=16 beats QSGD at s=32 on gaussian data.
    let mut rng = Rng::new(3);
    let v: Vec<f32> = (0..40_000).map(|_| rng.normal() as f32).collect();
    let mut lm = build_quantizer(
        &QuantizerKind::LloydMax { s: 16, iters: 25 });
    let mut qsgd = build_quantizer(&QuantizerKind::Qsgd { s: 32 });
    let lm_d = normalized_distortion(
        &v, &lm.quantize(&v, &mut rng).dequantize());
    let qs_d = normalized_distortion(
        &v, &qsgd.quantize(&v, &mut rng).dequantize());
    assert!(
        lm_d < qs_d,
        "LM s=16 ({lm_d}) should beat QSGD s=32 ({qs_d})"
    );
}

#[test]
fn paper_bits_scale_with_level_count() {
    let mut rng = Rng::new(4);
    let v: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
    let mut prev = 0u64;
    for s in [2usize, 4, 16, 256] {
        let mut q = build_quantizer(&QuantizerKind::Qsgd { s });
        let bits = q.quantize(&v, &mut rng).paper_bits();
        assert!(bits >= prev);
        prev = bits;
        assert_eq!(
            bits,
            lmdfl::quant::bits::c_s(1000, s),
            "paper bits must match Eq. 12"
        );
    }
}

#[test]
fn stochastic_quantizers_unbiased_through_wire() {
    // encode/decode then average many draws: mean ~ v
    let mut rng = Rng::new(5);
    let v = vec![0.42f32, -0.17, 0.9, -0.66];
    for kind in [QuantizerKind::Qsgd { s: 4 }, QuantizerKind::Alq { s: 6 }] {
        let mut q = build_quantizer(&kind);
        let n = 8000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..n {
            let msg = q.quantize(&v, &mut rng);
            let bytes = codec::encode(&msg);
            let back =
                codec::decode(&bytes, |s| implied(&kind, s)).unwrap();
            for (a, x) in acc.iter_mut().zip(back.dequantize()) {
                *a += x as f64;
            }
        }
        for (a, &want) in acc.iter().zip(&v) {
            let mean = a / n as f64;
            assert!(
                (mean - want as f64).abs() < 0.03,
                "{kind:?}: mean {mean} vs {want}"
            );
        }
    }
}

#[test]
fn qsgd_bound_comparison_sanity() {
    // the measured distortion tracks the analytic bound direction in s
    let mut rng = Rng::new(6);
    let v: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
    let mut prev = f64::INFINITY;
    for s in [4usize, 16, 64] {
        let mut q = build_quantizer(&QuantizerKind::Qsgd { s });
        let nd = normalized_distortion(
            &v, &q.quantize(&v, &mut rng).dequantize());
        assert!(nd < prev, "distortion should fall with s");
        assert!(nd <= qsgd_bound(v.len(), s) * 3.0);
        prev = nd;
    }
}

#[test]
fn adaptive_levels_integration_with_quantizer() {
    use lmdfl::quant::adaptive::AdaptiveLevels;
    use lmdfl::quant::Quantizer;
    let mut lm = lmdfl::quant::LloydMaxQuantizer::new(4, 8);
    let mut ad = AdaptiveLevels::new(4, 256);
    let mut rng = Rng::new(7);
    let v: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
    let mut losses = vec![2.0, 1.0, 0.5, 0.25, 0.1];
    let mut last_bits = 0u64;
    for loss in losses.drain(..) {
        let s = ad.update(loss);
        lm.set_levels(s);
        let msg = lm.quantize(&v, &mut rng);
        assert_eq!(msg.s(), s);
        assert!(msg.paper_bits() >= last_bits);
        last_bits = msg.paper_bits();
    }
    // s = round(4 * sqrt(2.0 / 0.1)) = round(17.9) = 18
    assert_eq!(ad.current(), (4.0 * (2.0f64 / 0.1).sqrt()).round() as usize);
}
