//! Integration tests over the full DFL stack: matrix engine vs threaded
//! runtime, convergence quality gates, non-IID behaviour, failure
//! injection, and CSV/metrics plumbing.

use lmdfl::config::{
    BackendKind, DatasetKind, ExperimentConfig, LrSchedule, QuantizerKind,
    TopologyKind,
};
use lmdfl::dfl::{NetOptions, Trainer};

fn blob_cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "itest".into(),
        seed: 21,
        nodes: 5,
        tau: 3,
        rounds: 20,
        batch_size: 24,
        lr: LrSchedule::fixed(0.1),
        topology: TopologyKind::Ring,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 10 },
        dataset: DatasetKind::Blobs {
            train: 500,
            test: 150,
            dim: 12,
            classes: 5,
        },
        backend: BackendKind::RustMlp { hidden: vec![24] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism: lmdfl::config::Parallelism::Auto,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

#[test]
fn lm_dfl_learns_blobs_to_high_accuracy() {
    let log = Trainer::build(&blob_cfg()).unwrap().run().unwrap();
    let acc = log.final_accuracy().unwrap();
    assert!(acc > 0.8, "accuracy {acc}");
    assert!(log.last_loss().unwrap() < 1.0);
}

#[test]
fn synth_mnist_end_to_end_learns() {
    let mut cfg = blob_cfg();
    cfg.dataset = DatasetKind::SynthMnist { train: 800, test: 200 };
    cfg.lr = LrSchedule::fixed(0.03);
    cfg.rounds = 25;
    cfg.backend = BackendKind::RustMlp { hidden: vec![48] };
    let log = Trainer::build(&cfg).unwrap().run().unwrap();
    let acc = log.final_accuracy().unwrap();
    assert!(acc > 0.5, "synth-mnist accuracy only {acc}");
}

#[test]
fn threaded_and_matrix_engines_agree_qualitatively() {
    // identical config: both must converge to similar loss (they are not
    // bit-identical: thread scheduling does not affect math, but the
    // threaded runtime wire-quantizes through f32 encode/decode exactly,
    // so losses should match closely; allow small tolerance)
    let cfg = blob_cfg();
    let m = Trainer::build(&cfg).unwrap().run().unwrap();
    let t = Trainer::run_threaded(&cfg, NetOptions::default()).unwrap();
    let lm = m.last_loss().unwrap();
    let lt = t.last_loss().unwrap();
    assert!(
        (lm - lt).abs() < 0.35 * lm.max(0.2),
        "matrix {lm} vs threaded {lt}"
    );
    // both converged
    assert!(lm < m.records.first().unwrap().loss);
    assert!(lt < t.records.first().unwrap().loss);
}

#[test]
fn noniid_harder_than_iid() {
    let mut iid = blob_cfg();
    iid.noniid_fraction = 0.0;
    iid.rounds = 10;
    let mut skew = blob_cfg();
    skew.noniid_fraction = 1.0;
    skew.rounds = 10;
    let li = Trainer::build(&iid).unwrap().run().unwrap();
    let ls = Trainer::build(&skew).unwrap().run().unwrap();
    // fully-by-label split should not converge faster than IID
    assert!(
        ls.last_loss().unwrap() >= li.last_loss().unwrap() * 0.7,
        "non-iid {} unexpectedly beat iid {}",
        ls.last_loss().unwrap(),
        li.last_loss().unwrap()
    );
}

#[test]
fn quantized_variants_track_full_precision() {
    // at s=256 the quantized run must be close to the unquantized one
    let mut full = blob_cfg();
    full.quantizer = QuantizerKind::Full;
    let mut fine = blob_cfg();
    fine.quantizer = QuantizerKind::LloydMax { s: 256, iters: 10 };
    let lf = Trainer::build(&full).unwrap().run().unwrap();
    let lq = Trainer::build(&fine).unwrap().run().unwrap();
    let (a, b) = (lf.last_loss().unwrap(), lq.last_loss().unwrap());
    assert!(
        (a - b).abs() < 0.3 * a.max(0.2),
        "full {a} vs lm-256 {b}"
    );
}

#[test]
fn coarse_quantization_converges_but_slower_or_noisier() {
    let mut coarse = blob_cfg();
    coarse.quantizer = QuantizerKind::LloydMax { s: 2, iters: 10 };
    let log = Trainer::build(&coarse).unwrap().run().unwrap();
    assert!(
        log.last_loss().unwrap() < log.records.first().unwrap().loss,
        "even 1-bit levels should make progress"
    );
}

#[test]
fn dropped_messages_degrade_gracefully_threaded() {
    let cfg = blob_cfg();
    let clean =
        Trainer::run_threaded(&cfg, NetOptions::default()).unwrap();
    let lossy =
        Trainer::run_threaded(&cfg, NetOptions::lossy(0.3)).unwrap();
    assert!(lossy.last_loss().unwrap().is_finite());
    // lossy should still learn
    assert!(
        lossy.last_loss().unwrap()
            < lossy.records.first().unwrap().loss
    );
    // and not be wildly better than clean (sanity on the fault model)
    assert!(
        lossy.last_loss().unwrap()
            > clean.last_loss().unwrap() * 0.5 - 0.05
    );
}

#[test]
fn star_and_torus_topologies_train() {
    for topo in [TopologyKind::Star, TopologyKind::Torus,
                 TopologyKind::Random { p: 0.5 }] {
        let mut cfg = blob_cfg();
        cfg.topology = topo.clone();
        cfg.rounds = 10;
        let log = Trainer::build(&cfg).unwrap().run().unwrap();
        assert!(
            log.last_loss().unwrap()
                < log.records.first().unwrap().loss,
            "{topo:?} failed to learn"
        );
    }
}

#[test]
fn run_log_csv_and_json_outputs() {
    let mut cfg = blob_cfg();
    cfg.rounds = 4;
    let log = Trainer::build(&cfg).unwrap().run().unwrap();
    let csv = log.to_csv();
    assert_eq!(csv.lines().count(), 5);
    let json = log.to_json().to_string();
    let parsed = lmdfl::config::Json::parse(&json).unwrap();
    assert_eq!(
        parsed.get("records").unwrap().as_arr().unwrap().len(),
        4
    );
}

#[test]
fn config_roundtrips_through_file_and_trains() {
    let cfg = blob_cfg();
    let dir = std::env::temp_dir();
    let path = dir.join("lmdfl_itest_cfg.json");
    std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
    let loaded = lmdfl::config::load_config(&path).unwrap();
    assert_eq!(loaded, cfg);
    let mut quick = loaded;
    quick.rounds = 2;
    let log = Trainer::build(&quick).unwrap().run().unwrap();
    assert_eq!(log.records.len(), 2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn doubly_adaptive_beats_fixed_coarse_per_bit_on_blobs() {
    let mut da = blob_cfg();
    da.quantizer =
        QuantizerKind::DoublyAdaptive { s1: 4, iters: 10, s_max: 1024 };
    da.rounds = 25;
    let mut fixed8 = blob_cfg();
    fixed8.quantizer = QuantizerKind::Qsgd { s: 256 };
    fixed8.rounds = 25;
    let lda = Trainer::build(&da).unwrap().run().unwrap();
    let lf = Trainer::build(&fixed8).unwrap().run().unwrap();
    let target = lda
        .last_loss()
        .unwrap()
        .max(lf.last_loss().unwrap())
        * 1.1;
    if let (Some(a), Some(b)) =
        (lda.bits_to_loss(target), lf.bits_to_loss(target))
    {
        assert!(
            a <= b,
            "doubly-adaptive {a} bits should be <= QSGD-8bit {b}"
        );
    }
}
