//! Streamed-vs-buffered run output parity: the streaming sinks must be
//! drop-in replacements for the buffered logs — a `CsvStream` produces
//! the exact bytes `RunLog::to_csv` would have, `RunLog::from_csv`
//! round-trips the streamed file, the async JSONL stream carries the
//! same documents `AsyncRunLog::nodes` would have buffered, and a
//! streamed run leaves nothing resident that the sink already consumed.

use std::io::Write;
use std::sync::{Arc, Mutex};

use lmdfl::agossip::{AsyncConfig, AsyncGossipEngine, WaitPolicy};
use lmdfl::config::{
    DatasetKind, EngineMode, ExperimentConfig, LrSchedule, QuantizerKind,
    TopologyKind,
};
use lmdfl::metrics::{
    CsvStream, LogSink, RecordSink, RoundRecord, RunLog, CSV_HEADER,
};
use lmdfl::simnet::{ComputeModel, Fabric, LinkModel, NetworkConfig};
use lmdfl::topology::Topology;

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "streaming-parity".into();
    cfg.seed = 31;
    cfg.nodes = 8;
    cfg.tau = 2;
    cfg.rounds = 6;
    cfg.batch_size = 16;
    cfg.lr = LrSchedule::fixed(0.05);
    cfg.topology = TopologyKind::Torus;
    cfg.quantizer = QuantizerKind::LloydMax { s: 8, iters: 6 };
    cfg.dataset = DatasetKind::Blobs {
        train: 240,
        test: 80,
        dim: 8,
        classes: 3,
    };
    // sparse eval cadence: NaN accuracy rows must survive the
    // stream → parse → re-serialize cycle too
    cfg.eval_every = 2;
    cfg
}

fn net() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.002,
            bandwidth_bps: 2e6,
            jitter_s: 0.001,
            drop_prob: 0.05,
        },
        link_hetero_spread: 0.4,
        compute: ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.5,
            straggler_prob: 0.1,
            straggler_slowdown: 4.0,
        },
        churn: Default::default(),
    }
}

/// Feed one run's records to two sinks at once: the byte comparison
/// then covers the exact same record sequence, wall-clock column and
/// all.
struct Tee<'a>(&'a mut dyn RecordSink, &'a mut dyn RecordSink);

impl RecordSink for Tee<'_> {
    fn record(&mut self, r: &RoundRecord) -> anyhow::Result<()> {
        self.0.record(r)?;
        self.1.record(r)
    }
}

#[test]
fn streamed_csv_is_byte_identical_to_buffered_and_round_trips() {
    let cfg = small_cfg();
    let mut trainer = lmdfl::dfl::Trainer::build(&cfg).unwrap();
    let mut csv = CsvStream::new(Vec::new()).unwrap();
    let mut buf = LogSink::new(&cfg.name);
    let summary = {
        let mut tee = Tee(&mut csv, &mut buf);
        trainer.engine_mut().run_streamed(None, &mut tee).unwrap()
    };
    let text = String::from_utf8(csv.finish().unwrap()).unwrap();
    assert_eq!(
        text,
        buf.0.to_csv(),
        "streamed bytes != buffered to_csv"
    );
    assert!(text.starts_with(CSV_HEADER));
    // the streamed file parses back losslessly and re-serializes to
    // the same bytes
    let back = RunLog::from_csv(&cfg.name, &text).unwrap();
    assert_eq!(back.records.len(), cfg.rounds);
    assert_eq!(back.to_csv(), text);
    // the summary carries the buffered log's scalar facts
    let last = buf.0.records.last().unwrap();
    assert_eq!(summary.rounds, cfg.rounds);
    assert_eq!(summary.last_loss.to_bits(), last.loss.to_bits());
    assert_eq!(summary.total_bits, last.bits_per_link);
    assert_eq!(summary.wire_bytes, last.wire_bytes);
}

#[test]
fn streamed_simulated_run_matches_buffered_replay() {
    let mut cfg = small_cfg();
    cfg.network = Some(net());
    let netcfg = cfg.network.clone().unwrap();
    let topo = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);

    // buffered reference run
    let mut fabric_a = Fabric::new(&netcfg, &topo, cfg.seed);
    let mut t_a = lmdfl::dfl::Trainer::build(&cfg).unwrap();
    let mut log =
        t_a.engine_mut().run_simulated(&mut fabric_a).unwrap();

    // streamed replay: same seed, same fabric, CSV straight to a sink
    let mut fabric_b = Fabric::new(&netcfg, &topo, cfg.seed);
    let mut t_b = lmdfl::dfl::Trainer::build(&cfg).unwrap();
    let mut csv = CsvStream::new(Vec::new()).unwrap();
    let summary = t_b
        .engine_mut()
        .run_streamed(Some(&mut fabric_b), &mut csv)
        .unwrap();
    assert_eq!(
        fabric_a.event_digest(),
        fabric_b.event_digest(),
        "streaming changed the event order"
    );
    let text = String::from_utf8(csv.finish().unwrap()).unwrap();
    let mut back = RunLog::from_csv(&cfg.name, &text).unwrap();
    // wall_secs is the one deliberately real-time column
    for r in log.records.iter_mut().chain(back.records.iter_mut()) {
        r.wall_secs = 0.0;
    }
    assert_eq!(log.to_csv(), back.to_csv());
    assert_eq!(
        summary.virtual_secs.to_bits(),
        log.records.last().unwrap().virtual_secs.to_bits()
    );
}

#[test]
fn threaded_run_streams_records_in_order() {
    let cfg = small_cfg();
    let mut csv = CsvStream::new(Vec::new()).unwrap();
    let mut buf = LogSink::new(&cfg.name);
    let summary = {
        let mut tee = Tee(&mut csv, &mut buf);
        lmdfl::dfl::Trainer::run_threaded_streamed(
            &cfg,
            lmdfl::dfl::NetOptions::default(),
            &mut tee,
        )
        .unwrap()
    };
    let text = String::from_utf8(csv.finish().unwrap()).unwrap();
    assert_eq!(
        text,
        buf.0.to_csv(),
        "threaded streamed bytes != buffered to_csv"
    );
    assert_eq!(buf.0.records.len(), cfg.rounds);
    // the coordinator must emit rounds strictly in order even though
    // worker threads finish out of order
    for (k, r) in buf.0.records.iter().enumerate() {
        assert_eq!(r.round, k + 1, "record {k} out of order");
        // threaded runs report no wall/virtual clocks per record
        assert_eq!(r.wall_secs, 0.0);
    }
    assert_eq!(summary.rounds, cfg.rounds);
    let last = buf.0.records.last().unwrap();
    assert_eq!(summary.last_loss.to_bits(), last.loss.to_bits());
    assert_eq!(summary.wire_bytes, last.wire_bytes);
    assert!(summary.peak_rss_bytes.is_none_or(|b| b > 0));
}

/// A `Write` that keeps its bytes reachable after the engine consumed
/// the boxed sink.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().write(b)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn async_node_records_stream_as_identical_jsonl() {
    let mut cfg = small_cfg();
    cfg.mode = EngineMode::Async;
    cfg.agossip = Some(AsyncConfig {
        wait_for: WaitPolicy::Quorum { k: 2 },
        staleness_lambda: 0.5,
        quorum_timeout_s: 0.2,
    });
    cfg.network = Some(net());

    // buffered reference
    let a = AsyncGossipEngine::new(&cfg).unwrap().run().unwrap();
    assert!(!a.nodes.is_empty());

    // streamed replay
    let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
    let mut eng = AsyncGossipEngine::new(&cfg).unwrap();
    eng.stream_node_records(Box::new(buf.clone()));
    let b = eng.run().unwrap();
    assert_eq!(
        a.event_digest, b.event_digest,
        "streaming changed the event order"
    );
    assert!(
        b.nodes.is_empty(),
        "streamed run still buffered node records"
    );
    let text =
        String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    let expect: String = a
        .nodes
        .iter()
        .map(|r| format!("{}\n", r.to_json().to_string()))
        .collect();
    assert_eq!(text, expect, "JSONL stream != buffered documents");
    // merged logs agree on everything but real wall-clock
    assert_eq!(a.merged.records.len(), b.merged.records.len());
    for (x, y) in a.merged.records.iter().zip(&b.merged.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        assert_eq!(x.virtual_secs.to_bits(), y.virtual_secs.to_bits());
    }
}
