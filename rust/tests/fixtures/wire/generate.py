#!/usr/bin/env python3
"""Independent reference implementation of the lmdfl wire format (v2).

Generates the golden hex fixtures consumed by
rust/tests/wire_conformance.rs from the format SPEC (see
rust/src/quant/wire.rs module docs), deliberately NOT by calling the
Rust encoder: the checked-in bytes therefore cross-check the Rust
implementation against a second, spec-derived one.

The in-repo blessing path (`LMDFL_BLESS=1 cargo test --test
wire_conformance`) rewrites the fixtures from the Rust encoder instead;
after an INTENTIONAL format change (which must bump WIRE_VERSION), run
that and update this script to match the new spec.

Layout (little-endian bit order within bytes, LSB first):
  u8 version; u8 tag; u8 phase; u8 idx_bits; u32 sender; u32 round;
  u32 d; u16 s; u8 flags(bit0: table shipped, bit1: sparse body);
  f32 norm; [f32 * s] level table (only if shipped);
  dense body:  d sign bits; d * idx_bits index bits
  sparse body: u32 k; k entries of (position: ceil_log2(d) bits,
               strictly increasing; sign: 1 bit; level index:
               idx_bits, never 0)
  zero padding to a whole byte.

The encoding is canonical: the sparse body is used exactly when level 0
is +0.0, every index-0 element carries a positive sign, d is within
1 << 24, and the sparse form is strictly smaller than the dense one.
"""

import struct
from pathlib import Path

MAX_SPARSE_DIM = 1 << 24


def ceil_log2(s: int) -> int:
    return 0 if s <= 1 else (s - 1).bit_length()


def pos_bits(d: int) -> int:
    return 0 if d <= 1 else ceil_log2(d)


class BitWriter:
    def __init__(self) -> None:
        self.bits: list[int] = []

    def write_bits(self, value: int, n: int) -> None:
        for k in range(n):
            self.bits.append((value >> k) & 1)

    def write_u8(self, v: int) -> None:
        self.write_bits(v, 8)

    def write_u16(self, v: int) -> None:
        self.write_bits(v, 16)

    def write_u32(self, v: int) -> None:
        self.write_bits(v, 32)

    def write_f32(self, v: float) -> None:
        (u,) = struct.unpack("<I", struct.pack("<f", v))
        self.write_u32(u)

    def to_bytes(self) -> bytes:
        out = bytearray()
        for i in range(0, len(self.bits), 8):
            byte = 0
            for j, bit in enumerate(self.bits[i : i + 8]):
                byte |= bit << j
            out.append(byte)
        return bytes(out)


def dense_bits(d: int, s: int, shipped: bool) -> int:
    body = 88 + (32 * s if shipped else 0) + d + d * ceil_log2(s)
    return (body + 7) // 8 * 8


def sparse_bits(d: int, s: int, shipped: bool, k: int) -> int:
    entry = pos_bits(d) + 1 + ceil_log2(s)
    body = 88 + (32 * s if shipped else 0) + 32 + k * entry
    return (body + 7) // 8 * 8


def sparse_nnz(fix: dict):
    """The canonical-form rule of quant::codec::sparse_nnz.

    Returns the listed-element count k when the message takes the
    sparse body, else None. Every implying tag's regenerated table
    (full, qsgd, natural) has level 0 == +0.0, so an implied table
    never blocks eligibility on the level-0 test.
    """
    d = len(fix["indices"])
    if d == 0 or d > MAX_SPARSE_DIM:
        return None
    levels = fix["levels"]
    if levels is not None and struct.pack("<f", levels[0]) != b"\x00" * 4:
        return None
    k = 0
    for idx, neg in zip(fix["indices"], fix["signs"]):
        if idx == 0:
            if neg:
                return None
        else:
            k += 1
    shipped = levels is not None
    s = fix["s"]
    if sparse_bits(d, s, shipped, k) < dense_bits(d, s, shipped):
        return k
    return None


def encode(fix: dict) -> bytes:
    w = BitWriter()
    s = fix["s"]
    d = len(fix["indices"])
    nnz = sparse_nnz(fix)
    w.write_u8(2)  # WIRE_VERSION
    w.write_u8(fix["tag"])
    w.write_u8(fix["phase"])
    w.write_u8(ceil_log2(s))
    w.write_u32(fix["sender"])
    w.write_u32(fix["round"])
    w.write_u32(d)
    w.write_u16(s)
    shipped = fix["levels"] is not None
    flags = (1 if shipped else 0) | (2 if nnz is not None else 0)
    w.write_u8(flags)
    w.write_f32(fix["norm"])
    if shipped:
        for level in fix["levels"]:
            w.write_f32(level)
    nbits = ceil_log2(s)
    if nnz is not None:
        w.write_u32(nnz)
        pbits = pos_bits(d)
        for p, (idx, neg) in enumerate(
            zip(fix["indices"], fix["signs"])
        ):
            if idx == 0:
                continue
            w.write_bits(p, pbits)
            w.write_bits(1 if neg else 0, 1)
            w.write_bits(idx, nbits)
    else:
        for sign in fix["signs"]:
            w.write_bits(1 if sign else 0, 1)
        for idx in fix["indices"]:
            w.write_bits(idx, nbits)
    return w.to_bytes()


# Keep these definitions in lockstep with fixtures() in
# rust/tests/wire_conformance.rs (all floats exactly representable).
FIXTURES = [
    dict(
        name="qsgd_s16", tag=1, phase=0, sender=3, round=7,
        norm=1.5, s=16, levels=None,
        signs=[i % 2 == 1 for i in range(11)],
        indices=[(i * 3 + 1) % 16 for i in range(11)],
    ),
    dict(
        name="natural_s8", tag=2, phase=0, sender=0, round=0,
        norm=2.0, s=8, levels=None,
        signs=[False, True, True, False, False],
        indices=[0, 7, 3, 5, 1],
    ),
    dict(
        name="full_s16384", tag=0, phase=2, sender=15, round=255,
        norm=0.5, s=16384, levels=None,
        signs=[True, False, True],
        indices=[0, 16383, 8192],
    ),
    dict(
        name="lloyd_max_s4", tag=4, phase=2, sender=1, round=9,
        norm=3.25, s=4, levels=[0.0, 0.25, 0.5, 1.0],
        signs=[i % 3 == 0 for i in range(13)],
        indices=[(i + 1) % 4 for i in range(13)],
    ),
    dict(
        name="alq_s6", tag=3, phase=0, sender=2, round=3,
        norm=4.0, s=6, levels=[0.0, 0.125, 0.25, 0.375, 0.5, 0.75],
        signs=[False, False, True, True, False, True, False],
        indices=[5, 0, 4, 1, 3, 2, 5],
    ),
    dict(
        name="doubly_adaptive_s4", tag=5, phase=0, sender=4, round=12,
        norm=0.75, s=4, levels=[0.0, 0.25, 0.5, 0.875],
        signs=[i % 4 == 2 for i in range(9)],
        indices=[i % 4 for i in range(9)],
    ),
    dict(
        name="empty_delta", tag=4, phase=0, sender=6, round=1,
        norm=0.0, s=2, levels=[0.25, 0.75],
        signs=[], indices=[],
    ),
    # sparse bodies (flags bit1): top-k keeps 5 of 64 coordinates —
    # positions 3, 17, 31, 32, 63 survive, everything else is the
    # implicit index-0/positive slot
    dict(
        name="topk_sparse", tag=7, phase=2, sender=5, round=21,
        norm=1.25, s=2, levels=[0.0, 0.5],
        signs=[p in (17, 32) for p in range(64)],
        indices=[1 if p in (3, 17, 31, 32, 63) else 0
                 for p in range(64)],
    ),
    # TernGrad over 48 coordinates, 6 survivors with mixed signs
    dict(
        name="terngrad_sparse", tag=6, phase=0, sender=9, round=4,
        norm=0.875, s=2, levels=[0.0, 0.75],
        signs=[p in (8, 24, 40) for p in range(48)],
        indices=[1 if p in (0, 8, 19, 24, 40, 47) else 0
                 for p in range(48)],
    ),
    # a top-k message that kept NOTHING: k = 0, s = 1 — the sparse
    # body still ships a whole frame (offline drop is zero bytes, an
    # empty message never is)
    dict(
        name="topk_empty_sparse", tag=7, phase=0, sender=2, round=33,
        norm=0.0, s=1, levels=[0.0],
        signs=[False] * 512,
        indices=[0] * 512,
    ),
]


def main() -> None:
    here = Path(__file__).parent
    for fix in FIXTURES:
        data = encode(fix)
        # sanity: exact size formula from the spec
        d = len(fix["indices"])
        shipped = fix["levels"] is not None
        nnz = sparse_nnz(fix)
        if nnz is not None:
            body = sparse_bits(d, fix["s"], shipped, nnz)
        else:
            body = dense_bits(d, fix["s"], shipped)
        want = 12 + body // 8
        assert len(data) == want, (fix["name"], len(data), want)
        expect_sparse = fix["name"].endswith("_sparse")
        assert (nnz is not None) == expect_sparse, fix["name"]
        path = here / f"{fix['name']}.hex"
        path.write_text(data.hex() + "\n")
        print(f"{fix['name']}: {len(data)} bytes -> {path.name}")


if __name__ == "__main__":
    main()
