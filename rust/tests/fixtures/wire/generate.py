#!/usr/bin/env python3
"""Independent reference implementation of the lmdfl wire format (v1).

Generates the golden hex fixtures consumed by
rust/tests/wire_conformance.rs from the format SPEC (see
rust/src/quant/wire.rs module docs), deliberately NOT by calling the
Rust encoder: the checked-in bytes therefore cross-check the Rust
implementation against a second, spec-derived one.

The in-repo blessing path (`LMDFL_BLESS=1 cargo test --test
wire_conformance`) rewrites the fixtures from the Rust encoder instead;
after an INTENTIONAL format change (which must bump WIRE_VERSION), run
that and update this script to match the new spec.

Layout (little-endian bit order within bytes, LSB first):
  u8 version; u8 tag; u8 phase; u8 idx_bits; u32 sender; u32 round;
  u32 d; u16 s; u8 flags(1 = table shipped); f32 norm;
  [f32 * s] level table (only if shipped);
  d sign bits; d * idx_bits index bits; zero padding to a whole byte.
"""

import struct
from pathlib import Path


def ceil_log2(s: int) -> int:
    return 0 if s <= 1 else (s - 1).bit_length()


class BitWriter:
    def __init__(self) -> None:
        self.bits: list[int] = []

    def write_bits(self, value: int, n: int) -> None:
        for k in range(n):
            self.bits.append((value >> k) & 1)

    def write_u8(self, v: int) -> None:
        self.write_bits(v, 8)

    def write_u16(self, v: int) -> None:
        self.write_bits(v, 16)

    def write_u32(self, v: int) -> None:
        self.write_bits(v, 32)

    def write_f32(self, v: float) -> None:
        (u,) = struct.unpack("<I", struct.pack("<f", v))
        self.write_u32(u)

    def to_bytes(self) -> bytes:
        out = bytearray()
        for i in range(0, len(self.bits), 8):
            byte = 0
            for j, bit in enumerate(self.bits[i : i + 8]):
                byte |= bit << j
            out.append(byte)
        return bytes(out)


def encode(fix: dict) -> bytes:
    w = BitWriter()
    s = fix["s"]
    w.write_u8(1)  # WIRE_VERSION
    w.write_u8(fix["tag"])
    w.write_u8(fix["phase"])
    w.write_u8(ceil_log2(s))
    w.write_u32(fix["sender"])
    w.write_u32(fix["round"])
    w.write_u32(len(fix["indices"]))
    w.write_u16(s)
    shipped = fix["levels"] is not None
    w.write_u8(1 if shipped else 0)
    w.write_f32(fix["norm"])
    if shipped:
        for level in fix["levels"]:
            w.write_f32(level)
    for sign in fix["signs"]:
        w.write_bits(1 if sign else 0, 1)
    nbits = ceil_log2(s)
    for idx in fix["indices"]:
        w.write_bits(idx, nbits)
    return w.to_bytes()


# Keep these definitions in lockstep with fixtures() in
# rust/tests/wire_conformance.rs (all floats exactly representable).
FIXTURES = [
    dict(
        name="qsgd_s16", tag=1, phase=0, sender=3, round=7,
        norm=1.5, s=16, levels=None,
        signs=[i % 2 == 1 for i in range(11)],
        indices=[(i * 3 + 1) % 16 for i in range(11)],
    ),
    dict(
        name="natural_s8", tag=2, phase=0, sender=0, round=0,
        norm=2.0, s=8, levels=None,
        signs=[False, True, True, False, False],
        indices=[0, 7, 3, 5, 1],
    ),
    dict(
        name="full_s16384", tag=0, phase=2, sender=15, round=255,
        norm=0.5, s=16384, levels=None,
        signs=[True, False, True],
        indices=[0, 16383, 8192],
    ),
    dict(
        name="lloyd_max_s4", tag=4, phase=2, sender=1, round=9,
        norm=3.25, s=4, levels=[0.0, 0.25, 0.5, 1.0],
        signs=[i % 3 == 0 for i in range(13)],
        indices=[(i + 1) % 4 for i in range(13)],
    ),
    dict(
        name="alq_s6", tag=3, phase=0, sender=2, round=3,
        norm=4.0, s=6, levels=[0.0, 0.125, 0.25, 0.375, 0.5, 0.75],
        signs=[False, False, True, True, False, True, False],
        indices=[5, 0, 4, 1, 3, 2, 5],
    ),
    dict(
        name="doubly_adaptive_s4", tag=5, phase=0, sender=4, round=12,
        norm=0.75, s=4, levels=[0.0, 0.25, 0.5, 0.875],
        signs=[i % 4 == 2 for i in range(9)],
        indices=[i % 4 for i in range(9)],
    ),
    dict(
        name="empty_delta", tag=4, phase=0, sender=6, round=1,
        norm=0.0, s=2, levels=[0.25, 0.75],
        signs=[], indices=[],
    ),
]


def main() -> None:
    here = Path(__file__).parent
    for fix in FIXTURES:
        data = encode(fix)
        # sanity: exact size formula from the spec
        body_bits = 88
        if fix["levels"] is not None:
            body_bits += 32 * fix["s"]
        d = len(fix["indices"])
        body_bits += d + d * ceil_log2(fix["s"])
        want = 12 + (body_bits + 7) // 8
        assert len(data) == want, (fix["name"], len(data), want)
        path = here / f"{fix['name']}.hex"
        path.write_text(data.hex() + "\n")
        print(f"{fix['name']}: {len(data)} bytes -> {path.name}")


if __name__ == "__main__":
    main()
