//! simnet determinism contract: same seed + same `network:` config ⇒
//! identical event order, bit-identical `virtual_secs`, and identical
//! final loss — two replays of a simulated run must be byte-identical
//! all the way down to the serialized log. Plus the churn invariant:
//! every rebuilt confusion matrix stays symmetric doubly stochastic.

use lmdfl::agossip::{AsyncConfig, AsyncGossipEngine, AsyncRunLog, WaitPolicy};
use lmdfl::config::{
    AttackConfig, AttackKind, DatasetKind, EngineMode, ExperimentConfig,
    MixingKind, QuantizerKind, TopologyKind, WireEncoding,
};
use lmdfl::metrics::RunLog;
use lmdfl::simnet::{
    ChurnConfig, ChurnState, ComputeModel, Fabric, LinkModel,
    NetworkConfig,
};
use lmdfl::topology::Topology;
use lmdfl::util::rng::Rng;

fn sim_cfg(quant: QuantizerKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "simnet-determinism".into();
    cfg.seed = 23;
    cfg.nodes = 8;
    cfg.tau = 2;
    cfg.rounds = 10;
    cfg.batch_size = 16;
    cfg.lr = lmdfl::config::LrSchedule::fixed(0.1);
    cfg.topology = TopologyKind::Torus;
    cfg.quantizer = quant;
    cfg.dataset = DatasetKind::Blobs {
        train: 240,
        test: 80,
        dim: 8,
        classes: 3,
    };
    cfg.network = Some(harsh_network());
    cfg
}

/// A network that exercises every stochastic knob at once.
fn harsh_network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.003,
            bandwidth_bps: 1e6,
            jitter_s: 0.002,
            drop_prob: 0.1,
        },
        link_hetero_spread: 0.6,
        compute: ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.8,
            straggler_prob: 0.2,
            straggler_slowdown: 5.0,
        },
        churn: ChurnConfig {
            interval_rounds: 3,
            link_fail_prob: 0.2,
            link_heal_prob: 0.5,
            node_leave_prob: 0.05,
            node_return_prob: 0.5,
        },
    }
}

fn run_once(cfg: &ExperimentConfig) -> (RunLog, u64, u64) {
    let net = cfg.network.clone().unwrap();
    let topo = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
    let mut fabric = Fabric::new(&net, &topo, cfg.seed);
    let mut trainer = lmdfl::dfl::Trainer::build(cfg).unwrap();
    let log = trainer.engine_mut().run_simulated(&mut fabric).unwrap();
    (log, fabric.event_digest(), fabric.events_processed())
}

#[test]
fn replay_is_byte_identical() {
    let cfg = sim_cfg(QuantizerKind::LloydMax { s: 8, iters: 6 });
    let (mut log_a, digest_a, events_a) = run_once(&cfg);
    let (mut log_b, digest_b, events_b) = run_once(&cfg);
    // wall_secs is real elapsed time (the one deliberately
    // nondeterministic column); zero it so the byte comparison covers
    // every simulated quantity
    for r in log_a.records.iter_mut().chain(log_b.records.iter_mut()) {
        r.wall_secs = 0.0;
    }
    // identical event order (digest covers every popped event) and count
    assert_eq!(digest_a, digest_b, "event order diverged");
    assert_eq!(events_a, events_b);
    // bit-identical records: virtual_secs, straggler wait, loss, bits
    assert_eq!(log_a.records.len(), log_b.records.len());
    for (a, b) in log_a.records.iter().zip(&log_b.records) {
        assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits());
        assert_eq!(
            a.straggler_wait_secs.to_bits(),
            b.straggler_wait_secs.to_bits()
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.bits_per_link, b.bits_per_link);
    }
    // ... and therefore the serialized artifacts are byte-identical
    assert_eq!(log_a.to_csv(), log_b.to_csv());
    assert_eq!(
        log_a.to_json().to_pretty(),
        log_b.to_json().to_pretty()
    );
}

#[test]
fn different_seeds_produce_different_timelines() {
    let cfg_a = sim_cfg(QuantizerKind::Qsgd { s: 8 });
    let mut cfg_b = cfg_a.clone();
    cfg_b.seed = 24;
    let (log_a, digest_a, _) = run_once(&cfg_a);
    let (log_b, digest_b, _) = run_once(&cfg_b);
    assert_ne!(digest_a, digest_b, "seeds should change the event order");
    let last_a = log_a.records.last().unwrap().virtual_secs;
    let last_b = log_b.records.last().unwrap().virtual_secs;
    assert_ne!(last_a.to_bits(), last_b.to_bits());
}

#[test]
fn virtual_clock_is_monotone_under_churn_and_drops() {
    for quant in [
        QuantizerKind::LloydMax { s: 8, iters: 6 },
        QuantizerKind::Qsgd { s: 8 },
        QuantizerKind::DoublyAdaptive { s1: 4, iters: 6, s_max: 256 },
    ] {
        let cfg = sim_cfg(quant);
        let (log, _, events) = run_once(&cfg);
        assert!(events > 0);
        let mut prev = 0.0;
        for r in &log.records {
            assert!(
                r.virtual_secs > prev,
                "virtual clock stalled: {prev} -> {}",
                r.virtual_secs
            );
            assert!(r.straggler_wait_secs >= 0.0);
            prev = r.virtual_secs;
        }
    }
}

/// Async-engine variant of the harsh config: same fabric, same seed,
/// event-driven execution with a tight quorum timer so forced mixes
/// and stale-timer events exercise the whole state machine.
fn async_sim_cfg(churn: bool) -> ExperimentConfig {
    let mut cfg = sim_cfg(QuantizerKind::LloydMax { s: 8, iters: 6 });
    cfg.mode = EngineMode::Async;
    cfg.agossip = Some(AsyncConfig {
        wait_for: WaitPolicy::Quorum { k: 2 },
        staleness_lambda: 0.5,
        quorum_timeout_s: 0.2,
    });
    if !churn {
        cfg.network.as_mut().unwrap().churn = Default::default();
    }
    cfg
}

fn run_async_once(cfg: &ExperimentConfig) -> AsyncRunLog {
    AsyncGossipEngine::new(cfg).unwrap().run().unwrap()
}

fn assert_async_replay_identical(cfg: &ExperimentConfig) {
    let mut a = run_async_once(cfg);
    let mut b = run_async_once(cfg);
    // identical event order and count
    assert_eq!(a.event_digest, b.event_digest, "event order diverged");
    assert_eq!(a.events, b.events);
    assert_eq!(a.messages_lost, b.messages_lost);
    assert_eq!(a.forced_mixes, b.forced_mixes);
    // per-node logs bit-identical (NodeRecord: PartialEq over f64 — a
    // replay must reproduce every field exactly)
    assert_eq!(a.nodes, b.nodes, "node records diverged");
    // merged logs byte-identical once the one deliberately
    // nondeterministic column (real wall-clock) is zeroed
    for r in a
        .merged
        .records
        .iter_mut()
        .chain(b.merged.records.iter_mut())
    {
        r.wall_secs = 0.0;
    }
    assert_eq!(a.merged.records.len(), b.merged.records.len());
    for (x, y) in a.merged.records.iter().zip(&b.merged.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        assert_eq!(x.virtual_secs.to_bits(), y.virtual_secs.to_bits());
        assert_eq!(
            x.straggler_wait_secs.to_bits(),
            y.straggler_wait_secs.to_bits()
        );
        assert_eq!(x.bits_per_link, y.bits_per_link);
        assert_eq!(x.levels, y.levels);
    }
    assert_eq!(a.merged.to_csv(), b.merged.to_csv());
}

#[test]
fn async_replay_is_byte_identical() {
    assert_async_replay_identical(&async_sim_cfg(false));
}

#[test]
fn async_replay_is_byte_identical_under_churn() {
    assert_async_replay_identical(&async_sim_cfg(true));
}

#[test]
fn async_different_seeds_produce_different_timelines() {
    let cfg_a = async_sim_cfg(false);
    let mut cfg_b = cfg_a.clone();
    cfg_b.seed = 24;
    let a = run_async_once(&cfg_a);
    let b = run_async_once(&cfg_b);
    assert_ne!(
        a.event_digest, b.event_digest,
        "seeds should change the event order"
    );
}

// ---- Byzantine determinism contract --------------------------------
//
// ISSUE 10: an adversary is part of the replayable world. Attacked
// runs — robust mixing engaged, corrupted streams on the wire — must
// replay byte-identically on both engines, with and without churn,
// and tracing an attacked run must not perturb it.

fn attacked_cfg(mixing: MixingKind, churn: bool) -> ExperimentConfig {
    let mut cfg = sim_cfg(QuantizerKind::LloydMax { s: 8, iters: 6 });
    cfg.attack = Some(AttackConfig { kind: AttackKind::SignFlip, f: 2 });
    cfg.mixing = mixing;
    if !churn {
        cfg.network.as_mut().unwrap().churn = Default::default();
    }
    cfg
}

#[test]
fn attacked_sync_replay_is_byte_identical() {
    for churn in [false, true] {
        for mixing in [MixingKind::Trimmed { f: 1 }, MixingKind::Median]
        {
            let cfg = attacked_cfg(mixing, churn);
            let (mut a, digest_a, events_a) = run_once(&cfg);
            let (mut b, digest_b, events_b) = run_once(&cfg);
            assert_eq!(
                digest_a, digest_b,
                "{mixing:?} churn={churn}: event order diverged"
            );
            assert_eq!(events_a, events_b);
            for r in a.records.iter_mut().chain(b.records.iter_mut()) {
                r.wall_secs = 0.0;
            }
            assert_eq!(
                a.to_csv(),
                b.to_csv(),
                "{mixing:?} churn={churn}"
            );
        }
    }
}

#[test]
fn attacked_async_replay_is_byte_identical() {
    for churn in [false, true] {
        let mut cfg = async_sim_cfg(churn);
        cfg.attack =
            Some(AttackConfig { kind: AttackKind::Random, f: 2 });
        cfg.mixing = MixingKind::Trimmed { f: 1 };
        assert_async_replay_identical(&cfg);
    }
}

/// `mixing: trimmed(0)` must route through the plain Metropolis path:
/// same event order, bit-identical records, byte-identical artifacts.
#[test]
fn trimmed_zero_replays_plain_metropolis_bitwise() {
    let mut cfg = sim_cfg(QuantizerKind::LloydMax { s: 8, iters: 6 });
    cfg.mixing = MixingKind::Metropolis;
    let (mut plain, digest_p, _) = run_once(&cfg);
    cfg.mixing = MixingKind::Trimmed { f: 0 };
    let (mut t0, digest_t, _) = run_once(&cfg);
    assert_eq!(digest_p, digest_t, "trimmed(0) changed the event order");
    for r in plain.records.iter_mut().chain(t0.records.iter_mut()) {
        r.wall_secs = 0.0;
    }
    assert_eq!(plain.to_csv(), t0.to_csv());
}

/// Tracing an attacked run is observation-only AND the trace carries
/// the adversarial counters (`byzantine_msgs`, `trimmed_drops`).
#[test]
fn attacked_traced_replay_matches_untraced() {
    use lmdfl::obs;

    let cfg = attacked_cfg(MixingKind::Trimmed { f: 1 }, false);
    let (mut plain, digest_plain, _) = run_once(&cfg);
    let path = std::env::temp_dir()
        .join(format!("lmdfl_attacked_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned();
    obs::start(
        &obs::ObserveConfig {
            trace_path: Some(path.clone()),
            chrome_path: None,
        },
        0,
    );
    let (mut traced, digest_traced, _) = run_once(&cfg);
    let written = obs::stop().unwrap();
    assert_eq!(written, vec![path.clone()]);
    assert_eq!(
        digest_plain, digest_traced,
        "tracing changed the attacked event order"
    );
    for r in plain.records.iter_mut().chain(traced.records.iter_mut()) {
        r.wall_secs = 0.0;
    }
    assert_eq!(plain.to_csv(), traced.to_csv());
    let text = std::fs::read_to_string(&path).unwrap();
    let tf = obs::export::parse_trace(&text).unwrap();
    assert!(tf.complete, "attacked trace missing its end footer");
    let byz: u64 = tf
        .counters
        .iter()
        .filter(|c| c.name == "byzantine_msgs")
        .map(|c| c.value)
        .sum();
    assert!(byz > 0, "no byzantine_msgs counted in an attacked run");
    assert!(
        tf.counters
            .iter()
            .any(|c| c.name == "trimmed_drops" && c.value > 0),
        "trimmed mixing recorded no drops"
    );
    let _ = std::fs::remove_file(&path);
}

/// Every configurable quantizer family, for the encoding-parity matrix
/// (the last two emit sparse wire bodies).
fn all_quantizers() -> [QuantizerKind; 8] {
    [
        QuantizerKind::Full,
        QuantizerKind::Qsgd { s: 8 },
        QuantizerKind::Natural { s: 8 },
        QuantizerKind::Alq { s: 8 },
        QuantizerKind::LloydMax { s: 8, iters: 6 },
        QuantizerKind::DoublyAdaptive { s1: 4, iters: 6, s_max: 64 },
        QuantizerKind::TernGrad,
        QuantizerKind::TopK { keep: 0.1 },
    ]
}

/// `encoding: matrix` vs `encoding: bitstream` must produce
/// byte-identical RunLogs for every quantizer under the harsh network
/// (drops, jitter, stragglers, churn): models, byte accounting, and
/// virtual timelines all — only the transport representation differs.
#[test]
fn sync_matrix_and_bitstream_runlogs_byte_identical() {
    for quant in all_quantizers() {
        let name = format!("{quant:?}");
        let mut cfg = sim_cfg(quant);
        cfg.rounds = 6;
        cfg.encoding = WireEncoding::Matrix;
        let (mut log_m, digest_m, _) = run_once(&cfg);
        cfg.encoding = WireEncoding::Bitstream;
        let (mut log_b, digest_b, _) = run_once(&cfg);
        assert_eq!(digest_m, digest_b, "{name}: event order diverged");
        for r in log_m
            .records
            .iter_mut()
            .chain(log_b.records.iter_mut())
        {
            r.wall_secs = 0.0; // the one deliberately real-time column
        }
        assert_eq!(log_m.to_csv(), log_b.to_csv(), "{name}");
        assert_eq!(
            log_m.to_json().to_pretty(),
            log_b.to_json().to_pretty(),
            "{name}"
        );
    }
}

/// The async half of the same contract, per quantizer (no churn) plus
/// the harsh churn configuration.
#[test]
fn async_matrix_and_bitstream_runlogs_byte_identical() {
    let mut cfgs: Vec<(String, ExperimentConfig)> = all_quantizers()
        .into_iter()
        .map(|q| {
            let name = format!("{q:?}");
            let mut cfg = sim_cfg(q);
            cfg.rounds = 5;
            cfg.mode = EngineMode::Async;
            cfg.agossip = Some(AsyncConfig {
                wait_for: WaitPolicy::Quorum { k: 2 },
                staleness_lambda: 0.5,
                quorum_timeout_s: 0.2,
            });
            cfg.network.as_mut().unwrap().churn = Default::default();
            (name, cfg)
        })
        .collect();
    let mut churny = async_sim_cfg(true);
    churny.rounds = 6;
    cfgs.push(("churn".into(), churny));
    for (name, base) in cfgs {
        let mut cfg = base;
        cfg.encoding = WireEncoding::Matrix;
        let mut m = run_async_once(&cfg);
        cfg.encoding = WireEncoding::Bitstream;
        let mut b = run_async_once(&cfg);
        assert_eq!(
            m.event_digest, b.event_digest,
            "{name}: event order diverged"
        );
        assert_eq!(m.nodes, b.nodes, "{name}: node records diverged");
        assert_eq!(m.wire_bytes, b.wire_bytes, "{name}");
        assert_eq!(m.link_bytes, b.link_bytes, "{name}");
        for r in m
            .merged
            .records
            .iter_mut()
            .chain(b.merged.records.iter_mut())
        {
            r.wall_secs = 0.0;
        }
        assert_eq!(m.merged.to_csv(), b.merged.to_csv(), "{name}");
    }
}

/// PR 7 acceptance: tracing is observation-only. A traced run of each
/// torus-16 preset — sync round-barrier and async event-driven — must
/// reproduce the untraced event digest and a bit-identical RunLog, and
/// the written trace must parse as a complete `lmdfl-trace-v1` file.
#[test]
fn traced_replay_is_byte_identical_to_untraced() {
    use lmdfl::experiments::fig_time;
    use lmdfl::experiments::Scale;
    use lmdfl::obs;

    let tmp = |name: &str| {
        std::env::temp_dir()
            .join(format!("lmdfl_traced_{}_{name}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    };
    let shrink = |cfg: &mut ExperimentConfig| {
        cfg.rounds = 4;
        cfg.dataset = DatasetKind::Blobs {
            train: 240,
            test: 80,
            dim: 8,
            classes: 3,
        };
    };
    let trace_to = |path: &str| {
        obs::start(
            &obs::ObserveConfig {
                trace_path: Some(path.to_string()),
                chrome_path: None,
            },
            0,
        );
    };
    let read_trace = |path: &str| {
        let text = std::fs::read_to_string(path).unwrap();
        obs::export::parse_trace(&text).unwrap()
    };

    // ---- sync preset on the round-barrier fabric --------------------
    let (mut cfg, net) =
        fig_time::preset("torus-16", Scale::Quick).unwrap();
    shrink(&mut cfg);
    cfg.network = Some(net);
    let (mut plain, digest_plain, events_plain) = run_once(&cfg);
    let path = tmp("sync.jsonl");
    trace_to(&path);
    let (mut traced, digest_traced, events_traced) = run_once(&cfg);
    let written = obs::stop().unwrap();
    assert_eq!(written, vec![path.clone()]);
    assert_eq!(
        digest_plain, digest_traced,
        "tracing changed the sync event order"
    );
    assert_eq!(events_plain, events_traced);
    for r in plain.records.iter_mut().chain(traced.records.iter_mut()) {
        r.wall_secs = 0.0; // the one deliberately real-time column
    }
    assert_eq!(
        plain.to_csv(),
        traced.to_csv(),
        "tracing changed the sync RunLog"
    );
    let tf = read_trace(&path);
    assert!(tf.complete, "sync trace missing its end footer");
    assert!(!tf.spans.is_empty(), "sync trace recorded no spans");
    obs::summary::check(&tf).unwrap();
    let _ = std::fs::remove_file(&path);

    // ---- async preset on the event-driven engine --------------------
    let (mut acfg, anet) =
        fig_time::preset("async-torus-16", Scale::Quick).unwrap();
    shrink(&mut acfg);
    acfg.network = Some(anet);
    acfg.mode = EngineMode::Async;
    acfg.agossip = Some(fig_time::async_torus16_policy());
    let mut aplain = run_async_once(&acfg);
    let apath = tmp("async.jsonl");
    trace_to(&apath);
    let mut atraced = run_async_once(&acfg);
    let awritten = obs::stop().unwrap();
    assert_eq!(awritten, vec![apath.clone()]);
    assert_eq!(
        aplain.event_digest, atraced.event_digest,
        "tracing changed the async event order"
    );
    assert_eq!(aplain.events, atraced.events);
    assert_eq!(aplain.nodes, atraced.nodes, "node records diverged");
    for r in aplain
        .merged
        .records
        .iter_mut()
        .chain(atraced.merged.records.iter_mut())
    {
        r.wall_secs = 0.0;
    }
    assert_eq!(
        aplain.merged.to_csv(),
        atraced.merged.to_csv(),
        "tracing changed the async RunLog"
    );
    let atf = read_trace(&apath);
    assert!(atf.complete, "async trace missing its end footer");
    assert!(
        atf.spans.iter().any(|s| s.virt),
        "async trace has no virtual spans"
    );
    obs::summary::check(&atf).unwrap();
    let _ = std::fs::remove_file(&apath);
}

// ---- large-fleet scale presets -------------------------------------
//
// PR 8 acceptance: the 4096-node random-regular and 10k-node torus
// presets replay to byte-identical event digests and logs — at full
// node count (the sparse state, group multiplexing, and arena queue
// all engaged), with and without churn, on both engines. Rounds are
// shrunk; throughput and RSS belong to the bench suite.

fn scale_cfg(name: &str, churn: bool) -> ExperimentConfig {
    let (mut cfg, mut net) = lmdfl::experiments::fig_time::preset(
        name,
        lmdfl::experiments::Scale::Quick,
    )
    .unwrap();
    cfg.rounds = 2;
    if churn {
        net.churn = ChurnConfig {
            interval_rounds: 1,
            link_fail_prob: 0.1,
            link_heal_prob: 0.5,
            node_leave_prob: 0.02,
            node_return_prob: 0.5,
        };
    }
    cfg.network = Some(net);
    cfg
}

fn assert_scale_sync_replay(name: &str) {
    for churn in [false, true] {
        let cfg = scale_cfg(name, churn);
        let (mut a, digest_a, events_a) = run_once(&cfg);
        let (mut b, digest_b, events_b) = run_once(&cfg);
        assert_eq!(
            digest_a, digest_b,
            "{name} churn={churn}: event order diverged"
        );
        assert_eq!(events_a, events_b);
        for r in a.records.iter_mut().chain(b.records.iter_mut()) {
            r.wall_secs = 0.0;
        }
        assert_eq!(a.to_csv(), b.to_csv(), "{name} churn={churn}");
    }
}

fn assert_scale_async_replay(name: &str) {
    for churn in [false, true] {
        let cfg = scale_cfg(name, churn);
        assert_async_replay_identical(&cfg);
    }
}

#[test]
fn scale_preset_random_regular_4096_sync_replays_identically() {
    assert_scale_sync_replay("random-regular-4096");
}

#[test]
fn scale_preset_torus_10k_sync_replays_identically() {
    assert_scale_sync_replay("torus-10k");
}

#[test]
fn scale_preset_random_regular_4096_async_replays_identically() {
    assert_scale_async_replay("async-random-regular-4096");
}

#[test]
fn scale_preset_torus_10k_async_replays_identically() {
    assert_scale_async_replay("async-torus-10k");
}

#[test]
fn churn_rebuilds_stay_symmetric_doubly_stochastic() {
    let base = Topology::build(&TopologyKind::Torus, 16, 7);
    let churn = ChurnConfig {
        interval_rounds: 1,
        link_fail_prob: 0.3,
        link_heal_prob: 0.4,
        node_leave_prob: 0.1,
        node_return_prob: 0.5,
    };
    let mut state = ChurnState::new(churn, &base, Rng::new(99));
    let mut rebuilds = 0;
    for k in 1..60 {
        if let Some(t) = state.pre_round(k) {
            rebuilds += 1;
            assert!(
                t.dense().is_symmetric(1e-12),
                "round {k}: C not symmetric"
            );
            assert!(
                t.dense().is_doubly_stochastic(1e-9),
                "round {k}: C not doubly stochastic"
            );
            assert!(
                (0.0..=1.0 + 1e-9).contains(&t.zeta),
                "round {k}: zeta {} out of range",
                t.zeta
            );
        }
    }
    assert!(rebuilds > 10, "churn fired only {rebuilds} times");
}
