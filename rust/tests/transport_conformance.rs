//! Transport conformance suite: every [`Delivery`] implementation —
//! in-process channels, localhost TCP sockets, and the fault-injecting
//! wrapper — must move the same golden wire bytes, meter them
//! identically (measured `wire_bytes` == sum of encoded message
//! lengths), surface faults as typed errors, and drive the gossip
//! runtime to the *same* loss trajectory for the same seed.
//!
//! The multi-process cases spawn the `lmdfl` binary (`node` /
//! `net-echo` subcommands) and skip gracefully when it is not built,
//! like `integration_cli.rs`.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use lmdfl::prelude::*;

// ---- shared helpers ---------------------------------------------------

/// Phase tag `lmdfl net-echo` announces itself with (kept in lockstep
/// with the constant in `src/main.rs`).
const HELLO_PHASE: u8 = 0xFD;

fn lmdfl_bin() -> Option<PathBuf> {
    // cargo puts test binaries next to the main binary
    let mut path = std::env::current_exe().ok()?;
    path.pop(); // test binary name
    if path.ends_with("deps") {
        path.pop();
    }
    let bin = path.join("lmdfl");
    bin.exists().then_some(bin)
}

macro_rules! require_bin {
    () => {
        match lmdfl_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: lmdfl binary not built");
                return;
            }
        }
    };
}

/// Kills leftover child processes if a test panics mid-run.
struct KillOnDrop(Vec<Child>);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn from_hex(text: &str) -> Vec<u8> {
    let t = text.trim();
    (0..t.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&t[i..i + 2], 16).unwrap())
        .collect()
}

/// The golden wire bitstreams pinned by `wire_conformance.rs` — the
/// exact payloads a real run broadcasts, name-sorted for determinism.
fn fixture_payloads() -> Vec<Vec<u8>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/wire");
    let mut names: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "hex"))
        .collect();
    names.sort();
    assert!(!names.is_empty(), "no wire fixtures under {dir:?}");
    names
        .iter()
        .map(|p| from_hex(&std::fs::read_to_string(p).unwrap()))
        .collect()
}

fn tcp_opts(base_port: u16) -> TcpOptions {
    TcpOptions {
        base_port,
        connect_timeout_s: 10.0,
        retry_backoff_s: 0.01,
        ..TcpOptions::default()
    }
}

/// Send every fixture payload 0 → 1 and assert bytes, envelope keys
/// and the meter contract on the sending endpoint.
fn check_pair(
    tx: &mut dyn Delivery,
    rx: &mut dyn Delivery,
    payloads: &[Vec<u8>],
) {
    let mut total = 0u64;
    for (i, p) in payloads.iter().enumerate() {
        let f = Frame::new(
            0,
            i as u32,
            (i % 4) as u8,
            Arc::from(p.as_slice()),
        );
        tx.send(1, f).unwrap();
        total += p.len() as u64;
    }
    // THE contract: measured wire bytes == sum of encoded lengths
    assert_eq!(tx.wire_bytes(), total);
    for (i, p) in payloads.iter().enumerate() {
        let f = rx
            .recv(Duration::from_secs(10))
            .unwrap()
            .expect("frame arrives");
        assert_eq!(
            (f.from, f.round, f.phase),
            (0, i as u32, (i % 4) as u8)
        );
        assert_eq!(&f.bytes[..], p.as_slice(), "payload {i} corrupted");
    }
}

// ---- golden bytes through each transport ------------------------------

#[test]
fn golden_payloads_cross_channel_transport() {
    let payloads = fixture_payloads();
    let mut mesh = channel_mesh(2);
    let mut rx = mesh.pop().unwrap();
    let mut tx = mesh.pop().unwrap();
    check_pair(&mut tx, &mut rx, &payloads);
}

#[test]
fn golden_payloads_cross_tcp_transport() {
    let payloads = fixture_payloads();
    let o = tcp_opts(18100);
    let mut tx = TcpDelivery::bind(0, o.clone()).unwrap();
    let mut rx = TcpDelivery::bind(1, o).unwrap();
    check_pair(&mut tx, &mut rx, &payloads);
}

#[test]
fn golden_payloads_cross_fault_wrapped_transport() {
    let payloads = fixture_payloads();
    let mut mesh = channel_mesh(2);
    let mut rx = FaultDelivery::new(
        Box::new(mesh.pop().unwrap()),
        LinkModel::ideal(),
        Rng::new(2),
    );
    let mut tx = FaultDelivery::new(
        Box::new(mesh.pop().unwrap()),
        LinkModel::ideal(),
        Rng::new(1),
    );
    check_pair(&mut tx, &mut rx, &payloads);
}

// ---- fault cases ------------------------------------------------------

#[test]
fn full_loss_over_tcp_tombstones_frames_but_meters_payloads() {
    let payloads = fixture_payloads();
    let o = tcp_opts(18150);
    let mut tx = FaultDelivery::new(
        Box::new(TcpDelivery::bind(0, o.clone()).unwrap()),
        LinkModel::lossy(1.0),
        Rng::new(5),
    );
    let mut rx = TcpDelivery::bind(1, o).unwrap();
    let mut total = 0u64;
    for (i, p) in payloads.iter().enumerate() {
        tx.send(1, Frame::new(0, i as u32, 2, Arc::from(p.as_slice())))
            .unwrap();
        total += p.len() as u64;
    }
    // a lost message still occupied the link: the outer meter counts
    // the full payload even though only tombstones cross the socket
    assert_eq!(tx.wire_bytes(), total);
    for i in 0..payloads.len() {
        let f = rx
            .recv(Duration::from_secs(10))
            .unwrap()
            .expect("tombstone arrives");
        assert!(f.is_tombstone(), "frame {i} not dropped");
        assert_eq!((f.from, f.round, f.phase), (0, i as u32, 2));
    }
}

#[test]
fn jitter_reorders_but_mailbox_reassembles_by_key() {
    let rounds = 10u32;
    let mut mesh = channel_mesh(2);
    let inner_rx = mesh.pop().unwrap();
    let mut tx = mesh.pop().unwrap();
    for k in 0..rounds {
        let payload = vec![k as u8; 3];
        tx.send(1, Frame::new(0, k, 0, Arc::from(payload.as_slice())))
            .unwrap();
    }
    let link = LinkModel {
        latency_s: 0.005,
        jitter_s: 0.02,
        ..LinkModel::ideal()
    };
    let delayed = FaultDelivery::new(Box::new(inner_rx), link, Rng::new(9));
    let mut mb = Mailbox::new(Box::new(delayed));
    // the wrapper delivers in jittered (= shuffled) real-time order;
    // the mailbox still hands each round's frame out by key, in order
    for k in 0..rounds {
        let bytes = mb.recv(0, k, 0, Duration::from_secs(5)).unwrap();
        assert_eq!(&bytes[..], &[k as u8; 3], "round {k}");
    }
}

#[test]
fn transport_faults_are_typed_errors() {
    // channel: unknown peer
    let mut mesh = channel_mesh(2);
    let mut tx = mesh.pop().unwrap();
    assert!(matches!(
        tx.send(9, Frame::tombstone(1, 0, 0)),
        Err(LmdflError::Transport { peer: Some(9), .. })
    ));
    // tcp: unreachable peer, bounded by the connect budget
    let mut o = tcp_opts(18170);
    o.connect_timeout_s = 0.2;
    let mut t = TcpDelivery::bind(0, o).unwrap();
    assert!(matches!(
        t.send(3, Frame::tombstone(0, 0, 0)),
        Err(LmdflError::Transport { peer: Some(3), .. })
    ));
    // mailbox: a frame that never arrives is a deadline error, and the
    // error chain stays matchable (never a panic, never a bare string)
    let mut mb = Mailbox::new(Box::new(mesh.pop().unwrap()));
    let err = mb.recv(1, 7, 0, Duration::from_millis(20)).unwrap_err();
    assert!(matches!(
        err,
        LmdflError::Transport { peer: Some(1), .. }
    ));
}

// ---- trajectory parity ------------------------------------------------

fn parity_cfg(name: &str, nodes: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        seed: 11,
        nodes,
        tau: 2,
        rounds: 4,
        batch_size: 16,
        lr: LrSchedule::fixed(0.1),
        topology: TopologyKind::Ring,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 8 },
        dataset: DatasetKind::Blobs {
            train: 200,
            test: 60,
            dim: 8,
            classes: 3,
        },
        backend: BackendKind::RustMlp { hidden: vec![16] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism: Parallelism::Off,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

/// Same seed, same config, different transport: the threaded runtime's
/// trajectory (loss, accuracy, measured bits, levels) must be
/// byte-identical whether frames cross channels or real TCP sockets.
#[test]
fn tcp_threaded_run_matches_channel_run_exactly() {
    let cfg = parity_cfg("parity", 4);
    let channel_log =
        Trainer::run_threaded(&cfg, NetOptions::default()).unwrap();
    let mut tcp_cfg = cfg.clone();
    tcp_cfg.transport = Some(TransportConfig {
        kind: TransportKind::Tcp,
        tcp: tcp_opts(18200),
    });
    let tcp_log =
        Trainer::run_threaded(&tcp_cfg, NetOptions::default()).unwrap();
    assert_eq!(channel_log.to_csv(), tcp_log.to_csv());
}

/// The headline acceptance case: a 16-process torus-16 run over real
/// localhost TCP reproduces the in-process threaded trajectory for the
/// same seed, byte-for-byte at the CSV level.
#[test]
fn multiprocess_torus16_matches_inprocess_run() {
    let bin = require_bin!();
    let mut cfg = parity_cfg("mp-torus16", 16);
    cfg.topology = TopologyKind::Torus;
    cfg.rounds = 3;
    cfg.tau = 1;
    cfg.dataset = DatasetKind::Blobs {
        train: 320,
        test: 80,
        dim: 8,
        classes: 4,
    };
    let mut mp_cfg = cfg.clone();
    mp_cfg.transport = Some(TransportConfig {
        kind: TransportKind::Tcp,
        tcp: TcpOptions {
            connect_timeout_s: 30.0,
            ..tcp_opts(18300)
        },
    });

    let dir = std::env::temp_dir().join("lmdfl_transport_conformance");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("mp_torus16.json");
    std::fs::write(&cfg_path, mp_cfg.to_json().to_pretty()).unwrap();
    let csv_path = dir.join("mp_torus16.csv");
    let _ = std::fs::remove_file(&csv_path);

    let mut guard = KillOnDrop(Vec::new());
    for rank in 1..mp_cfg.nodes {
        let child = Command::new(&bin)
            .args([
                "node",
                "--rank",
                &rank.to_string(),
                "--config",
                cfg_path.to_str().unwrap(),
            ])
            .stdout(Stdio::null())
            .spawn()
            .unwrap();
        guard.0.push(child);
    }
    let rank0 = Command::new(&bin)
        .args([
            "node",
            "--rank",
            "0",
            "--config",
            cfg_path.to_str().unwrap(),
            "--csv",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        rank0.status.success(),
        "rank 0 failed:\n{}",
        String::from_utf8_lossy(&rank0.stderr)
    );
    for mut c in std::mem::take(&mut guard.0) {
        assert!(c.wait().unwrap().success());
    }

    let mp_csv = std::fs::read_to_string(&csv_path).unwrap();
    let in_process =
        Trainer::run_threaded(&cfg, NetOptions::default()).unwrap();
    assert_eq!(
        mp_csv,
        in_process.to_csv(),
        "multi-process TCP trajectory diverged from in-process run"
    );
}

// ---- peer death and resume --------------------------------------------

fn spawn_echo(bin: &Path, base_port: u16, count: usize) -> Child {
    Command::new(bin)
        .args([
            "net-echo",
            "--rank",
            "1",
            "--peer",
            "0",
            "--base-port",
            &base_port.to_string(),
            "--count",
            &count.to_string(),
        ])
        .stdout(Stdio::null())
        .spawn()
        .unwrap()
}

fn wait_hello(d: &mut TcpDelivery) {
    for _ in 0..60 {
        if let Some(f) = d.recv(Duration::from_secs(1)).unwrap() {
            if f.phase == HELLO_PHASE && f.from == 1 {
                return;
            }
        }
    }
    panic!("echo peer never said hello");
}

/// Collect `n` echoed rounds, ignoring hello frames.
fn collect_echoes(d: &mut TcpDelivery, n: usize) -> Vec<u32> {
    let mut rounds = Vec::new();
    while rounds.len() < n {
        let f = d
            .recv(Duration::from_secs(15))
            .unwrap()
            .expect("echo arrives");
        if f.phase != HELLO_PHASE {
            rounds.push(f.round);
        }
    }
    rounds.sort_unstable();
    rounds
}

/// Kill one process mid-run, restart it on the same rank/port, and the
/// surviving endpoint transparently re-dials: no frame of the second
/// batch is lost and the meter still counts exactly the payload bytes.
#[test]
fn tcp_survives_peer_kill_and_restart() {
    let bin = require_bin!();
    let base = 18400u16;
    let mut o = tcp_opts(base);
    o.connect_timeout_s = 15.0;
    let mut d = TcpDelivery::bind(0, o).unwrap();

    let mut guard = KillOnDrop(vec![spawn_echo(&bin, base, 1000)]);
    wait_hello(&mut d);
    for k in 0..5u32 {
        d.send(1, Frame::new(0, k, 1, Arc::from(vec![k as u8; 8])))
            .unwrap();
    }
    assert_eq!(collect_echoes(&mut d, 5), vec![0, 1, 2, 3, 4]);

    // kill the peer mid-life (it wanted 1000 echoes) and restart it on
    // the SAME rank and port
    let mut first = guard.0.pop().unwrap();
    first.kill().unwrap();
    first.wait().unwrap();
    guard.0.push(spawn_echo(&bin, base, 5));
    wait_hello(&mut d);

    // probe sends absorb the stale half-open connection: a write on a
    // dead socket can succeed locally before the reset arrives, so the
    // sacrificial (0-byte, ignored-phase) frames take that loss and
    // force the re-dial before real payloads flow
    for _ in 0..2 {
        let _ = d.send(1, Frame::tombstone(0, 99, HELLO_PHASE));
        std::thread::sleep(Duration::from_millis(50));
    }

    for k in 10..15u32 {
        d.send(1, Frame::new(0, k, 1, Arc::from(vec![k as u8; 8])))
            .unwrap();
    }
    assert_eq!(collect_echoes(&mut d, 5), vec![10, 11, 12, 13, 14]);
    // 10 real frames × 8 payload bytes; tombstone probes meter zero
    assert_eq!(d.wire_bytes(), 80);
    let mut second = guard.0.pop().unwrap();
    assert!(second.wait().unwrap().success());
}
