//! Staleness-weighted Metropolis mixing weights.
//!
//! The asynchronous engine cannot mix with the symmetric doubly
//! stochastic confusion matrix directly: at mix time some neighbor
//! estimates are stale (their last message is several of my local
//! rounds old) and trusting them at full Metropolis weight re-amplifies
//! stale drift through the gossip recursion (the failure mode DAdaQuant
//! observes when adaptive quantization meets uneven client progress).
//! Instead each node builds its mixing row at mix time:
//!
//!   w_ij = c_ij · λ^{stale_j}     (neighbors)
//!   w_ii = 1 − Σ_j w_ij           (self absorbs the remainder)
//!
//! where `c` is the live-graph Metropolis matrix and `stale_j` counts
//! how many of *my* completed rounds ago neighbor j's last message
//! arrived. Invariants (property-tested below, for arbitrary quorum
//! arrival orders):
//!
//! * every row is stochastic: entries in [0, 1], row sum exactly
//!   renormalized to 1 via the self-weight remainder;
//! * with every neighbor fresh (stale = 0) the construction returns the
//!   Metropolis row unchanged, so the implied global matrix is the
//!   symmetric doubly stochastic `C` — the synchronous mixing recovered
//!   as the fresh-everything special case.
//!
//! Weights are read from the O(degree) [`SparseTopology`] rows — at 10k
//! nodes there is no dense C to index into.

use crate::linalg::Matrix;
use crate::topology::SparseTopology;

/// Exponent cap: λ^64 underflows any meaningful weight long before the
/// cap matters, and keeps `powi` in `i32` range for pathological
/// staleness counts.
const MAX_STALE_EXP: u64 = 64;

/// Staleness sentinel meaning "never heard from this neighbor": its
/// estimate column is still the zero vector, so it must carry weight 0
/// regardless of λ (λ = 1.0 would otherwise average the zero vector in
/// at full Metropolis weight and pull the node's params toward zero).
pub const NEVER: u64 = u64::MAX;

/// Build node `i`'s mixing row over `neighbors` (parallel to
/// `staleness`): returns `(self_weight, neighbor_weights)` with
/// `self_weight + Σ neighbor_weights == 1` (up to float rounding, with
/// the self-weight clamped at 0). `c` must be row-stochastic with
/// non-negative entries (Metropolis over the live graph); neighbors
/// whose live weight is 0 (churned-away links) contribute nothing
/// regardless of staleness.
pub fn staleness_row(
    c: &SparseTopology,
    i: usize,
    neighbors: &[usize],
    staleness: &[u64],
    lambda: f64,
) -> (f64, Vec<f64>) {
    assert_eq!(
        neighbors.len(),
        staleness.len(),
        "one staleness per neighbor"
    );
    let mut w = Vec::with_capacity(neighbors.len());
    let mut sum = 0.0f64;
    for (idx, &j) in neighbors.iter().enumerate() {
        let decay = if staleness[idx] == NEVER {
            0.0
        } else if staleness[idx] == 0 {
            1.0
        } else {
            lambda.powi(staleness[idx].min(MAX_STALE_EXP) as i32)
        };
        let wij = c.weight(i, j) * decay;
        w.push(wij);
        sum += wij;
    }
    ((1.0 - sum).max(0.0), w)
}

/// Assemble the full n×n mixing matrix implied by per-row staleness
/// (`staleness[i][idx]` aligned with `adj[i]`). Test/diagnostic helper —
/// the engine itself only ever materializes single rows.
pub fn staleness_matrix(
    c: &SparseTopology,
    adj: &[Vec<usize>],
    staleness: &[Vec<u64>],
    lambda: f64,
) -> Matrix {
    let n = adj.len();
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        let (self_w, w) =
            staleness_row(c, i, &adj[i], &staleness[i], lambda);
        m.set(i, i, self_w);
        for (idx, &j) in adj[i].iter().enumerate() {
            m.set(i, j, w[idx]);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::topology::Topology;
    use crate::util::proptest::check;

    fn row_sums(m: &Matrix, n: usize) -> Vec<f64> {
        (0..n).map(|i| (0..n).map(|j| m[(i, j)]).sum()).collect()
    }

    #[test]
    fn fresh_rows_recover_metropolis() {
        let topo = Topology::build(&TopologyKind::Torus, 16, 0);
        let stale: Vec<Vec<u64>> =
            topo.adj.iter().map(|a| vec![0; a.len()]).collect();
        let m = staleness_matrix(&topo.sparse, &topo.adj, &stale, 0.5);
        assert!(
            m.max_abs_diff(topo.dense()) < 1e-12,
            "fresh != Metropolis"
        );
        assert!(m.is_doubly_stochastic(1e-9));
        assert!(m.is_symmetric(1e-12));
    }

    #[test]
    fn lambda_one_ignores_staleness() {
        let topo = Topology::build(&TopologyKind::Ring, 8, 0);
        let stale: Vec<Vec<u64>> =
            topo.adj.iter().map(|a| vec![7; a.len()]).collect();
        let m = staleness_matrix(&topo.sparse, &topo.adj, &stale, 1.0);
        assert!(m.max_abs_diff(topo.dense()) < 1e-12);
    }

    #[test]
    fn never_heard_carries_zero_weight_even_without_decay() {
        // λ = 1.0 disables staleness decay, but a neighbor that never
        // delivered must still be excluded — its estimate is the zero
        // vector, not a stale model
        let topo = Topology::build(&TopologyKind::Ring, 6, 0);
        let stale = vec![NEVER; topo.adj[0].len()];
        let (self_w, w) =
            staleness_row(&topo.sparse, 0, &topo.adj[0], &stale, 1.0);
        assert!(w.iter().all(|&x| x == 0.0), "NEVER must zero weights");
        assert!((self_w - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stale_neighbors_lose_weight_to_self() {
        let topo = Topology::build(&TopologyKind::Ring, 6, 0);
        let fresh = vec![0u64; topo.adj[0].len()];
        let stale = vec![3u64; topo.adj[0].len()];
        let (self_f, w_f) =
            staleness_row(&topo.sparse, 0, &topo.adj[0], &fresh, 0.5);
        let (self_s, w_s) =
            staleness_row(&topo.sparse, 0, &topo.adj[0], &stale, 0.5);
        assert!(self_s > self_f, "self weight must absorb decayed mass");
        for (a, b) in w_s.iter().zip(&w_f) {
            assert!(a < b, "stale neighbor weight must shrink");
        }
    }

    /// Satellite property: the staleness-weighted mixing matrix stays
    /// row-stochastic (and doubly stochastic when all weights are
    /// fresh) for *arbitrary quorum arrival orders* — modeled by
    /// drawing, per node, a random arrival round for each neighbor and
    /// deriving staleness from it, over random graphs and λ.
    #[test]
    fn prop_row_stochastic_for_arbitrary_arrival_orders() {
        check("staleness rows stay stochastic", 60, |g| {
            let n = g.usize_in(2..24);
            let p = g.f64_in(0.05..1.0);
            let topo = Topology::build(
                &TopologyKind::Random { p },
                n,
                g.seed,
            );
            let lambda = g.f64_in(0.05..1.0);
            // arbitrary arrival order: each node has completed some
            // number of rounds, and each neighbor's last message landed
            // at an arbitrary earlier round (or never: huge staleness)
            let stale: Vec<Vec<u64>> = topo
                .adj
                .iter()
                .map(|a| {
                    (0..a.len())
                        .map(|_| {
                            if g.usize_in(0..8) == 0 {
                                NEVER // some neighbors never delivered
                            } else {
                                g.usize_in(0..200) as u64
                            }
                        })
                        .collect()
                })
                .collect();
            let m =
                staleness_matrix(&topo.sparse, &topo.adj, &stale, lambda);
            for (i, s) in row_sums(&m, n).iter().enumerate() {
                assert!(
                    (s - 1.0).abs() < 1e-9,
                    "row {i} sums to {s}"
                );
            }
            for i in 0..n {
                for j in 0..n {
                    let v = m[(i, j)];
                    assert!(
                        (0.0..=1.0 + 1e-12).contains(&v),
                        "entry ({i},{j}) = {v} out of range"
                    );
                }
            }
            // all-fresh rows of the same graph are doubly stochastic
            let fresh: Vec<Vec<u64>> =
                topo.adj.iter().map(|a| vec![0; a.len()]).collect();
            let mf =
                staleness_matrix(&topo.sparse, &topo.adj, &fresh, lambda);
            assert!(mf.is_doubly_stochastic(1e-9));
        });
    }
}
