//! The asynchronous event-driven gossip engine.
//!
//! One [`AsyncGossipEngine`] owns a [`Substrate`] (links, compute
//! fleet, churn — the same deployment model the synchronous
//! [`crate::simnet::Fabric`] replays) and drives it from per-node state
//! machines on a single [`EventQueue`]:
//!
//! ```text
//!            ┌────────────┐ ComputeDone ┌───────────┐
//!   mix ───► │ Computing  │ ──────────► │  Waiting  │ ──► mix ...
//!            └────────────┘  broadcast  └───────────┘
//!                 ▲        quantized Δ     │    ▲
//!                 │                 quorum │    │ Arrive / Timeout
//!                 └────────────────────────┘    │ (re-check quorum)
//! ```
//!
//! A node runs its τ local steps as soon as its previous mix lands
//! (heterogeneous per-node compute durations), broadcasts ONE damped
//! quantized differential per round to its one-hop neighbors (the
//! CHOCO-style single-message exchange; the synchronous engine's
//! two-message form exists to keep a *globally consistent* estimate,
//! which asynchrony gives up by construction), and mixes as soon as its
//! [`WaitPolicy`] quorum of fresh neighbor messages is in — or its
//! per-node quorum timer fires (the deadlock-free fallback when
//! neighbors finished, churned away, or messages dropped). Mixing uses
//! [`super::weights::staleness_row`]: the live-graph Metropolis row
//! with per-neighbor λ^staleness decay, row-stochastic for every
//! arrival order.
//!
//! Per-node learning state is the exact [`NodeCore`] the matrix engine
//! uses (same quantizers, same damped error-feedback recursion, LM-DFL
//! refits and doubly-adaptive schedules keyed to the node's *local*
//! round count); each node additionally tracks one received-estimate
//! column per neighbor, updated by applying arriving deltas. Arrivals
//! land in a durable per-node mailbox (in-flight deltas are absorbed
//! even if the receiver churns offline mid-flight), so estimate
//! tracking drifts only under genuine message loss: per-link drops,
//! and deltas never transmitted because the receiver was offline at
//! broadcast time — the staleness weighting is what bounds that
//! drift's influence.
//!
//! Determinism: the queue pops in `(time, seq)` order, every state
//! transition and rng draw happens inside a pop (or the deterministic
//! t=0 prologue), and stale events (superseded generations/epochs) are
//! ignored but still folded into the digest — so identical seed +
//! config reproduce byte-identical event digests, node records, and
//! merged logs. `rust/tests/simnet_determinism.rs` enforces this with
//! and without churn.
//!
//! Unlike the synchronous hot path the async engine allocates per
//! event: one `Arc<Vec<f32>>` per broadcast (in-flight messages must
//! outlive the sender's scratch; bounded by the directed-link count)
//! and two degree-sized weight rows per mix. The d-sized learning
//! buffers are all preallocated in [`NodeCore`] / the per-neighbor
//! estimate columns.

use std::io::Write;
use std::sync::Arc;

use crate::config::json::Json;
use crate::config::{ExperimentConfig, WireEncoding};
use crate::data::Dataset;
use crate::dfl::backend::LocalUpdate;
use crate::dfl::core::{self, NodeCore};
use crate::metrics::{JsonlStream, RoundRecord, RunLog};
use crate::quant::wire;
use crate::simnet::clock::{
    ns_to_secs, secs_to_ns, EventQueue, VirtualTime,
};
use crate::simnet::substrate::{fold_event, Substrate, DIGEST_OFFSET};
use crate::topology::Topology;
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use super::weights;
use super::{AsyncConfig, WaitPolicy};

/// One completed local round of one node.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeRecord {
    pub node: usize,
    /// 1-based local round the node just completed
    pub round: usize,
    /// virtual clock at the node's mix
    pub virtual_secs: f64,
    /// mean local batch loss of the round's τ steps
    pub local_loss: f64,
    /// quantization levels after the round's adaptive update
    pub levels: usize,
    /// neighbors with a fresh message at mix time
    pub fresh_neighbors: usize,
    /// mean staleness (in own rounds) across neighbors at mix time
    pub stale_mean: f64,
    /// whether the quorum timer forced this mix
    pub forced: bool,
    /// measured wire bytes of this round's broadcast message (the
    /// encoded [`crate::quant::wire`] frame)
    pub wire_bytes: u64,
}

impl NodeRecord {
    /// One JSONL document — the streaming form of this record (see
    /// [`AsyncGossipEngine::stream_node_records`]). Non-finite values
    /// (a node that never evaluated has `local_loss = NaN`) serialize
    /// as `null`, matching [`RunLog::to_json`]'s convention.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::num(self.node as f64)),
            ("round", Json::num(self.round as f64)),
            ("virtual_secs", Json::num(self.virtual_secs)),
            ("local_loss", Json::num(self.local_loss)),
            ("levels", Json::num(self.levels as f64)),
            (
                "fresh_neighbors",
                Json::num(self.fresh_neighbors as f64),
            ),
            ("stale_mean", Json::num(self.stale_mean)),
            ("forced", Json::Bool(self.forced)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
        ])
    }
}

/// Everything an asynchronous run produces.
#[derive(Clone, Debug)]
pub struct AsyncRunLog {
    /// loss-vs-virtual-time log compatible with `fig-time` (one record
    /// per *global* round watermark: emitted when every participating
    /// node completed that local round)
    pub merged: RunLog,
    /// per-node per-round records, in mix order
    pub nodes: Vec<NodeRecord>,
    /// FNV-1a fingerprint of the popped event stream
    pub event_digest: u64,
    /// total events processed
    pub events: u64,
    pub messages_lost: u64,
    /// mixes fired by the quorum timer instead of the policy
    pub forced_mixes: u64,
    /// straggling local-update draws
    pub stragglers: u64,
    /// Σ measured bytes over all broadcasts (one encoded message each)
    pub wire_bytes: u64,
    /// Σ measured bytes over every transmitted link copy, counted at
    /// the engine's transmit call sites
    pub link_bytes: u64,
    /// the substrate's independent per-copy byte meter — must equal
    /// `link_bytes` exactly (asserted by the torus-16 preset tests)
    pub fabric_link_bytes: u64,
}

/// What a broadcast physically carries (see
/// [`crate::config::WireEncoding`]): the matrix-form damped delta, or
/// the encoded wire frame receivers must decode. One `Arc` per
/// broadcast either way; peers clone handles, not payloads.
#[derive(Clone)]
enum Payload {
    Delta(Arc<[f32]>),
    Wire(Arc<[u8]>),
}

/// Simulation events. Stale generations/epochs are ignored on pop.
enum AEv {
    ComputeDone { node: usize, gen: u64 },
    Arrive {
        to: usize,
        from: usize,
        /// sender's completed-round count when the message departed
        round: usize,
        payload: Payload,
    },
    QuorumTimeout { node: usize, epoch: u64 },
    /// Zero-delay quorum re-check (a neighbor finished, or churn
    /// changed eligibility). Routing wakeups through the queue instead
    /// of calling `try_mix` recursively keeps the mix call depth O(1)
    /// — a synchronous finish cascade would recurse O(n) deep on large
    /// fleets.
    Recheck { node: usize, epoch: u64 },
}

/// Node lifecycle (see module diagram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// τ local steps in flight (ComputeDone scheduled)
    Computing,
    /// broadcast sent, blocked on the mix quorum
    Waiting,
    /// churned offline mid-run (resumes at a later churn epoch)
    Parked,
    /// completed all configured local rounds
    Finished,
}

/// Per-node async state machine around the shared [`NodeCore`].
struct AsyncNode {
    core: NodeCore,
    phase: Phase,
    /// completed local rounds (mixes)
    round: usize,
    /// generation guard: bumped per compute start / park, so stale
    /// ComputeDone events are ignored deterministically
    gen: u64,
    /// epoch guard for quorum timers, bumped per mix / park
    epoch: u64,
    /// one pending timer per waiting epoch
    timer_armed: bool,
    /// parked while Waiting (broadcast already out): on return the node
    /// resumes waiting for its quorum instead of redoing the round
    parked_waiting: bool,
    /// when the node entered Waiting (quorum-wait accounting)
    wait_start: VirtualTime,
    /// mean local loss of the last completed local update (the steps
    /// run at ComputeDone, after the modeled duration elapsed)
    pending_loss: f64,
    /// ω̂ of the last broadcast message
    last_distortion: f64,
    /// measured wire bytes of the last broadcast message
    last_wire_bytes: u64,
    /// base-graph one-hop neighbors, sorted (fixed for the run; churn
    /// gates traffic at the link layer and zeroes Metropolis weights)
    nbrs: Vec<usize>,
    /// per-neighbor received-estimate columns, aligned with `nbrs`
    nbr_hat: Vec<Vec<f32>>,
    /// neighbors that delivered since this node's last mix
    fresh: Vec<bool>,
    /// whether each neighbor ever delivered
    heard: Vec<bool>,
    /// this node's round count when each neighbor last delivered
    last_arrival_round: Vec<usize>,
    /// sender-side completed-round count carried by the last delivery
    sender_round: Vec<usize>,
}

/// The asynchronous DFL engine.
pub struct AsyncGossipEngine {
    cfg: ExperimentConfig,
    acfg: AsyncConfig,
    /// live topology (Metropolis C; churn-rebuilt mid-run)
    topology: Topology,
    dataset: Dataset,
    nodes: Vec<AsyncNode>,
    backends: Vec<Box<dyn LocalUpdate>>,
    param_count: usize,
    sub: Substrate,
    queue: EventQueue<AEv>,
    digest: u64,
    /// eval subsample caps, shared with the sync engine's defaults so
    /// sync-vs-async loss curves evaluate the same subsamples
    eval_train_cap: usize,
    eval_test_cap: usize,
    /// eval executor (node-sharded, bit-identical across parallelism)
    pool: WorkerPool,
    timer: Timer,
    merged: RunLog,
    node_records: Vec<NodeRecord>,
    /// when set, per-node records stream here as JSONL instead of
    /// accumulating in `node_records` (the 10k-node memory model)
    node_sink: Option<JsonlStream<Box<dyn Write>>>,
    /// Σ paper bits over all broadcast messages (each directed link
    /// carries one copy, so /n is the mean per-link cost)
    bits_acc: u64,
    /// Σ measured wire bytes over all broadcasts (one message each)
    wire_acc: u64,
    /// Σ measured wire bytes over every transmitted link copy
    link_bytes: u64,
    /// next global-round watermark to evaluate
    eval_round: usize,
    total_mixes: u64,
    churn_epochs: usize,
    messages_lost: u64,
    forced_mixes: u64,
    stragglers: u64,
    quorum_wait_ns: u64,
    timeout_ns: VirtualTime,
    mix_scratch: Vec<f32>,
}

impl AsyncGossipEngine {
    /// Build the engine from a config. `network:` defaults to the ideal
    /// fabric when absent; `async:` defaults per [`AsyncConfig`].
    pub fn new(cfg: &ExperimentConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let topology = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let dataset = Dataset::build(&cfg.dataset, cfg.seed);
        let mut backends: Vec<Box<dyn LocalUpdate>> =
            Vec::with_capacity(cfg.nodes);
        for _ in 0..cfg.nodes {
            backends.push(crate::dfl::build_backend(cfg, &dataset)?);
        }
        let param_count = backends[0].param_count();
        let mut rng = Rng::new(cfg.seed);
        // paper: identical initial params at every node
        let init = backends[0].init_params(&mut rng.split(0xBEEF));
        let cores = NodeCore::build_fleet(
            cfg,
            &dataset,
            param_count,
            &init,
            &mut rng,
        );
        let net = cfg.network.clone().unwrap_or_default();
        let sub = Substrate::new(&net, &topology, cfg.seed);
        let acfg = cfg.agossip.clone().unwrap_or_default();
        acfg.validate()?;
        let timeout_ns = secs_to_ns(acfg.quorum_timeout_s);
        let eval_opts = crate::dfl::EngineOptions::default();
        let nodes: Vec<AsyncNode> = cores
            .into_iter()
            .enumerate()
            .map(|(i, core)| {
                let nbrs = topology.adj[i].clone();
                let deg = nbrs.len();
                AsyncNode {
                    core,
                    phase: Phase::Parked,
                    round: 0,
                    gen: 0,
                    epoch: 0,
                    timer_armed: false,
                    parked_waiting: false,
                    wait_start: 0,
                    pending_loss: f64::NAN,
                    last_distortion: 0.0,
                    last_wire_bytes: 0,
                    nbr_hat: vec![vec![0.0; param_count]; deg],
                    fresh: vec![false; deg],
                    heard: vec![false; deg],
                    last_arrival_round: vec![0; deg],
                    sender_round: vec![0; deg],
                    nbrs,
                }
            })
            .collect();
        let pool =
            WorkerPool::from_parallelism(cfg.parallelism, cfg.nodes);
        Ok(AsyncGossipEngine {
            cfg: cfg.clone(),
            acfg,
            topology,
            dataset,
            nodes,
            backends,
            param_count,
            sub,
            queue: EventQueue::new(),
            digest: DIGEST_OFFSET,
            eval_train_cap: eval_opts.eval_train_cap,
            eval_test_cap: eval_opts.eval_test_cap,
            pool,
            timer: Timer::start(),
            merged: RunLog::new(&cfg.name),
            node_records: Vec::new(),
            node_sink: None,
            bits_acc: 0,
            wire_acc: 0,
            link_bytes: 0,
            eval_round: 0,
            total_mixes: 0,
            churn_epochs: 0,
            messages_lost: 0,
            forced_mixes: 0,
            stragglers: 0,
            quorum_wait_ns: 0,
            timeout_ns,
            mix_scratch: vec![0.0; param_count],
        })
    }

    /// Stream per-node records to `w` as JSONL — one
    /// [`NodeRecord::to_json`] document per completed local round, in
    /// the same mix order the buffered path uses — instead of
    /// accumulating them in [`AsyncRunLog::nodes`] (which then stays
    /// empty). A 10k-node run completes O(rounds · n) local rounds;
    /// streaming them keeps resident memory at the fleet's working
    /// set instead of the run's history.
    pub fn stream_node_records(&mut self, w: Box<dyn Write>) {
        self.node_sink = Some(JsonlStream::new(w));
    }

    /// Drive every node through `cfg.rounds` local rounds and drain the
    /// event queue.
    pub fn run(mut self) -> anyhow::Result<AsyncRunLog> {
        let n = self.nodes.len();
        // t=0 prologue: every node starts its first local update, in
        // node order (deterministic rng draw order)
        for i in 0..n {
            if !self.sub.is_offline(i) {
                self.start_compute(i, 0)?;
            }
        }
        while let Some((t, ev)) = self.queue.pop() {
            match ev {
                AEv::ComputeDone { node, gen } => {
                    fold_event(&mut self.digest, t, 1, node as u64);
                    self.on_compute_done(node, gen, t)?;
                }
                AEv::Arrive { to, from, round, payload } => {
                    fold_event(&mut self.digest, t, 2, to as u64);
                    self.on_arrive(to, from, round, &payload, t)?;
                }
                AEv::QuorumTimeout { node, epoch } => {
                    fold_event(&mut self.digest, t, 3, node as u64);
                    self.on_timeout(node, epoch, t)?;
                }
                AEv::Recheck { node, epoch } => {
                    fold_event(&mut self.digest, t, 4, node as u64);
                    if self.nodes[node].epoch == epoch
                        && self.nodes[node].phase == Phase::Waiting
                        && !self.sub.is_offline(node)
                    {
                        self.try_mix(node, t)?;
                    }
                }
            }
        }
        // flush any remaining watermark records at the final clock
        let t_end = self.queue.now();
        self.maybe_eval(t_end)?;
        if let Some(sink) = self.node_sink.take() {
            sink.finish()?;
        }
        let events = self.queue.processed();
        Ok(AsyncRunLog {
            merged: self.merged,
            nodes: self.node_records,
            event_digest: self.digest,
            events,
            messages_lost: self.messages_lost,
            forced_mixes: self.forced_mixes,
            stragglers: self.stragglers,
            wire_bytes: self.wire_acc,
            link_bytes: self.link_bytes,
            fabric_link_bytes: self.sub.bytes_on_wire(),
        })
    }

    /// Begin node `i`'s next local round at virtual time `now`: draw
    /// its τ-step duration on the node's own compute model and schedule
    /// the completion. The steps themselves run at ComputeDone, so a
    /// node parked mid-compute has mutated nothing and restarts its
    /// round cleanly, and watermark evaluations never see compute that
    /// nominally finishes in the virtual future.
    fn start_compute(
        &mut self,
        i: usize,
        now: VirtualTime,
    ) -> anyhow::Result<()> {
        let gen = {
            let node = &mut self.nodes[i];
            node.phase = Phase::Computing;
            node.gen += 1;
            node.gen
        };
        let (dur, straggled) = self.sub.local_update_ns(i, self.cfg.tau);
        self.stragglers += u64::from(straggled);
        self.queue
            .schedule(now + dur, AEv::ComputeDone { node: i, gen });
        // the interval is fully known at schedule time, so the virtual
        // span can be recorded here (observation only, after the draw)
        crate::obs::vspan("compute", i, now, now + dur);
        Ok(())
    }

    /// Node `i` finished its local round: run the τ SGD steps, the
    /// adaptive level update (keyed to the node's own round count),
    /// quantize the differential, broadcast it, and try to mix.
    fn on_compute_done(
        &mut self,
        i: usize,
        gen: u64,
        t: VirtualTime,
    ) -> anyhow::Result<()> {
        if self.nodes[i].gen != gen
            || self.nodes[i].phase != Phase::Computing
        {
            return Ok(()); // superseded (parked / restarted)
        }
        if self.sub.is_offline(i) {
            // nothing ran yet (steps execute below): a clean park
            self.nodes[i].phase = Phase::Parked;
            self.nodes[i].parked_waiting = false;
            return Ok(());
        }
        let lr = self.cfg.lr.at(self.nodes[i].round) as f32;
        let (payload, wire_bytes, paper_bits, round) = {
            let node = &mut self.nodes[i];
            let backend = self.backends[i].as_mut();
            let loss = node.core.local_steps(
                backend,
                &self.dataset,
                self.cfg.tau,
                self.cfg.batch_size,
                lr,
            )?;
            node.pending_loss = loss;
            node.core.observe_local_loss(loss);
            // one shared dispatch point with the sync engine (round
            // key = the node's LOCAL round here, phase always 0)
            let st = node.core.broadcast_delta(
                self.cfg.encoding,
                node.round as u32,
                0,
                i as u32,
            )?;
            // in-flight copy either way: receivers reconstruct this
            // exact broadcast, keeping their estimate column equal to
            // the sender's x̂ (absent drops). The bitstream path ships
            // the encoded wire frame itself; the sender's own estimate
            // already advanced from a decode of those same bytes
            let payload = match self.cfg.encoding {
                WireEncoding::Matrix => {
                    Payload::Delta(Arc::from(&node.core.dq[..]))
                }
                WireEncoding::Bitstream => {
                    Payload::Wire(Arc::from(node.core.enc.as_slice()))
                }
            };
            node.last_distortion = st.distortion;
            node.last_wire_bytes = st.wire_bytes;
            (payload, st.wire_bytes, st.paper_bits, node.round)
        };
        self.bits_acc += paper_bits;
        self.wire_acc += wire_bytes;
        for idx in 0..self.nodes[i].nbrs.len() {
            let j = self.nodes[i].nbrs[idx];
            match self.sub.transmit_on(i, j, t, wire_bytes) {
                None => {} // no link / link down / receiver offline
                Some((_, true)) => {
                    // transmitted then lost in flight: the copy still
                    // occupied the link, so it still counts
                    self.messages_lost += 1;
                    self.link_bytes += wire_bytes;
                    crate::obs::counter("sim_messages_lost", "total", 1);
                }
                Some((arrive, false)) => {
                    self.link_bytes += wire_bytes;
                    self.queue.schedule(
                        arrive,
                        AEv::Arrive {
                            to: j,
                            from: i,
                            round,
                            payload: payload.clone(),
                        },
                    );
                }
            }
        }
        {
            let node = &mut self.nodes[i];
            node.phase = Phase::Waiting;
            node.wait_start = t;
        }
        self.try_mix(i, t)
    }

    /// A quantized delta from `from` lands at `to`: apply it to the
    /// receiver's estimate column (durable mailbox — applied even while
    /// the receiver is offline) and re-check the quorum. Wire payloads
    /// are reconstructed exclusively from the received bytes; malformed
    /// frames or headers contradicting the link metadata are errors.
    fn on_arrive(
        &mut self,
        to: usize,
        from: usize,
        round: usize,
        payload: &Payload,
        t: VirtualTime,
    ) -> anyhow::Result<()> {
        {
            let node = &mut self.nodes[to];
            let Some(idx) = node.nbrs.iter().position(|&x| x == from)
            else {
                return Ok(());
            };
            match payload {
                Payload::Delta(delta) => {
                    crate::quant::kernels::add_assign(
                        &mut node.nbr_hat[idx],
                        delta,
                    );
                }
                Payload::Wire(bytes) => {
                    let h = wire::decode_into(
                        bytes,
                        &mut node.core.implied,
                        &mut node.core.dec,
                    )
                    .map_err(|e| {
                        anyhow::anyhow!(
                            "node {to}: bad wire message from {from}: {e}"
                        )
                    })?;
                    // typed decode-total error on a header/event
                    // mismatch (the phase check is vacuous here: the
                    // header's own phase is passed through)
                    wire::validate_frame(&h, from, round as u32, h.phase)
                        .map_err(|e| {
                            anyhow::anyhow!("node {to}: {e}")
                        })?;
                    node.core
                        .dec
                        .dequantize_accumulate_into(&mut node.nbr_hat[idx]);
                }
            }
            node.heard[idx] = true;
            // the message carries the sender's actual round count, so
            // drops never let the Staleness policy's view of a neighbor
            // fall permanently behind
            node.sender_round[idx] = node.sender_round[idx].max(round + 1);
            node.last_arrival_round[idx] = node.round;
            node.fresh[idx] = true;
        }
        if self.nodes[to].phase == Phase::Waiting
            && !self.sub.is_offline(to)
        {
            self.try_mix(to, t)?;
        }
        Ok(())
    }

    /// The quorum timer for a still-waiting node fired: mix with
    /// whatever is fresh (staleness weighting discounts the rest).
    fn on_timeout(
        &mut self,
        i: usize,
        epoch: u64,
        t: VirtualTime,
    ) -> anyhow::Result<()> {
        if self.nodes[i].epoch != epoch
            || self.nodes[i].phase != Phase::Waiting
            || self.sub.is_offline(i)
        {
            return Ok(()); // superseded
        }
        self.mix(i, t, true)
    }

    /// Whether node `i`'s wait policy is satisfied right now. A fresh
    /// delta already in hand counts toward the quorum even if its
    /// sender has since finished or churned away; waiting is only ever
    /// justified by neighbors that could still deliver (`pending`).
    fn quorum_satisfied(&self, i: usize) -> bool {
        let node = &self.nodes[i];
        // fresh deltas in hand (any sender)
        let mut fresh_total = 0usize;
        // not-yet-fresh neighbors that can still send: online,
        // unfinished, j→i link up
        let mut pending = 0usize;
        let mut stale_ok = true;
        for (idx, &j) in node.nbrs.iter().enumerate() {
            if node.fresh[idx] {
                fresh_total += 1;
                continue;
            }
            let can_send = !self.sub.is_offline(j)
                && self.nodes[j].phase != Phase::Finished
                && self.sub.link_up(j, i);
            if !can_send {
                continue;
            }
            pending += 1;
            if let WaitPolicy::Staleness { tau } = self.acfg.wait_for {
                let behind = (node.round + 1)
                    .saturating_sub(node.sender_round[idx]);
                if behind > tau {
                    stale_ok = false;
                }
            }
        }
        match self.acfg.wait_for {
            WaitPolicy::All => pending == 0,
            WaitPolicy::Quorum { k } => {
                fresh_total >= k || pending == 0
            }
            WaitPolicy::Staleness { .. } => stale_ok,
        }
    }

    /// Mix if the quorum allows; otherwise arm the (one-shot per epoch)
    /// quorum timer.
    fn try_mix(&mut self, i: usize, t: VirtualTime) -> anyhow::Result<()> {
        if self.nodes[i].phase != Phase::Waiting {
            return Ok(());
        }
        if !self.quorum_satisfied(i) {
            let node = &mut self.nodes[i];
            if !node.timer_armed {
                node.timer_armed = true;
                self.queue.schedule(
                    t + self.timeout_ns,
                    AEv::QuorumTimeout { node: i, epoch: node.epoch },
                );
            }
            return Ok(());
        }
        self.mix(i, t, false)
    }

    /// Node `i` mixes: staleness-weighted Metropolis row over the live
    /// graph, CHOCO-style consensus correction on the true params, then
    /// the next local round (or Finished).
    fn mix(
        &mut self,
        i: usize,
        t: VirtualTime,
        forced: bool,
    ) -> anyhow::Result<()> {
        let (self_w, w, stale_sum, fresh_count) = {
            let node = &self.nodes[i];
            let mut stale = Vec::with_capacity(node.nbrs.len());
            for idx in 0..node.nbrs.len() {
                // a neighbor we never heard from carries no weight for
                // ANY λ (its estimate column is still the zero vector —
                // averaging with it would pull params toward zero)
                let s = if node.heard[idx] {
                    (node.round - node.last_arrival_round[idx]) as u64
                } else {
                    weights::NEVER
                };
                stale.push(s);
            }
            let (self_w, w) = weights::staleness_row(
                &self.topology.sparse,
                i,
                &node.nbrs,
                &stale,
                self.acfg.staleness_lambda,
            );
            // reporting only: clamp the NEVER sentinel so the mean
            // stays a readable "rounds behind" figure
            let stale_sum: u64 = stale.iter().map(|&s| s.min(64)).sum();
            let fresh_count =
                node.fresh.iter().filter(|&&f| f).count();
            (self_w, w, stale_sum, fresh_count)
        };
        {
            // x_i += (Σ_j w_ij x̂_j + w_ii x̂_i) − x̂_i — consensus
            // correction on the true params, so stale estimate error
            // can never erase local SGD progress (same rationale as the
            // synchronous engine's Eq. 21 form)
            let mixing = self.cfg.mixing;
            let scratch = &mut self.mix_scratch;
            let node = &mut self.nodes[i];
            if mixing.is_plain() {
                crate::quant::kernels::scaled_into(
                    scratch,
                    self_w as f32,
                    &node.core.hat,
                );
                for (idx, &wj) in w.iter().enumerate() {
                    if wj == 0.0 {
                        continue;
                    }
                    crate::quant::kernels::axpy(
                        scratch,
                        wj as f32,
                        &node.nbr_hat[idx],
                    );
                }
            } else {
                // robust row over the staleness-weighted live columns
                // (zero-weight neighbors — never heard, or churned out
                // of the Metropolis row — are not candidates)
                let mut nbrs: Vec<(&[f32], f64)> =
                    Vec::with_capacity(w.len());
                for (idx, &wj) in w.iter().enumerate() {
                    if wj != 0.0 {
                        nbrs.push((node.nbr_hat[idx].as_slice(), wj));
                    }
                }
                let drops = crate::topology::robust_mix_into(
                    scratch,
                    &node.core.hat,
                    self_w,
                    &nbrs,
                    &mixing,
                );
                if drops > 0 {
                    crate::obs::counter("trimmed_drops", "async", drops);
                }
            }
            crate::quant::kernels::add_delta(
                &mut node.core.params,
                scratch,
                &node.core.hat,
            );
            let deg = node.nbrs.len();
            let rec = NodeRecord {
                node: i,
                round: node.round + 1,
                virtual_secs: ns_to_secs(t),
                local_loss: node.pending_loss,
                levels: node.core.quantizer.levels(),
                fresh_neighbors: fresh_count,
                stale_mean: if deg > 0 {
                    stale_sum as f64 / deg as f64
                } else {
                    0.0
                },
                forced,
                wire_bytes: node.last_wire_bytes,
            };
            if let Some(sink) = self.node_sink.as_mut() {
                sink.push(&rec.to_json())?;
            } else {
                self.node_records.push(rec);
            }
            node.round += 1;
            node.epoch += 1;
            node.timer_armed = false;
            node.fresh.iter_mut().for_each(|f| *f = false);
            self.quorum_wait_ns += t - node.wait_start;
            crate::obs::vspan("wait", i, node.wait_start, t);
            crate::obs::hist("quorum_fill_ns", t - node.wait_start);
        }
        self.total_mixes += 1;
        self.forced_mixes += u64::from(forced);
        if forced {
            crate::obs::counter("forced_mix", "total", 1);
        }
        // next round, or done — decided BEFORE churn/eval so nested
        // wakeups never see this node in a stale Waiting phase
        if self.nodes[i].round < self.cfg.rounds {
            if self.sub.is_offline(i) {
                self.nodes[i].phase = Phase::Parked;
                self.nodes[i].parked_waiting = false;
            } else {
                self.start_compute(i, t)?;
            }
        } else {
            self.nodes[i].phase = Phase::Finished;
            // neighbors waiting on this node have a smaller quorum now
            self.wake_neighbors(i, t);
        }
        self.maybe_churn(t)?;
        self.maybe_eval(t)?;
        Ok(())
    }

    /// Schedule a quorum re-check for every Waiting neighbor of `i`
    /// (zero-delay events, not recursion — see [`AEv::Recheck`]).
    fn wake_neighbors(&mut self, i: usize, t: VirtualTime) {
        for idx in 0..self.nodes[i].nbrs.len() {
            let j = self.nodes[i].nbrs[idx];
            if self.nodes[j].phase == Phase::Waiting
                && !self.sub.is_offline(j)
            {
                let epoch = self.nodes[j].epoch;
                self.queue
                    .schedule(t, AEv::Recheck { node: j, epoch });
            }
        }
    }

    /// Aggregate-progress churn epochs: the synchronous fabric re-draws
    /// faults every `interval_rounds` global rounds; the async engine
    /// re-keys that to every `interval_rounds × n` completed mixes —
    /// the same expected cadence, deterministic in event order.
    fn maybe_churn(&mut self, t: VirtualTime) -> anyhow::Result<()> {
        let interval = match &self.cfg.network {
            Some(net) if net.churn.enabled() => net.churn.interval_rounds,
            _ => return Ok(()),
        };
        let n = self.nodes.len();
        let epoch_size = (interval * n) as u64;
        while self.total_mixes
            >= (self.churn_epochs as u64 + 1) * epoch_size
        {
            self.churn_epochs += 1;
            let k = self.churn_epochs * interval;
            let Some(topo) = self.sub.pre_round(k) else {
                continue;
            };
            self.topology = topo;
            for i in 0..n {
                if self.sub.is_offline(i) {
                    let node = &mut self.nodes[i];
                    if node.phase != Phase::Finished
                        && node.phase != Phase::Parked
                    {
                        // park: cancel the in-flight compute/timer. A
                        // Computing node has mutated nothing (steps run
                        // at ComputeDone) and restarts its round on
                        // return; a Waiting node's broadcast is already
                        // out, so it resumes waiting instead
                        node.parked_waiting =
                            node.phase == Phase::Waiting;
                        if node.parked_waiting {
                            // bank the online wait accrued so far
                            self.quorum_wait_ns += t - node.wait_start;
                        }
                        node.phase = Phase::Parked;
                        node.gen += 1;
                        node.epoch += 1;
                        node.timer_armed = false;
                    }
                } else if self.nodes[i].phase == Phase::Parked {
                    if self.nodes[i].round >= self.cfg.rounds {
                        self.nodes[i].phase = Phase::Finished;
                    } else if self.nodes[i].parked_waiting {
                        let node = &mut self.nodes[i];
                        node.parked_waiting = false;
                        node.phase = Phase::Waiting;
                        // don't bill offline time as quorum wait
                        node.wait_start = t;
                        let epoch = node.epoch;
                        self.queue
                            .schedule(t, AEv::Recheck { node: i, epoch });
                    } else {
                        self.start_compute(i, t)?;
                    }
                }
            }
            // link/offline changes alter every quorum: schedule a
            // re-check for all waiting nodes (node order, so the
            // zero-delay events pop deterministically)
            for i in 0..n {
                if self.nodes[i].phase == Phase::Waiting
                    && !self.sub.is_offline(i)
                {
                    let epoch = self.nodes[i].epoch;
                    self.queue
                        .schedule(t, AEv::Recheck { node: i, epoch });
                }
            }
        }
        Ok(())
    }

    /// Advance the global-round watermark: once every participating
    /// node completed local round k, emit the merged `RoundRecord` for
    /// k at the current clock (the virtual time the *slowest* node
    /// crossed k — the honest async analog of the sync round row).
    fn maybe_eval(&mut self, t: VirtualTime) -> anyhow::Result<()> {
        let min_round = self
            .nodes
            .iter()
            .filter(|nd| nd.phase != Phase::Parked)
            .map(|nd| nd.round)
            .min()
            .unwrap_or(self.eval_round);
        // params don't change while the watermark loop runs, so one
        // evaluation serves every record emitted at this instant
        let mut cached: Option<(f64, f64)> = None;
        while self.eval_round < min_round {
            let k = self.eval_round;
            let (loss, acc) = if k % self.cfg.eval_every == 0 {
                match cached {
                    Some(v) => v,
                    None => {
                        let v = self.evaluate_global()?;
                        cached = Some(v);
                        v
                    }
                }
            } else {
                (f64::NAN, f64::NAN)
            };
            let n = self.nodes.len();
            let levels = self
                .nodes
                .iter()
                .map(|nd| nd.core.quantizer.levels())
                .sum::<usize>()
                / n;
            let distortion = self
                .nodes
                .iter()
                .map(|nd| nd.last_distortion)
                .sum::<f64>()
                / n as f64;
            self.merged.push(RoundRecord {
                round: k + 1,
                loss,
                accuracy: acc,
                bits_per_link: self.bits_acc / n as u64,
                distortion,
                levels,
                lr: self.cfg.lr.at(k),
                wall_secs: self.timer.elapsed_secs(),
                virtual_secs: ns_to_secs(t),
                // no straggler barrier in async mode: report the mean
                // quorum wait instead (same "time lost coordinating"
                // semantics)
                straggler_wait_secs: if self.total_mixes > 0 {
                    ns_to_secs(self.quorum_wait_ns)
                        / self.total_mixes as f64
                } else {
                    0.0
                },
                // measured per-copy bytes on links at this watermark:
                // the substrate meter, same truth run_simulated reports
                wire_bytes: self.sub.bytes_on_wire(),
            });
            self.eval_round += 1;
        }
        Ok(())
    }

    /// Global train loss + test accuracy of the averaged model, sharded
    /// across the worker pool (bit-identical for any parallelism).
    fn evaluate_global(&mut self) -> anyhow::Result<(f64, f64)> {
        let u = core::average_params(
            self.nodes.iter().map(|n| n.core.params.as_slice()),
            self.param_count,
        );
        let feat = self.dataset.feat_dim;
        let train_n = self.dataset.train_n().min(self.eval_train_cap);
        let (loss_sum, _) = core::evaluate_sharded(
            &self.pool,
            &mut self.backends,
            feat,
            &u,
            &self.dataset.train_x[..train_n * feat],
            &self.dataset.train_y[..train_n],
        )?;
        let loss = if train_n > 0 {
            loss_sum / train_n as f64
        } else {
            f64::NAN
        };
        let test_n = self.dataset.test_n().min(self.eval_test_cap);
        let acc = if test_n > 0 {
            let (_, correct) = core::evaluate_sharded(
                &self.pool,
                &mut self.backends,
                feat,
                &u,
                &self.dataset.test_x[..test_n * feat],
                &self.dataset.test_y[..test_n],
            )?;
            correct as f64 / test_n as f64
        } else {
            f64::NAN
        };
        Ok((loss, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agossip::WaitPolicy;
    use crate::config::{
        BackendKind, DatasetKind, EngineMode, QuantizerKind, TopologyKind,
        WireEncoding,
    };
    use crate::simnet::{ComputeModel, LinkModel, NetworkConfig};

    fn async_cfg(quant: QuantizerKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "agossip-test".into();
        cfg.seed = 11;
        cfg.nodes = 8;
        cfg.tau = 2;
        cfg.rounds = 10;
        cfg.batch_size = 16;
        cfg.lr = crate::config::LrSchedule::fixed(0.1);
        cfg.topology = TopologyKind::Torus;
        cfg.quantizer = quant;
        cfg.dataset = DatasetKind::Blobs {
            train: 240,
            test: 80,
            dim: 8,
            classes: 3,
        };
        cfg.backend = BackendKind::RustMlp { hidden: vec![16] };
        cfg.mode = EngineMode::Async;
        cfg.network = Some(NetworkConfig {
            link: LinkModel {
                latency_s: 0.001,
                bandwidth_bps: 2e6,
                jitter_s: 0.0,
                drop_prob: 0.0,
            },
            link_hetero_spread: 0.3,
            compute: ComputeModel {
                base_step_s: 1e-3,
                hetero_spread: 0.5,
                straggler_prob: 0.2,
                straggler_slowdown: 6.0,
            },
            churn: Default::default(),
        });
        cfg.agossip = Some(crate::agossip::AsyncConfig {
            wait_for: WaitPolicy::Quorum { k: 2 },
            staleness_lambda: 0.5,
            quorum_timeout_s: 0.5,
        });
        cfg
    }

    fn run(cfg: &ExperimentConfig) -> AsyncRunLog {
        AsyncGossipEngine::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn completes_all_rounds_and_learns() {
        let cfg =
            async_cfg(QuantizerKind::LloydMax { s: 16, iters: 8 });
        let log = run(&cfg);
        // every node completed every local round
        assert_eq!(
            log.nodes.len(),
            cfg.nodes * cfg.rounds,
            "missing node records"
        );
        // merged log covers the full watermark
        assert_eq!(log.merged.records.len(), cfg.rounds);
        let first = log.merged.records.first().unwrap().loss;
        let last = log.merged.records.last().unwrap().loss;
        assert!(
            last < first,
            "async engine did not learn: {first} -> {last}"
        );
        assert!(log.events > 0);
    }

    #[test]
    fn virtual_clock_is_monotone_per_node_and_merged() {
        let cfg = async_cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&cfg);
        let mut per_node = vec![0.0f64; cfg.nodes];
        for r in &log.nodes {
            assert!(
                r.virtual_secs >= per_node[r.node],
                "node {} clock went backwards",
                r.node
            );
            per_node[r.node] = r.virtual_secs;
        }
        let mut prev = 0.0;
        for r in &log.merged.records {
            assert!(r.virtual_secs >= prev, "merged clock not monotone");
            prev = r.virtual_secs;
        }
    }

    #[test]
    fn all_policies_terminate() {
        for wait_for in [
            WaitPolicy::All,
            WaitPolicy::Quorum { k: 4 },
            WaitPolicy::Staleness { tau: 2 },
        ] {
            let mut cfg =
                async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
            cfg.rounds = 5;
            cfg.agossip.as_mut().unwrap().wait_for = wait_for;
            let log = run(&cfg);
            assert_eq!(
                log.nodes.len(),
                cfg.nodes * cfg.rounds,
                "{wait_for:?} stalled"
            );
        }
    }

    #[test]
    fn replay_is_bit_identical() {
        let cfg = async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.event_digest, b.event_digest);
        assert_eq!(a.events, b.events);
        assert_eq!(a.nodes, b.nodes);
        for (x, y) in a.merged.records.iter().zip(&b.merged.records) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.virtual_secs.to_bits(), y.virtual_secs.to_bits());
            assert_eq!(x.bits_per_link, y.bits_per_link);
        }
    }

    #[test]
    fn doubly_adaptive_levels_ascend_per_node() {
        let cfg = async_cfg(QuantizerKind::DoublyAdaptive {
            s1: 4,
            iters: 6,
            s_max: 256,
        });
        let log = run(&cfg);
        let mut last = vec![0usize; cfg.nodes];
        for r in &log.nodes {
            assert!(
                r.levels >= last[r.node],
                "node {} levels dipped: {} -> {}",
                r.node,
                last[r.node],
                r.levels
            );
            last[r.node] = r.levels;
        }
        // the schedule starts at s1 and only ascends; by the first
        // watermark the mean is at least s1
        assert!(log.merged.records.first().unwrap().levels >= 4);
    }

    #[test]
    fn matrix_and_bitstream_encodings_bit_identical_async() {
        // in-module smoke for the async half of the encoding parity
        // contract (the full harsh-network version lives in
        // rust/tests/simnet_determinism.rs)
        let mut cfg =
            async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 6;
        cfg.encoding = WireEncoding::Matrix;
        let m = run(&cfg);
        cfg.encoding = WireEncoding::Bitstream;
        let b = run(&cfg);
        assert_eq!(m.event_digest, b.event_digest);
        assert_eq!(m.events, b.events);
        assert_eq!(m.nodes, b.nodes);
        assert_eq!(m.wire_bytes, b.wire_bytes);
        assert_eq!(m.link_bytes, b.link_bytes);
        for (x, y) in m.merged.records.iter().zip(&b.merged.records) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.wire_bytes, y.wire_bytes);
        }
    }

    #[test]
    fn wire_byte_meters_agree() {
        let cfg = async_cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&cfg);
        // engine-side per-copy count == the substrate's independent
        // meter, byte for byte
        assert_eq!(log.link_bytes, log.fabric_link_bytes);
        assert!(log.wire_bytes > 0);
        // without churn every broadcast yields exactly one mix record
        let per_record: u64 =
            log.nodes.iter().map(|r| r.wire_bytes).sum();
        assert_eq!(per_record, log.wire_bytes);
        // merged rows carry the cumulative fabric meter
        let mut prev = 0u64;
        for r in &log.merged.records {
            assert!(r.wire_bytes >= prev);
            prev = r.wire_bytes;
        }
        assert!(prev <= log.fabric_link_bytes);
    }

    #[test]
    fn robust_mixing_async_completes_and_replays() {
        for mixing in [
            crate::config::MixingKind::Trimmed { f: 1 },
            crate::config::MixingKind::Median,
        ] {
            let mut cfg =
                async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
            cfg.rounds = 5;
            cfg.mixing = mixing;
            let a = run(&cfg);
            let b = run(&cfg);
            assert_eq!(
                a.nodes.len(),
                cfg.nodes * cfg.rounds,
                "{mixing:?} stalled"
            );
            assert_eq!(a.event_digest, b.event_digest, "{mixing:?}");
            assert_eq!(a.nodes, b.nodes, "{mixing:?} not replayable");
        }
    }

    #[test]
    fn attacked_async_run_replays_bitwise() {
        let mut cfg =
            async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 5;
        cfg.attack = Some(crate::config::AttackConfig {
            kind: crate::config::AttackKind::Random,
            f: 2,
        });
        cfg.mixing = crate::config::MixingKind::Trimmed { f: 1 };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.event_digest, b.event_digest);
        assert_eq!(a.nodes, b.nodes);
        for (x, y) in a.merged.records.iter().zip(&b.merged.records) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn drops_and_timeouts_still_terminate() {
        let mut cfg =
            async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 6;
        cfg.network.as_mut().unwrap().link.drop_prob = 0.3;
        cfg.agossip.as_mut().unwrap().wait_for = WaitPolicy::All;
        cfg.agossip.as_mut().unwrap().quorum_timeout_s = 0.05;
        let log = run(&cfg);
        assert_eq!(log.nodes.len(), cfg.nodes * cfg.rounds);
        assert!(log.messages_lost > 0, "drops never fired");
    }

    #[test]
    fn churn_run_terminates_and_records() {
        let mut cfg =
            async_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 8;
        cfg.network.as_mut().unwrap().churn =
            crate::simnet::ChurnConfig {
                interval_rounds: 2,
                link_fail_prob: 0.3,
                link_heal_prob: 0.5,
                node_leave_prob: 0.15,
                node_return_prob: 0.6,
            };
        let log = run(&cfg);
        // node records exist for every node; the merged watermark may
        // stop early if a node is parked at drain time
        assert!(!log.nodes.is_empty());
        assert!(!log.merged.records.is_empty());
        let mut prev = 0.0;
        for r in &log.merged.records {
            assert!(r.virtual_secs >= prev);
            prev = r.virtual_secs;
        }
    }
}
