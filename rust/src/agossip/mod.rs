//! agossip — asynchronous event-driven gossip DFL on the simnet
//! virtual clock.
//!
//! The paper analyzes LM-DFL / doubly-adaptive DFL under a synchronous
//! round barrier: every node waits for the slowest node (and the
//! slowest message) before mixing. On a heterogeneous fabric with
//! transient stragglers that barrier wastes exactly the virtual time
//! the quantizers are trying to save — Liu, Chen & Zhang
//! ("Decentralized Federated Learning: Balancing Communication and
//! Computing Costs") show the communication/computation trade-off is
//! governed by *when* nodes exchange, not just how many bits. This
//! subsystem removes the barrier: each node is a state machine driven
//! directly by [`crate::simnet`] events —
//!
//! 1. it runs its τ local SGD steps as soon as its *own* compute
//!    finishes (heterogeneous [`crate::simnet::ComputeModel`] timing);
//! 2. it quantizes its differential with the exact
//!    [`crate::quant::Quantizer`] stack the synchronous engine uses
//!    (LM-DFL level refits and doubly-adaptive schedules re-keyed to
//!    the node's *local* step count) and broadcasts it to its one-hop
//!    neighbors over the per-link [`crate::simnet::LinkModel`]s;
//! 3. it mixes as soon as a configurable neighborhood quorum of fresh
//!    neighbor messages has arrived — [`WaitPolicy::All`] (neighborhood
//!    barrier), [`WaitPolicy::Quorum`] (any k fresh neighbors), or
//!    [`WaitPolicy::Staleness`] (bounded-staleness progress) — with a
//!    per-node quorum timer as the deadlock-free fallback;
//! 4. the mixing weights are **staleness-weighted Metropolis** rows
//!    ([`weights::staleness_row`]): each neighbor's Metropolis weight
//!    is decayed by λ^staleness and the self-weight absorbs the
//!    remainder, so the row stays stochastic for every arrival order
//!    and the full matrix is doubly stochastic when everything is
//!    fresh (property-tested in [`weights`]).
//!
//! Determinism contract: identical seed + config ⇒ byte-identical
//! event digests, node records, and merged logs — the same contract as
//! the synchronous fabric, enforced by
//! `rust/tests/simnet_determinism.rs` (with and without churn).
//!
//! Configure with `mode: "async"` plus the optional `async:` section
//! of the experiment JSON, or `lmdfl train --mode async --async-*`.

pub mod engine;
pub mod weights;

pub use engine::{AsyncGossipEngine, AsyncRunLog, NodeRecord};

use crate::config::json::Json;
use crate::config::ConfigError;

/// When a node may mix after finishing its own local steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaitPolicy {
    /// every *eligible* neighbor (online, link up, not finished) has
    /// delivered a fresh message since the node's last mix — the
    /// neighborhood barrier (strictest; still no global barrier)
    All,
    /// at least `min(k, eligible)` neighbors delivered fresh messages
    Quorum { k: usize },
    /// proceed immediately unless more than `tau` local rounds ahead of
    /// the slowest eligible neighbor's last reported progress
    Staleness { tau: usize },
}

impl WaitPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            WaitPolicy::All => "all",
            WaitPolicy::Quorum { .. } => "quorum",
            WaitPolicy::Staleness { .. } => "staleness",
        }
    }
}

/// The `async:` config section: everything the asynchronous engine
/// needs beyond the shared `network:` fabric model.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncConfig {
    /// quorum policy gating each node's mix
    pub wait_for: WaitPolicy,
    /// staleness decay base λ of the mixing weights: a neighbor whose
    /// last message is `s` of my rounds old mixes with weight
    /// `c_ij · λ^s` (1.0 = no decay)
    pub staleness_lambda: f64,
    /// forced-mix timer: a quorum-blocked node mixes with whatever it
    /// has after this many virtual seconds (the deadlock-free fallback
    /// under drops / finished neighbors)
    pub quorum_timeout_s: f64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            wait_for: WaitPolicy::Quorum { k: 2 },
            staleness_lambda: 0.5,
            quorum_timeout_s: 1.0,
        }
    }
}

impl AsyncConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: &str| ConfigError(format!("async: {m}"));
        match self.wait_for {
            WaitPolicy::Quorum { k } if k == 0 => {
                return Err(err("quorum must be >= 1"));
            }
            WaitPolicy::Staleness { tau } if tau == 0 => {
                return Err(err("staleness must be >= 1"));
            }
            _ => {}
        }
        if !(self.staleness_lambda > 0.0 && self.staleness_lambda <= 1.0) {
            return Err(err("staleness_lambda must be in (0, 1]"));
        }
        if !(self.quorum_timeout_s > 0.0
            && self.quorum_timeout_s.is_finite())
        {
            return Err(err("quorum_timeout_s must be finite and > 0"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs =
            vec![("wait_for", Json::str(self.wait_for.name()))];
        match self.wait_for {
            WaitPolicy::Quorum { k } => {
                pairs.push(("quorum", Json::num(k as f64)));
            }
            WaitPolicy::Staleness { tau } => {
                pairs.push(("staleness", Json::num(tau as f64)));
            }
            WaitPolicy::All => {}
        }
        pairs.push((
            "staleness_lambda",
            Json::num(self.staleness_lambda),
        ));
        pairs.push(("quorum_timeout_s", Json::num(self.quorum_timeout_s)));
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let d = AsyncConfig::default();
        let wait_for = match j.get_str("wait_for") {
            // a bare count key selects the matching policy (same
            // contract as the CLI's --async-quorum / --async-staleness)
            None => match (j.get_usize("quorum"), j.get_usize("staleness"))
            {
                (Some(k), _) => WaitPolicy::Quorum { k },
                (None, Some(tau)) => WaitPolicy::Staleness { tau },
                (None, None) => d.wait_for,
            },
            Some("all") => WaitPolicy::All,
            Some("quorum") => WaitPolicy::Quorum {
                k: j.get_usize("quorum").unwrap_or(2),
            },
            Some("staleness") => WaitPolicy::Staleness {
                tau: j.get_usize("staleness").unwrap_or(2),
            },
            Some(other) => {
                return Err(ConfigError(format!(
                    "async: unknown wait_for '{other}' \
                     (have: all, quorum, staleness)"
                )));
            }
        };
        let cfg = AsyncConfig {
            wait_for,
            staleness_lambda: j
                .get_f64("staleness_lambda")
                .unwrap_or(d.staleness_lambda),
            quorum_timeout_s: j
                .get_f64("quorum_timeout_s")
                .unwrap_or(d.quorum_timeout_s),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        AsyncConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_all_policies() {
        for wait_for in [
            WaitPolicy::All,
            WaitPolicy::Quorum { k: 3 },
            WaitPolicy::Staleness { tau: 4 },
        ] {
            let cfg = AsyncConfig {
                wait_for,
                staleness_lambda: 0.8,
                quorum_timeout_s: 2.5,
            };
            let text = cfg.to_json().to_pretty();
            let parsed = Json::parse(&text).unwrap();
            let back = AsyncConfig::from_json(&parsed).unwrap();
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"wait_for": "all"}"#).unwrap();
        let cfg = AsyncConfig::from_json(&j).unwrap();
        assert_eq!(cfg.wait_for, WaitPolicy::All);
        assert_eq!(
            cfg.staleness_lambda,
            AsyncConfig::default().staleness_lambda
        );
    }

    #[test]
    fn bare_count_keys_select_their_policy() {
        let j = Json::parse(r#"{"quorum": 4}"#).unwrap();
        let cfg = AsyncConfig::from_json(&j).unwrap();
        assert_eq!(cfg.wait_for, WaitPolicy::Quorum { k: 4 });
        let j = Json::parse(r#"{"staleness": 3}"#).unwrap();
        let cfg = AsyncConfig::from_json(&j).unwrap();
        assert_eq!(cfg.wait_for, WaitPolicy::Staleness { tau: 3 });
    }

    #[test]
    fn invalid_fields_rejected() {
        let bad = [
            r#"{"wait_for": "quorum", "quorum": 0}"#,
            r#"{"wait_for": "staleness", "staleness": 0}"#,
            r#"{"staleness_lambda": 0.0}"#,
            r#"{"staleness_lambda": 1.5}"#,
            r#"{"quorum_timeout_s": 0.0}"#,
            r#"{"wait_for": "bogus"}"#,
        ];
        for text in bad {
            let j = Json::parse(text).unwrap();
            assert!(AsyncConfig::from_json(&j).is_err(), "{text}");
        }
    }
}
