//! Pure-Rust model substrate: an MLP with hand-derived gradients.
//!
//! Mirrors the L2 jax MLP (python/compile/model.py) on the same flat
//! parameter layout, so the DFL engine can run fast multi-config sweeps
//! without PJRT in the loop; the HLO backend (runtime::HloBackend) is the
//! production path and the integration tests assert the two agree.

pub mod mlp;

pub use mlp::MlpModel;

/// Numerically stable log-sum-exp over a logits row.
pub(crate) fn log_sum_exp(row: &[f32]) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let s: f32 = row.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Softmax cross-entropy loss and probability-space gradient for one row:
/// grad = softmax(logits) - onehot(y).
pub(crate) fn xent_row(
    logits: &[f32],
    y: usize,
    grad: &mut [f32],
) -> f32 {
    let lse = log_sum_exp(logits);
    for (g, &l) in grad.iter_mut().zip(logits) {
        *g = (l - lse).exp();
    }
    grad[y] -= 1.0;
    lse - logits[y]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_sum_exp_stable() {
        let row = [1000.0f32, 1000.0];
        let lse = log_sum_exp(&row);
        assert!((lse - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
    }

    #[test]
    fn xent_uniform_logits() {
        let logits = [0.0f32; 4];
        let mut grad = [0.0f32; 4];
        let loss = xent_row(&logits, 2, &mut grad);
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        assert!((grad[0] - 0.25).abs() < 1e-6);
        assert!((grad[2] + 0.75).abs() < 1e-6);
    }
}
