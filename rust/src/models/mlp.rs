//! MLP with hand-derived gradients over a flat parameter vector.
//!
//! Layout matches python/compile/model.py `mlp_spec`: per layer, W
//! (in×out, row-major) then b (out). ReLU hidden activations, linear
//! output, mean softmax cross-entropy — the exact computation the HLO
//! artifact `mlp_mnist_step` performs, reimplemented natively so sweeps
//! don't pay PJRT dispatch.

use super::xent_row;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct MlpModel {
    pub dims: Vec<usize>,
    /// (w_offset, b_offset) per layer into the flat vector
    offsets: Vec<(usize, usize)>,
    total: usize,
}

/// Reusable forward/backward scratch so the τ-step inner loop allocates
/// nothing (hot-path requirement; see DESIGN.md §Perf).
#[derive(Clone, Debug, Default)]
pub struct MlpScratch {
    /// activations per layer: a[0] = input batch, a[L] = logits
    acts: Vec<Vec<f32>>,
    /// gradient buffers per layer (same shapes as acts[1..])
    deltas: Vec<Vec<f32>>,
}

impl MlpModel {
    pub fn new(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        let mut offsets = Vec::new();
        let mut total = 0usize;
        for i in 0..dims.len() - 1 {
            offsets.push((total, total + dims[i] * dims[i + 1]));
            total += dims[i] * dims[i + 1] + dims[i + 1];
        }
        MlpModel { dims: dims.to_vec(), offsets, total }
    }

    pub fn param_count(&self) -> usize {
        self.total
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    pub fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// He-style init matching the jax models' N(0, 0.05) scale.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0.0f32; self.total];
        rng.fill_normal(&mut p, 0.0, 0.05);
        // zero biases
        for (l, &(_, b_off)) in self.offsets.iter().enumerate() {
            for v in &mut p[b_off..b_off + self.dims[l + 1]] {
                *v = 0.0;
            }
        }
        p
    }

    fn w<'a>(&self, params: &'a [f32], layer: usize) -> &'a [f32] {
        let (w_off, b_off) = self.offsets[layer];
        &params[w_off..b_off]
    }

    fn b<'a>(&self, params: &'a [f32], layer: usize) -> &'a [f32] {
        let (_, b_off) = self.offsets[layer];
        &params[b_off..b_off + self.dims[layer + 1]]
    }

    /// Forward pass on a batch. `x` is batch-major (batch × dims[0]).
    /// Fills `scratch.acts`; returns nothing (logits live in last act).
    fn forward(&self, params: &[f32], x: &[f32], batch: usize,
               scratch: &mut MlpScratch) {
        let nl = self.layers();
        scratch.acts.resize(nl + 1, Vec::new());
        scratch.acts[0].clear();
        scratch.acts[0].extend_from_slice(x);
        for l in 0..nl {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = self.w(params, l);
            let bias = self.b(params, l);
            // split_at_mut dance: read acts[l], write acts[l+1]
            let (head, tail) = scratch.acts.split_at_mut(l + 1);
            let input = &head[l];
            let out = &mut tail[0];
            out.clear();
            out.resize(batch * dout, 0.0);
            for bi in 0..batch {
                let xrow = &input[bi * din..(bi + 1) * din];
                let orow = &mut out[bi * dout..(bi + 1) * dout];
                orow.copy_from_slice(bias);
                for (i, &xi) in xrow.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let wrow = &w[i * dout..(i + 1) * dout];
                    for (o, &wij) in orow.iter_mut().zip(wrow) {
                        *o += xi * wij;
                    }
                }
                if l + 1 < nl {
                    for o in orow.iter_mut() {
                        if *o < 0.0 {
                            *o = 0.0; // ReLU
                        }
                    }
                }
            }
        }
    }

    /// Mean loss + gradient into `grad` (len = param_count). Returns loss.
    pub fn loss_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
        grad: &mut [f32],
        scratch: &mut MlpScratch,
    ) -> f64 {
        let batch = y.len();
        assert_eq!(x.len(), batch * self.dims[0]);
        assert_eq!(grad.len(), self.total);
        self.forward(params, x, batch, scratch);
        let nl = self.layers();
        scratch.deltas.resize(nl, Vec::new());
        grad.iter_mut().for_each(|g| *g = 0.0);

        // output delta: softmax - onehot, averaged over batch
        let classes = self.classes();
        let mut loss = 0.0f64;
        {
            let logits = &scratch.acts[nl];
            let delta = &mut scratch.deltas[nl - 1];
            delta.clear();
            delta.resize(batch * classes, 0.0);
            for bi in 0..batch {
                let lrow = &logits[bi * classes..(bi + 1) * classes];
                let drow = &mut delta[bi * classes..(bi + 1) * classes];
                loss += xent_row(lrow, y[bi] as usize, drow) as f64;
            }
        }
        loss /= batch as f64;
        let inv_b = 1.0 / batch as f32;

        // backprop layers top-down
        for l in (0..nl).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let (w_off, b_off) = self.offsets[l];
            // dW = a_l^T delta ; db = sum(delta)
            {
                let input = &scratch.acts[l];
                let delta = &scratch.deltas[l];
                let gw = &mut grad[w_off..b_off];
                for bi in 0..batch {
                    let xrow = &input[bi * din..(bi + 1) * din];
                    let drow = &delta[bi * dout..(bi + 1) * dout];
                    for (i, &xi) in xrow.iter().enumerate() {
                        if xi == 0.0 {
                            continue;
                        }
                        let gww = &mut gw[i * dout..(i + 1) * dout];
                        let scale = xi * inv_b;
                        for (g, &d) in gww.iter_mut().zip(drow) {
                            *g += scale * d;
                        }
                    }
                }
                let gb = &mut grad[b_off..b_off + dout];
                for bi in 0..batch {
                    let drow = &delta[bi * dout..(bi + 1) * dout];
                    for (g, &d) in gb.iter_mut().zip(drow) {
                        *g += inv_b * d;
                    }
                }
            }
            // delta_{l-1} = (delta_l W^T) ⊙ relu'(a_l)
            if l > 0 {
                let w = self.w(params, l);
                let (head, tail) = scratch.deltas.split_at_mut(l);
                let delta = &tail[0];
                let prev = &mut head[l - 1];
                prev.clear();
                prev.resize(batch * din, 0.0);
                let acts_l = &scratch.acts[l];
                for bi in 0..batch {
                    let drow = &delta[bi * dout..(bi + 1) * dout];
                    let prow = &mut prev[bi * din..(bi + 1) * din];
                    let arow = &acts_l[bi * din..(bi + 1) * din];
                    for i in 0..din {
                        if arow[i] <= 0.0 {
                            continue; // ReLU gate (also skips the matmul)
                        }
                        let wrow = &w[i * dout..(i + 1) * dout];
                        let mut acc = 0.0f32;
                        for (&wij, &d) in wrow.iter().zip(drow) {
                            acc += wij * d;
                        }
                        prow[i] = acc;
                    }
                }
            }
        }
        loss
    }

    /// One SGD step in place; returns the batch loss (pre-update).
    pub fn sgd_step(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[u32],
        lr: f32,
        grad: &mut [f32],
        scratch: &mut MlpScratch,
    ) -> f64 {
        let loss = self.loss_grad(params, x, y, grad, scratch);
        for (p, &g) in params.iter_mut().zip(grad.iter()) {
            *p -= lr * g;
        }
        loss
    }

    /// Mean loss + correct count on a labeled set.
    pub fn evaluate(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
    ) -> (f64, usize) {
        let batch = y.len();
        let mut scratch = MlpScratch::default();
        self.forward(params, x, batch, &mut scratch);
        let classes = self.classes();
        let logits = &scratch.acts[self.layers()];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut dump = vec![0.0f32; classes];
        for bi in 0..batch {
            let lrow = &logits[bi * classes..(bi + 1) * classes];
            loss += xent_row(lrow, y[bi] as usize, &mut dump) as f64;
            // first-max argmax (matches jnp.argmax tie-breaking)
            let mut pred = 0usize;
            for (c, &v) in lrow.iter().enumerate() {
                if v > lrow[pred] {
                    pred = c;
                }
            }
            if pred == y[bi] as usize {
                correct += 1;
            }
        }
        (loss / batch as f64, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn finite_diff_check(dims: &[usize], seed: u64) {
        let model = MlpModel::new(dims);
        let mut rng = Rng::new(seed);
        let params = model.init_params(&mut rng);
        let batch = 3;
        let x: Vec<f32> = (0..batch * dims[0])
            .map(|_| rng.normal() as f32)
            .collect();
        let y: Vec<u32> = (0..batch)
            .map(|_| rng.below(*dims.last().unwrap()) as u32)
            .collect();
        let mut grad = vec![0.0f32; model.param_count()];
        let mut scratch = MlpScratch::default();
        let base =
            model.loss_grad(&params, &x, &y, &mut grad, &mut scratch);
        // check a few random coordinates by central differences
        let eps = 1e-3f32;
        let mut dump = vec![0.0f32; model.param_count()];
        for _ in 0..12 {
            let k = rng.below(model.param_count());
            let mut pp = params.clone();
            pp[k] += eps;
            let lp = model.loss_grad(&pp, &x, &y, &mut dump, &mut scratch);
            pp[k] -= 2.0 * eps;
            let lm = model.loss_grad(&pp, &x, &y, &mut dump, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - grad[k] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "coord {k}: fd={fd} analytic={} (base loss {base})",
                grad[k]
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        finite_diff_check(&[5, 8, 3], 0);
        finite_diff_check(&[7, 4], 1); // logistic regression case
        finite_diff_check(&[6, 10, 10, 4], 2); // two hidden layers
    }

    #[test]
    fn param_count_matches_formula() {
        let m = MlpModel::new(&[784, 256, 128, 10]);
        assert_eq!(
            m.param_count(),
            784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
        );
    }

    #[test]
    fn training_reduces_loss_and_learns_blobs() {
        let data = crate::data::blobs::generate(300, 100, 8, 3, 5);
        let model = MlpModel::new(&[8, 16, 3]);
        let mut rng = Rng::new(7);
        let mut params = model.init_params(&mut rng);
        let mut grad = vec![0.0f32; model.param_count()];
        let mut scratch = MlpScratch::default();
        let mut sampler = crate::data::BatchSampler::new(
            (0..data.train_n()).collect(),
            Rng::new(8),
        );
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            let idx = sampler.next_batch(32);
            let (x, y) = data.gather_batch(&idx);
            last = model.sgd_step(
                &mut params, &x, &y, 0.1, &mut grad, &mut scratch);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() * 0.5, "{last} vs {first:?}");
        let (loss, correct) =
            model.evaluate(&params, &data.test_x, &data.test_y);
        assert!(loss < 0.5);
        assert!(correct as f64 / data.test_n() as f64 > 0.85);
    }

    #[test]
    fn evaluate_counts_match_manual_argmax() {
        let model = MlpModel::new(&[4, 3]);
        let params = vec![0.0f32; model.param_count()];
        // zero params → uniform logits → argmax = class 0
        let x = vec![1.0f32; 8];
        let y = vec![0u32, 1];
        let (_, correct) = model.evaluate(&params, &x, &y);
        assert_eq!(correct, 1);
    }

    #[test]
    fn prop_gradient_zero_at_uniform_when_labels_balanced() {
        // with zero params the logit gradient rows are softmax-uniform;
        // bias gradient for class c is (1/C - freq(c))·(-1)... just check
        // gradient is finite and loss = ln(C)
        check("mlp zero-params loss ln C", 20, |g| {
            let classes = g.usize_in(2..6);
            let din = g.usize_in(2..10);
            let model = MlpModel::new(&[din, classes]);
            let params = vec![0.0f32; model.param_count()];
            let batch = g.usize_in(1..8);
            let x: Vec<f32> =
                (0..batch * din).map(|_| g.f32_in(-1.0..1.0)).collect();
            let y: Vec<u32> = (0..batch)
                .map(|_| g.usize_in(0..classes) as u32)
                .collect();
            let mut grad = vec![0.0f32; model.param_count()];
            let mut scratch = MlpScratch::default();
            let loss = model.loss_grad(
                &params, &x, &y, &mut grad, &mut scratch);
            assert!((loss - (classes as f64).ln()).abs() < 1e-5);
            assert!(grad.iter().all(|g| g.is_finite()));
        });
    }
}
