//! The curated public surface of the crate.
//!
//! `use lmdfl::prelude::*;` brings in every type the examples, the CLI
//! and downstream experiment drivers are expected to touch: config
//! schema, the [`Trainer`] entry point, the transport layer
//! ([`Delivery`] and its implementations), quantizers, wire codec
//! types, metrics, the figure drivers, and the typed error
//! ([`LmdflError`]). Anything *not* re-exported here is an
//! implementation detail that may change between releases.

pub use crate::agossip::{AsyncConfig, WaitPolicy};
pub use crate::cli::Args;
pub use crate::config::{
    load_config, AttackConfig, AttackKind, BackendKind, ConfigError,
    DatasetKind, EngineMode, ExperimentConfig, LrSchedule, MixingKind,
    Parallelism, QuantizerKind, TopologyKind, WireEncoding,
};
pub use crate::dfl::{
    run_node_process, DflEngine, EngineOptions, LocalUpdate,
    NetOptions, RustMlpBackend, Trainer,
};
pub use crate::error::LmdflError;
pub use crate::linalg::eigen::alpha_of_zeta;
pub use crate::experiments::{
    fig4, fig6, fig7, fig8, fig_robust, fig_time, paper_base_config,
    paper_cifar_config, run_labeled, table1, Curve, Scale,
};
pub use crate::metrics::{fnum, RoundRecord, RunLog, Table};
pub use crate::net::{
    channel_mesh, connect_retry, ChannelDelivery, Delivery,
    FaultDelivery, Frame, Mailbox, TcpDelivery, TcpOptions,
    TransportConfig, TransportKind,
};
pub use crate::obs::{self, ObserveConfig, TRACE_SCHEMA};
pub use crate::quant::codec::CodecError;
pub use crate::quant::wire::{
    Envelope, QuantTag, WireHeader, WIRE_VERSION,
};
pub use crate::quant::{
    bits, build_quantizer, distortion, quantize_damped, AdaptiveLevels,
    AlqQuantizer, FullPrecision, LloydMaxQuantizer, NaturalQuantizer,
    QsgdQuantizer, QuantizedVector, Quantizer, TernGradQuantizer,
    TopKQuantizer,
};
pub use crate::runtime::{
    artifacts_available, artifacts_dir, literal_f32, literal_i32,
    HloBackend, HloExecutor, Manifest,
};
pub use crate::simnet::{LinkModel, NetworkConfig};
pub use crate::sweep::{
    self, AttackRegime, CellResult, Grid, NetRegime, SweepManifest,
    SweepOptions, SWEEP_SCHEMA,
};
pub use crate::topology::Topology;
pub use crate::util::rng::Rng;
pub use crate::xla;
