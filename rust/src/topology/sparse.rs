//! Sparse O(degree) confusion-matrix rows — the at-scale mixing state.
//!
//! A dense n×n `Matrix` for C is 800 MB at n = 10 000; the engines only
//! ever read *rows* of C restricted to a node's neighborhood (mixing,
//! staleness weighting, threaded-runtime weight tables), so this type
//! stores exactly that: one sorted `(col, weight)` row per node plus
//! the diagonal. It is THE mixing authority on every path — the dense
//! matrix survives only as a small-n bit-identity oracle on
//! [`crate::topology::Topology`].
//!
//! Bit-identity contract with the dense path (property-tested in
//! `util/proptest.rs` and relied on by the simnet digest tests):
//!
//! * [`SparseTopology::metropolis`] computes each edge weight with the
//!   same single expression as `metropolis_weights` and subtracts the
//!   diagonal remainder **in adjacency-list order** — the exact f64
//!   accumulation order of the dense builder — before sorting the
//!   stored row by column.
//! * [`SparseTopology::from_dense`] copies dense entries bitwise, so a
//!   small-n `Topology` carries identical weights in both forms.
//! * Row iteration is by ascending column, which matches the dense
//!   mixing loop's ascending-j traversal once the diagonal is merged
//!   at position i (see `DflEngine::round`).
//!
//! Churn rebuilds are incremental: only rows whose weights can have
//! changed (touched nodes and their one-hop neighborhoods) are
//! recomputed ([`SparseTopology::rebuild_rows`]); ζ is re-estimated by
//! deflated power iteration over the sparse matvec
//! ([`SparseTopology::zeta_power`]) instead of dense Jacobi.

use crate::linalg::power::{power_iteration_zeta, PowerBudget};
use crate::linalg::Matrix;

/// Per-node confusion-matrix rows: sorted neighbor `(col, weight)`
/// pairs + the diagonal. Memory is O(nodes + edges).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTopology {
    n: usize,
    /// off-diagonal row entries, sorted by column, zero weights omitted
    rows: Vec<Vec<(u32, f64)>>,
    /// diagonal (self) weight per node
    self_w: Vec<f64>,
}

impl SparseTopology {
    /// Metropolis–Hastings rows for an arbitrary graph. Identical f64
    /// results to the dense `metropolis_weights`: same per-edge weight
    /// expression, same adjacency-order diagonal subtraction.
    pub fn metropolis(adj: &[Vec<usize>]) -> SparseTopology {
        let n = adj.len();
        let mut rows = Vec::with_capacity(n);
        let mut self_w = Vec::with_capacity(n);
        for i in 0..n {
            let (row, diag) = metropolis_row(adj, i);
            rows.push(row);
            self_w.push(diag);
        }
        SparseTopology { n, rows, self_w }
    }

    /// Uniform ring averaging over {left, self, right} — the sparse
    /// form of the dense `ring_matrix` (same weights bitwise).
    pub fn ring(n: usize) -> SparseTopology {
        let mut rows = vec![Vec::new(); n];
        let mut self_w = vec![1.0; n];
        if n == 2 {
            rows[0].push((1, 0.5));
            rows[1].push((0, 0.5));
            self_w[0] = 0.5;
            self_w[1] = 0.5;
        } else if n >= 3 {
            let w = 1.0 / 3.0;
            for (i, row) in rows.iter_mut().enumerate() {
                let prev = (i + n - 1) % n;
                let next = (i + 1) % n;
                row.push((prev.min(next) as u32, w));
                row.push((prev.max(next) as u32, w));
                self_w[i] = w;
            }
        }
        SparseTopology { n, rows, self_w }
    }

    /// C = I — the disconnected network.
    pub fn identity(n: usize) -> SparseTopology {
        SparseTopology {
            n,
            rows: vec![Vec::new(); n],
            self_w: vec![1.0; n],
        }
    }

    /// C = J = 11ᵀ/n — fully mixed. O(n²) entries by nature; only the
    /// small-n `full` topology uses it.
    pub fn consensus(n: usize) -> SparseTopology {
        let w = 1.0 / n as f64;
        let rows = (0..n)
            .map(|i| {
                (0..n)
                    .filter(|&j| j != i)
                    .map(|j| (j as u32, w))
                    .collect()
            })
            .collect();
        SparseTopology { n, rows, self_w: vec![w; n] }
    }

    /// Bitwise import of a dense confusion matrix (nonzero off-diagonal
    /// entries + diagonal). The small-n oracle path builds the dense
    /// matrix first and derives its sparse twin through this.
    pub fn from_dense(c: &Matrix) -> SparseTopology {
        assert_eq!(c.rows, c.cols, "confusion matrix must be square");
        let n = c.rows;
        let mut rows = Vec::with_capacity(n);
        let mut self_w = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::new();
            for (j, &w) in c.row(i).iter().enumerate() {
                if j != i && w != 0.0 {
                    row.push((j as u32, w));
                }
            }
            rows.push(row);
            self_w.push(c[(i, i)]);
        }
        SparseTopology { n, rows, self_w }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Off-diagonal row of node i: `(col, weight)` sorted by column.
    #[inline]
    pub fn row(&self, i: usize) -> &[(u32, f64)] {
        &self.rows[i]
    }

    /// Diagonal (self) weight of node i.
    #[inline]
    pub fn self_weight(&self, i: usize) -> f64 {
        self.self_w[i]
    }

    /// c_ij (0.0 when i and j are not neighbors).
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.self_w[i];
        }
        match self.rows[i].binary_search_by_key(&(j as u32), |&(c, _)| c)
        {
            Ok(k) => self.rows[i][k].1,
            Err(_) => 0.0,
        }
    }

    /// Stored off-diagonal entries (== directed links with nonzero
    /// weight).
    pub fn stored_entries(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Recompute only the given rows against an updated adjacency.
    /// Callers must pass every node whose weights can have changed: a
    /// toggled edge or node changes degrees at its endpoints, which
    /// changes the incident weights of every *neighbor* too — so the
    /// dirty set is the touched nodes plus their one-hop neighborhoods
    /// under both the old and the new adjacency (see
    /// `simnet::churn`). Incremental-vs-full equivalence is tested
    /// below.
    pub fn rebuild_rows<I>(&mut self, adj: &[Vec<usize>], dirty: I)
    where
        I: IntoIterator<Item = usize>,
    {
        assert_eq!(adj.len(), self.n, "adjacency size changed");
        for i in dirty {
            let (row, diag) = metropolis_row(adj, i);
            self.rows[i] = row;
            self.self_w[i] = diag;
        }
    }

    /// ζ = max(|λ₂|, |λ_N|) by deflated power iteration over the
    /// sparse matvec — O(edges) per iteration, no dense matrix.
    pub fn zeta_power(&self, budget: PowerBudget) -> f64 {
        power_iteration_zeta(self.n, budget, |x, y| {
            for i in 0..self.n {
                let mut acc = self.self_w[i] * x[i];
                for &(j, w) in &self.rows[i] {
                    acc += w * x[j as usize];
                }
                y[i] = acc;
            }
        })
    }

    /// Render as a dense matrix (tests / small-n display only).
    pub fn to_dense(&self) -> Matrix {
        let mut c = Matrix::zeros(self.n, self.n);
        for i in 0..self.n {
            c[(i, i)] = self.self_w[i];
            for &(j, w) in &self.rows[i] {
                c[(i, j as usize)] = w;
            }
        }
        c
    }
}

/// One Metropolis row: the same arithmetic, in the same order, as one
/// iteration of the dense `metropolis_weights` loop. Returns the
/// sorted `(col, weight)` row and the diagonal remainder.
fn metropolis_row(
    adj: &[Vec<usize>],
    i: usize,
) -> (Vec<(u32, f64)>, f64) {
    let deg_i = adj[i].len();
    let mut diag = 1.0;
    let mut row = Vec::with_capacity(deg_i);
    for &j in &adj[i] {
        let w = 1.0 / (1 + deg_i.max(adj[j].len())) as f64;
        row.push((j as u32, w));
        diag -= w;
    }
    row.sort_unstable_by_key(|&(c, _)| c);
    (row, diag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::metropolis_weights;

    fn path3() -> Vec<Vec<usize>> {
        vec![vec![1], vec![0, 2], vec![1]]
    }

    #[test]
    fn metropolis_rows_bit_identical_to_dense() {
        // unsorted adjacency on purpose: the torus builder pushes
        // down/up/right/left, not ascending — edges 0-1, 0-2, 0-3,
        // 1-2, 2-3 with mixed degrees
        let adj = vec![
            vec![3, 1, 2],
            vec![0, 2],
            vec![1, 3, 0],
            vec![2, 0],
        ];
        let dense = metropolis_weights(&adj);
        let sp = SparseTopology::metropolis(&adj);
        for i in 0..adj.len() {
            assert_eq!(
                sp.self_weight(i).to_bits(),
                dense[(i, i)].to_bits(),
                "diag {i}"
            );
            for j in 0..adj.len() {
                assert_eq!(
                    sp.weight(i, j).to_bits(),
                    dense[(i, j)].to_bits(),
                    "entry ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn rows_are_sorted_by_column() {
        let adj = vec![vec![2, 1], vec![0, 2], vec![1, 0]];
        let sp = SparseTopology::metropolis(&adj);
        for i in 0..3 {
            let cols: Vec<u32> =
                sp.row(i).iter().map(|&(c, _)| c).collect();
            let mut sorted = cols.clone();
            sorted.sort_unstable();
            assert_eq!(cols, sorted, "row {i} unsorted");
        }
    }

    #[test]
    fn from_dense_roundtrips_bitwise() {
        let dense = metropolis_weights(&path3());
        let sp = SparseTopology::from_dense(&dense);
        let back = sp.to_dense();
        for (a, b) in dense.data.iter().zip(&back.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn weight_lookup_is_symmetric_and_sparse() {
        let sp = SparseTopology::metropolis(&path3());
        assert_eq!(sp.weight(0, 1).to_bits(), sp.weight(1, 0).to_bits());
        assert_eq!(sp.weight(0, 2), 0.0, "non-edge must read 0");
        assert_eq!(sp.stored_entries(), 4);
    }

    #[test]
    fn special_forms_match_their_dense_twins() {
        for n in [1usize, 2, 3, 4, 10] {
            let ring = SparseTopology::ring(n);
            let dense_ring = crate::topology::Topology::build(
                &crate::config::TopologyKind::Ring,
                n,
                0,
            );
            let d = dense_ring.c.as_ref().unwrap();
            assert!(
                ring.to_dense().max_abs_diff(d) == 0.0,
                "ring n={n}"
            );
            let id = SparseTopology::identity(n);
            assert_eq!(
                id.to_dense().max_abs_diff(&Matrix::identity(n)),
                0.0
            );
            let j = SparseTopology::consensus(n);
            assert_eq!(
                j.to_dense().max_abs_diff(&Matrix::consensus(n)),
                0.0
            );
        }
    }

    #[test]
    fn incremental_rebuild_equals_full_rebuild() {
        // start from a 4-cycle, remove edge 1-2, rebuild only the
        // touched neighborhoods — must equal a from-scratch build
        let mut adj = vec![vec![1, 3], vec![0, 2], vec![1, 3], vec![2, 0]];
        let mut sp = SparseTopology::metropolis(&adj);
        adj[1].retain(|&j| j != 2);
        adj[2].retain(|&j| j != 1);
        // dirty: endpoints {1,2} + their old/new neighborhoods
        sp.rebuild_rows(&adj, [0, 1, 2, 3]);
        let full = SparseTopology::metropolis(&adj);
        assert_eq!(sp, full);
    }

    #[test]
    fn zeta_power_matches_jacobi_on_small_graphs() {
        use crate::linalg::eigen::second_largest_abs_eigenvalue;
        for (name, adj) in [
            ("path3", path3()),
            ("square", vec![vec![1, 3], vec![0, 2], vec![1, 3], vec![2, 0]]),
        ] {
            let sp = SparseTopology::metropolis(&adj);
            let z = sp.zeta_power(PowerBudget::Oracle);
            let dense = metropolis_weights(&adj);
            let jac = second_largest_abs_eigenvalue(&dense);
            assert!((z - jac).abs() < 1e-9, "{name}: {z} vs {jac}");
        }
    }
}
