//! Byzantine-robust mixing rules (the `mixing:` config axis).
//!
//! Plain Metropolis mixing is a fixed convex combination of neighbor
//! estimates — a single Byzantine neighbor can drag a node arbitrarily
//! far by shipping huge values. The two robust variants bound that
//! influence per coordinate:
//!
//! * **trimmed(f)** — drop the `f` largest and `f` smallest *neighbor*
//!   values at each coordinate (the node's own estimate is always
//!   kept), then redistribute the dropped weight over the kept
//!   neighbors so the row stays stochastic. With `2f ≥ deg` the whole
//!   neighbor mass falls back to the node itself. `trimmed(0)` is
//!   plain Metropolis.
//! * **median** — replace the neighbor average by the unweighted
//!   coordinate-wise median of {self} ∪ neighbors (even count →
//!   midpoint), scaled by the row's total mass.
//!
//! One helper serves every runtime: the synchronous matrix engine, the
//! asynchronous gossip engine (with its staleness-discounted weights),
//! and the threaded/socket protocol loop all gather (values, weight)
//! columns and call [`robust_mix_into`]. Engines route
//! [`MixingKind::is_plain`] configurations through their historical
//! axpy path, so default runs stay bit-identical to pre-robust builds.

use crate::config::MixingKind;

/// Mix `self_vals` (weight `self_w`) with neighbor columns into `out`
/// under `kind`. Each neighbor is a (values, weight) pair; all slices
/// must have `out.len()` elements and weights must be non-negative.
/// Accumulation is f64 in a deterministic order (sorted per coordinate
/// for the robust rules), so results are replayable bit-for-bit.
///
/// Returns the number of neighbor contributions discarded per
/// coordinate (`min(2f, deg)` for trimmed, 0 otherwise) — the
/// `trimmed_drops` observability quantity.
pub fn robust_mix_into(
    out: &mut [f32],
    self_vals: &[f32],
    self_w: f64,
    neighbors: &[(&[f32], f64)],
    kind: &MixingKind,
) -> u64 {
    debug_assert_eq!(out.len(), self_vals.len());
    for (vals, _) in neighbors {
        debug_assert_eq!(vals.len(), out.len());
    }
    let total_w: f64 = neighbors.iter().map(|(_, w)| *w).sum();
    match kind {
        MixingKind::Metropolis | MixingKind::Trimmed { f: 0 } => {
            plain_mix(out, self_vals, self_w, neighbors);
            0
        }
        MixingKind::Trimmed { f } => {
            trimmed_mix(out, self_vals, self_w, neighbors, total_w, *f)
        }
        MixingKind::Median => {
            median_mix(out, self_vals, self_w, neighbors, total_w);
            0
        }
    }
}

/// Reference weighted sum (f64 accumulation, caller order). The
/// engines' hot paths keep their own kernels for this case; this form
/// exists so the helper is total over [`MixingKind`] and testable.
fn plain_mix(
    out: &mut [f32],
    self_vals: &[f32],
    self_w: f64,
    neighbors: &[(&[f32], f64)],
) {
    for (c, o) in out.iter_mut().enumerate() {
        let mut acc = self_w * self_vals[c] as f64;
        for (vals, w) in neighbors {
            acc += w * vals[c] as f64;
        }
        *o = acc as f32;
    }
}

fn trimmed_mix(
    out: &mut [f32],
    self_vals: &[f32],
    self_w: f64,
    neighbors: &[(&[f32], f64)],
    total_w: f64,
    f: usize,
) -> u64 {
    let deg = neighbors.len();
    if 2 * f >= deg {
        // not enough neighbors to trim around: every neighbor value is
        // suspect, so the whole row mass stays on the node itself
        for (o, &s) in out.iter_mut().zip(self_vals) {
            *o = ((self_w + total_w) * s as f64) as f32;
        }
        return deg as u64;
    }
    let mut entries: Vec<(f32, f64)> = Vec::with_capacity(deg);
    for (c, o) in out.iter_mut().enumerate() {
        entries.clear();
        entries
            .extend(neighbors.iter().map(|(vals, w)| (vals[c], *w)));
        entries.sort_by(|a, b| a.0.total_cmp(&b.0));
        let kept = &entries[f..deg - f];
        let kept_w: f64 = kept.iter().map(|(_, w)| *w).sum();
        // redistribute the trimmed mass proportionally over the kept
        // neighbors; if every kept weight is zero the mass falls back
        // to the node (total_w is then also the trimmed weight)
        let scale = if kept_w > 0.0 { total_w / kept_w } else { 0.0 };
        let mut acc = self_w * self_vals[c] as f64;
        if scale > 0.0 {
            for (v, w) in kept {
                acc += w * scale * *v as f64;
            }
        } else {
            acc += total_w * self_vals[c] as f64;
        }
        *o = acc as f32;
    }
    (2 * f) as u64
}

fn median_mix(
    out: &mut [f32],
    self_vals: &[f32],
    self_w: f64,
    neighbors: &[(&[f32], f64)],
    total_w: f64,
) {
    let mass = self_w + total_w;
    let mut vals: Vec<f32> = Vec::with_capacity(neighbors.len() + 1);
    for (c, o) in out.iter_mut().enumerate() {
        vals.clear();
        vals.push(self_vals[c]);
        vals.extend(neighbors.iter().map(|(v, _)| v[c]));
        vals.sort_by(|a, b| a.total_cmp(b));
        let n = vals.len();
        let med = if n % 2 == 1 {
            vals[n / 2] as f64
        } else {
            (vals[n / 2 - 1] as f64 + vals[n / 2] as f64) / 2.0
        };
        *o = (mass * med) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix(
        self_vals: &[f32],
        self_w: f64,
        neighbors: &[(&[f32], f64)],
        kind: &MixingKind,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self_vals.len()];
        robust_mix_into(&mut out, self_vals, self_w, neighbors, kind);
        out
    }

    #[test]
    fn trimmed_zero_is_the_plain_weighted_sum() {
        let a = [1.0f32, -2.0, 3.0];
        let b = [0.5f32, 0.5, 0.5];
        let s = [0.0f32, 1.0, -1.0];
        let nbrs: Vec<(&[f32], f64)> =
            vec![(&a[..], 0.3), (&b[..], 0.3)];
        let plain = mix(&s, 0.4, &nbrs, &MixingKind::Metropolis);
        let t0 = mix(&s, 0.4, &nbrs, &MixingKind::Trimmed { f: 0 });
        for (x, y) in plain.iter().zip(&t0) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn trimmed_discards_the_outlier() {
        // four honest-ish neighbors plus one shipping a huge value:
        // with f=1 the attacker is in the trimmed extreme, so the
        // output stays near the honest range regardless of magnitude
        let honest = [[0.9f32], [1.0f32], [1.1f32], [1.05f32]];
        let evil = [1.0e9f32];
        let s = [1.0f32];
        let nbrs: Vec<(&[f32], f64)> = vec![
            (&honest[0][..], 0.15),
            (&honest[1][..], 0.15),
            (&evil[..], 0.15),
            (&honest[2][..], 0.15),
            (&honest[3][..], 0.15),
        ];
        let plain = mix(&s, 0.25, &nbrs, &MixingKind::Metropolis);
        assert!(plain[0] > 1.0e7, "plain mixing absorbed the attack?");
        let trimmed = mix(&s, 0.25, &nbrs, &MixingKind::Trimmed { f: 1 });
        assert!(
            (0.8..=1.2).contains(&trimmed[0]),
            "trimmed={}",
            trimmed[0]
        );
    }

    #[test]
    fn median_ignores_a_minority_of_outliers() {
        let cols = [[-1.0e8f32], [0.1f32], [0.15f32], [1.0e8f32]];
        let s = [0.0f32];
        let nbrs: Vec<(&[f32], f64)> =
            cols.iter().map(|c| (&c[..], 0.2)).collect();
        // 5 values {-1e8, 0, 0.1, 0.15, 1e8} -> median 0.1, mass 1.0
        let m = mix(&s, 0.2, &nbrs, &MixingKind::Median);
        assert!((m[0] - 0.1).abs() < 1e-6, "median={}", m[0]);
    }

    #[test]
    fn rows_stay_stochastic_on_consensus_inputs() {
        // every estimate equal => every rule must reproduce it scaled
        // by the row mass (here 1.0): the row still sums to one
        let v = [3.25f32, -7.5, 0.0, 42.0];
        let nbrs: Vec<(&[f32], f64)> =
            vec![(&v[..], 0.25), (&v[..], 0.25), (&v[..], 0.25)];
        for kind in [
            MixingKind::Metropolis,
            MixingKind::Trimmed { f: 1 },
            MixingKind::Median,
        ] {
            let out = mix(&v, 0.25, &nbrs, &kind);
            for (o, &x) in out.iter().zip(&v) {
                assert!(
                    (o - x).abs() <= x.abs() * 1e-6 + 1e-6,
                    "{kind:?}: {o} vs {x}"
                );
            }
        }
    }

    #[test]
    fn overtrimmed_rows_fall_back_to_self() {
        let a = [9.0f32];
        let b = [-9.0f32];
        let s = [2.0f32];
        let nbrs: Vec<(&[f32], f64)> =
            vec![(&a[..], 0.3), (&b[..], 0.3)];
        // 2f = 2 >= deg = 2: all mass (0.4 + 0.6) collapses onto self
        let mut out = [0.0f32];
        let drops = robust_mix_into(
            &mut out,
            &s,
            0.4,
            &nbrs,
            &MixingKind::Trimmed { f: 1 },
        );
        assert_eq!(drops, 2);
        assert!((out[0] - 2.0).abs() < 1e-6, "out={}", out[0]);
    }

    #[test]
    fn no_neighbors_degenerates_to_scaled_self() {
        let s = [1.5f32, -2.5];
        for kind in [
            MixingKind::Metropolis,
            MixingKind::Trimmed { f: 2 },
            MixingKind::Median,
        ] {
            let out = mix(&s, 0.5, &[], &kind);
            assert!((out[0] - 0.75).abs() < 1e-7, "{kind:?}");
            assert!((out[1] + 1.25).abs() < 1e-7, "{kind:?}");
        }
    }

    #[test]
    fn trimmed_reports_drop_count() {
        let a = [1.0f32];
        let cols: Vec<(&[f32], f64)> =
            vec![(&a[..], 0.2), (&a[..], 0.2), (&a[..], 0.2), (&a[..], 0.2)];
        let mut out = [0.0f32];
        let d = robust_mix_into(
            &mut out,
            &a,
            0.2,
            &cols,
            &MixingKind::Trimmed { f: 1 },
        );
        assert_eq!(d, 2);
        let d0 = robust_mix_into(
            &mut out,
            &a,
            0.2,
            &cols,
            &MixingKind::Trimmed { f: 0 },
        );
        assert_eq!(d0, 0);
    }
}
