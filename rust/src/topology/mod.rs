//! Network topology → confusion matrix C (paper §II-B, Assumption 1.5).
//!
//! `C` is symmetric doubly stochastic; `c_ji` is node j's weight in node
//! i's model averaging; `c_ij = 0` iff i and j are not neighbors. The
//! spectral quantity ζ = max(|λ₂|, |λ_N|) measures confusion degree
//! (ζ=0: C=J fully mixed; ζ=1: C=I disconnected) and enters the bounds
//! via α(ζ) (Lemma 2).
//!
//! Irregular graphs get Metropolis–Hastings weights, the standard way to
//! make a doubly-stochastic symmetric matrix from an arbitrary graph:
//! `c_ij = 1/(1 + max(deg_i, deg_j))` for edges, diagonal = remainder.

use crate::config::TopologyKind;
use crate::linalg::eigen::{alpha_of_zeta, second_largest_abs_eigenvalue};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A built topology: adjacency + confusion matrix + spectral info.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    /// adjacency (excluding self-loops)
    pub adj: Vec<Vec<usize>>,
    /// confusion matrix C (row-major, symmetric doubly stochastic)
    pub c: Matrix,
    /// ζ = max(|λ₂|, |λ_N|)
    pub zeta: f64,
}

impl Topology {
    /// Build from a [`TopologyKind`]; `seed` only matters for random graphs.
    pub fn build(kind: &TopologyKind, n: usize, seed: u64) -> Topology {
        assert!(n > 0);
        let adj = match kind {
            TopologyKind::Full => full_adj(n),
            TopologyKind::Ring => ring_adj(n),
            TopologyKind::Disconnected => vec![Vec::new(); n],
            TopologyKind::Star => star_adj(n),
            TopologyKind::Torus => torus_adj(n),
            TopologyKind::Random { p } => random_adj(n, *p, seed),
        };
        let c = match kind {
            TopologyKind::Full => Matrix::consensus(n),
            TopologyKind::Disconnected => Matrix::identity(n),
            TopologyKind::Ring => ring_matrix(n),
            _ => metropolis_weights(&adj),
        };
        let zeta = second_largest_abs_eigenvalue(&c);
        Topology { n, adj, c, zeta }
    }

    /// Neighbors of node i (excluding i itself).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Number of directed links (paper counts bits per directed link).
    pub fn directed_links(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }

    /// α(ζ) — topology term of the convergence bound (Lemma 2).
    pub fn alpha(&self) -> f64 {
        alpha_of_zeta(self.zeta)
    }

    /// Whether the graph is connected (BFS). Disconnected topologies can
    /// never reach consensus; the engine warns on them.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(i) = stack.pop() {
            for &j in &self.adj[i] {
                if !seen[j] {
                    seen[j] = true;
                    count += 1;
                    stack.push(j);
                }
            }
        }
        count == self.n
    }
}

fn full_adj(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| (0..n).filter(|&j| j != i).collect())
        .collect()
}

fn ring_adj(n: usize) -> Vec<Vec<usize>> {
    if n == 1 {
        return vec![Vec::new()];
    }
    if n == 2 {
        return vec![vec![1], vec![0]];
    }
    (0..n)
        .map(|i| vec![(i + n - 1) % n, (i + 1) % n])
        .collect()
}

fn star_adj(n: usize) -> Vec<Vec<usize>> {
    if n == 1 {
        return vec![Vec::new()];
    }
    let mut adj = vec![Vec::new(); n];
    for i in 1..n {
        adj[0].push(i);
        adj[i].push(0);
    }
    adj
}

fn torus_adj(n: usize) -> Vec<Vec<usize>> {
    // closest-to-square factorization
    let mut rows = (n as f64).sqrt() as usize;
    while rows > 1 && n % rows != 0 {
        rows -= 1;
    }
    let cols = n / rows.max(1);
    let idx = |r: usize, c: usize| (r % rows) * cols + (c % cols);
    let mut adj = vec![Vec::new(); n];
    for r in 0..rows {
        for c in 0..cols {
            let i = idx(r, c);
            let mut push = |j: usize| {
                if j != i && !adj[i].contains(&j) {
                    adj[i].push(j);
                }
            };
            push(idx(r + 1, c));
            push(idx(r + rows - 1, c));
            push(idx(r, c + 1));
            push(idx(r, c + cols - 1));
        }
    }
    adj
}

fn random_adj(n: usize, p: f64, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x7070_1064);
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.uniform() < p {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
    }
    // ensure connectivity by threading a ring through any isolated parts
    for i in 0..n {
        if adj[i].is_empty() && n > 1 {
            let j = (i + 1) % n;
            adj[i].push(j);
            adj[j].push(i);
        }
    }
    adj
}

/// Uniform ring averaging over {left, self, right} — the matrix whose ζ has
/// the closed form (1 + 2cos(2πk/n))/3; at n=10 this is the paper's ring.
fn ring_matrix(n: usize) -> Matrix {
    let mut c = Matrix::zeros(n, n);
    if n == 1 {
        c[(0, 0)] = 1.0;
        return c;
    }
    if n == 2 {
        // avoid double-counting the single edge
        c[(0, 0)] = 0.5;
        c[(1, 1)] = 0.5;
        c[(0, 1)] = 0.5;
        c[(1, 0)] = 0.5;
        return c;
    }
    let w = 1.0 / 3.0;
    for i in 0..n {
        c[(i, i)] = w;
        c[(i, (i + 1) % n)] = w;
        c[(i, (i + n - 1) % n)] = w;
    }
    c
}

/// Metropolis–Hastings weights: symmetric doubly stochastic for any graph.
pub fn metropolis_weights(adj: &[Vec<usize>]) -> Matrix {
    let n = adj.len();
    let deg: Vec<usize> = adj.iter().map(|a| a.len()).collect();
    let mut c = Matrix::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &adj[i] {
            let w = 1.0 / (1 + deg[i].max(deg[j])) as f64;
            c[(i, j)] = w;
            diag -= w;
        }
        c[(i, i)] = diag;
    }
    c
}

/// A ring-like sparse topology tuned to hit a target ζ by mixing the ring
/// matrix with identity: C(λ) = λ·C_ring + (1-λ)·I has
/// ζ(λ) = λ·ζ_ring + (1-λ). Used to reproduce the paper's ζ = 0.87 setup.
pub fn ring_with_zeta(n: usize, target_zeta: f64) -> Topology {
    let base = Topology::build(&TopologyKind::Ring, n, 0);
    let zr = base.zeta;
    if target_zeta <= zr || zr >= 1.0 {
        return base;
    }
    // solve λ·zr + (1-λ) = target  =>  λ = (1-target)/(1-zr)
    let lambda = (1.0 - target_zeta) / (1.0 - zr);
    let mut c = Matrix::zeros(n, n);
    let eye = Matrix::identity(n);
    for i in 0..n {
        for j in 0..n {
            c[(i, j)] = lambda * base.c[(i, j)] + (1.0 - lambda) * eye[(i, j)];
        }
    }
    let zeta = second_largest_abs_eigenvalue(&c);
    Topology { n, adj: base.adj, c, zeta }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> Vec<TopologyKind> {
        vec![
            TopologyKind::Full,
            TopologyKind::Ring,
            TopologyKind::Disconnected,
            TopologyKind::Star,
            TopologyKind::Torus,
            TopologyKind::Random { p: 0.4 },
        ]
    }

    #[test]
    fn all_kinds_doubly_stochastic_symmetric() {
        for kind in kinds() {
            for n in [1, 2, 3, 4, 10, 17] {
                let t = Topology::build(&kind, n, 7);
                assert!(
                    t.c.is_doubly_stochastic(1e-9),
                    "{kind:?} n={n} not doubly stochastic"
                );
                assert!(
                    t.c.is_symmetric(1e-9),
                    "{kind:?} n={n} not symmetric"
                );
            }
        }
    }

    #[test]
    fn zeta_extremes() {
        let full = Topology::build(&TopologyKind::Full, 10, 0);
        assert!(full.zeta.abs() < 1e-9, "full zeta={}", full.zeta);
        let disc = Topology::build(&TopologyKind::Disconnected, 10, 0);
        assert!((disc.zeta - 1.0).abs() < 1e-9);
        let ring = Topology::build(&TopologyKind::Ring, 10, 0);
        assert!(ring.zeta > 0.0 && ring.zeta < 1.0);
    }

    #[test]
    fn ring_zeta_closed_form_n10() {
        // (1 + 2cos(2π/10))/3 ≈ 0.8727 — the paper's ζ = 0.87 topology
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        let expect = (1.0
            + 2.0 * (2.0 * std::f64::consts::PI / 10.0).cos())
            / 3.0;
        assert!((t.zeta - expect).abs() < 1e-9, "{} vs {expect}", t.zeta);
        assert!((t.zeta - 0.87).abs() < 0.01);
    }

    #[test]
    fn adjacency_symmetric_no_self_loops() {
        for kind in kinds() {
            let t = Topology::build(&kind, 12, 3);
            for i in 0..t.n {
                assert!(!t.adj[i].contains(&i));
                for &j in &t.adj[i] {
                    assert!(t.adj[j].contains(&i), "{kind:?} asym edge");
                }
            }
        }
    }

    #[test]
    fn connectivity() {
        assert!(Topology::build(&TopologyKind::Full, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Ring, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Star, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Torus, 12, 0).is_connected());
        assert!(
            !Topology::build(&TopologyKind::Disconnected, 8, 0)
                .is_connected()
        );
        assert!(
            Topology::build(&TopologyKind::Random { p: 0.3 }, 20, 5)
                .is_connected()
        );
    }

    #[test]
    fn metropolis_on_path_graph() {
        // path 0-1-2: degrees 1,2,1
        let adj = vec![vec![1], vec![0, 2], vec![1]];
        let c = metropolis_weights(&adj);
        assert!(c.is_doubly_stochastic(1e-12));
        assert!((c[(0, 1)] - 1.0 / 3.0).abs() < 1e-12);
        assert!((c[(0, 0)] - 2.0 / 3.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ring_with_zeta_hits_target() {
        let t = ring_with_zeta(10, 0.95);
        assert!((t.zeta - 0.95).abs() < 1e-6, "zeta={}", t.zeta);
        assert!(t.c.is_doubly_stochastic(1e-9));
    }

    #[test]
    fn directed_links_count() {
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        assert_eq!(t.directed_links(), 20);
        let f = Topology::build(&TopologyKind::Full, 10, 0);
        assert_eq!(f.directed_links(), 90);
    }

    #[test]
    fn mixing_contracts_disagreement() {
        // X C^k -> consensus for connected topologies
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        let mut x = Matrix::zeros(1, 10);
        for j in 0..10 {
            x[(0, j)] = j as f64;
        }
        let mean = 4.5;
        let mut spread_prev = f64::INFINITY;
        let mut cur = x.clone();
        for _ in 0..50 {
            cur = cur.matmul(&t.c);
            let spread: f64 = (0..10)
                .map(|j| (cur[(0, j)] - mean).abs())
                .fold(0.0, f64::max);
            assert!(spread <= spread_prev + 1e-12);
            spread_prev = spread;
        }
        assert!(spread_prev < 0.2, "spread={spread_prev}");
    }
}
