//! Network topology → confusion matrix C (paper §II-B, Assumption 1.5).
//!
//! `C` is symmetric doubly stochastic; `c_ji` is node j's weight in node
//! i's model averaging; `c_ij = 0` iff i and j are not neighbors. The
//! spectral quantity ζ = max(|λ₂|, |λ_N|) measures confusion degree
//! (ζ=0: C=J fully mixed; ζ=1: C=I disconnected) and enters the bounds
//! via α(ζ) (Lemma 2).
//!
//! Irregular graphs get Metropolis–Hastings weights, the standard way to
//! make a doubly-stochastic symmetric matrix from an arbitrary graph:
//! `c_ij = 1/(1 + max(deg_i, deg_j))` for edges, diagonal = remainder.
//!
//! At scale the dense matrix disappears: every engine path reads mixing
//! weights from the O(degree) [`SparseTopology`] rows, and the dense
//! `Matrix` form survives only as a bit-identity oracle on small graphs
//! (n ≤ [`DENSE_ORACLE_MAX`]), where it also feeds the Jacobi
//! eigensolver. Larger graphs estimate ζ by deflated power iteration
//! over the sparse matvec and never materialize C.

pub mod robust;
pub mod sparse;

use crate::config::TopologyKind;
use crate::linalg::eigen::{alpha_of_zeta, second_largest_abs_eigenvalue};
use crate::linalg::power::PowerBudget;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

pub use robust::robust_mix_into;
pub use sparse::SparseTopology;

/// Largest node count for which the dense confusion matrix (and the
/// Jacobi ζ) is kept alongside the sparse rows. Below this, builds are
/// byte-for-byte what they were before the sparse path existed; above
/// it, only O(degree) state is materialized.
pub const DENSE_ORACLE_MAX: usize = 64;

/// A built topology: adjacency + confusion-matrix rows + spectral info.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    /// adjacency (excluding self-loops)
    pub adj: Vec<Vec<usize>>,
    /// sparse confusion rows — the mixing authority on every path
    pub sparse: SparseTopology,
    /// dense C oracle; `None` when n > [`DENSE_ORACLE_MAX`]
    pub c: Option<Matrix>,
    /// ζ = max(|λ₂|, |λ_N|)
    pub zeta: f64,
}

impl Topology {
    /// Build from a [`TopologyKind`]; `seed` only matters for random graphs.
    pub fn build(kind: &TopologyKind, n: usize, seed: u64) -> Topology {
        assert!(n > 0);
        let adj = match kind {
            TopologyKind::Full => full_adj(n),
            TopologyKind::Ring => ring_adj(n),
            TopologyKind::Disconnected => vec![Vec::new(); n],
            TopologyKind::Star => star_adj(n),
            TopologyKind::Torus => torus_adj(n),
            TopologyKind::Random { p } => random_adj(n, *p, seed),
            TopologyKind::RandomRegular { k } => {
                random_regular_adj(n, *k, seed)
            }
        };
        let (c, sparse, zeta) = if n <= DENSE_ORACLE_MAX {
            // oracle path: exactly the historical dense construction,
            // with the sparse rows derived from it bitwise
            let dense = match kind {
                TopologyKind::Full => Matrix::consensus(n),
                TopologyKind::Disconnected => Matrix::identity(n),
                TopologyKind::Ring => ring_matrix(n),
                _ => metropolis_weights(&adj),
            };
            let sparse = SparseTopology::from_dense(&dense);
            let zeta = second_largest_abs_eigenvalue(&dense);
            (Some(dense), sparse, zeta)
        } else {
            let sparse = match kind {
                TopologyKind::Full => SparseTopology::consensus(n),
                TopologyKind::Disconnected => SparseTopology::identity(n),
                TopologyKind::Ring => SparseTopology::ring(n),
                _ => SparseTopology::metropolis(&adj),
            };
            let zeta = sparse.zeta_power(PowerBudget::Hot);
            (None, sparse, zeta)
        };
        Topology { n, adj, sparse, c, zeta }
    }

    /// c_ij read through the sparse rows (identical bits to the dense
    /// oracle where one exists).
    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.sparse.weight(i, j)
    }

    /// The dense oracle matrix; panics above [`DENSE_ORACLE_MAX`].
    /// Small-n analysis/test code only — engines must read `sparse`.
    pub fn dense(&self) -> &Matrix {
        self.c
            .as_ref()
            .expect("dense C oracle not kept above DENSE_ORACLE_MAX")
    }

    /// Neighbors of node i (excluding i itself).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Number of directed links (paper counts bits per directed link).
    pub fn directed_links(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }

    /// α(ζ) — topology term of the convergence bound (Lemma 2).
    pub fn alpha(&self) -> f64 {
        alpha_of_zeta(self.zeta)
    }

    /// Whether the graph is connected (BFS). Disconnected topologies can
    /// never reach consensus; the engine warns on them.
    pub fn is_connected(&self) -> bool {
        adj_is_connected(&self.adj)
    }
}

fn full_adj(n: usize) -> Vec<Vec<usize>> {
    (0..n)
        .map(|i| (0..n).filter(|&j| j != i).collect())
        .collect()
}

fn ring_adj(n: usize) -> Vec<Vec<usize>> {
    if n == 1 {
        return vec![Vec::new()];
    }
    if n == 2 {
        return vec![vec![1], vec![0]];
    }
    (0..n)
        .map(|i| vec![(i + n - 1) % n, (i + 1) % n])
        .collect()
}

fn star_adj(n: usize) -> Vec<Vec<usize>> {
    if n == 1 {
        return vec![Vec::new()];
    }
    let mut adj = vec![Vec::new(); n];
    for i in 1..n {
        adj[0].push(i);
        adj[i].push(0);
    }
    adj
}

fn torus_adj(n: usize) -> Vec<Vec<usize>> {
    // closest-to-square factorization
    let mut rows = (n as f64).sqrt() as usize;
    while rows > 1 && n % rows != 0 {
        rows -= 1;
    }
    let cols = n / rows.max(1);
    let idx = |r: usize, c: usize| (r % rows) * cols + (c % cols);
    let mut adj = vec![Vec::new(); n];
    for r in 0..rows {
        for c in 0..cols {
            let i = idx(r, c);
            let mut push = |j: usize| {
                if j != i && !adj[i].contains(&j) {
                    adj[i].push(j);
                }
            };
            push(idx(r + 1, c));
            push(idx(r + rows - 1, c));
            push(idx(r, c + 1));
            push(idx(r, c + cols - 1));
        }
    }
    adj
}

/// Seeded random k-regular graph by the pairing (configuration) model:
/// shuffle n·k stubs, pair them off, reject attempts that produce
/// self-loops, parallel edges, or a disconnected graph. Rejection keeps
/// the construction simple and exactly uniform over simple pairings;
/// for the k we use (k ≪ n) an attempt succeeds with probability
/// ≈ e^{-(k²-1)/4}, so the attempt cap is never approached in practice.
/// Deterministic in `(n, k, seed)`.
pub fn random_regular_adj(
    n: usize,
    k: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    assert!(k >= 1, "random-regular degree must be >= 1");
    assert!(k < n, "random-regular degree must be < n (got k={k}, n={n})");
    assert!(
        (n * k) % 2 == 0,
        "random-regular requires n*k even (got n={n}, k={k})"
    );
    let mut rng = Rng::new(seed ^ 0x4E67_5265_6775_6C61);
    for _ in 0..10_000 {
        if let Some(adj) = regular_pairing_attempt(n, k, &mut rng) {
            if adj_is_connected(&adj) {
                return adj;
            }
        }
    }
    panic!("no connected simple {k}-regular graph found on {n} nodes");
}

/// One configuration-model attempt: None on a self-loop or repeated
/// edge (the caller redraws).
fn regular_pairing_attempt(
    n: usize,
    k: usize,
    rng: &mut Rng,
) -> Option<Vec<Vec<usize>>> {
    let mut stubs: Vec<u32> = (0..n * k).map(|s| (s / k) as u32).collect();
    rng.shuffle(&mut stubs);
    let mut adj = vec![Vec::with_capacity(k); n];
    let mut seen = std::collections::BTreeSet::new();
    for pair in stubs.chunks_exact(2) {
        let (u, v) = (pair[0], pair[1]);
        if u == v || !seen.insert((u.min(v), u.max(v))) {
            return None;
        }
        adj[u as usize].push(v as usize);
        adj[v as usize].push(u as usize);
    }
    Some(adj)
}

/// BFS connectivity over a raw adjacency (pre-`Topology` form).
fn adj_is_connected(adj: &[Vec<usize>]) -> bool {
    let n = adj.len();
    if n == 0 {
        return true;
    }
    let mut seen = vec![false; n];
    let mut stack = vec![0usize];
    seen[0] = true;
    let mut count = 1;
    while let Some(i) = stack.pop() {
        for &j in &adj[i] {
            if !seen[j] {
                seen[j] = true;
                count += 1;
                stack.push(j);
            }
        }
    }
    count == n
}

fn random_adj(n: usize, p: f64, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x7070_1064);
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.uniform() < p {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
    }
    // ensure connectivity by threading a ring through any isolated parts
    for i in 0..n {
        if adj[i].is_empty() && n > 1 {
            let j = (i + 1) % n;
            adj[i].push(j);
            adj[j].push(i);
        }
    }
    adj
}

/// Uniform ring averaging over {left, self, right} — the matrix whose ζ has
/// the closed form (1 + 2cos(2πk/n))/3; at n=10 this is the paper's ring.
fn ring_matrix(n: usize) -> Matrix {
    let mut c = Matrix::zeros(n, n);
    if n == 1 {
        c[(0, 0)] = 1.0;
        return c;
    }
    if n == 2 {
        // avoid double-counting the single edge
        c[(0, 0)] = 0.5;
        c[(1, 1)] = 0.5;
        c[(0, 1)] = 0.5;
        c[(1, 0)] = 0.5;
        return c;
    }
    let w = 1.0 / 3.0;
    for i in 0..n {
        c[(i, i)] = w;
        c[(i, (i + 1) % n)] = w;
        c[(i, (i + n - 1) % n)] = w;
    }
    c
}

/// Metropolis–Hastings weights: symmetric doubly stochastic for any graph.
pub fn metropolis_weights(adj: &[Vec<usize>]) -> Matrix {
    let n = adj.len();
    let deg: Vec<usize> = adj.iter().map(|a| a.len()).collect();
    let mut c = Matrix::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &adj[i] {
            let w = 1.0 / (1 + deg[i].max(deg[j])) as f64;
            c[(i, j)] = w;
            diag -= w;
        }
        c[(i, i)] = diag;
    }
    c
}

/// A ring-like sparse topology tuned to hit a target ζ by mixing the ring
/// matrix with identity: C(λ) = λ·C_ring + (1-λ)·I has
/// ζ(λ) = λ·ζ_ring + (1-λ). Used to reproduce the paper's ζ = 0.87 setup.
pub fn ring_with_zeta(n: usize, target_zeta: f64) -> Topology {
    assert!(
        n <= DENSE_ORACLE_MAX,
        "ring_with_zeta is a small-n analysis helper (n <= {DENSE_ORACLE_MAX})"
    );
    let base = Topology::build(&TopologyKind::Ring, n, 0);
    let zr = base.zeta;
    if target_zeta <= zr || zr >= 1.0 {
        return base;
    }
    // solve λ·zr + (1-λ) = target  =>  λ = (1-target)/(1-zr)
    let lambda = (1.0 - target_zeta) / (1.0 - zr);
    let mut c = Matrix::zeros(n, n);
    let eye = Matrix::identity(n);
    let base_c = base.dense();
    for i in 0..n {
        for j in 0..n {
            c[(i, j)] =
                lambda * base_c[(i, j)] + (1.0 - lambda) * eye[(i, j)];
        }
    }
    let zeta = second_largest_abs_eigenvalue(&c);
    let sparse = SparseTopology::from_dense(&c);
    Topology { n, adj: base.adj, sparse, c: Some(c), zeta }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> Vec<TopologyKind> {
        vec![
            TopologyKind::Full,
            TopologyKind::Ring,
            TopologyKind::Disconnected,
            TopologyKind::Star,
            TopologyKind::Torus,
            TopologyKind::Random { p: 0.4 },
        ]
    }

    #[test]
    fn all_kinds_doubly_stochastic_symmetric() {
        for kind in kinds() {
            for n in [1, 2, 3, 4, 10, 17] {
                let t = Topology::build(&kind, n, 7);
                assert!(
                    t.dense().is_doubly_stochastic(1e-9),
                    "{kind:?} n={n} not doubly stochastic"
                );
                assert!(
                    t.dense().is_symmetric(1e-9),
                    "{kind:?} n={n} not symmetric"
                );
            }
        }
    }

    #[test]
    fn sparse_rows_bitwise_equal_dense_oracle() {
        for kind in kinds() {
            let t = Topology::build(&kind, 17, 7);
            let d = t.dense();
            for i in 0..t.n {
                for j in 0..t.n {
                    assert_eq!(
                        t.weight(i, j).to_bits(),
                        d[(i, j)].to_bits(),
                        "{kind:?} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn large_builds_are_sparse_only() {
        for kind in [
            TopologyKind::Torus,
            TopologyKind::Ring,
            TopologyKind::RandomRegular { k: 4 },
        ] {
            let t = Topology::build(&kind, 100, 3);
            assert!(t.c.is_none(), "{kind:?} kept a dense matrix");
            assert!(
                t.sparse.to_dense().is_doubly_stochastic(1e-9),
                "{kind:?} sparse rows not doubly stochastic"
            );
            assert!(
                t.zeta > 0.0 && t.zeta < 1.0,
                "{kind:?} zeta={}",
                t.zeta
            );
        }
    }

    #[test]
    fn dense_oracle_threshold_is_exact() {
        let at = Topology::build(&TopologyKind::Torus, DENSE_ORACLE_MAX, 0);
        assert!(at.c.is_some());
        let above =
            Topology::build(&TopologyKind::Torus, DENSE_ORACLE_MAX + 1, 0);
        assert!(above.c.is_none());
    }

    #[test]
    fn power_zeta_close_to_jacobi_at_threshold_boundary() {
        // same graph both ways: n = 64 gets Jacobi, but the sparse rows
        // are identical, so power iteration must land on the same zeta
        let t = Topology::build(&TopologyKind::Torus, 64, 0);
        let pz = t.sparse.zeta_power(PowerBudget::Oracle);
        assert!(
            (pz - t.zeta).abs() < 1e-6,
            "power {pz} vs jacobi {}",
            t.zeta
        );
    }

    #[test]
    fn random_regular_degree_symmetry_no_self_loops() {
        for (n, k) in [(10, 3), (16, 4), (90, 4)] {
            let adj = random_regular_adj(n, k, 42);
            for i in 0..n {
                assert_eq!(adj[i].len(), k, "n={n} k={k} node {i}");
                assert!(!adj[i].contains(&i), "self-loop at {i}");
                let mut sorted = adj[i].clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), k, "parallel edge at {i}");
                for &j in &adj[i] {
                    assert!(adj[j].contains(&i), "asym edge {i}-{j}");
                }
            }
            assert!(adj_is_connected(&adj), "n={n} k={k} disconnected");
        }
    }

    #[test]
    fn random_regular_deterministic_and_seed_sensitive() {
        let a = random_regular_adj(32, 4, 7);
        let b = random_regular_adj(32, 4, 7);
        assert_eq!(a, b);
        let c = random_regular_adj(32, 4, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn random_regular_builds_as_topology() {
        let t =
            Topology::build(&TopologyKind::RandomRegular { k: 4 }, 16, 9);
        assert!(t.dense().is_doubly_stochastic(1e-9));
        assert!(t.is_connected());
        assert_eq!(t.directed_links(), 16 * 4);
    }

    #[test]
    fn zeta_extremes() {
        let full = Topology::build(&TopologyKind::Full, 10, 0);
        assert!(full.zeta.abs() < 1e-9, "full zeta={}", full.zeta);
        let disc = Topology::build(&TopologyKind::Disconnected, 10, 0);
        assert!((disc.zeta - 1.0).abs() < 1e-9);
        let ring = Topology::build(&TopologyKind::Ring, 10, 0);
        assert!(ring.zeta > 0.0 && ring.zeta < 1.0);
    }

    #[test]
    fn ring_zeta_closed_form_n10() {
        // (1 + 2cos(2π/10))/3 ≈ 0.8727 — the paper's ζ = 0.87 topology
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        let expect = (1.0
            + 2.0 * (2.0 * std::f64::consts::PI / 10.0).cos())
            / 3.0;
        assert!((t.zeta - expect).abs() < 1e-9, "{} vs {expect}", t.zeta);
        assert!((t.zeta - 0.87).abs() < 0.01);
    }

    #[test]
    fn adjacency_symmetric_no_self_loops() {
        for kind in kinds() {
            let t = Topology::build(&kind, 12, 3);
            for i in 0..t.n {
                assert!(!t.adj[i].contains(&i));
                for &j in &t.adj[i] {
                    assert!(t.adj[j].contains(&i), "{kind:?} asym edge");
                }
            }
        }
    }

    #[test]
    fn connectivity() {
        assert!(Topology::build(&TopologyKind::Full, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Ring, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Star, 8, 0).is_connected());
        assert!(Topology::build(&TopologyKind::Torus, 12, 0).is_connected());
        assert!(
            !Topology::build(&TopologyKind::Disconnected, 8, 0)
                .is_connected()
        );
        assert!(
            Topology::build(&TopologyKind::Random { p: 0.3 }, 20, 5)
                .is_connected()
        );
    }

    #[test]
    fn metropolis_on_path_graph() {
        // path 0-1-2: degrees 1,2,1
        let adj = vec![vec![1], vec![0, 2], vec![1]];
        let c = metropolis_weights(&adj);
        assert!(c.is_doubly_stochastic(1e-12));
        assert!((c[(0, 1)] - 1.0 / 3.0).abs() < 1e-12);
        assert!((c[(0, 0)] - 2.0 / 3.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ring_with_zeta_hits_target() {
        let t = ring_with_zeta(10, 0.95);
        assert!((t.zeta - 0.95).abs() < 1e-6, "zeta={}", t.zeta);
        assert!(t.dense().is_doubly_stochastic(1e-9));
    }

    #[test]
    fn directed_links_count() {
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        assert_eq!(t.directed_links(), 20);
        let f = Topology::build(&TopologyKind::Full, 10, 0);
        assert_eq!(f.directed_links(), 90);
    }

    #[test]
    fn mixing_contracts_disagreement() {
        // X C^k -> consensus for connected topologies
        let t = Topology::build(&TopologyKind::Ring, 10, 0);
        let mut x = Matrix::zeros(1, 10);
        for j in 0..10 {
            x[(0, j)] = j as f64;
        }
        let mean = 4.5;
        let mut spread_prev = f64::INFINITY;
        let mut cur = x.clone();
        for _ in 0..50 {
            cur = cur.matmul(t.dense());
            let spread: f64 = (0..10)
                .map(|j| (cur[(0, j)] - mean).abs())
                .fold(0.0, f64::max);
            assert!(spread <= spread_prev + 1e-12);
            spread_prev = spread;
        }
        assert!(spread_prev < 0.2, "spread={spread_prev}");
    }
}
