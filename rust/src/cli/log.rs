//! Leveled stdout logger for the CLI and experiment drivers.
//!
//! One process-global level, three tiers: `--quiet`/`-q` silences the
//! drivers' progress output (tables, banners, per-round prints),
//! the default level keeps today's output exactly, and `-v`/
//! `--verbose` adds diagnostics (resolved config sections, trace sink
//! paths). Machine-consumed outputs (CSV files, bench JSON) never go
//! through here, so quiet runs still produce their artifacts.

use std::sync::atomic::{AtomicU8, Ordering};

pub const QUIET: u8 = 0;
pub const INFO: u8 = 1;
pub const VERBOSE: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

pub fn set_level(level: u8) {
    LEVEL.store(level.min(VERBOSE), Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Resolve `--quiet | -q | -v | --verbose` from parsed args (quiet
/// wins when both are given).
pub fn set_from_args(args: &super::Args) {
    if args.has_flag("quiet") || args.has_flag("q") {
        set_level(QUIET);
    } else if args.has_flag("verbose") || args.has_flag("v") {
        set_level(VERBOSE);
    } else {
        set_level(INFO);
    }
}

/// Driver progress output (default level; suppressed by `--quiet`).
pub fn info(msg: impl std::fmt::Display) {
    if level() >= INFO {
        println!("{msg}");
    }
}

/// Diagnostics only shown with `-v` / `--verbose`.
pub fn verbose(msg: impl std::fmt::Display) {
    if level() >= VERBOSE {
        println!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn levels_resolve_from_flags() {
        // NOTE: the level is process-global; this test sets and
        // restores it around each assertion to stay order-independent
        let prev = level();
        set_from_args(&parse("train --quiet"));
        assert_eq!(level(), QUIET);
        set_from_args(&parse("train -v"));
        assert_eq!(level(), VERBOSE);
        set_from_args(&parse("train"));
        assert_eq!(level(), INFO);
        // quiet beats verbose
        set_from_args(&parse("train -v -q"));
        assert_eq!(level(), QUIET);
        set_level(prev);
    }
}
