//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports `command [--key value] [--flag] [-x] [positional...]`,
//! typed accessors with defaults, required options, and auto-generated
//! usage. [`log`] is the leveled stdout logger the experiment drivers
//! print through (`--quiet` / `-v`).

pub mod log;

use std::collections::BTreeMap;

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

/// `-v`-style token: one dash then a letter (`-0.5` is a value).
fn is_short_flag(t: &str) -> bool {
    !t.starts_with("--")
        && t.len() >= 2
        && t.starts_with('-')
        && t.as_bytes()[1].is_ascii_alphabetic()
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// first non-flag token (subcommand), if any
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse tokens. `--key value` and `--key=value` are options; a `--key`
    /// followed by another `--...` (or end) is a boolean flag. A single
    /// dash followed by a letter (`-v`) is a short boolean flag (stored
    /// without the dash); `-0.5`-style tokens stay ordinary values. The
    /// first positional token becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if is_short_flag(t) {
                out.flags.push(t[1..].to_string());
                i += 1;
                continue;
            }
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len()
                    && !toks[i + 1].starts_with("--")
                    && !is_short_flag(&toks[i + 1])
                {
                    out.options
                        .insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str, ArgError> {
        self.get(name)
            .ok_or_else(|| ArgError(format!("missing required --{name}")))
    }

    pub fn get_usize(
        &self,
        name: &str,
        default: usize,
    ) -> Result<usize, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ArgError(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ArgError(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ArgError(format!("--{name} expects a number, got '{v}'"))
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse("train --config cfg.json --verbose --rounds 50 extra");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get("rounds"), Some("50"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --lr=0.01 --s=16");
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.get_usize("s", 0).unwrap(), 16);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --dry-run");
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
        assert!(a.require("missing").is_err());
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
    }

    #[test]
    fn no_command() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.has_flag("help"));
    }

    #[test]
    fn short_flags_parse_and_negative_values_do_not() {
        let a = parse("train -v --config cfg.json");
        assert!(a.has_flag("v"));
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        // a short flag right after an option name does not become its
        // value; the option degrades to a flag instead
        let a = parse("train --threaded -v");
        assert!(a.has_flag("threaded"));
        assert!(a.has_flag("v"));
        // negative numbers still work as option values
        let a = parse("x --bias -0.5 -q");
        assert_eq!(a.get_f64("bias", 0.0).unwrap(), -0.5);
        assert!(a.has_flag("q"));
    }
}
