//! lmdfl — CLI for the quantized decentralized federated learning system.
//!
//! Subcommands:
//!   train      run a DFL training from a JSON config (or inline flags)
//!   node       run ONE node of a multi-process TCP training (by rank)
//!   table1     regenerate Table I (distortion comparison)
//!   fig4       regenerate Fig. 4 (adaptive vs fixed s)
//!   fig6       regenerate Fig. 6 (--dataset mnist|cifar)
//!   fig7       regenerate Fig. 7 (topology sweep)
//!   fig8       regenerate Fig. 8 (--variable-lr for panels b/e)
//!   fig-time   loss vs virtual time on a simulated fabric (simnet)
//!   sweep      run a grid of configs to one manifest (sweep module)
//!   analyse    aggregate a sweep's traces into tidy CSVs
//!   topo       inspect a topology (confusion matrix, ζ, α)
//!   quant      inspect quantizer bit costs and distortion bounds
//!   artifacts  list AOT artifacts from the manifest
//!   trace      validate / summarize a JSONL trace (obs subsystem)
//!
//! Global flags: `--quiet`/`-q` and `-v`/`--verbose` set the stdout
//! log level; `--trace-out` / `--chrome-out` enable the tracing layer
//! for any command (see [`lmdfl::obs`]).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use lmdfl::cli::log;
use lmdfl::prelude::*;

const USAGE: &str = "\
lmdfl <command> [options]

commands:
  train      --config <file.json> [--threaded] [--simulate]
             [--csv out.csv] [--stream-csv out.csv]
             (--stream-csv writes each round as it finishes instead of
             buffering the run log — the large-fleet memory model)
             or inline: --nodes N --rounds K --tau T --quantizer q --s S
                        --dataset synth_mnist|synth_cifar|blobs --lr F
                        --parallelism auto|off|N   (matrix-engine workers)
             network (simnet) flags, enable virtual-time simulation:
                        --net-latency-s F --net-bandwidth-bps F
                        --net-jitter-s F --net-drop P
                        --net-link-spread F --compute-step-s F
                        --compute-spread F --straggler-prob P
                        --straggler-slowdown F --churn-interval N
                        --churn-link-fail P --churn-link-heal P
                        --churn-node-leave P --churn-node-return P
             broadcast transport (quant::wire; parity-tested paths):
                        --encoding bitstream|matrix   (default bitstream)
             engine mode (async event-driven gossip, see agossip):
                        --mode sync|async
                        --async-wait-for all|quorum|staleness
                        --async-quorum K --async-staleness N
                        --async-lambda F --async-timeout-s F
             delivery transport (threaded runtime; see net):
                        --transport channel|tcp --tcp-host H
                        --tcp-base-port P --tcp-connect-timeout-s F
                        --tcp-backoff-s F
             adversarial scenario (Byzantine senders + robust mixing):
                        --attack none|sign_flip|scale|random
                        --attack-f N   (nodes 0..N are Byzantine)
                        --attack-factor F   (scale attack multiplier)
                        --mixing metropolis|trimmed(f)|median
  node       --rank R + the train config flags: one OS process per
             node over real TCP sockets (node i listens on
             base_port+i). Launch every rank; rank 0 runs the
             report plane and prints the summary [--csv out.csv]
  table1     [--d N]... [--s N]... [--trials N]
  fig4       [--full]
  fig6       --dataset mnist|cifar [--full]
  fig7       [--full]
  fig8       --dataset mnist|cifar [--variable-lr] [--full]
  fig-time   --preset torus-16|async-torus-16|random-regular-4096|
             async-random-regular-4096|torus-10k|async-torus-10k
             [--target-loss F] [--full]
             [--from-sweep manifest.json]  rebuild the tables from a
             sweep's artifacts instead of re-running
  fig-robust [--target-loss F] [--full]  honest loss vs measured wire
             bytes under an f=2 sign-flip minority on the torus-16
             fabric: plain vs trimmed vs median mixing
  sweep      run a grid of configs, one manifest + traced artifacts:
             base config from --preset <fig-time preset> or the train
             config flags, then axis lists (comma-separated):
             [--quantizers q,..] [--topologies t,..]
             [--nets base|ideal|torus16|straggler|scale,..]
             [--modes sync,async]
             [--attacks none|sign_flip|scale|random,..]
             [--seeds N | --seed-list a,b,..]
             [--out dir] [--slots N] [--no-resume] [--name label]
             cells run as subprocesses with tracing on; CPU/RSS are
             sampled to resources.jsonl; completed cells are skipped
             on re-run (resume)
  analyse    <sweep-out/manifest.json> [--out dir]
             aggregate every cell's trace into tidy CSVs
             (cells/spans/counters/hists; default out: <sweep>/analysis)
  topo       --kind full|ring|disconnected|star|torus|random|
             random_regular --nodes N [--p F] [--k N]
  quant      --d N --s N
  artifacts  [--dir artifacts]
  trace      <trace.jsonl> [--check] [--chrome-out out.trace.json]
             validate (--check) or summarize a recorded trace; rank 0
             of a `node` run merges per-rank traces into the base path

global flags (any command):
  --quiet | -q     suppress progress output (artifacts still written)
  -v | --verbose   extra diagnostics (resolved sinks, merge reports)
  --trace-out t.jsonl --chrome-out t.trace.json
                   record a trace of the run (schema lmdfl-trace-v1;
                   chrome file opens in about:tracing / Perfetto)
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn scale_of(args: &Args) -> Scale {
    if args.has_flag("full") {
        Scale::Full
    } else {
        Scale::from_env()
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    log::set_from_args(args);
    // trace sinks: `train` reads them from the merged config section
    // (so a --config file can enable tracing too), `node` starts one
    // recorder per rank, and `trace` only *reads* traces; every other
    // command records the whole invocation as rank 0
    let generic_trace = !matches!(
        args.command.as_deref(),
        Some("train") | Some("node") | Some("trace")
    );
    if generic_trace {
        if let Some(o) = observe_from_flags(args) {
            obs::start(&o, 0);
        }
    }
    let res = match args.command.as_deref() {
        Some("train") => cmd_train(args),
        Some("node") => cmd_node(args),
        // hidden: TCP echo peer used by the transport conformance
        // suite's kill-and-resume case
        Some("net-echo") => cmd_net_echo(args),
        Some("table1") => cmd_table1(args),
        Some("fig4") => cmd_fig4(args),
        Some("fig6") => cmd_fig6(args),
        Some("fig7") => cmd_fig7(args),
        Some("fig8") => cmd_fig8(args),
        Some("fig-time") => cmd_fig_time(args),
        Some("fig-robust") => cmd_fig_robust(args),
        Some("sweep") => cmd_sweep(args),
        Some("analyse") | Some("analyze") => cmd_analyse(args),
        Some("topo") => cmd_topo(args),
        Some("quant") => cmd_quant(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("trace") => cmd_trace(args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    // flush sinks even when the command failed — a partial trace is
    // exactly what you want for debugging the failure
    if obs::active() {
        match obs::stop() {
            Ok(paths) => {
                for p in paths {
                    log::verbose(format!("wrote trace sink {p}"));
                }
            }
            Err(e) => eprintln!("warning: trace flush failed: {e:#}"),
        }
    }
    res
}

/// The `--trace-out` / `--chrome-out` sinks, when either is present.
fn observe_from_flags(args: &Args) -> Option<ObserveConfig> {
    let o = ObserveConfig {
        trace_path: args.get("trace-out").map(str::to_string),
        chrome_path: args.get("chrome-out").map(str::to_string),
    };
    o.enabled().then_some(o)
}

/// `lmdfl trace`: validate (`--check`) or summarize a JSONL trace,
/// optionally re-rendering it as a Chrome trace (`--chrome-out`).
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("in"))
        .ok_or_else(|| {
            anyhow::anyhow!("usage: lmdfl trace <file.jsonl> [--check]")
        })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let tf = obs::export::parse_trace(&text)?;
    if args.has_flag("check") {
        // machine-consumed (CI greps it): bypass the log level
        println!("{}", obs::summary::check(&tf)?);
        return Ok(());
    }
    if let Some(out) = args.get("chrome-out") {
        std::fs::write(
            out,
            obs::export::chrome_trace(&obs::export::chrome_spans(
                &tf.spans,
            )),
        )?;
        log::info(format!("wrote {out}"));
    }
    print!("{}", obs::summary::summarize(&tf));
    Ok(())
}

fn config_from_args(args: &Args) -> anyhow::Result<ExperimentConfig> {
    // a --config file is the base; the sectioned flags further down
    // (transport, network, encoding, mode, async) still layer on top,
    // so one file re-runs over a different fabric without editing it
    let mut cfg = if let Some(path) = args.get("config") {
        load_config(Path::new(path))?
    } else {
        inline_config(args)?
    };
    apply_section_flags(args, &mut cfg)?;
    Ok(cfg)
}

/// Build an [`ExperimentConfig`] purely from inline CLI flags (no
/// `--config` file given).
fn inline_config(args: &Args) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = args.get_or("name", "cli").to_string();
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.rounds = args.get_usize("rounds", cfg.rounds)?;
    cfg.tau = args.get_usize("tau", cfg.tau)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.batch_size = args.get_usize("batch", cfg.batch_size)?;
    cfg.lr = LrSchedule::fixed(
        args.get_f64("lr", cfg.lr.base)?);
    let s = args.get_usize("s", 16)?;
    if let Some(q) = args.get("quantizer") {
        cfg.quantizer = match q {
            "full" => QuantizerKind::Full,
            "qsgd" => QuantizerKind::Qsgd { s },
            "natural" => QuantizerKind::Natural { s },
            "alq" => QuantizerKind::Alq { s },
            "lloyd_max" | "lm" => QuantizerKind::LloydMax { s, iters: 12 },
            "terngrad" => QuantizerKind::TernGrad,
            "topk" => QuantizerKind::TopK {
                keep: args.get_f64("keep", 0.1)?,
            },
            "doubly_adaptive" | "da" => QuantizerKind::DoublyAdaptive {
                s1: args.get_usize("s1", 4)?,
                iters: 12,
                s_max: args.get_usize("s-max", 4096)?,
            },
            other => anyhow::bail!("unknown quantizer '{other}'"),
        };
    }
    if let Some(d) = args.get("dataset") {
        cfg.dataset = match d {
            "synth_mnist" | "mnist" => DatasetKind::SynthMnist {
                train: args.get_usize("train", 2000)?,
                test: args.get_usize("test", 500)?,
            },
            "synth_cifar" | "cifar" => DatasetKind::SynthCifar {
                train: args.get_usize("train", 2000)?,
                test: args.get_usize("test", 500)?,
            },
            "blobs" => DatasetKind::Blobs {
                train: args.get_usize("train", 2000)?,
                test: args.get_usize("test", 500)?,
                dim: args.get_usize("dim", 32)?,
                classes: args.get_usize("classes", 10)?,
            },
            other => anyhow::bail!("unknown dataset '{other}'"),
        };
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = match t {
            "full" => TopologyKind::Full,
            "ring" => TopologyKind::Ring,
            "disconnected" => TopologyKind::Disconnected,
            "star" => TopologyKind::Star,
            "torus" => TopologyKind::Torus,
            "random" => TopologyKind::Random {
                p: args.get_f64("p", 0.4)?,
            },
            other => anyhow::bail!("unknown topology '{other}'"),
        };
    }
    if let Some(a) = args.get("hlo") {
        cfg.backend = BackendKind::Hlo {
            artifact: a.to_string(),
        };
    }
    if let Some(p) = args.get("parallelism") {
        cfg.parallelism = Parallelism::parse_str(p)?;
    }
    Ok(cfg)
}

/// Apply the sectioned flags — transport, network (simnet), encoding,
/// mode and async — over `cfg`, whichever source built it.
fn apply_section_flags(
    args: &Args,
    cfg: &mut ExperimentConfig,
) -> anyhow::Result<()> {
    // delivery transport: which net::Delivery the threaded runtime
    // uses; any flag present materializes a `transport:` section
    let tcp_keys = [
        "tcp-host",
        "tcp-base-port",
        "tcp-connect-timeout-s",
        "tcp-backoff-s",
    ];
    if args.get("transport").is_some()
        || tcp_keys.iter().any(|k| args.get(k).is_some())
    {
        let mut t = cfg.transport.clone().unwrap_or_default();
        if let Some(k) = args.get("transport") {
            t.kind = TransportKind::parse_str(k)?;
        }
        if let Some(h) = args.get("tcp-host") {
            t.tcp.host = h.to_string();
        }
        let bp =
            args.get_usize("tcp-base-port", t.tcp.base_port as usize)?;
        anyhow::ensure!(
            (1..=65535).contains(&bp),
            "--tcp-base-port {bp} outside 1..=65535"
        );
        t.tcp.base_port = bp as u16;
        t.tcp.connect_timeout_s = args
            .get_f64("tcp-connect-timeout-s", t.tcp.connect_timeout_s)?;
        t.tcp.retry_backoff_s =
            args.get_f64("tcp-backoff-s", t.tcp.retry_backoff_s)?;
        cfg.transport = Some(t);
    }
    // network (simnet) flags: any of them present materializes a
    // `network:` section (over the config file's, when both are given)
    let net_keys = [
        "net-latency-s",
        "net-bandwidth-bps",
        "net-jitter-s",
        "net-drop",
        "net-link-spread",
        "compute-step-s",
        "compute-spread",
        "straggler-prob",
        "straggler-slowdown",
        "churn-interval",
        "churn-link-fail",
        "churn-link-heal",
        "churn-node-leave",
        "churn-node-return",
    ];
    if net_keys.iter().any(|k| args.get(k).is_some()) {
        let mut net = cfg.network.clone().unwrap_or_default();
        net.link.latency_s =
            args.get_f64("net-latency-s", net.link.latency_s)?;
        net.link.bandwidth_bps =
            args.get_f64("net-bandwidth-bps", net.link.bandwidth_bps)?;
        net.link.jitter_s =
            args.get_f64("net-jitter-s", net.link.jitter_s)?;
        net.link.drop_prob = args.get_f64("net-drop", net.link.drop_prob)?;
        net.link_hetero_spread =
            args.get_f64("net-link-spread", net.link_hetero_spread)?;
        net.compute.base_step_s =
            args.get_f64("compute-step-s", net.compute.base_step_s)?;
        net.compute.hetero_spread =
            args.get_f64("compute-spread", net.compute.hetero_spread)?;
        net.compute.straggler_prob =
            args.get_f64("straggler-prob", net.compute.straggler_prob)?;
        net.compute.straggler_slowdown = args
            .get_f64("straggler-slowdown", net.compute.straggler_slowdown)?;
        net.churn.interval_rounds =
            args.get_usize("churn-interval", net.churn.interval_rounds)?;
        net.churn.link_fail_prob =
            args.get_f64("churn-link-fail", net.churn.link_fail_prob)?;
        net.churn.link_heal_prob =
            args.get_f64("churn-link-heal", net.churn.link_heal_prob)?;
        net.churn.node_leave_prob =
            args.get_f64("churn-node-leave", net.churn.node_leave_prob)?;
        net.churn.node_return_prob =
            args.get_f64("churn-node-return", net.churn.node_return_prob)?;
        cfg.network = Some(net);
    }
    // broadcast transport: real codec bitstreams (default) or the
    // legacy matrix exchange (bit-identical models either way)
    if let Some(e) = args.get("encoding") {
        cfg.encoding = WireEncoding::parse_str(e)?;
    }
    // engine mode + async (agossip) flags
    if let Some(m) = args.get("mode") {
        cfg.mode = EngineMode::parse_str(m)?;
    }
    let async_keys = [
        "async-wait-for",
        "async-quorum",
        "async-staleness",
        "async-lambda",
        "async-timeout-s",
    ];
    if async_keys.iter().any(|k| args.get(k).is_some()) {
        let mut a = cfg.agossip.clone().unwrap_or_default();
        // count defaults come from the config's current policy, so a
        // redundant --async-wait-for never resets a configured k/τ
        let cur_k = match a.wait_for {
            WaitPolicy::Quorum { k } => k,
            _ => 2,
        };
        let cur_tau = match a.wait_for {
            WaitPolicy::Staleness { tau } => tau,
            _ => 2,
        };
        match args.get("async-wait-for") {
            Some("all") => {
                if args.get("async-quorum").is_some()
                    || args.get("async-staleness").is_some()
                {
                    anyhow::bail!(
                        "--async-wait-for all takes no count flag"
                    );
                }
                a.wait_for = WaitPolicy::All;
            }
            Some("quorum") => {
                anyhow::ensure!(
                    args.get("async-staleness").is_none(),
                    "--async-staleness contradicts --async-wait-for \
                     quorum"
                );
                a.wait_for = WaitPolicy::Quorum {
                    k: args.get_usize("async-quorum", cur_k)?,
                };
            }
            Some("staleness") => {
                anyhow::ensure!(
                    args.get("async-quorum").is_none(),
                    "--async-quorum contradicts --async-wait-for \
                     staleness"
                );
                a.wait_for = WaitPolicy::Staleness {
                    tau: args.get_usize("async-staleness", cur_tau)?,
                };
            }
            Some(other) => {
                anyhow::bail!("unknown --async-wait-for '{other}'")
            }
            None => {
                // a bare count flag selects the matching policy;
                // quorum wins a conflict, same as the JSON parser
                if args.get("async-quorum").is_some() {
                    a.wait_for = WaitPolicy::Quorum {
                        k: args.get_usize("async-quorum", cur_k)?,
                    };
                } else if args.get("async-staleness").is_some() {
                    a.wait_for = WaitPolicy::Staleness {
                        tau: args.get_usize("async-staleness", cur_tau)?,
                    };
                }
            }
        }
        a.staleness_lambda =
            args.get_f64("async-lambda", a.staleness_lambda)?;
        a.quorum_timeout_s =
            args.get_f64("async-timeout-s", a.quorum_timeout_s)?;
        cfg.agossip = Some(a);
    }
    // adversarial scenario: Byzantine roles (`attack:` section) and
    // the mixing rule defending against them
    if args.get("attack").is_some()
        || args.get("attack-f").is_some()
        || args.get("attack-factor").is_some()
    {
        let base = cfg.attack.clone();
        let cur_factor = match base.as_ref().map(|a| &a.kind) {
            Some(AttackKind::Scale { factor }) => *factor,
            _ => -4.0,
        };
        let kind = match args.get("attack") {
            Some("none") => None,
            Some("sign_flip") => Some(AttackKind::SignFlip),
            Some("scale") => Some(AttackKind::Scale {
                factor: args.get_f64("attack-factor", cur_factor)?,
            }),
            Some("random") => Some(AttackKind::Random),
            Some(other) => anyhow::bail!(
                "--attack must be none, sign_flip, scale or random, \
                 got '{other}'"
            ),
            None => base.as_ref().map(|a| a.kind.clone()),
        };
        match kind {
            Some(kind) => {
                let f = args
                    .get_usize("attack-f", base.map_or(1, |a| a.f))?;
                cfg.attack = Some(AttackConfig { kind, f });
            }
            None => {
                anyhow::ensure!(
                    args.get("attack").is_some(),
                    "--attack-f / --attack-factor need --attack (or an \
                     attack: section in the config file)"
                );
                cfg.attack = None;
            }
        }
    }
    if let Some(m) = args.get("mixing") {
        cfg.mixing = MixingKind::parse_str(m)?;
    }
    // trace sinks: either flag materializes an `observe:` section,
    // each overriding only its own path in the config file's section
    if let Some(o) = observe_from_flags(args) {
        let mut cur = cfg.observe.clone().unwrap_or_default();
        if o.trace_path.is_some() {
            cur.trace_path = o.trace_path;
        }
        if o.chrome_path.is_some() {
            cur.chrome_path = o.chrome_path;
        }
        cfg.observe = Some(cur);
    }
    cfg.validate()?;
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from_args(args)?;
    if let Some(o) = &cfg.observe {
        obs::start(o, 0);
    }
    log::info(format!("config:\n{}", cfg.to_json().to_pretty()));
    let simulate = args.has_flag("simulate")
        || cfg.network.is_some()
        || cfg.mode == EngineMode::Async;
    let tcp = cfg
        .transport
        .as_ref()
        .is_some_and(|t| t.kind == TransportKind::Tcp);
    if tcp && (args.has_flag("simulate") || !args.has_flag("threaded")) {
        anyhow::bail!(
            "transport tcp moves real bytes over sockets: it needs the \
             threaded runtime (add --threaded, drop --simulate), or \
             launch one process per node with `lmdfl node --rank R`"
        );
    }
    if args.has_flag("threaded") && args.has_flag("simulate") {
        anyhow::bail!(
            "--threaded and --simulate are mutually exclusive: the \
             threaded runtime runs on real OS threads (no virtual clock)"
        );
    }
    if args.has_flag("threaded") && cfg.mode == EngineMode::Async {
        anyhow::bail!(
            "--threaded runs the synchronous protocol on real OS \
             threads; async mode needs the simulated engine"
        );
    }
    if args.has_flag("threaded") && cfg.encoding == WireEncoding::Matrix {
        anyhow::bail!(
            "--encoding matrix applies to the simulated engines only: \
             the threaded runtime always ships encoded wire frames"
        );
    }
    // --stream-csv: large-fleet path — write each round record to the
    // file as it is produced instead of buffering a RunLog (same bytes
    // as --csv; see rust/tests/streaming_parity.rs)
    if let Some(path) = args.get("stream-csv") {
        if cfg.mode == EngineMode::Async {
            anyhow::bail!(
                "--stream-csv streams sync round records; async runs \
                 buffer a merged log (use --csv)"
            );
        }
        let file = std::fs::File::create(path)?;
        let mut sink = lmdfl::metrics::CsvStream::new(
            std::io::BufWriter::new(file),
        )?;
        let s = if args.has_flag("threaded") {
            // the threaded coordinator streams its report plane too
            // (same records, same order as --csv; see
            // rust/tests/streaming_parity.rs)
            let mut link = cfg
                .network
                .as_ref()
                .map(|n| n.link.clone())
                .unwrap_or_else(LinkModel::ideal);
            link.drop_prob = args.get_f64("drop-prob", link.drop_prob)?;
            Trainer::run_threaded_streamed(
                &cfg,
                NetOptions { link, eval_every: cfg.eval_every },
                &mut sink,
            )?
        } else {
            let mut sim_cfg = cfg.clone();
            if simulate && sim_cfg.network.is_none() {
                sim_cfg.network = Some(Default::default());
            }
            Trainer::run_streamed(&sim_cfg, &mut sink)?
        };
        sink.finish()?;
        log::info(format!(
            "streamed {} rounds to {path}: loss={} acc={} \
             bits/link={} wire-bytes={} virtual={:.3}s peak-rss={}",
            s.rounds,
            fnum(s.last_loss),
            fnum(s.final_accuracy),
            s.total_bits,
            s.wire_bytes,
            s.virtual_secs,
            s.peak_rss_bytes
                .map(|b| format!("{:.1}MiB", b as f64 / (1 << 20) as f64))
                .unwrap_or_else(|| "n/a".into()),
        ));
        return Ok(());
    }
    let log = if args.has_flag("threaded") {
        if cfg.network.is_some() {
            eprintln!(
                "note: --threaded uses only the network link's drop_prob; \
                 latency/bandwidth/stragglers/churn need the simulated \
                 engine (drop --threaded)"
            );
        }
        let mut link = cfg
            .network
            .as_ref()
            .map(|n| n.link.clone())
            .unwrap_or_else(LinkModel::ideal);
        // legacy knob: --drop-prob still works (now a LinkModel field)
        link.drop_prob = args.get_f64("drop-prob", link.drop_prob)?;
        Trainer::run_threaded(
            &cfg,
            NetOptions { link, eval_every: cfg.eval_every },
        )?
    } else if simulate {
        let mut sim_cfg = cfg.clone();
        if sim_cfg.network.is_none() {
            sim_cfg.network = Some(Default::default());
        }
        Trainer::run_simulated(&sim_cfg)?
    } else {
        Trainer::build(&cfg)?.run()?
    };
    let mut t = Table::new(&[
        "round", "loss", "acc", "bits/link", "s_k", "virt_s",
    ]);
    let stride = (log.records.len() / 20).max(1);
    for r in log.records.iter().step_by(stride) {
        t.row(vec![
            r.round.to_string(),
            fnum(r.loss),
            fnum(r.accuracy),
            r.bits_per_link.to_string(),
            r.levels.to_string(),
            format!("{:.3}", r.virtual_secs),
        ]);
    }
    log::info(t.render());
    log::info(format!(
        "final: loss={} acc={} bits/link={} wire-bytes={} \
         time@{}Mbps={:.1}ms",
        fnum(log.last_loss().unwrap_or(f64::NAN)),
        fnum(log.final_accuracy().unwrap_or(f64::NAN)),
        log.total_bits(),
        log.records.last().map_or(0, |r| r.wire_bytes),
        cfg.link_bps / 1e6,
        log.total_bits() as f64 / cfg.link_bps * 1e3,
    ));
    if let Some(last) = log.records.last() {
        if last.virtual_secs > 0.0 {
            log::info(format!(
                "simnet: virtual time {:.3}s, mean straggler wait {:.4}s",
                last.virtual_secs,
                log.records
                    .iter()
                    .map(|r| r.straggler_wait_secs)
                    .sum::<f64>()
                    / log.records.len() as f64,
            ));
        }
    }
    if let Some(csv) = args.get("csv") {
        log.write_csv(Path::new(csv))?;
        log::info(format!("wrote {csv}"));
    }
    Ok(())
}

fn cmd_node(args: &Args) -> anyhow::Result<()> {
    args.require("rank")?;
    let rank = args.get_usize("rank", 0)?;
    let mut cfg = config_from_args(args)?;
    // `node` is the multi-process entry point: the transport is TCP by
    // definition (the config may still tune host/ports/timeouts)
    let mut t = cfg
        .transport
        .clone()
        .unwrap_or_else(TransportConfig::tcp_default);
    t.kind = TransportKind::Tcp;
    cfg.transport = Some(t.clone());
    cfg.validate()?;
    eprintln!(
        "node {rank}/{}: listening on {}:{}",
        cfg.nodes,
        t.tcp.host,
        t.tcp.base_port as usize + rank,
    );
    // every rank records into its own sink files (rank_path suffixes);
    // rank 0's report plane merges the JSONL traces once all ranks'
    // end footers land
    let observe = cfg.observe.clone();
    if let Some(o) = &observe {
        let per_rank = ObserveConfig {
            trace_path: o
                .trace_path
                .as_deref()
                .map(|p| obs::export::rank_path(p, rank)),
            chrome_path: o
                .chrome_path
                .as_deref()
                .map(|p| obs::export::rank_path(p, rank)),
        };
        obs::start(&per_rank, rank);
    }
    let run_res = run_node_process(&cfg, rank);
    // flush this rank's trace before inspecting the result: a partial
    // trace of a failed run is still wanted, and the merge below needs
    // rank 0's own file complete
    if obs::active() {
        match obs::stop() {
            Ok(paths) => {
                for p in paths {
                    log::verbose(format!("wrote trace sink {p}"));
                }
            }
            Err(e) => eprintln!("warning: trace flush failed: {e:#}"),
        }
    }
    if let Some(log) = run_res? {
        log::info(format!(
            "final: loss={} acc={} bits/link={} wire-bytes={}",
            fnum(log.last_loss().unwrap_or(f64::NAN)),
            fnum(log.final_accuracy().unwrap_or(f64::NAN)),
            log.total_bits(),
            log.records.last().map_or(0, |r| r.wire_bytes),
        ));
        if let Some(csv) = args.get("csv") {
            log.write_csv(Path::new(csv))?;
            log::info(format!("wrote {csv}"));
        }
    }
    if rank == 0 {
        if let Some(base) =
            observe.as_ref().and_then(|o| o.trace_path.as_deref())
        {
            let msg = obs::export::merge_ranks(
                base,
                cfg.nodes,
                Duration::from_secs(10),
            )?;
            log::info(msg);
            if let Some(cp) = observe
                .as_ref()
                .and_then(|o| o.chrome_path.as_deref())
            {
                let text = std::fs::read_to_string(base)?;
                let tf = obs::export::parse_trace(&text)?;
                std::fs::write(
                    cp,
                    obs::export::chrome_trace(
                        &obs::export::chrome_spans(&tf.spans),
                    ),
                )?;
                log::info(format!("wrote merged chrome trace {cp}"));
            }
        }
    }
    Ok(())
}

/// Phase tag a `net-echo` peer announces itself with (outside the
/// protocol's 0..=3 range and the report plane's 0xFE).
const HELLO_PHASE: u8 = 0xFD;

/// Hidden helper for the transport conformance suite: bind a
/// [`TcpDelivery`] at `--rank`, send a hello frame to `--peer`, then
/// echo `--count` frames back to their sender. Killing and respawning
/// this process exercises the transport's reconnect path.
fn cmd_net_echo(args: &Args) -> anyhow::Result<()> {
    args.require("rank")?;
    let rank = args.get_usize("rank", 0)?;
    let peer = args.get_usize("peer", 0)?;
    let count = args.get_usize("count", 5)?;
    let mut opts = TcpOptions::default();
    if let Some(h) = args.get("host") {
        opts.host = h.to_string();
    }
    let bp = args.get_usize("base-port", opts.base_port as usize)?;
    anyhow::ensure!(
        (1..=65535).contains(&bp),
        "--base-port {bp} outside 1..=65535"
    );
    opts.base_port = bp as u16;
    let mut d = TcpDelivery::bind(rank, opts)?;
    d.send(
        peer,
        Frame::new(rank, 0, HELLO_PHASE, Arc::from(&[0xAA][..])),
    )?;
    let mut echoed = 0usize;
    while echoed < count {
        match d.recv(Duration::from_secs(30))? {
            Some(f) if f.phase == HELLO_PHASE => continue,
            Some(f) => {
                d.send(
                    f.from,
                    Frame::new(rank, f.round, f.phase, f.bytes),
                )?;
                echoed += 1;
            }
            None => anyhow::bail!("net-echo: no frame within 30s"),
        }
    }
    Ok(())
}

fn cmd_fig_time(args: &Args) -> anyhow::Result<()> {
    // --from-sweep: rebuild the tables from a sweep's per-cell round
    // CSVs (one curve per completed cell) — no training runs here
    if let Some(manifest) = args.get("from-sweep") {
        let curves =
            fig_time::curves_from_sweep(Path::new(manifest))?;
        log::info(format!(
            "fig-time from sweep {manifest}: {} curve(s)",
            curves.len()
        ));
        log::info(fig_time::render_loss_vs_time(&curves));
        let default_target = curves
            .iter()
            .map(|c| c.log.last_loss().unwrap_or(f64::NAN))
            .fold(f64::MIN, f64::max)
            * 1.1;
        let target = args.get_f64("target-loss", default_target)?;
        log::info(fig_time::time_to_target(&curves, target));
        return Ok(());
    }
    let scale = scale_of(args);
    let preset_name = args.get_or("preset", "torus-16");
    let (cfg, net) =
        fig_time::preset(preset_name, scale)?;
    log::info(format!(
        "fig-time preset {preset_name}: {} nodes, {} topology, \
         {:.1} Mbps links, straggler p={}",
        cfg.nodes,
        cfg.topology.name(),
        net.link.bandwidth_bps / 1e6,
        net.compute.straggler_prob,
    ));
    let curves =
        fig_time::run_preset(preset_name, cfg, net)?;
    log::info(fig_time::render_loss_vs_time(&curves));
    let default_target = curves
        .iter()
        .map(|c| c.log.last_loss().unwrap_or(f64::NAN))
        .fold(f64::MIN, f64::max)
        * 1.1;
    let target = args.get_f64("target-loss", default_target)?;
    log::info(fig_time::time_to_target(&curves, target));
    Ok(())
}

/// `lmdfl fig-robust`: honest loss vs measured wire bytes under an
/// f=2 sign-flip minority, one curve per mixing rule.
fn cmd_fig_robust(args: &Args) -> anyhow::Result<()> {
    let scale = scale_of(args);
    let cfg = fig_robust::robust_config(scale);
    let net = fig_robust::robust_network();
    let atk = cfg.attack.as_ref().expect("preset is attacked");
    log::info(format!(
        "fig-robust: {} nodes, {} topology, {} attack f={}, \
         {:.1} Mbps links",
        cfg.nodes,
        cfg.topology.name(),
        atk.kind.name(),
        atk.f,
        net.link.bandwidth_bps / 1e6,
    ));
    let curves = fig_robust::run(cfg, net)?;
    log::info(fig_robust::render_loss_vs_bytes(&curves));
    // default target: just above the best robust curve's final honest
    // loss, so the table shows what the plain row failed to reach
    let default_target = curves[1..]
        .iter()
        .map(|c| c.log.last_loss().unwrap_or(f64::NAN))
        .fold(f64::MIN, f64::max)
        * 1.05;
    let target = args.get_f64("target-loss", default_target)?;
    log::info(fig_robust::bytes_to_target(&curves, target));
    Ok(())
}

/// `lmdfl sweep`: expand a grid over a base config and run every
/// cell to one manifest (see [`lmdfl::sweep`]).
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    // base config: a fig-time preset (with its fabric) or the plain
    // train config flags / --config file
    let mut cfg = if let Some(preset) = args.get("preset") {
        let (mut cfg, net) =
            fig_time::preset(preset, scale_of(args))?;
        cfg.network = Some(net);
        cfg.rounds = args.get_usize("rounds", cfg.rounds)?;
        cfg.seed = args.get_u64("seed", cfg.seed)?;
        // section flags (--mixing, --attack, --encoding, net knobs, …)
        // refine the preset base just like a --config base
        apply_section_flags(args, &mut cfg)?;
        cfg
    } else {
        config_from_args(args)?
    };
    if let Some(name) = args.get("name") {
        cfg.name = name.to_string();
    }

    let mut grid = Grid::from_base(&cfg);
    if let Some(list) = args.get("quantizers") {
        grid.set_quantizers(list)?;
    }
    if let Some(list) = args.get("topologies") {
        grid.set_topologies(list)?;
    }
    if let Some(list) = args.get("nets") {
        grid.set_nets(list)?;
    }
    if let Some(list) = args.get("modes") {
        grid.set_modes(list)?;
    }
    if let Some(list) = args.get("attacks") {
        grid.set_attacks(list)?;
    }
    if let Some(list) = args.get("seed-list") {
        grid.set_seed_list(list)?;
    } else {
        let repeats = args.get_usize("seeds", 1)?;
        grid.set_seed_repeats(cfg.seed, repeats);
    }

    let opts = SweepOptions {
        out_dir: args.get_or("out", "sweep-out").into(),
        slots: args.get_usize("slots", 0)?,
        resume: !args.has_flag("no-resume"),
        ..Default::default()
    };
    let manifest = sweep::run_sweep(&cfg, &grid, &opts)?;

    let mut t = Table::new(&[
        "cell", "status", "rounds", "loss", "virt_s", "wire MB",
        "peak rss",
    ]);
    for c in &manifest.cells {
        t.row(vec![
            c.id.clone(),
            if c.timing.cached {
                format!("{} (cached)", c.status)
            } else {
                c.status.clone()
            },
            c.rounds.to_string(),
            fnum(c.last_loss),
            format!("{:.2}", c.virtual_secs),
            format!("{:.3}", c.wire_bytes as f64 / 1e6),
            format!(
                "{:.1}MiB",
                c.timing.peak_rss_bytes as f64 / (1 << 20) as f64
            ),
        ]);
    }
    log::info(t.render());
    let ok = manifest.cells.iter().filter(|c| c.ok()).count();
    log::info(format!(
        "sweep {}: {}/{} cells ok -> {}",
        manifest.name,
        ok,
        manifest.cells.len(),
        opts.out_dir.join("manifest.json").display(),
    ));
    anyhow::ensure!(
        ok == manifest.cells.len(),
        "{} cell(s) failed",
        manifest.cells.len() - ok
    );
    Ok(())
}

/// `lmdfl analyse <manifest.json>`: roll every cell's trace up into
/// tidy CSVs (see [`lmdfl::sweep::analyse`]).
fn cmd_analyse(args: &Args) -> anyhow::Result<()> {
    let manifest = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("manifest"))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "usage: lmdfl analyse <sweep-out/manifest.json> \
                 [--out dir]"
            )
        })?;
    let manifest = Path::new(manifest);
    let out = match args.get("out") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => manifest
            .parent()
            .unwrap_or_else(|| Path::new("."))
            .join("analysis"),
    };
    for path in sweep::analyse::analyse(manifest, &out)? {
        log::info(format!("wrote {}", path.display()));
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> anyhow::Result<()> {
    let trials = args.get_usize("trials", 3)?;
    let mut rows = Vec::new();
    for d in [1000usize, 10_000, 100_000] {
        for s in [4usize, 16, 64, 256] {
            for dist in ["gaussian", "laplace", "gradient"] {
                rows.extend(table1::measure(
                    d, s, dist, trials, 42));
            }
        }
    }
    log::info(table1::render(&rows));
    Ok(())
}

fn cmd_fig4(args: &Args) -> anyhow::Result<()> {
    let curves = fig4::run_mnist(scale_of(args))?;
    log::info(fig8::render_loss_vs_bits(&curves));
    log::info(fig8::render_bits_per_element(&curves));
    log::info(fig8::render_wire_totals(&curves));
    Ok(())
}

fn cmd_fig6(args: &Args) -> anyhow::Result<()> {
    let scale = scale_of(args);
    let curves = match args.get_or("dataset", "mnist") {
        "cifar" => fig6::run_cifar(scale)?,
        _ => fig6::run_mnist(scale)?,
    };
    log::info(fig6::render_panels(&curves, 100e6));
    Ok(())
}

fn cmd_fig7(args: &Args) -> anyhow::Result<()> {
    for (label, zeta) in fig7::zetas(10) {
        log::info(format!("{label}: zeta = {zeta:.4}"));
    }
    let curves = fig7::run(scale_of(args))?;
    log::info(fig7::render(&curves));
    Ok(())
}

fn cmd_fig8(args: &Args) -> anyhow::Result<()> {
    let scale = scale_of(args);
    let var = args.has_flag("variable-lr");
    let curves = match args.get_or("dataset", "mnist") {
        "cifar" => fig8::run_cifar(scale, var)?,
        _ => fig8::run_mnist(scale, var)?,
    };
    log::info(fig8::render_loss_vs_bits(&curves));
    log::info(fig8::render_bits_per_element(&curves));
    log::info(fig8::render_wire_totals(&curves));
    Ok(())
}

fn cmd_topo(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("nodes", 10)?;
    let kind = match args.get_or("kind", "ring") {
        "full" => TopologyKind::Full,
        "ring" => TopologyKind::Ring,
        "disconnected" => TopologyKind::Disconnected,
        "star" => TopologyKind::Star,
        "torus" => TopologyKind::Torus,
        "random" => TopologyKind::Random { p: args.get_f64("p", 0.4)? },
        "random_regular" => TopologyKind::RandomRegular {
            k: args.get_usize("k", 4)?,
        },
        other => anyhow::bail!("unknown topology '{other}'"),
    };
    let t = Topology::build(
        &kind, n, args.get_u64("seed", 0)?);
    log::info(format!(
        "topology: {} n={} zeta={:.6} alpha={:.4} connected={}",
        kind.name(),
        n,
        t.zeta,
        t.alpha(),
        t.is_connected()
    ));
    log::info(format!("directed links: {}", t.directed_links()));
    if n <= 12 {
        log::info("confusion matrix C:");
        for i in 0..n {
            let row: Vec<String> =
                (0..n)
                    .map(|j| format!("{:.3}", t.weight(i, j)))
                    .collect();
            log::info(format!("  [{}]", row.join(" ")));
        }
    }
    Ok(())
}

fn cmd_quant(args: &Args) -> anyhow::Result<()> {
    let d = args.get_usize("d", 100_000)?;
    let mut t = Table::new(&[
        "s", "bits/elem", "C_s (bits)", "vs f32", "QSGD bound",
        "natural bound", "LM bound",
    ]);
    for s in [2usize, 4, 16, 50, 64, 100, 256, 1024, 16384] {
        let cs = bits::c_s(d, s);
        let full = bits::full_precision_bits(d);
        t.row(vec![
            s.to_string(),
            bits::bits_per_element(s).to_string(),
            cs.to_string(),
            format!("{:.1}x", full as f64 / cs as f64),
            fnum(distortion::qsgd_bound(d, s)),
            fnum(distortion::natural_bound(d, s)),
            fnum(distortion::lm_bound(d, s)),
        ]);
    }
    log::info(format!("d = {d}"));
    log::info(t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let m = Manifest::load(&dir)?;
    let mut t = Table::new(&["artifact", "kind", "params", "batch", "file"]);
    for (name, a) in &m.artifacts {
        t.row(vec![
            name.clone(),
            a.kind.clone(),
            a.params.map(|p| p.to_string()).unwrap_or_default(),
            a.batch.map(|b| b.to_string()).unwrap_or_default(),
            a.file.file_name().unwrap().to_string_lossy().to_string(),
        ]);
    }
    log::info(t.render());
    Ok(())
}
