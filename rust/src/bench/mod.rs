//! Micro-benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::run`]: auto-calibrated iteration counts, warmup, and a
//! mean/std/min/p50/p95 report in criterion-like format. Figure benches
//! also use it to time end-to-end rounds.
//!
//! # Machine-readable reports
//!
//! Alongside the text report, [`Bencher::finish`] emits a JSON document
//! (`BENCH_<target>.json`) so CI can archive the perf trajectory across
//! PRs. Set `LMDFL_BENCH_JSON=<dir>` to enable it (the CI bench-smoke job
//! does; unset = no file I/O). Schema (`lmdfl-bench-v1`):
//!
//! ```json
//! {
//!   "schema": "lmdfl-bench-v1",
//!   "bench": "micro_runtime",
//!   "peak_rss_bytes": 123456789,
//!   "results": [
//!     {"name": "...", "mean_s": 1e-3, "std_s": 1e-5, "min_s": 9e-4,
//!      "p50_s": 1e-3, "p95_s": 1.2e-3, "samples": 20,
//!      "elems_per_iter": 1000, "elems_per_s": 1e6}
//!   ]
//! }
//! ```
//!
//! `peak_rss_bytes` is the process high-water mark
//! ([`peak_rss_bytes`]); it is omitted on platforms without
//! `/proc/self/status`.
//!
//! Environment knobs: `LMDFL_BENCH_QUICK=1` shrinks the measurement budget
//! (CI smoke), `LMDFL_BENCH_JSON=<dir>` enables the JSON artifact.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::config::json::Json;
use crate::util::stats::percentile;

/// One benchmark's timing results (per-iteration seconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    /// optional elements-processed per iteration for throughput reporting
    pub elems_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        let v = self
            .samples
            .iter()
            .map(|&x| (x - m) * (x - m))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        v.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.sorted(), 0.5)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.sorted(), 0.95)
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<40} mean {:>12}  std {:>10}  min {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            fmt_time(self.mean()),
            fmt_time(self.std()),
            fmt_time(self.min()),
            fmt_time(self.p50()),
            fmt_time(self.p95()),
        );
        if let Some(n) = self.elems_per_iter {
            let rate = n as f64 / self.mean();
            line.push_str(&format!("  [{}/s]", fmt_count(rate)));
        }
        line
    }

    /// Machine-readable form (see module docs for the schema).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("mean_s", Json::num(self.mean())),
            ("std_s", Json::num(self.std())),
            ("min_s", Json::num(self.min())),
            ("p50_s", Json::num(self.p50())),
            ("p95_s", Json::num(self.p95())),
            ("samples", Json::num(self.samples.len() as f64)),
        ];
        if let Some(n) = self.elems_per_iter {
            pairs.push(("elems_per_iter", Json::num(n as f64)));
            pairs.push(("elems_per_s", Json::num(n as f64 / self.mean())));
        }
        Json::obj(pairs)
    }
}

/// Pretty time: ns/µs/ms/s.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Pretty count: K/M/G suffixes.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner.
pub struct Bencher {
    /// target seconds of measurement per benchmark
    pub measure_secs: f64,
    /// warmup seconds before measuring
    pub warmup_secs: f64,
    /// number of measured samples
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor quick runs: LMDFL_BENCH_QUICK=1 shrinks the budget so CI
        // and `cargo bench` smoke passes stay fast.
        let quick = std::env::var("LMDFL_BENCH_QUICK").is_ok();
        Bencher {
            measure_secs: if quick { 0.05 } else { 1.0 },
            warmup_secs: if quick { 0.01 } else { 0.2 },
            samples: if quick { 5 } else { 20 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, auto-calibrating inner iterations. `f` must do one unit of
    /// work per call; use `black_box` on its result in the caller.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_with_elems(name, None, &mut f)
    }

    /// As [`run`], also recording an elements-per-iteration figure so the
    /// report includes throughput.
    pub fn run_elems<F: FnMut()>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: F,
    ) -> &BenchResult {
        self.run_with_elems(name, Some(elems), &mut f)
    }

    fn run_with_elems(
        &mut self,
        name: &str,
        elems: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // warmup + calibration: find iters/sample so each sample ~
        // measure_secs / samples
        let mut iters_per_sample = 1u64;
        let warm_deadline = Instant::now();
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let dt = t.elapsed().as_secs_f64();
            if warm_deadline.elapsed().as_secs_f64() > self.warmup_secs
                && dt * self.samples as f64 >= self.measure_secs * 0.5
            {
                break;
            }
            if dt * (self.samples as f64) < self.measure_secs {
                iters_per_sample = (iters_per_sample * 2).min(1 << 30);
            } else {
                break;
            }
            if warm_deadline.elapsed().as_secs_f64() > self.warmup_secs * 10.0
            {
                break; // long single iterations: stop calibrating
            }
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            elems_per_iter: elems,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Full machine-readable report for a named bench target. Includes
    /// the process's peak RSS (bytes) when the platform exposes it, so
    /// CI can gate memory alongside throughput.
    pub fn to_json(&self, bench: &str) -> Json {
        let mut pairs = vec![
            ("schema", Json::str("lmdfl-bench-v1")),
            ("bench", Json::str(bench)),
        ];
        if let Some(rss) = peak_rss_bytes() {
            pairs.push(("peak_rss_bytes", Json::num(rss as f64)));
        }
        pairs.push((
            "results",
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        ));
        Json::obj(pairs)
    }

    /// Write `BENCH_<bench>.json` into `dir` (created if missing).
    pub fn write_json(
        &self,
        bench: &str,
        dir: &Path,
    ) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{bench}.json"));
        std::fs::write(&path, self.to_json(bench).to_pretty())?;
        Ok(path)
    }

    /// End-of-target hook every bench binary calls: when
    /// `LMDFL_BENCH_JSON=<dir>` is set, persist the JSON report there and
    /// announce the path; otherwise do nothing (local text-only runs).
    pub fn finish(&self, bench: &str) {
        let Ok(dir) = std::env::var("LMDFL_BENCH_JSON") else {
            return;
        };
        if dir.is_empty() {
            return;
        }
        match self.write_json(bench, Path::new(&dir)) {
            Ok(path) => println!("bench json: {}", path.display()),
            Err(e) => eprintln!("bench json write failed: {e}"),
        }
    }
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or `None` where the kernel doesn't expose it.
/// A high-water mark, not an instantaneous figure: it covers everything
/// the process touched since start, which is exactly what the scale
/// benches gate — a 10k-node run must stay under its memory ceiling at
/// its *worst* moment.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 =
                rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Opaque value sink to stop the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        std::env::set_var("LMDFL_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let expect_samples = b.samples;
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.samples.len(), expect_samples);
        assert!(r.mean() >= 0.0);
        assert!(r.min() <= r.p95());
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
        assert_eq!(fmt_count(1500.0), "1.50K");
        assert_eq!(fmt_count(2.5e6), "2.50M");
    }

    #[test]
    fn result_stats_consistent() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0],
            elems_per_iter: Some(10),
        };
        assert!((r.mean() - 2.0).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert!((r.p50() - 2.0).abs() < 1e-12);
        assert!(r.report().contains("/s]"));
    }

    #[test]
    fn json_report_schema() {
        let b = Bencher {
            measure_secs: 0.0,
            warmup_secs: 0.0,
            samples: 0,
            results: vec![BenchResult {
                name: "roundtrip".into(),
                samples: vec![2.0, 4.0],
                elems_per_iter: Some(6),
            }],
        };
        let j = b.to_json("unit");
        assert_eq!(j.get_str("schema"), Some("lmdfl-bench-v1"));
        assert_eq!(j.get_str("bench"), Some("unit"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.get_str("name"), Some("roundtrip"));
        assert!((r.get_f64("mean_s").unwrap() - 3.0).abs() < 1e-12);
        assert_eq!(r.get_usize("samples"), Some(2));
        assert!((r.get_f64("elems_per_s").unwrap() - 2.0).abs() < 1e-12);
        // serialized form parses back
        let text = j.to_pretty();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn peak_rss_is_positive_where_supported() {
        if let Some(rss) = peak_rss_bytes() {
            // any live process has touched at least a page
            assert!(rss >= 4096, "implausible peak RSS {rss}");
            let b = Bencher {
                measure_secs: 0.0,
                warmup_secs: 0.0,
                samples: 0,
                results: Vec::new(),
            };
            // the high-water mark is monotone, so the report's figure
            // can only be >= the earlier reading
            let j = b.to_json("rss");
            assert!(j.get_f64("peak_rss_bytes").unwrap() >= rss as f64);
        }
    }

    #[test]
    fn json_report_written_to_dir() {
        let b = Bencher {
            measure_secs: 0.0,
            warmup_secs: 0.0,
            samples: 0,
            results: vec![BenchResult {
                name: "w".into(),
                samples: vec![1.0],
                elems_per_iter: None,
            }],
        };
        let dir = std::env::temp_dir().join("lmdfl_bench_json_test");
        let path = b.write_json("unitfile", &dir).unwrap();
        assert!(path.ends_with("BENCH_unitfile.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get_str("bench"), Some("unitfile"));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
