//! Shared per-node round-executor core.
//!
//! The synchronous matrix engine ([`super::DflEngine`]) and the
//! asynchronous event-driven engine
//! ([`crate::agossip::AsyncGossipEngine`]) execute the same per-node
//! work — τ local-SGD steps over a non-IID shard, the damped quantized
//! differential of Eq. 22, the doubly-adaptive level update — they only
//! differ in *when* that work runs (global round barrier vs per-node
//! quorum wakeups). [`NodeCore`] owns everything one node needs for
//! those phases, including all preallocated scratch, so both engines
//! share one implementation and the per-round hot path allocates
//! nothing after warm-up in either mode.
//!
//! Determinism: [`NodeCore::build_fleet`] derives the per-node rng
//! streams with the exact split tags the matrix engine always used
//! (sampler = `split(i)`, node = `split(0x1000 + i)`), so extracting
//! the core changed no byte of the synchronous trajectories.

use crate::config::{
    AttackKind, ExperimentConfig, QuantizerKind, WireEncoding,
};
use crate::data::{BatchSampler, Dataset};
use crate::dfl::backend::LocalUpdate;
use crate::quant::adaptive::AdaptiveLevels;
use crate::quant::wire::{self, QuantTag, WireHeader};
use crate::quant::{build_quantizer, QuantizedVector, Quantizer};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

/// Measured cost/quality of one quantized differential message.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    /// paper bits (Eq. 12) of the message
    pub paper_bits: u64,
    /// wire bytes of the encoded [`crate::quant::wire`] message (header
    /// + codec framing) — what a simnet fabric puts on the links. The
    /// bitstream path measures the actual encoded buffer; the matrix
    /// path uses the exact size formula (the two are asserted equal)
    pub wire_bytes: u64,
    /// measured relative distortion ω̂
    pub distortion: f64,
}

/// One node's learning state plus all per-round scratch buffers.
pub struct NodeCore {
    /// x^(i): params after mixing
    pub params: Vec<f32>,
    /// x̂^(i): the node's broadcast estimate (error-feedback reference)
    pub hat: Vec<f32>,
    pub sampler: BatchSampler,
    pub quantizer: Box<dyn Quantizer>,
    pub adaptive: Option<AdaptiveLevels>,
    pub rng: Rng,
    /// configured quantizer family (the wire message's [`QuantTag`])
    pub kind: QuantizerKind,
    /// Byzantine role: `Some` makes this node corrupt every outgoing
    /// differential (see [`apply_attack`]); honest nodes carry `None`
    pub attack: Option<AttackKind>,
    // ---- preallocated scratch (rounds allocate nothing after warm-up) --
    /// delta scratch: x − x̂
    pub diff: Vec<f32>,
    /// decode scratch: dequantized (damped) delta
    pub dq: Vec<f32>,
    /// reusable quantized-message buffer
    pub msg: QuantizedVector,
    /// encoded wire-message scratch (`encoding: bitstream` broadcasts)
    pub enc: Vec<u8>,
    /// wire-decode scratch: the message reconstructed from `enc`
    pub dec: QuantizedVector,
    /// receive-side implied-level-table cache
    pub implied: wire::ImpliedCache,
    /// mini-batch index / feature / label scratch
    batch_idx: Vec<usize>,
    batch_x: Vec<f32>,
    batch_y: Vec<u32>,
}

impl NodeCore {
    /// Build the per-node fleet for `cfg`: non-IID partition, per-node
    /// rng streams, identical initial params at every node (paper
    /// §VI-A3). `rng` must be the engine rng *after* the `0xBEEF`
    /// init-params split.
    pub fn build_fleet(
        cfg: &ExperimentConfig,
        dataset: &Dataset,
        param_count: usize,
        init: &[f32],
        rng: &mut Rng,
    ) -> Vec<NodeCore> {
        let parts = crate::data::partition::partition_noniid(
            &dataset.train_y,
            cfg.nodes,
            cfg.noniid_fraction,
            cfg.seed,
        );
        let mut nodes = Vec::with_capacity(cfg.nodes);
        for (i, part) in parts.into_iter().enumerate() {
            let adaptive = match &cfg.quantizer {
                QuantizerKind::DoublyAdaptive { s1, s_max, .. } => {
                    Some(AdaptiveLevels::new(*s1, *s_max))
                }
                _ => None,
            };
            nodes.push(NodeCore {
                params: init.to_vec(),
                hat: vec![0.0; param_count],
                sampler: BatchSampler::new(part, rng.split(i as u64)),
                quantizer: build_quantizer(&cfg.quantizer),
                adaptive,
                rng: rng.split(0x1000 + i as u64),
                kind: cfg.quantizer.clone(),
                attack: cfg
                    .attack
                    .as_ref()
                    .and_then(|a| a.role(i))
                    .cloned(),
                diff: vec![0.0; param_count],
                dq: vec![0.0; param_count],
                msg: QuantizedVector::empty(),
                enc: Vec::new(),
                dec: QuantizedVector::empty(),
                implied: wire::ImpliedCache::new(),
                batch_idx: Vec::new(),
                batch_x: Vec::new(),
                batch_y: Vec::new(),
            });
        }
        nodes
    }

    /// Run `tau` local SGD steps (Eq. 18) on this node's shard; returns
    /// the mean batch loss across the steps.
    pub fn local_steps(
        &mut self,
        backend: &mut dyn LocalUpdate,
        dataset: &Dataset,
        tau: usize,
        batch: usize,
        lr: f32,
    ) -> anyhow::Result<f64> {
        let mut local_loss = 0.0f64;
        for _ in 0..tau {
            self.sampler.next_batch_into(batch, &mut self.batch_idx);
            dataset.gather_batch_into(
                &self.batch_idx,
                &mut self.batch_x,
                &mut self.batch_y,
            );
            local_loss += backend.step(
                &mut self.params,
                &self.batch_x,
                &self.batch_y,
                lr,
            )?;
        }
        Ok(local_loss / tau.max(1) as f64)
    }

    /// Doubly-adaptive level update (Alg. 3 step 8), keyed to whatever
    /// loss sequence the owning engine observes — the global round in
    /// the synchronous engine, the node's own local step count in the
    /// asynchronous one.
    pub fn observe_local_loss(&mut self, mean_loss: f64) {
        if let Some(ad) = self.adaptive.as_mut() {
            let s = ad.update(mean_loss);
            self.quantizer.set_levels(s);
        }
    }

    /// Quantize the differential without touching the estimate: fills
    /// `self.msg` (the wire message) and `self.dq` (the damped delta,
    /// bit-identical to what a receiver reconstructs from the bytes);
    /// returns ω̂.
    fn prepare_delta(&mut self) -> f64 {
        crate::quant::kernels::sub_into(
            &mut self.diff,
            &self.params,
            &self.hat,
        );
        if let Some(kind) = &self.attack {
            apply_attack(kind, &mut self.diff, &mut self.rng);
        }
        crate::quant::quantize_damped_into(
            self.quantizer.as_mut(),
            &self.diff,
            &mut self.rng,
            &mut self.dq,
            &mut self.msg,
        )
    }

    /// Quantized differential broadcast (Eq. 22 one step):
    /// `q = Q(x − x̂); x̂ += q`, exchanged in matrix form. The damped
    /// dequantized delta is left in `self.dq` and the message in
    /// `self.msg` for the caller to ship; returns the message stats
    /// (`wire_bytes` from the exact encoded-size formula).
    pub fn quantize_delta(&mut self) -> DeltaStats {
        let omega = self.prepare_delta();
        let stats = DeltaStats {
            paper_bits: self.msg.paper_bits(),
            wire_bytes: self.msg.wire_message_bytes(),
            distortion: omega,
        };
        crate::quant::kernels::add_assign(&mut self.hat, &self.dq);
        stats
    }

    /// Bitstream variant of [`quantize_delta`](Self::quantize_delta):
    /// encodes the message into the versioned wire frame (left in
    /// `self.enc` for the caller to ship), then advances the estimate
    /// exclusively from the *decoded bytes* — the exact reconstruction
    /// every receiver of the broadcast performs. `wire_bytes` is the
    /// measured encoded length.
    pub fn quantize_delta_wire(
        &mut self,
        round: u32,
        phase: u8,
        sender: u32,
    ) -> anyhow::Result<DeltaStats> {
        let omega = self.prepare_delta();
        // tag the frame with the ACTIVE quantizer — set_all_quantizers
        // (extension baselines) may have swapped it away from the
        // configured kind, and an implied-table message under a wrong
        // tag would reconstruct the wrong level table (or refuse to)
        let tag = match QuantTag::from_name(self.quantizer.name()) {
            Some(t) => t,
            None => {
                // unknown custom quantizer: fine when the table is
                // shipped (the tag is then only a label), but an
                // implied table under a borrowed tag would silently
                // rebuild the WRONG levels at every receiver — refuse
                anyhow::ensure!(
                    !self.msg.implied_table,
                    "quantizer '{}' has no wire tag but produced an \
                     implied-table message: receivers could not \
                     rebuild its levels",
                    self.quantizer.name()
                );
                QuantTag::from_kind(&self.kind)
            }
        };
        let header = WireHeader::new(
            tag,
            phase,
            sender,
            round,
            self.msg.s(),
        );
        self.enc = wire::encode_with_buf(
            &header,
            &self.msg,
            std::mem::take(&mut self.enc),
        );
        debug_assert_eq!(
            self.enc.len() as u64,
            self.msg.wire_message_bytes(),
            "encoded length disagrees with the size formula"
        );
        let stats = DeltaStats {
            paper_bits: self.msg.paper_bits(),
            wire_bytes: self.enc.len() as u64,
            distortion: omega,
        };
        let back =
            wire::decode_into(&self.enc, &mut self.implied, &mut self.dec)
                .map_err(|e| {
                    anyhow::anyhow!(
                        "node {sender}: own broadcast failed to decode: {e}"
                    )
                })?;
        debug_assert_eq!(back, header);
        debug_assert_eq!(self.dec, self.msg, "wire roundtrip drifted");
        self.dec.dequantize_accumulate_into(&mut self.hat);
        Ok(stats)
    }

    /// One broadcast under the configured transport — the single
    /// dispatch point both engines share, so the matrix/bitstream
    /// round-and-phase keying can never diverge between them. The
    /// matrix delta stays in `self.dq`, the encoded frame (bitstream
    /// only) in `self.enc`.
    pub fn broadcast_delta(
        &mut self,
        encoding: WireEncoding,
        round: u32,
        phase: u8,
        sender: u32,
    ) -> anyhow::Result<DeltaStats> {
        let _span = crate::obs::span("quantize");
        let stats = match encoding {
            WireEncoding::Matrix => self.quantize_delta(),
            WireEncoding::Bitstream => {
                self.quantize_delta_wire(round, phase, sender)?
            }
        };
        crate::obs::counter(
            "encoded_bytes",
            self.quantizer.name(),
            stats.wire_bytes,
        );
        Ok(stats)
    }
}

/// Corrupt an outgoing differential in place — the Byzantine injection
/// point shared by every runtime (sync matrix, async gossip, threaded
/// sockets). The attack runs BEFORE quantization, so the attacker's own
/// estimate tracks its corrupted stream: the wire bytes, the matrix
/// delta, and the attacker's x̂ all agree, which preserves the
/// matrix/bitstream parity and determinism contracts under attack.
///
/// Each call bumps the `byzantine_msgs` observability counter keyed by
/// the attack name.
pub(crate) fn apply_attack(
    kind: &AttackKind,
    diff: &mut [f32],
    rng: &mut Rng,
) {
    match kind {
        AttackKind::SignFlip => {
            for x in diff.iter_mut() {
                *x = -*x;
            }
        }
        AttackKind::Scale { factor } => {
            let f = *factor as f32;
            for x in diff.iter_mut() {
                *x *= f;
            }
        }
        AttackKind::Random => {
            // uniform noise matched to the honest message's energy:
            // E‖u‖² = ‖diff‖² when each coord ~ U[-√3·norm/√d, √3·norm/√d);
            // drawn from the node rng so attacked runs stay replayable
            let norm = crate::util::stats::l2_norm(diff) as f32;
            let scale = if diff.is_empty() {
                0.0
            } else {
                norm * (3.0f32 / diff.len() as f32).sqrt()
            };
            for x in diff.iter_mut() {
                *x = (rng.uniform_f32() * 2.0 - 1.0) * scale;
            }
        }
    }
    crate::obs::counter("byzantine_msgs", kind.name(), 1);
}

/// Average model u = Σ params / n over an iterator of parameter slices.
pub fn average_params<'a, I>(params: I, param_count: usize) -> Vec<f32>
where
    I: Iterator<Item = &'a [f32]>,
{
    let mut u = vec![0.0f32; param_count];
    let mut n = 0usize;
    for p in params {
        for (a, &x) in u.iter_mut().zip(p) {
            *a += x;
        }
        n += 1;
    }
    let inv = 1.0 / n.max(1) as f32;
    u.iter_mut().for_each(|x| *x *= inv);
    u
}

/// Evaluate `u` on `x`/`y` sharded across the worker pool: one fixed
/// contiguous chunk per *backend* (NOT per worker), and a sequential
/// index-order reduction of (Σ chunk-loss × chunk-rows, Σ correct) — so
/// the result is bit-identical for any `parallelism` setting. Shared by
/// both engines' global evaluations.
pub fn evaluate_sharded(
    pool: &WorkerPool,
    backends: &mut [Box<dyn LocalUpdate>],
    feat: usize,
    u: &[f32],
    x: &[f32],
    y: &[u32],
) -> anyhow::Result<(f64, usize)> {
    let n = backends.len();
    let (base, rem) = (y.len() / n, y.len() % n);
    let mut bounds = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let take = base + usize::from(i < rem);
        bounds.push((start, start + take));
        start += take;
    }
    let mut outs: Vec<(f64, usize)> = vec![(0.0, 0); n];
    let b = &bounds;
    pool.run2(&mut outs, backends, |i, out, backend| {
        let (s, e) = b[i];
        if s < e {
            *out = backend.evaluate(u, &x[s * feat..e * feat], &y[s..e])?;
        }
        Ok(())
    })?;
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for (i, (l, c)) in outs.iter().enumerate() {
        let (s, e) = bounds[i];
        loss_sum += l * (e - s) as f64;
        correct += c;
    }
    Ok((loss_sum, correct))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        AttackConfig, DatasetKind, ExperimentConfig, QuantizerKind,
    };
    use crate::dfl::backend::RustMlpBackend;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 3;
        cfg.dataset = DatasetKind::Blobs {
            train: 120,
            test: 40,
            dim: 6,
            classes: 3,
        };
        cfg.quantizer = QuantizerKind::LloydMax { s: 8, iters: 4 };
        cfg
    }

    fn fleet(cfg: &ExperimentConfig) -> (Vec<NodeCore>, Dataset, usize) {
        let dataset = Dataset::build(&cfg.dataset, cfg.seed);
        let backend = RustMlpBackend::new(dataset.feat_dim, &[8], 3);
        let pc = backend.param_count();
        let mut rng = Rng::new(cfg.seed);
        let init = backend.init_params(&mut rng.split(0xBEEF));
        let nodes =
            NodeCore::build_fleet(cfg, &dataset, pc, &init, &mut rng);
        (nodes, dataset, pc)
    }

    #[test]
    fn fleet_starts_identical_and_hat_zero() {
        let cfg = tiny_cfg();
        let (nodes, _, pc) = fleet(&cfg);
        assert_eq!(nodes.len(), 3);
        for node in &nodes {
            assert_eq!(node.params, nodes[0].params);
            assert_eq!(node.hat, vec![0.0; pc]);
        }
    }

    #[test]
    fn quantize_delta_tracks_params() {
        let cfg = tiny_cfg();
        let (mut nodes, _, _) = fleet(&cfg);
        let node = &mut nodes[0];
        let st = node.quantize_delta();
        assert!(st.paper_bits > 0);
        assert!(st.wire_bytes > 0);
        assert!(st.distortion >= 0.0 && st.distortion.is_finite());
        // estimate moved toward params: repeated deltas contract ‖x − x̂‖
        let gap = |n: &NodeCore| -> f64 {
            n.params
                .iter()
                .zip(&n.hat)
                .map(|(&p, &h)| (p as f64 - h as f64).abs())
                .fold(0.0, f64::max)
        };
        let g1 = gap(node);
        for _ in 0..6 {
            node.quantize_delta();
        }
        let g2 = gap(node);
        assert!(g2 < g1, "estimate did not contract: {g1} -> {g2}");
    }

    #[test]
    fn wire_and_matrix_delta_paths_match_bitwise() {
        // the encoding parity contract at its root: advancing the
        // estimate from decoded wire bytes is bit-identical to the
        // matrix form, and both report the same wire size
        let cfg = tiny_cfg();
        let (mut a, _, _) = fleet(&cfg);
        let (mut b, _, _) = fleet(&cfg);
        for step in 0..5u32 {
            let sa = a[0].quantize_delta();
            let sb = b[0].quantize_delta_wire(step, 0, 0).unwrap();
            assert_eq!(sa.paper_bits, sb.paper_bits);
            assert_eq!(sa.wire_bytes, sb.wire_bytes);
            assert_eq!(sa.distortion.to_bits(), sb.distortion.to_bits());
            for (x, y) in a[0].hat.iter().zip(&b[0].hat) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert!(sb.wire_bytes >= wire::MIN_ENCODED_BYTES as u64);
        }
    }

    #[test]
    fn sign_flip_attacker_negates_its_differential() {
        let mut cfg = tiny_cfg();
        cfg.attack = Some(AttackConfig {
            kind: AttackKind::SignFlip,
            f: 1,
        });
        let (mut bad, _, _) = fleet(&cfg);
        let (mut good, _, _) = fleet(&tiny_cfg());
        assert!(bad[0].attack.is_some(), "node 0 should be Byzantine");
        assert!(bad[1].attack.is_none(), "node 1 should be honest");
        bad[0].quantize_delta();
        good[0].quantize_delta();
        // sign flipping before the sign-magnitude decomposition negates
        // the quantized message exactly: same norm, same magnitudes,
        // flipped signs — so the attacker's estimate is the mirror of
        // the honest one
        for (a, b) in bad[0].hat.iter().zip(&good[0].hat) {
            assert_eq!(*a, -*b);
        }
    }

    #[test]
    fn random_attacker_matches_honest_energy_and_replays() {
        let mut cfg = tiny_cfg();
        cfg.attack = Some(AttackConfig {
            kind: AttackKind::Random,
            f: 1,
        });
        let (mut a, _, _) = fleet(&cfg);
        let (mut b, _, _) = fleet(&cfg);
        let sa = a[0].quantize_delta();
        let sb = b[0].quantize_delta();
        // deterministic: same seed+config replays the attack bitwise
        assert_eq!(sa.distortion.to_bits(), sb.distortion.to_bits());
        for (x, y) in a[0].hat.iter().zip(&b[0].hat) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn local_steps_return_finite_mean_loss() {
        let cfg = tiny_cfg();
        let (mut nodes, dataset, _) = fleet(&cfg);
        let mut backend = RustMlpBackend::new(dataset.feat_dim, &[8], 3);
        let loss = nodes[0]
            .local_steps(&mut backend, &dataset, 3, 16, 0.05)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn average_params_averages() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        let u = average_params([a.as_slice(), b.as_slice()].into_iter(), 2);
        assert_eq!(u, vec![2.0, 4.0]);
    }
}
