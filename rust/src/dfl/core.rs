//! Shared per-node round-executor core.
//!
//! The synchronous matrix engine ([`super::DflEngine`]) and the
//! asynchronous event-driven engine
//! ([`crate::agossip::AsyncGossipEngine`]) execute the same per-node
//! work — τ local-SGD steps over a non-IID shard, the damped quantized
//! differential of Eq. 22, the doubly-adaptive level update — they only
//! differ in *when* that work runs (global round barrier vs per-node
//! quorum wakeups). [`NodeCore`] owns everything one node needs for
//! those phases, including all preallocated scratch, so both engines
//! share one implementation and the per-round hot path allocates
//! nothing after warm-up in either mode.
//!
//! Determinism: [`NodeCore::build_fleet`] derives the per-node rng
//! streams with the exact split tags the matrix engine always used
//! (sampler = `split(i)`, node = `split(0x1000 + i)`), so extracting
//! the core changed no byte of the synchronous trajectories.

use crate::config::{ExperimentConfig, QuantizerKind};
use crate::data::{BatchSampler, Dataset};
use crate::dfl::backend::LocalUpdate;
use crate::quant::adaptive::AdaptiveLevels;
use crate::quant::{build_quantizer, QuantizedVector, Quantizer};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

/// Measured cost/quality of one quantized differential message.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    /// paper bits (Eq. 12) of the message
    pub paper_bits: u64,
    /// measured wire bytes (codec framing included) — what a simnet
    /// fabric puts on the links
    pub wire_bytes: u64,
    /// measured relative distortion ω̂
    pub distortion: f64,
}

/// One node's learning state plus all per-round scratch buffers.
pub struct NodeCore {
    /// x^(i): params after mixing
    pub params: Vec<f32>,
    /// x̂^(i): the node's broadcast estimate (error-feedback reference)
    pub hat: Vec<f32>,
    pub sampler: BatchSampler,
    pub quantizer: Box<dyn Quantizer>,
    pub adaptive: Option<AdaptiveLevels>,
    pub rng: Rng,
    // ---- preallocated scratch (rounds allocate nothing after warm-up) --
    /// delta scratch: x − x̂
    pub diff: Vec<f32>,
    /// decode scratch: dequantized (damped) delta
    pub dq: Vec<f32>,
    /// reusable quantized-message buffer
    pub msg: QuantizedVector,
    /// mini-batch index / feature / label scratch
    batch_idx: Vec<usize>,
    batch_x: Vec<f32>,
    batch_y: Vec<u32>,
}

impl NodeCore {
    /// Build the per-node fleet for `cfg`: non-IID partition, per-node
    /// rng streams, identical initial params at every node (paper
    /// §VI-A3). `rng` must be the engine rng *after* the `0xBEEF`
    /// init-params split.
    pub fn build_fleet(
        cfg: &ExperimentConfig,
        dataset: &Dataset,
        param_count: usize,
        init: &[f32],
        rng: &mut Rng,
    ) -> Vec<NodeCore> {
        let parts = crate::data::partition::partition_noniid(
            &dataset.train_y,
            cfg.nodes,
            cfg.noniid_fraction,
            cfg.seed,
        );
        let mut nodes = Vec::with_capacity(cfg.nodes);
        for (i, part) in parts.into_iter().enumerate() {
            let adaptive = match &cfg.quantizer {
                QuantizerKind::DoublyAdaptive { s1, s_max, .. } => {
                    Some(AdaptiveLevels::new(*s1, *s_max))
                }
                _ => None,
            };
            nodes.push(NodeCore {
                params: init.to_vec(),
                hat: vec![0.0; param_count],
                sampler: BatchSampler::new(part, rng.split(i as u64)),
                quantizer: build_quantizer(&cfg.quantizer),
                adaptive,
                rng: rng.split(0x1000 + i as u64),
                diff: vec![0.0; param_count],
                dq: vec![0.0; param_count],
                msg: QuantizedVector::empty(),
                batch_idx: Vec::new(),
                batch_x: Vec::new(),
                batch_y: Vec::new(),
            });
        }
        nodes
    }

    /// Run `tau` local SGD steps (Eq. 18) on this node's shard; returns
    /// the mean batch loss across the steps.
    pub fn local_steps(
        &mut self,
        backend: &mut dyn LocalUpdate,
        dataset: &Dataset,
        tau: usize,
        batch: usize,
        lr: f32,
    ) -> anyhow::Result<f64> {
        let mut local_loss = 0.0f64;
        for _ in 0..tau {
            self.sampler.next_batch_into(batch, &mut self.batch_idx);
            dataset.gather_batch_into(
                &self.batch_idx,
                &mut self.batch_x,
                &mut self.batch_y,
            );
            local_loss += backend.step(
                &mut self.params,
                &self.batch_x,
                &self.batch_y,
                lr,
            )?;
        }
        Ok(local_loss / tau.max(1) as f64)
    }

    /// Doubly-adaptive level update (Alg. 3 step 8), keyed to whatever
    /// loss sequence the owning engine observes — the global round in
    /// the synchronous engine, the node's own local step count in the
    /// asynchronous one.
    pub fn observe_local_loss(&mut self, mean_loss: f64) {
        if let Some(ad) = self.adaptive.as_mut() {
            let s = ad.update(mean_loss);
            self.quantizer.set_levels(s);
        }
    }

    /// Quantized differential broadcast (Eq. 22 one step):
    /// `q = Q(x − x̂); x̂ += q`. The damped dequantized delta is left in
    /// `self.dq` and the wire message in `self.msg` for the caller to
    /// ship; returns the message stats.
    pub fn quantize_delta(&mut self) -> DeltaStats {
        crate::quant::kernels::sub_into(
            &mut self.diff,
            &self.params,
            &self.hat,
        );
        let omega = crate::quant::quantize_damped_into(
            self.quantizer.as_mut(),
            &self.diff,
            &mut self.rng,
            &mut self.dq,
            &mut self.msg,
        );
        let stats = DeltaStats {
            paper_bits: self.msg.paper_bits(),
            wire_bytes: self.msg.wire_bits() / 8,
            distortion: omega,
        };
        crate::quant::kernels::add_assign(&mut self.hat, &self.dq);
        stats
    }
}

/// Average model u = Σ params / n over an iterator of parameter slices.
pub fn average_params<'a, I>(params: I, param_count: usize) -> Vec<f32>
where
    I: Iterator<Item = &'a [f32]>,
{
    let mut u = vec![0.0f32; param_count];
    let mut n = 0usize;
    for p in params {
        for (a, &x) in u.iter_mut().zip(p) {
            *a += x;
        }
        n += 1;
    }
    let inv = 1.0 / n.max(1) as f32;
    u.iter_mut().for_each(|x| *x *= inv);
    u
}

/// Evaluate `u` on `x`/`y` sharded across the worker pool: one fixed
/// contiguous chunk per *backend* (NOT per worker), and a sequential
/// index-order reduction of (Σ chunk-loss × chunk-rows, Σ correct) — so
/// the result is bit-identical for any `parallelism` setting. Shared by
/// both engines' global evaluations.
pub fn evaluate_sharded(
    pool: &WorkerPool,
    backends: &mut [Box<dyn LocalUpdate>],
    feat: usize,
    u: &[f32],
    x: &[f32],
    y: &[u32],
) -> anyhow::Result<(f64, usize)> {
    let n = backends.len();
    let (base, rem) = (y.len() / n, y.len() % n);
    let mut bounds = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let take = base + usize::from(i < rem);
        bounds.push((start, start + take));
        start += take;
    }
    let mut outs: Vec<(f64, usize)> = vec![(0.0, 0); n];
    let b = &bounds;
    pool.run2(&mut outs, backends, |i, out, backend| {
        let (s, e) = b[i];
        if s < e {
            *out = backend.evaluate(u, &x[s * feat..e * feat], &y[s..e])?;
        }
        Ok(())
    })?;
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for (i, (l, c)) in outs.iter().enumerate() {
        let (s, e) = bounds[i];
        loss_sum += l * (e - s) as f64;
        correct += c;
    }
    Ok((loss_sum, correct))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, ExperimentConfig, QuantizerKind};
    use crate::dfl::backend::RustMlpBackend;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 3;
        cfg.dataset = DatasetKind::Blobs {
            train: 120,
            test: 40,
            dim: 6,
            classes: 3,
        };
        cfg.quantizer = QuantizerKind::LloydMax { s: 8, iters: 4 };
        cfg
    }

    fn fleet(cfg: &ExperimentConfig) -> (Vec<NodeCore>, Dataset, usize) {
        let dataset = Dataset::build(&cfg.dataset, cfg.seed);
        let backend = RustMlpBackend::new(dataset.feat_dim, &[8], 3);
        let pc = backend.param_count();
        let mut rng = Rng::new(cfg.seed);
        let init = backend.init_params(&mut rng.split(0xBEEF));
        let nodes =
            NodeCore::build_fleet(cfg, &dataset, pc, &init, &mut rng);
        (nodes, dataset, pc)
    }

    #[test]
    fn fleet_starts_identical_and_hat_zero() {
        let cfg = tiny_cfg();
        let (nodes, _, pc) = fleet(&cfg);
        assert_eq!(nodes.len(), 3);
        for node in &nodes {
            assert_eq!(node.params, nodes[0].params);
            assert_eq!(node.hat, vec![0.0; pc]);
        }
    }

    #[test]
    fn quantize_delta_tracks_params() {
        let cfg = tiny_cfg();
        let (mut nodes, _, _) = fleet(&cfg);
        let node = &mut nodes[0];
        let st = node.quantize_delta();
        assert!(st.paper_bits > 0);
        assert!(st.wire_bytes > 0);
        assert!(st.distortion >= 0.0 && st.distortion.is_finite());
        // estimate moved toward params: repeated deltas contract ‖x − x̂‖
        let gap = |n: &NodeCore| -> f64 {
            n.params
                .iter()
                .zip(&n.hat)
                .map(|(&p, &h)| (p as f64 - h as f64).abs())
                .fold(0.0, f64::max)
        };
        let g1 = gap(node);
        for _ in 0..6 {
            node.quantize_delta();
        }
        let g2 = gap(node);
        assert!(g2 < g1, "estimate did not contract: {g1} -> {g2}");
    }

    #[test]
    fn local_steps_return_finite_mean_loss() {
        let cfg = tiny_cfg();
        let (mut nodes, dataset, _) = fleet(&cfg);
        let mut backend = RustMlpBackend::new(dataset.feat_dim, &[8], 3);
        let loss = nodes[0]
            .local_steps(&mut backend, &dataset, 3, 16, 0.05)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn average_params_averages() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        let u = average_params([a.as_slice(), b.as_slice()].into_iter(), 2);
        assert_eq!(u, vec![2.0, 4.0]);
    }
}
