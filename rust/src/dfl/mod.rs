//! Decentralized federated learning engine (the paper's system layer).
//!
//! * [`engine::DflEngine`] — matrix-form gossip simulator (Algorithms 2-3)
//! * [`net`] — threaded message-passing runtime over encoded bitstreams
//! * [`backend`] — local-update compute backends (pure Rust / PJRT HLO)
//! * [`Trainer`] — config-to-run convenience wrapper

pub mod backend;
pub(crate) mod core;
pub mod engine;
pub mod net;

pub use backend::{LocalUpdate, RustMlpBackend};
pub(crate) use core::NodeCore;
pub use engine::{DflEngine, EngineOptions};
pub use net::{run_node_process, NetOptions};

use std::sync::Arc;

use crate::config::{BackendKind, ExperimentConfig};
use crate::data::Dataset;
use crate::metrics::RunLog;
use crate::topology::Topology;

/// Build one backend instance per the config.
pub(crate) fn build_backend(
    cfg: &ExperimentConfig,
    dataset: &Dataset,
) -> anyhow::Result<Box<dyn LocalUpdate>> {
    match &cfg.backend {
        BackendKind::RustMlp { hidden } => Ok(Box::new(RustMlpBackend::new(
            dataset.feat_dim,
            hidden,
            dataset.classes,
        ))),
        BackendKind::Hlo { artifact } => {
            let dir = crate::runtime::artifacts_dir();
            let backend = crate::runtime::HloBackend::load(
                &dir, artifact, dataset.feat_dim, dataset.classes)?;
            Ok(Box::new(backend))
        }
    }
}

/// High-level runner: config in, metrics out.
pub struct Trainer {
    engine: DflEngine,
}

impl Trainer {
    /// Build topology, dataset and per-node backends from the config.
    pub fn build(cfg: &ExperimentConfig) -> anyhow::Result<Trainer> {
        Self::build_with_options(cfg, EngineOptions::default())
    }

    pub fn build_with_options(
        cfg: &ExperimentConfig,
        opts: EngineOptions,
    ) -> anyhow::Result<Trainer> {
        cfg.validate()?;
        let topology = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let dataset = Dataset::build(&cfg.dataset, cfg.seed);
        let mut backends = Vec::with_capacity(cfg.nodes);
        for _ in 0..cfg.nodes {
            backends.push(build_backend(cfg, &dataset)?);
        }
        let engine = DflEngine::new(
            cfg.clone(), topology, dataset, backends, opts)?;
        Ok(Trainer { engine })
    }

    /// Run all configured rounds on the matrix engine.
    pub fn run(mut self) -> anyhow::Result<RunLog> {
        self.engine.run()
    }

    /// Run on a simnet fabric. `mode: sync` (default) builds the
    /// topology, the fabric (from the config's `network:` section,
    /// required), and the matrix engine, then drives the round-barrier
    /// virtual-time rounds. `mode: async` hands the whole run to the
    /// asynchronous event-driven engine ([`crate::agossip`]; the
    /// `network:` section defaults to the ideal fabric when absent)
    /// and returns its merged loss-vs-virtual-time log.
    pub fn run_simulated(
        cfg: &ExperimentConfig,
    ) -> anyhow::Result<RunLog> {
        if cfg.mode == crate::config::EngineMode::Async {
            let log =
                crate::agossip::AsyncGossipEngine::new(cfg)?.run()?;
            return Ok(log.merged);
        }
        let net = cfg.network.clone().ok_or_else(|| {
            anyhow::anyhow!("config has no network: section to simulate")
        })?;
        let topology = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let mut fabric =
            crate::simnet::Fabric::new(&net, &topology, cfg.seed);
        let mut trainer = Self::build(cfg)?;
        trainer.engine.run_simulated(&mut fabric)
    }

    /// Streamed variant of [`run`](Self::run) /
    /// [`run_simulated`](Self::run_simulated): drives the same rounds
    /// but hands each [`RoundRecord`](crate::metrics::RoundRecord) to
    /// `sink` instead of buffering a [`RunLog`], so resident memory
    /// stays O(fleet) instead of O(fleet + rounds) on large runs.
    /// Simulates on a fabric when the config has a `network:` section,
    /// otherwise runs the ideal engine. Sync engine only: async runs
    /// stream per-node records instead (see
    /// [`AsyncGossipEngine::stream_node_records`]).
    ///
    /// [`AsyncGossipEngine::stream_node_records`]:
    ///     crate::agossip::AsyncGossipEngine::stream_node_records
    pub fn run_streamed(
        cfg: &ExperimentConfig,
        sink: &mut dyn crate::metrics::RecordSink,
    ) -> anyhow::Result<crate::metrics::RunSummary> {
        anyhow::ensure!(
            cfg.mode != crate::config::EngineMode::Async,
            "streamed round records are a sync-engine feature; async \
             runs stream per-node JSONL records via \
             AsyncGossipEngine::stream_node_records"
        );
        let mut trainer = Self::build(cfg)?;
        match cfg.network.clone() {
            Some(net) => {
                let topology =
                    Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
                let mut fabric =
                    crate::simnet::Fabric::new(&net, &topology, cfg.seed);
                trainer.engine.run_streamed(Some(&mut fabric), sink)
            }
            None => trainer.engine.run_streamed(None, sink),
        }
    }

    /// Run on the threaded message-passing runtime instead.
    pub fn run_threaded(
        cfg: &ExperimentConfig,
        opts: NetOptions,
    ) -> anyhow::Result<RunLog> {
        cfg.validate()?;
        let topology = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let dataset = Arc::new(Dataset::build(&cfg.dataset, cfg.seed));
        let cfg2 = cfg.clone();
        let ds2 = Arc::clone(&dataset);
        let factory =
            move |_i: usize| build_backend(&cfg2, &ds2);
        net::run_threaded(cfg, &topology, dataset, &factory, opts)
    }

    /// Streamed variant of [`run_threaded`](Self::run_threaded): the
    /// coordinator hands each finished round record to `sink` instead
    /// of buffering a [`RunLog`] — same records, same order, O(fleet)
    /// resident memory (the threaded report plane no longer buffers
    /// the run).
    pub fn run_threaded_streamed(
        cfg: &ExperimentConfig,
        opts: NetOptions,
        sink: &mut dyn crate::metrics::RecordSink,
    ) -> anyhow::Result<crate::metrics::RunSummary> {
        cfg.validate()?;
        let topology = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let dataset = Arc::new(Dataset::build(&cfg.dataset, cfg.seed));
        let cfg2 = cfg.clone();
        let ds2 = Arc::clone(&dataset);
        let factory =
            move |_i: usize| build_backend(&cfg2, &ds2);
        net::run_threaded_streamed(
            cfg, &topology, dataset, &factory, opts, sink,
        )
    }

    /// Borrow the engine (examples/benches that drive rounds manually).
    pub fn engine_mut(&mut self) -> &mut DflEngine {
        &mut self.engine
    }

    pub fn engine(&self) -> &DflEngine {
        &self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, QuantizerKind};

    #[test]
    fn trainer_end_to_end_small() {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 3;
        cfg.rounds = 5;
        cfg.dataset =
            DatasetKind::Blobs { train: 90, test: 30, dim: 6, classes: 3 };
        cfg.quantizer = QuantizerKind::LloydMax { s: 8, iters: 5 };
        let log = Trainer::build(&cfg).unwrap().run().unwrap();
        assert_eq!(log.records.len(), 5);
        assert!(log.last_loss().unwrap().is_finite());
    }

    #[test]
    fn trainer_rejects_invalid_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 0;
        assert!(Trainer::build(&cfg).is_err());
    }
}
