//! Local-update backend abstraction.
//!
//! The DFL engine drives τ SGD steps per round through [`LocalUpdate`];
//! two implementations exist:
//! * [`RustMlpBackend`] — the pure-Rust MLP (fast sweeps, tests)
//! * `runtime::HloBackend` — the AOT-compiled PJRT path (production)

use crate::models::mlp::{MlpModel, MlpScratch};
use crate::util::rng::Rng;

/// One node's compute engine: SGD steps + evaluation on flat params.
///
/// `Send` is required so the matrix engine's round executor can partition
/// node backends across its worker pool (each backend is owned by exactly
/// one worker at a time; it is never shared). Both implementations are
/// plain owned data — the PJRT stand-in included. If real PJRT bindings
/// (raw device pointers) return, wrap them in a `Send` handle or construct
/// them per-thread the way the threaded runtime (dfl::net) already does
/// with its `Sync` factory.
pub trait LocalUpdate: Send {
    /// Flat parameter vector length.
    fn param_count(&self) -> usize;

    /// Expected feature dimension of a batch row.
    fn input_dim(&self) -> usize;

    /// Deterministic initial parameters (all nodes start identical —
    /// paper §VI-A3 initializes x_{1,0} equal at every node).
    fn init_params(&self, rng: &mut Rng) -> Vec<f32>;

    /// One SGD step in place on a batch; returns the batch loss.
    fn step(
        &mut self,
        params: &mut [f32],
        x: &[f32],
        y: &[u32],
        lr: f32,
    ) -> anyhow::Result<f64>;

    /// Mean loss + number of correct predictions on a labeled set.
    fn evaluate(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
    ) -> anyhow::Result<(f64, usize)>;
}

/// Pure-Rust MLP backend.
pub struct RustMlpBackend {
    model: MlpModel,
    grad: Vec<f32>,
    scratch: MlpScratch,
}

impl RustMlpBackend {
    pub fn new(input_dim: usize, hidden: &[usize], classes: usize) -> Self {
        let mut dims = vec![input_dim];
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let model = MlpModel::new(&dims);
        let grad = vec![0.0f32; model.param_count()];
        RustMlpBackend { model, grad, scratch: MlpScratch::default() }
    }

    pub(crate) fn model(&self) -> &MlpModel {
        &self.model
    }
}

impl LocalUpdate for RustMlpBackend {
    fn param_count(&self) -> usize {
        self.model.param_count()
    }

    fn input_dim(&self) -> usize {
        self.model.input_dim()
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        self.model.init_params(rng)
    }

    fn step(
        &mut self,
        params: &mut [f32],
        x: &[f32],
        y: &[u32],
        lr: f32,
    ) -> anyhow::Result<f64> {
        Ok(self.model.sgd_step(
            params, x, y, lr, &mut self.grad, &mut self.scratch))
    }

    fn evaluate(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
    ) -> anyhow::Result<(f64, usize)> {
        Ok(self.model.evaluate(params, x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_roundtrip() {
        let mut b = RustMlpBackend::new(8, &[16], 3);
        let mut rng = Rng::new(0);
        let mut params = b.init_params(&mut rng);
        assert_eq!(params.len(), b.param_count());
        let x: Vec<f32> = (0..4 * 8).map(|_| rng.normal() as f32).collect();
        let y = vec![0u32, 1, 2, 0];
        let l0 = b.step(&mut params, &x, &y, 0.1).unwrap();
        for _ in 0..30 {
            b.step(&mut params, &x, &y, 0.1).unwrap();
        }
        let (l1, _) = b.evaluate(&params, &x, &y).unwrap();
        assert!(l1 < l0, "{l1} !< {l0}");
    }

    #[test]
    fn init_params_deterministic_per_seed() {
        let b = RustMlpBackend::new(4, &[], 2);
        let p1 = b.init_params(&mut Rng::new(5));
        let p2 = b.init_params(&mut Rng::new(5));
        assert_eq!(p1, p2);
    }
}
