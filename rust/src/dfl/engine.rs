//! The LM-DFL / QDFL gossip engine (paper Algorithms 2 & 3).
//!
//! Implements the differential-quantized exchange in matrix form:
//!
//!   X̂_k     = X̂_{k-1,τ} + Q(X_k − X̂_{k-1,τ})      (mixing delta, Eq. 22)
//!   X̂_{k,τ} = X̂_k      + Q(X_{k,τ} − X̂_k)        (local-update delta)
//!   X_{k+1}  = X̂_{k,τ} · C                         (Eq. 21)
//!
//! Every round each node ships TWO quantized differentials per directed
//! link (Algorithm 2 step 8), and the estimate recursion "X̂ += the two
//! quantized deltas" is exactly Eq. (22). One deliberate deviation from
//! the paper's literal reference points (documented in DESIGN.md
//! §Deviations): the deltas are measured against the receiver-side
//! *running estimate* (x̂) rather than the raw previous state
//! (x_{k-1,τ}). The two are identical when quantization is exact, but the
//! literal form lets estimate error accumulate as a random walk
//! (E_{k+1} = E_k + e1 + e2, with e re-amplified through the mixing —
//! empirically divergent at coarse s), whereas the estimate-referenced
//! form is the standard error-feedback contraction (‖x − x̂‖ shrinks by
//! √ω per message, ω < 1) that makes Theorem 1-style tracking actually
//! hold. All nodes start from identical parameters and quantization is
//! deterministic-broadcast, so X̂ is globally consistent and the matrix
//! form is exact — the threaded message-passing runtime (dfl::net)
//! reproduces the same protocol over real encoded bitstreams.
//!
//! # Round execution model (parallel, allocation-free)
//!
//! A round is three fork-join phases over a [`crate::util::pool`]
//! **persistent** worker pool sized by `cfg.parallelism`
//! (`auto` / `off` / N) — the workers are spawned once per engine and
//! parked between phases, so a round costs condvar hand-offs, not
//! thread spawns. Nodes are not dispatched individually: a
//! [`crate::util::multiplex::NodeGroups`] partition multiplexes
//! bounded contiguous node groups onto the workers (10k nodes ≈ 160
//! groups, many per worker), and each node ships its per-round
//! outputs to the reducer through the per-group
//! [`crate::util::multiplex::GroupMailboxes`]. The per-element inner
//! loops (delta, quantize, dequantize-apply, mixing) run as the batch
//! kernels of [`crate::quant::kernels`]:
//!
//! 1. **per-node phase** — quantized mixing-delta broadcast (step A),
//!    τ local-SGD steps (step B), the doubly-adaptive level update
//!    (step C) and the local-update delta (step D). These touch only the
//!    node's own state, so nodes are partitioned contiguously across
//!    workers.
//! 2. **mixing accumulate** — `mix_i = Σ_j c_ji · x̂_j` reads every node's
//!    (now frozen) estimate and writes node-i's private accumulator.
//! 3. **mixing apply** — `x_i += mix_i − x̂_i` (Eq. 21 as a consensus
//!    correction, CHOCO-SGD style).
//!
//! Determinism contract: per-node work always runs in node order within a
//! worker, cross-node reductions (bits, distortion, levels) happen
//! sequentially in node order after the phase, and every per-node buffer
//! (delta / decode / message / batch scratch, the mixing accumulators) is
//! preallocated — so the parallel engine is **bit-identical** to the
//! sequential one (`parallelism = off`) for any worker count, and rounds
//! allocate nothing after warm-up. `rust/tests/engine_parallel.rs`
//! enforces this.

use crate::config::{ExperimentConfig, MixingKind};
use crate::data::Dataset;
use crate::dfl::backend::LocalUpdate;
use crate::dfl::core::{self, NodeCore};
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::Quantizer;
use crate::topology::Topology;
use crate::util::multiplex::{Envelope, GroupMailboxes, NodeGroups};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Per-node outputs of the round's per-node phase. Reduced sequentially in
/// node order afterwards so floating-point accumulation order never
/// depends on the worker count.
#[derive(Clone, Copy, Debug, Default)]
struct NodeRound {
    /// paper bits (Eq. 12) of the mixing-delta message q2 (0 if dropped)
    q2_bits: u64,
    /// paper bits of the local-update delta message q1
    q1_bits: u64,
    /// measured *wire* bytes of q2 / q1 (codec framing included) — what
    /// a simnet fabric puts on the links
    q2_wire_bytes: u64,
    q1_wire_bytes: u64,
    /// measured relative distortion ω̂ of q1
    distortion: f64,
}

impl NodeRound {
    /// Wire size q2 actually occupied on the links: an engine-level
    /// dropped broadcast was still *transmitted* (receivers lost it),
    /// so the same-dimension q1 size stands in (off by one adaptive
    /// level step at most, since step C runs between them). The single
    /// definition both the byte-accounting reduction and the fabric
    /// charging use — they must never diverge.
    fn effective_q2_wire_bytes(&self) -> u64 {
        if self.q2_wire_bytes > 0 {
            self.q2_wire_bytes
        } else {
            self.q1_wire_bytes
        }
    }
}

/// Options beyond [`ExperimentConfig`] (failure injection, eval subsample).
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// cap on training samples used for the global-loss evaluation
    pub eval_train_cap: usize,
    /// cap on test samples for accuracy
    pub eval_test_cap: usize,
    /// probability a quantized message is dropped (failure injection; the
    /// matrix engine models a drop as "receiver reuses the stale estimate",
    /// i.e. the delta is skipped for everyone — a broadcast-level fault)
    pub drop_prob: f64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            eval_train_cap: 2048,
            eval_test_cap: 2048,
            drop_prob: 0.0,
        }
    }
}

/// The matrix-form DFL engine.
pub struct DflEngine {
    pub cfg: ExperimentConfig,
    pub topology: Topology,
    pub(crate) dataset: Dataset,
    nodes: Vec<NodeCore>,
    backends: Vec<Box<dyn LocalUpdate>>,
    param_count: usize,
    opts: EngineOptions,
    rng: Rng,
    /// round executor sized by `cfg.parallelism`
    pool: WorkerPool,
    /// node groups multiplexed over the pool: the dispatch unit of
    /// every phase, bounded at [`crate::util::multiplex::GROUP_NODES`]
    /// nodes each so 10k-node fleets don't mean 10k work items
    groups: NodeGroups,
    /// per-group shared mailboxes carrying each node's [`NodeRound`]
    /// outputs to the sequential reduction
    round_box: GroupMailboxes<NodeRound>,
    /// scratch: envelopes drained from `round_box`, node order
    round_in: Vec<Envelope<NodeRound>>,
    /// scratch: per-node mixing accumulators
    mix_buf: Vec<Vec<f32>>,
    /// scratch: per-node wire bytes handed to the simnet fabric
    q2_wire: Vec<u64>,
    q1_wire: Vec<u64>,
    /// exact per-node cumulative wire bytes (one encoded message per
    /// broadcast; engine-dropped q2 broadcasts count their substituted
    /// size, matching what the fabric is charged)
    node_wire: Vec<u64>,
    /// nodes whose params feed the evaluated average model; `None`
    /// means all of them. Adversarial experiments evaluate the honest
    /// subset — a Byzantine node's own params are its to poison.
    eval_nodes: Option<Vec<usize>>,
}

impl DflEngine {
    /// Assemble an engine from parts (the [`crate::dfl::Trainer`] builder
    /// is the public entry point — [`Dataset`] is not part of the
    /// supported API surface).
    pub(crate) fn new(
        cfg: ExperimentConfig,
        topology: Topology,
        dataset: Dataset,
        backends: Vec<Box<dyn LocalUpdate>>,
        opts: EngineOptions,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(backends.len() == cfg.nodes, "one backend per node");
        let n = cfg.nodes;
        let param_count = backends[0].param_count();
        for b in &backends {
            anyhow::ensure!(
                b.param_count() == param_count,
                "backends disagree on param_count"
            );
            anyhow::ensure!(
                b.input_dim() == dataset.feat_dim,
                "backend input dim {} != dataset feat dim {}",
                b.input_dim(),
                dataset.feat_dim
            );
        }
        let mut rng = Rng::new(cfg.seed);
        // paper: identical initial params at every node
        let init = backends[0].init_params(&mut rng.split(0xBEEF));
        let nodes: Vec<NodeCore> = NodeCore::build_fleet(
            &cfg,
            &dataset,
            param_count,
            &init,
            &mut rng,
        );
        let pool = WorkerPool::from_parallelism(cfg.parallelism, n);
        let groups = NodeGroups::for_pool(n, pool.workers());
        let round_box = GroupMailboxes::new(&groups);
        Ok(DflEngine {
            cfg,
            topology,
            dataset,
            nodes,
            backends,
            param_count,
            opts,
            rng,
            pool,
            groups,
            round_box,
            round_in: Vec::with_capacity(n),
            mix_buf: vec![vec![0.0; param_count]; n],
            q2_wire: Vec::with_capacity(n),
            q1_wire: Vec::with_capacity(n),
            node_wire: vec![0; n],
            eval_nodes: None,
        })
    }

    /// Exact cumulative wire bytes each node has broadcast so far (one
    /// encoded message per broadcast — multiply by the out-degree for
    /// link-level totals).
    pub fn node_wire_bytes(&self) -> &[u64] {
        &self.node_wire
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Resolved worker count of the round executor.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Average model u_k = X_k · 1/N.
    pub fn average_model(&self) -> Vec<f32> {
        core::average_params(
            self.nodes.iter().map(|n| n.params.as_slice()),
            self.param_count,
        )
    }

    /// Restrict [`evaluate_global`](Self::evaluate_global) to the
    /// average over `nodes` (e.g. the honest subset under a Byzantine
    /// attack); `None` restores the full-fleet average.
    pub fn set_eval_nodes(&mut self, nodes: Option<Vec<usize>>) {
        if let Some(list) = &nodes {
            assert!(
                !list.is_empty()
                    && list.iter().all(|&i| i < self.nodes.len()),
                "eval subset must be non-empty node ids"
            );
        }
        self.eval_nodes = nodes;
    }

    /// The model the global evaluation scores: the full-fleet average,
    /// or the [`set_eval_nodes`](Self::set_eval_nodes) subset average.
    fn eval_model(&self) -> Vec<f32> {
        match &self.eval_nodes {
            None => self.average_model(),
            Some(ids) => core::average_params(
                ids.iter().map(|&i| self.nodes[i].params.as_slice()),
                self.param_count,
            ),
        }
    }

    /// Node i's current parameters.
    pub fn node_params(&self, i: usize) -> &[f32] {
        &self.nodes[i].params
    }

    /// Max pairwise L∞ disagreement across node params (consensus gap).
    pub fn consensus_gap(&self) -> f64 {
        let u = self.average_model();
        let mut gap = 0.0f64;
        for node in &self.nodes {
            for (&p, &m) in node.params.iter().zip(&u) {
                gap = gap.max((p as f64 - m as f64).abs());
            }
        }
        gap
    }

    /// Evaluate the averaged model: (global train loss, test accuracy).
    ///
    /// Runs sharded across the round executor's worker pool (ROADMAP
    /// "parallel eval path"); the node-order reduction keeps the result
    /// bit-identical across `parallelism` settings.
    pub fn evaluate_global(&mut self) -> anyhow::Result<(f64, f64)> {
        let _span = crate::obs::span("eval");
        let u = self.eval_model();
        let feat = self.dataset.feat_dim;
        let train_n = self.dataset.train_n().min(self.opts.eval_train_cap);
        // the eval prefix is contiguous, so shards are plain row slices
        // (core::evaluate_sharded: one chunk per node, node-order
        // reduction — bit-identical for any `parallelism` setting)
        let (loss_sum, _) = core::evaluate_sharded(
            &self.pool,
            &mut self.backends,
            feat,
            &u,
            &self.dataset.train_x[..train_n * feat],
            &self.dataset.train_y[..train_n],
        )?;
        let loss = if train_n > 0 {
            loss_sum / train_n as f64
        } else {
            f64::NAN
        };
        let test_n = self.dataset.test_n().min(self.opts.eval_test_cap);
        let acc = if test_n > 0 {
            let (_, correct) = core::evaluate_sharded(
                &self.pool,
                &mut self.backends,
                feat,
                &u,
                &self.dataset.test_x[..test_n * feat],
                &self.dataset.test_y[..test_n],
            )?;
            correct as f64 / test_n as f64
        } else {
            f64::NAN
        };
        Ok((loss, acc))
    }

    /// Run one full communication round `k` (0-based); returns the record.
    pub fn round(&mut self, k: usize) -> anyhow::Result<RoundRecord> {
        let _round_span = crate::obs::span("round");
        let timer = Timer::start();
        let n = self.nodes.len();
        let lr = self.cfg.lr.at(k) as f32;
        let tau = self.cfg.tau;
        let batch = self.cfg.batch_size;
        let drop_prob = self.opts.drop_prob;

        // ---- parallel per-node phase: steps A-D -------------------------
        // Each node touches only its own state; workers process contiguous
        // node ranges in index order (see module docs).
        let dataset = &self.dataset;
        let encoding = self.cfg.encoding;
        let round_key = k as u32;
        let round_box = &self.round_box;
        self.groups.run2(
            &self.pool,
            &mut self.nodes,
            &mut self.backends,
            |i, node, backend| {
                let mut out = NodeRound::default();

                // step A: mixing-delta message (Eq. 22 first term)
                // q2 = Q(x_k − x̂);  x̂ += q2  →  x̂ = X̂_k
                let dropped =
                    drop_prob > 0.0 && node.rng.uniform() < drop_prob;
                if !dropped {
                    let st = node.broadcast_delta(
                        encoding, round_key, 0, i as u32,
                    )?;
                    out.q2_bits = st.paper_bits;
                    out.q2_wire_bytes = st.wire_bytes;
                }
                // (dropped: receivers keep the stale estimate)

                // step B: τ local SGD steps (Eq. 18)
                let train_span = crate::obs::span("train");
                let local_loss = node.local_steps(
                    backend.as_mut(),
                    dataset,
                    tau,
                    batch,
                    lr,
                )?;
                drop(train_span);

                // step C: doubly-adaptive level update (Alg. 3 step 8)
                node.observe_local_loss(local_loss);

                // step D: local-update delta q1 (Alg. 2 step 8)
                // q1 = Q(x_{k,τ} − x̂_k);  x̂ += q1  →  x̂ = X̂_{k,τ}
                let st = node.broadcast_delta(
                    encoding, round_key, 2, i as u32,
                )?;
                out.q1_bits = st.paper_bits;
                out.q1_wire_bytes = st.wire_bytes;
                out.distortion = st.distortion;
                // ship the round outputs to the reducer through the
                // group mailbox (self-addressed: node i's record)
                round_box.post_to(i, i, out);
                Ok(())
            },
        )?;

        // ---- sequential reduction (node order, worker-count invariant) --
        // Draining group boxes in index order yields envelopes in node
        // order (each box sorts by (to, from)), so every accumulation
        // below — the f64 distortion sum included — runs in exactly
        // the order the per-node field scan used to.
        self.round_in.clear();
        self.round_box.drain_all(&mut self.round_in);
        debug_assert_eq!(self.round_in.len(), n);
        let mut q1_bits_paper = 0u64;
        let mut q2_bits_paper = 0u64;
        let mut distortion_sum = 0.0f64;
        let mut levels_now = 0usize;
        // measured wire bytes this round, counted per transmitted link
        // copy (size × out-degree); an engine-dropped q2 broadcast was
        // still transmitted, so it counts at the substituted q1 size —
        // the same convention run_simulated charges the fabric with
        let mut wire_link_bytes = 0u64;
        self.q2_wire.clear();
        self.q1_wire.clear();
        for env in &self.round_in {
            let (i, out) = (env.to, env.msg);
            debug_assert_eq!(i, self.q2_wire.len());
            q1_bits_paper += out.q1_bits;
            q2_bits_paper += out.q2_bits;
            distortion_sum += out.distortion;
            levels_now += self.nodes[i].quantizer.levels();
            let q2_eff = out.effective_q2_wire_bytes();
            self.node_wire[i] += q2_eff + out.q1_wire_bytes;
            wire_link_bytes += (q2_eff + out.q1_wire_bytes)
                * self.topology.adj[i].len() as u64;
            // per-node wire sizes this round, kept for the fabric
            self.q2_wire.push(q2_eff);
            self.q1_wire.push(out.q1_wire_bytes);
        }
        levels_now /= n;

        // ---- mixing (Eq. 21) --------------------------------------------
        // X_{k+1} = X_{k,τ} + (X̂_{k,τ}C − X̂_{k,τ})
        // — identical to the paper's X̂_{k,τ}C when x̂ = x (exact
        // quantization), but expressed as a consensus *correction* on the
        // true local params so residual estimate error (coarse/damped
        // quantizers) never erases local SGD progress (CHOCO-SGD [21]).
        // Phase 1: accumulate mix_i = Σ_j c_ji x̂_j (reads frozen hats).
        let mix_span = crate::obs::span("mix");
        // O(degree) accumulation over the sparse row of C. The dense
        // loop read column i in ascending j (self included at j == i);
        // C is bitwise symmetric and the sparse row is sorted by
        // column, so merging the self weight in at position i
        // reproduces the exact f32 accumulation order.
        let sp = &self.topology.sparse;
        let nodes = &self.nodes;
        let mixing = self.cfg.mixing;
        if mixing.is_plain() {
            self.groups.run(&self.pool, &mut self.mix_buf, |i, out| {
                out.iter_mut().for_each(|x| *x = 0.0);
                let self_w = sp.self_weight(i) as f32;
                let mut self_done = false;
                for &(j, w) in sp.row(i) {
                    if !self_done && j as usize > i {
                        if self_w != 0.0 {
                            crate::quant::kernels::axpy(
                                out,
                                self_w,
                                &nodes[i].hat,
                            );
                        }
                        self_done = true;
                    }
                    let w = w as f32;
                    if w == 0.0 {
                        continue;
                    }
                    crate::quant::kernels::axpy(
                        out,
                        w,
                        &nodes[j as usize].hat,
                    );
                }
                if !self_done && self_w != 0.0 {
                    crate::quant::kernels::axpy(
                        out,
                        self_w,
                        &nodes[i].hat,
                    );
                }
                Ok(())
            })?;
        } else {
            // robust row: gather live-neighbor estimate columns and
            // let the shared helper trim / median them per coordinate
            // (topology::robust — same rule every runtime applies)
            self.groups.run(&self.pool, &mut self.mix_buf, |i, out| {
                let row = sp.row(i);
                let mut nbrs: Vec<(&[f32], f64)> =
                    Vec::with_capacity(row.len());
                for &(j, w) in row {
                    if w != 0.0 {
                        nbrs.push((nodes[j as usize].hat.as_slice(), w));
                    }
                }
                crate::topology::robust_mix_into(
                    out,
                    &nodes[i].hat,
                    sp.self_weight(i),
                    &nbrs,
                    &mixing,
                );
                Ok(())
            })?;
            if let MixingKind::Trimmed { f } = mixing {
                // deterministic per-round drop count: min(2f, live
                // degree) neighbor contributions discarded per node
                let drops: u64 = (0..n)
                    .map(|i| {
                        let deg = sp
                            .row(i)
                            .iter()
                            .filter(|&&(_, w)| w != 0.0)
                            .count();
                        (2 * f).min(deg) as u64
                    })
                    .sum();
                crate::obs::counter("trimmed_drops", "sync", drops);
            }
        }
        // Phase 2: apply the consensus correction.
        let mix_buf = &self.mix_buf;
        self.groups.run(&self.pool, &mut self.nodes, |i, node| {
            crate::quant::kernels::add_delta(
                &mut node.params,
                &mix_buf[i],
                &node.hat,
            );
            Ok(())
        })?;
        drop(mix_span);

        // ---- metrics -----------------------------------------------------
        // Per-link bits: each directed link carried q1 + q2 this round.
        // The per-node totals are identical (synchronized s), so report the
        // mean per-node message cost (q1+q2)/n.
        let bits_this_round = (q1_bits_paper + q2_bits_paper) / n as u64;
        let (loss, acc) = if k % self.cfg.eval_every == 0 {
            self.evaluate_global()?
        } else {
            (f64::NAN, f64::NAN)
        };
        Ok(RoundRecord {
            round: k + 1,
            loss,
            accuracy: acc,
            bits_per_link: bits_this_round, // cumulative handled by caller
            distortion: distortion_sum / n as f64,
            levels: levels_now,
            lr: lr as f64,
            wall_secs: timer.elapsed_secs(),
            virtual_secs: 0.0,
            straggler_wait_secs: 0.0,
            wire_bytes: wire_link_bytes, // cumulative handled by caller
        })
    }

    /// Run the configured number of rounds; returns the full log with
    /// cumulative per-link bits.
    pub fn run(&mut self) -> anyhow::Result<RunLog> {
        self.run_with(None)
    }

    /// Run all configured rounds on a [`crate::simnet::Fabric`]: the
    /// matrix engine produces the learning dynamics, the fabric's
    /// discrete-event clock produces *when* each round happens —
    /// `virtual_secs` / `straggler_wait_secs` in the returned log hold
    /// the paper's time-progression axis under heterogeneous links,
    /// stragglers, and churn.
    ///
    /// The fabric's link drop probability subsumes
    /// [`EngineOptions::drop_prob`] (broadcast-level fault injection),
    /// and churn-rebuilt topologies replace the engine's confusion
    /// matrix mid-run.
    pub fn run_simulated(
        &mut self,
        fabric: &mut crate::simnet::Fabric,
    ) -> anyhow::Result<RunLog> {
        // borrow the fabric's loss rate for the duration of this run
        // only — the engine stays reusable for ideal-network runs after
        let saved_drop_prob = self.opts.drop_prob;
        self.opts.drop_prob = fabric.link_drop_prob();
        let result = self.run_with(Some(fabric));
        self.opts.drop_prob = saved_drop_prob;
        result
    }

    /// Run all configured rounds, streaming each finished
    /// [`RoundRecord`] to `sink` instead of buffering the run — the
    /// 10k-node memory model: what stays resident is the returned
    /// [`crate::metrics::RunSummary`], not O(rounds) records. The
    /// record sequence is identical to [`run`](Self::run) /
    /// [`run_simulated`] (one shared round loop), so a
    /// [`crate::metrics::CsvStream`] sink produces byte-identical CSV
    /// to the buffered log's `to_csv` (`rust/tests/streaming_parity.rs`).
    pub fn run_streamed(
        &mut self,
        fabric: Option<&mut crate::simnet::Fabric>,
        sink: &mut dyn crate::metrics::RecordSink,
    ) -> anyhow::Result<crate::metrics::RunSummary> {
        let saved_drop_prob = self.opts.drop_prob;
        if let Some(f) = fabric.as_ref() {
            self.opts.drop_prob = f.link_drop_prob();
        }
        let mut summary = crate::metrics::RunSummary::default();
        let result = self.run_inner(fabric, |rec| {
            summary.observe(&rec);
            sink.record(&rec)
        });
        self.opts.drop_prob = saved_drop_prob;
        result?;
        summary.stamp_peak_rss();
        Ok(summary)
    }

    /// Shared driver for [`run`](Self::run) / [`run_simulated`]: one
    /// round loop, one cumulative-bits convention.
    fn run_with(
        &mut self,
        fabric: Option<&mut crate::simnet::Fabric>,
    ) -> anyhow::Result<RunLog> {
        let mut log = RunLog::new(&self.cfg.name);
        self.run_inner(fabric, |rec| {
            log.push(rec);
            Ok(())
        })?;
        Ok(log)
    }

    /// The one round loop behind every run entry point: emits each
    /// finished record through `emit` (buffered push or streaming
    /// sink — same records either way).
    fn run_inner(
        &mut self,
        mut fabric: Option<&mut crate::simnet::Fabric>,
        mut emit: impl FnMut(RoundRecord) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let mut cum_bits = 0u64;
        let mut cum_wire = 0u64;
        for k in 0..self.cfg.rounds {
            if let Some(f) = fabric.as_deref_mut() {
                if let Some(topo) = f.pre_round(k) {
                    self.topology = topo;
                }
            }
            let mut rec = self.round(k)?;
            if let Some(f) = fabric.as_deref_mut() {
                // per-node wire sizes were filled by the round's
                // reduction (same q2 substitution — see
                // NodeRound::effective_q2_wire_bytes)
                let timing = f.simulate_round(
                    self.cfg.tau,
                    &self.q2_wire,
                    &self.q1_wire,
                );
                rec.virtual_secs = timing.virtual_secs;
                rec.straggler_wait_secs = timing.straggler_wait_secs;
                // the fabric's own byte meter is the accounting truth
                // under churn (down links / offline receivers carry
                // nothing; the engine-side estimate can't see that)
                rec.wire_bytes = f.bytes_on_wire();
            } else {
                cum_wire += rec.wire_bytes;
                rec.wire_bytes = cum_wire;
            }
            cum_bits += rec.bits_per_link;
            rec.bits_per_link = cum_bits;
            emit(rec)?;
        }
        Ok(())
    }

    /// Access the engine rng (tests).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Force every node's quantizer to `s` levels (used by scripted level
    /// schedules, e.g. the Fig. 4 descending ablation).
    pub fn set_all_levels(&mut self, s: usize) {
        for node in &mut self.nodes {
            node.quantizer.set_levels(s);
        }
    }

    /// Replace every node's quantizer (extension baselines such as
    /// TernGrad / top-k that are not part of the config enum).
    pub fn set_all_quantizers(
        &mut self,
        mut make: impl FnMut() -> Box<dyn Quantizer>,
    ) {
        for node in &mut self.nodes {
            node.quantizer = make();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        BackendKind, DatasetKind, Parallelism, QuantizerKind, TopologyKind,
    };
    use crate::dfl::backend::RustMlpBackend;

    fn small_cfg(quant: QuantizerKind) -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            seed: 42,
            nodes: 4,
            tau: 2,
            rounds: 12,
            batch_size: 16,
            lr: crate::config::LrSchedule::fixed(0.1),
            topology: TopologyKind::Ring,
            quantizer: quant,
            dataset: DatasetKind::Blobs {
                train: 240,
                test: 80,
                dim: 8,
                classes: 3,
            },
            backend: BackendKind::RustMlp { hidden: vec![16] },
            noniid_fraction: 0.5,
            link_bps: 100e6,
            eval_every: 1,
            parallelism: Parallelism::Auto,
            network: None,
            mode: Default::default(),
            encoding: Default::default(),
            agossip: None,
            transport: None,
            observe: None,
            attack: None,
            mixing: Default::default(),
        }
    }

    fn build_engine(cfg: ExperimentConfig) -> DflEngine {
        let topo = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let data = Dataset::build(&cfg.dataset, cfg.seed);
        let backends: Vec<Box<dyn LocalUpdate>> = (0..cfg.nodes)
            .map(|_| {
                Box::new(RustMlpBackend::new(
                    data.feat_dim,
                    &[16],
                    data.classes,
                )) as Box<dyn LocalUpdate>
            })
            .collect();
        DflEngine::new(cfg, topo, data, backends, EngineOptions::default())
            .unwrap()
    }

    #[test]
    fn loss_decreases_with_lm_quantizer() {
        let mut e = build_engine(
            small_cfg(QuantizerKind::LloydMax { s: 16, iters: 8 }));
        let log = e.run().unwrap();
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn loss_decreases_with_all_quantizers() {
        for q in [
            QuantizerKind::Full,
            QuantizerKind::Qsgd { s: 16 },
            QuantizerKind::Natural { s: 16 },
            QuantizerKind::Alq { s: 16 },
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 8, s_max: 64 },
        ] {
            let name = format!("{q:?}");
            let mut e = build_engine(small_cfg(q));
            let log = e.run().unwrap();
            let first = log.records.first().unwrap().loss;
            let last = log.records.last().unwrap().loss;
            assert!(
                last < first,
                "{name}: loss did not decrease ({first} -> {last})"
            );
        }
    }

    #[test]
    fn bits_accumulate_monotonically() {
        let mut e =
            build_engine(small_cfg(QuantizerKind::Qsgd { s: 16 }));
        let log = e.run().unwrap();
        let mut prev = 0;
        let mut prev_wire = 0;
        for r in &log.records {
            assert!(r.bits_per_link > prev);
            prev = r.bits_per_link;
            assert!(r.wire_bytes > prev_wire);
            prev_wire = r.wire_bytes;
        }
        // per-node counters add up to the per-link total: ring degree 2
        let per_node: u64 = e.node_wire_bytes().iter().sum();
        assert_eq!(log.records.last().unwrap().wire_bytes, per_node * 2);
    }

    #[test]
    fn matrix_and_bitstream_encodings_bit_identical() {
        // the fast in-module smoke for the encoding parity contract;
        // the full sync/async × every-quantizer matrix lives in
        // rust/tests/simnet_determinism.rs
        for quant in [
            QuantizerKind::LloydMax { s: 8, iters: 5 },
            QuantizerKind::Qsgd { s: 4 },
        ] {
            let mut cfg = small_cfg(quant);
            cfg.encoding = crate::config::WireEncoding::Matrix;
            let m = build_engine(cfg.clone()).run().unwrap();
            cfg.encoding = crate::config::WireEncoding::Bitstream;
            let b = build_engine(cfg).run().unwrap();
            assert_eq!(m.records.len(), b.records.len());
            for (x, y) in m.records.iter().zip(&b.records) {
                assert_eq!(x.loss.to_bits(), y.loss.to_bits());
                assert_eq!(x.distortion.to_bits(), y.distortion.to_bits());
                assert_eq!(x.bits_per_link, y.bits_per_link);
                assert_eq!(x.wire_bytes, y.wire_bytes);
                assert_eq!(x.levels, y.levels);
            }
        }
    }

    #[test]
    fn lower_s_means_fewer_bits() {
        let mut e4 =
            build_engine(small_cfg(QuantizerKind::Qsgd { s: 4 }));
        let mut e256 =
            build_engine(small_cfg(QuantizerKind::Qsgd { s: 256 }));
        let b4 = e4.run().unwrap().total_bits();
        let b256 = e256.run().unwrap().total_bits();
        assert!(b4 < b256, "{b4} !< {b256}");
    }

    #[test]
    fn consensus_gap_shrinks_on_full_topology() {
        let mut cfg = small_cfg(QuantizerKind::Full);
        cfg.topology = TopologyKind::Full;
        cfg.rounds = 2;
        let mut e = build_engine(cfg);
        let _ = e.round(0).unwrap();
        let gap1 = e.consensus_gap();
        // a couple more rounds: nodes stay near consensus despite local
        // updates because C = J averages fully
        let _ = e.round(1).unwrap();
        let gap2 = e.consensus_gap();
        assert!(gap2 < gap1 * 5.0 + 1.0, "gap exploded: {gap1} -> {gap2}");
    }

    #[test]
    fn doubly_adaptive_levels_ascend() {
        let mut e = build_engine(small_cfg(
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 8, s_max: 256 }));
        let log = e.run().unwrap();
        let first = log.records.first().unwrap().levels;
        let last = log.records.last().unwrap().levels;
        assert_eq!(first, 4);
        assert!(last >= first, "levels should ascend: {first} -> {last}");
        for w in log.records.windows(2) {
            assert!(w[1].levels >= w[0].levels, "levels dipped");
        }
    }

    #[test]
    fn distortion_recorded_and_reasonable() {
        let mut e = build_engine(
            small_cfg(QuantizerKind::LloydMax { s: 16, iters: 10 }));
        let log = e.run().unwrap();
        for r in &log.records {
            assert!(r.distortion.is_finite());
            assert!(r.distortion >= 0.0);
            // Theorem 2 bound with slack: d/(12 s^2)
            let bound = e.param_count() as f64 / (12.0 * 256.0);
            assert!(
                r.distortion <= bound * 2.0 + 0.05,
                "distortion {} above bound {bound}",
                r.distortion
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let l1 = build_engine(
            small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 }))
            .run()
            .unwrap();
        let l2 = build_engine(
            small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 }))
            .run()
            .unwrap();
        assert_eq!(l1.records.len(), l2.records.len());
        for (a, b) in l1.records.iter().zip(&l2.records) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.bits_per_link, b.bits_per_link);
        }
    }

    #[test]
    fn sequential_and_parallel_rounds_bit_identical() {
        // the dedicated integration test covers all quantizers; this is
        // the fast in-module smoke for the core guarantee
        let mut cfg = small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.parallelism = Parallelism::Off;
        let seq = build_engine(cfg.clone()).run().unwrap();
        cfg.parallelism = Parallelism::Fixed(3);
        let par = build_engine(cfg).run().unwrap();
        for (a, b) in seq.records.iter().zip(&par.records) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.distortion.to_bits(), b.distortion.to_bits());
            assert_eq!(a.bits_per_link, b.bits_per_link);
            assert_eq!(a.levels, b.levels);
        }
    }

    #[test]
    fn swapped_quantizers_ship_wire_frames() {
        // set_all_quantizers installs baselines the config enum does
        // not know; under encoding: bitstream the frames must carry
        // the ACTIVE quantizer's tag (an implied-table message under
        // the configured kind's tag would refuse to self-decode)
        let mut cfg = small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 3;
        // full precision: implied table, tag must say "full"
        let mut e = build_engine(cfg.clone());
        e.set_all_quantizers(|| {
            Box::new(crate::quant::FullPrecision::new())
        });
        let log = e.run().unwrap();
        assert!(log.last_loss().unwrap().is_finite());
        // terngrad: a shipped-table extension baseline (new wire tag)
        let mut e = build_engine(cfg);
        e.set_all_quantizers(|| {
            Box::new(crate::quant::TernGradQuantizer::new())
        });
        let log = e.run().unwrap();
        assert!(log.last_loss().unwrap().is_finite());
    }

    #[test]
    fn trimmed_zero_mixing_is_bit_identical_to_metropolis() {
        // the f = 0 degenerate form must route through the plain axpy
        // path — same bits, not just same values
        let mut cfg = small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.mixing = crate::config::MixingKind::Metropolis;
        let a = build_engine(cfg.clone()).run().unwrap();
        cfg.mixing = crate::config::MixingKind::Trimmed { f: 0 };
        let b = build_engine(cfg).run().unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.distortion.to_bits(), y.distortion.to_bits());
            assert_eq!(x.wire_bytes, y.wire_bytes);
        }
    }

    #[test]
    fn robust_mixing_rules_still_learn_unattacked() {
        for mixing in [
            crate::config::MixingKind::Trimmed { f: 1 },
            crate::config::MixingKind::Median,
        ] {
            let mut cfg =
                small_cfg(QuantizerKind::LloydMax { s: 16, iters: 8 });
            cfg.topology = TopologyKind::Full;
            cfg.mixing = mixing;
            let log = build_engine(cfg).run().unwrap();
            let first = log.records.first().unwrap().loss;
            let last = log.records.last().unwrap().loss;
            assert!(last < first, "{mixing:?}: loss {first} -> {last}");
        }
    }

    #[test]
    fn honest_subset_eval_differs_under_attack() {
        let mut cfg = small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        cfg.rounds = 4;
        cfg.attack = Some(crate::config::AttackConfig {
            kind: crate::config::AttackKind::SignFlip,
            f: 1,
        });
        let mut e = build_engine(cfg);
        for k in 0..4 {
            e.round(k).unwrap();
        }
        let (all_loss, _) = e.evaluate_global().unwrap();
        e.set_eval_nodes(Some(vec![1, 2, 3]));
        let (honest_loss, _) = e.evaluate_global().unwrap();
        assert!(all_loss.is_finite() && honest_loss.is_finite());
        assert_ne!(
            all_loss.to_bits(),
            honest_loss.to_bits(),
            "subset eval should change the scored model"
        );
        e.set_eval_nodes(None);
        let (back, _) = e.evaluate_global().unwrap();
        assert_eq!(back.to_bits(), all_loss.to_bits());
    }

    #[test]
    fn worker_count_follows_config() {
        let mut cfg = small_cfg(QuantizerKind::Full);
        cfg.parallelism = Parallelism::Off;
        assert_eq!(build_engine(cfg.clone()).workers(), 1);
        cfg.parallelism = Parallelism::Fixed(2);
        assert_eq!(build_engine(cfg.clone()).workers(), 2);
        // fixed counts clamp to the node count
        cfg.parallelism = Parallelism::Fixed(64);
        assert_eq!(build_engine(cfg).workers(), 4);
    }

    #[test]
    fn failure_injection_still_converges() {
        let cfg = small_cfg(QuantizerKind::LloydMax { s: 16, iters: 8 });
        let topo = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let data = Dataset::build(&cfg.dataset, cfg.seed);
        let backends: Vec<Box<dyn LocalUpdate>> = (0..cfg.nodes)
            .map(|_| {
                Box::new(RustMlpBackend::new(
                    data.feat_dim, &[16], data.classes))
                    as Box<dyn LocalUpdate>
            })
            .collect();
        let opts = EngineOptions { drop_prob: 0.2, ..Default::default() };
        let mut e =
            DflEngine::new(cfg, topo, data, backends, opts).unwrap();
        let log = e.run().unwrap();
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last < first, "lossy links broke training entirely");
    }

    #[test]
    fn simulated_run_fills_virtual_time() {
        let cfg = small_cfg(QuantizerKind::LloydMax { s: 8, iters: 5 });
        let topo = Topology::build(&cfg.topology, cfg.nodes, cfg.seed);
        let net = crate::simnet::NetworkConfig {
            link: crate::simnet::LinkModel {
                latency_s: 0.001,
                bandwidth_bps: 1e6,
                jitter_s: 0.0,
                drop_prob: 0.0,
            },
            ..Default::default()
        };
        let mut fabric =
            crate::simnet::Fabric::new(&net, &topo, cfg.seed);
        let mut e = build_engine(cfg);
        let log = e.run_simulated(&mut fabric).unwrap();
        let mut prev = 0.0;
        for r in &log.records {
            assert!(
                r.virtual_secs > prev,
                "virtual clock not monotone: {} -> {}",
                prev,
                r.virtual_secs
            );
            prev = r.virtual_secs;
            assert!(r.straggler_wait_secs >= 0.0);
        }
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last < first, "simulated run did not learn");
    }

    #[test]
    fn full_quantizer_matches_exact_dfl_closely() {
        // with the full-precision quantizer, X̂ ≈ X and the update reduces
        // to plain DFL; average model must track a direct simulation well.
        let cfg = small_cfg(QuantizerKind::Full);
        let mut e = build_engine(cfg);
        let log = e.run().unwrap();
        // sanity: loss went down substantially on blobs
        assert!(log.records.last().unwrap().loss < 0.7);
    }
}
