//! Threaded message-passing DFL runtime.
//!
//! Where [`super::engine::DflEngine`] simulates the gossip in matrix form,
//! this runtime runs one OS thread per node exchanging *encoded bitstreams*
//! (quant::codec) over channels — the wire bytes are measured, per-link
//! faults drop real messages, and each node maintains its own per-neighbor
//! estimate state (no shared memory between nodes beyond the channels).
//!
//! Protocol per round k (Algorithm 2 with estimate-referenced deltas —
//! see dfl::engine for the deviation note):
//!   phase 0: broadcast  q2 = Q(x_k − x̂_self)     → everyone x̂ += q2
//!   phase 1: τ local SGD steps
//!   phase 2: broadcast  q1 = Q(x_{k,τ} − x̂_self) → everyone x̂ += q1
//!   phase 3: x_{k+1} = Σ_j c_ji x̂_j               (neighbors ∪ self)
//!
//! Messages are tagged (round, phase) and buffered, so fast neighbors may
//! run ahead one round without corrupting a slow receiver.
//!
//! # Zero-alloc message path
//!
//! After warm-up a node thread allocates one `Arc<[u8]>` per *broadcast*
//! (shared by every peer — the old path cloned the byte vector per
//! peer): the encode scratch buffer, the decode-side message buffer, the
//! implied-level-table cache, and the batch index/feature/label buffers
//! are all reused across rounds, and the mailbox stash only moves `Arc`
//! handles around.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::config::{ExperimentConfig, QuantizerKind};
use crate::data::{BatchSampler, Dataset};
use crate::dfl::backend::LocalUpdate;
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::adaptive::AdaptiveLevels;
use crate::quant::wire;
use crate::quant::{build_quantizer, Quantizer};
use crate::simnet::LinkModel;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// A tagged wire message. The payload is shared across every receiver of
/// the broadcast; an empty payload is the drop tombstone.
struct WireMsg {
    from: usize,
    round: usize,
    phase: u8,
    bytes: Arc<[u8]>,
}

/// Per-round report a node thread sends to the coordinator.
struct NodeReport {
    round: usize,
    wire_bits: u64,
    /// paper-accounting bits (Eq. 12) — kept alongside the measured wire
    /// bits for the overhead cross-check in integration tests
    #[allow(dead_code)]
    paper_bits: u64,
    levels: usize,
    #[allow(dead_code)]
    local_loss: f64,
    /// params snapshot (only when the coordinator asked for an eval round)
    params: Option<Vec<f32>>,
}

/// Options for the threaded runtime.
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// per-directed-link transmission model. The old `drop_prob` knob is
    /// `link.drop_prob` now; latency/bandwidth/jitter are carried for
    /// simnet-configured runs (they shape the virtual-time axis, not the
    /// OS thread scheduling).
    pub link: LinkModel,
    /// evaluate (collect params) every this many rounds
    pub eval_every: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions { link: LinkModel::ideal(), eval_every: 1 }
    }
}

impl NetOptions {
    /// Back-compat constructor for the common "ideal link with losses"
    /// setup (the old `drop_prob` field).
    pub fn lossy(drop_prob: f64) -> Self {
        NetOptions { link: LinkModel::lossy(drop_prob), eval_every: 1 }
    }
}

/// Buffered receiver: returns the message for (from, round, phase),
/// stashing any out-of-order arrivals. Payloads are shared `Arc`s, so
/// stashing moves a handle, never the bytes.
struct Mailbox {
    rx: Receiver<WireMsg>,
    stash: HashMap<(usize, usize, u8), VecDeque<Arc<[u8]>>>,
}

impl Mailbox {
    fn new(rx: Receiver<WireMsg>) -> Self {
        Mailbox { rx, stash: HashMap::new() }
    }

    fn recv(
        &mut self,
        from: usize,
        round: usize,
        phase: u8,
    ) -> anyhow::Result<Arc<[u8]>> {
        let key = (from, round, phase);
        loop {
            if let Some(q) = self.stash.get_mut(&key) {
                if let Some(bytes) = q.pop_front() {
                    return Ok(bytes);
                }
            }
            let msg = self
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("peer channel closed"))?;
            let mkey = (msg.from, msg.round, msg.phase);
            if mkey == key {
                return Ok(msg.bytes);
            }
            self.stash.entry(mkey).or_default().push_back(msg.bytes);
        }
    }
}

/// Backend factory: called once per node *inside that node's thread* (the
/// PJRT types are not `Send`, so backends cannot cross threads).
pub type BackendFactory<'a> =
    &'a (dyn Fn(usize) -> anyhow::Result<Box<dyn LocalUpdate>> + Sync);

/// Run a full DFL training with one thread per node. Returns a [`RunLog`]
/// whose bits_per_link are MEASURED wire bits (cumulative, averaged over
/// directed links).
pub fn run_threaded(
    cfg: &ExperimentConfig,
    topology: &Topology,
    dataset: Arc<Dataset>,
    factory: BackendFactory<'_>,
    opts: NetOptions,
) -> anyhow::Result<RunLog> {
    let n = cfg.nodes;
    // probe instance: shared init params + param_count (coordinator reuses
    // it for evaluation)
    let mut eval_backend = factory(n)?;
    let param_count = eval_backend.param_count();
    let mut seed_rng = Rng::new(cfg.seed);
    let init = eval_backend.init_params(&mut seed_rng.split(0xBEEF));
    let parts = crate::data::partition::partition_noniid(
        &dataset.train_y, n, cfg.noniid_fraction, cfg.seed);

    // channels: one receiver per node; senders cloned per incoming edge
    let mut txs: Vec<Sender<WireMsg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<WireMsg>();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let (report_tx, report_rx) = channel::<anyhow::Result<NodeReport>>();

    let kind = cfg.quantizer.clone();
    let rounds = cfg.rounds;
    let tau = cfg.tau;
    let batch = cfg.batch_size;
    let lr = cfg.lr.clone();

    let result: anyhow::Result<RunLog> = std::thread::scope(|scope| {
        for i in 0..n {
            let my_rx = rxs[i].take().unwrap();
            let neighbors: Vec<usize> = topology.neighbors(i).to_vec();
            let peer_tx: Vec<Sender<WireMsg>> =
                neighbors.iter().map(|&j| txs[j].clone()).collect();
            let weights: Vec<f32> = neighbors
                .iter()
                .map(|&j| topology.c[(j, i)] as f32)
                .collect();
            let self_weight = topology.c[(i, i)] as f32;
            let dataset = Arc::clone(&dataset);
            let part = parts[i].clone();
            let init = init.clone();
            let kind = kind.clone();
            let report_tx = report_tx.clone();
            let lr = lr.clone();
            let link = opts.link.clone();
            let eval_every = opts.eval_every;
            let node_seed = cfg.seed ^ (0xA000 + i as u64);

            scope.spawn(move || {
                let run = || -> anyhow::Result<()> {
                    let mut backend = factory(i)?;
                    let mut rng = Rng::new(node_seed);
                    let mut sampler =
                        BatchSampler::new(part, rng.split(1));
                    let mut quantizer = build_quantizer(&kind);
                    let mut adaptive = match &kind {
                        QuantizerKind::DoublyAdaptive {
                            s1, s_max, ..
                        } => Some(AdaptiveLevels::new(*s1, *s_max)),
                        _ => None,
                    };
                    let tag = wire::QuantTag::from_kind(&kind);
                    let mut mailbox = Mailbox::new(my_rx);
                    let mut params = init.clone();
                    // own + per-neighbor estimates x̂
                    let mut hat_self = vec![0.0f32; param_count];
                    let mut hat: Vec<Vec<f32>> =
                        vec![vec![0.0f32; param_count]; neighbors.len()];
                    let mut dq = vec![0.0f32; param_count];
                    let mut diff = vec![0.0f32; param_count];
                    let mut mix = vec![0.0f32; param_count];
                    // reusable message buffers (zero-alloc path): encode
                    // scratch, decode target, implied-table cache,
                    // mini-batch scratch, and the shared drop tombstone
                    let mut msg_out = crate::quant::QuantizedVector::empty();
                    let mut msg_in = crate::quant::QuantizedVector::empty();
                    let mut enc_buf: Vec<u8> = Vec::new();
                    let mut implied_cache = wire::ImpliedCache::new();
                    let tombstone: Arc<[u8]> =
                        Arc::from(Vec::new().into_boxed_slice());
                    let mut batch_idx: Vec<usize> = Vec::new();
                    let mut batch_x: Vec<f32> = Vec::new();
                    let mut batch_y: Vec<u32> = Vec::new();

                    for k in 0..rounds {
                        let mut wire_bits = 0u64;
                        let mut paper_bits = 0u64;

                        // one broadcast phase: q = Q(target − x̂_self),
                        // everyone (incl. self) applies x̂ += q
                        let mut broadcast = |phase: u8,
                                             params: &[f32],
                                             hat_self: &mut [f32],
                                             hat: &mut [Vec<f32>],
                                             quantizer: &mut Box<dyn Quantizer>,
                                             rng: &mut Rng,
                                             mailbox: &mut Mailbox,
                                             wire_bits: &mut u64,
                                             paper_bits: &mut u64|
                         -> anyhow::Result<()> {
                            crate::quant::kernels::sub_into(
                                &mut diff, params, hat_self,
                            );
                            crate::quant::quantize_damped_into(
                                quantizer.as_mut(), &diff, rng, &mut dq,
                                &mut msg_out);
                            let q = &msg_out;
                            // the versioned wire frame: header (round /
                            // sender / tag / bit-width) + codec body
                            enc_buf = wire::encode_with_buf(
                                &wire::WireHeader::new(
                                    tag, phase, i as u32, k as u32,
                                    q.s(),
                                ),
                                q,
                                std::mem::take(&mut enc_buf),
                            );
                            // one shared allocation per broadcast; peers
                            // clone the Arc handle, not the bytes
                            let bytes: Arc<[u8]> =
                                Arc::from(enc_buf.as_slice());
                            for tx in &peer_tx {
                                let dropped = link.dropped(rng);
                                *wire_bits += bytes.len() as u64 * 8;
                                *paper_bits += q.paper_bits();
                                // tombstone (empty payload) on drop so
                                // receivers don't deadlock
                                let payload = if dropped {
                                    Arc::clone(&tombstone)
                                } else {
                                    Arc::clone(&bytes)
                                };
                                let _ = tx.send(WireMsg {
                                    from: i,
                                    round: k,
                                    phase,
                                    bytes: payload,
                                });
                            }
                            // re-dequantize from the (damped) wire
                            // message fused with the estimate update, so
                            // sender and receivers apply byte-identical
                            // deltas
                            q.dequantize_accumulate_into(hat_self);
                            for (ni, &from) in
                                neighbors.iter().enumerate()
                            {
                                let bytes = mailbox.recv(from, k, phase)?;
                                if bytes.is_empty() {
                                    continue; // dropped: stale estimate
                                }
                                let h = wire::decode_into(
                                    &bytes,
                                    &mut implied_cache,
                                    &mut msg_in,
                                )?;
                                anyhow::ensure!(
                                    h.sender as usize == from
                                        && h.round as usize == k
                                        && h.phase == phase,
                                    "wire header (sender {}, round {}, \
                                     phase {}) contradicts mailbox key \
                                     ({from}, {k}, {phase})",
                                    h.sender,
                                    h.round,
                                    h.phase
                                );
                                msg_in
                                    .dequantize_accumulate_into(&mut hat[ni]);
                            }
                            Ok(())
                        };

                        // ---- phase 0: mixing-delta broadcast ----------
                        broadcast(
                            0, &params, &mut hat_self, &mut hat,
                            &mut quantizer, &mut rng, &mut mailbox,
                            &mut wire_bits, &mut paper_bits,
                        )?;

                        // ---- phase 1: τ local updates -----------------
                        let lr_k = lr.at(k) as f32;
                        let mut local_loss = 0.0f64;
                        for _ in 0..tau {
                            sampler.next_batch_into(batch, &mut batch_idx);
                            dataset.gather_batch_into(
                                &batch_idx,
                                &mut batch_x,
                                &mut batch_y,
                            );
                            local_loss += backend.step(
                                &mut params,
                                &batch_x,
                                &batch_y,
                                lr_k,
                            )?;
                        }
                        local_loss /= tau as f64;
                        if let Some(ad) = adaptive.as_mut() {
                            let s = ad.update(local_loss);
                            quantizer.set_levels(s);
                        }

                        // ---- phase 2: local-update-delta broadcast ----
                        broadcast(
                            2, &params, &mut hat_self, &mut hat,
                            &mut quantizer, &mut rng, &mut mailbox,
                            &mut wire_bits, &mut paper_bits,
                        )?;

                        // ---- phase 3: mixing ---------------------------
                        // x += Σ c_ji x̂_j − x̂_self (consensus correction
                        // on true params; = X̂C when estimates are exact)
                        crate::quant::kernels::scaled_into(
                            &mut mix, self_weight, &hat_self,
                        );
                        for (ni, _) in neighbors.iter().enumerate() {
                            crate::quant::kernels::axpy(
                                &mut mix, weights[ni], &hat[ni],
                            );
                        }
                        crate::quant::kernels::add_delta(
                            &mut params, &mix, &hat_self,
                        );

                        // ---- report -----------------------------------
                        let snapshot = if k % eval_every == 0 {
                            Some(params.clone())
                        } else {
                            None
                        };
                        report_tx
                            .send(Ok(NodeReport {
                                round: k,
                                wire_bits,
                                paper_bits,
                                levels: quantizer.levels(),
                                local_loss,
                                params: snapshot,
                            }))
                            .ok();
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    let _ = report_tx.send(Err(e));
                }
            });
        }
        drop(report_tx);
        drop(txs);

        // ---- coordinator: aggregate reports, evaluate ------------------
        let mut log = RunLog::new(&cfg.name);
        let mut cum_bits = 0u64;
        let mut cum_wire_bytes = 0u64;
        let links = topology.directed_links().max(1) as u64;
        let mut per_round: HashMap<usize, Vec<NodeReport>> = HashMap::new();
        let mut done_rounds = 0usize;
        while done_rounds < rounds {
            let report = report_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("all nodes exited early"))??;
            let k = report.round;
            let entry = per_round.entry(k).or_default();
            entry.push(report);
            if entry.len() == n {
                let reports = per_round.remove(&k).unwrap();
                let wire: u64 =
                    reports.iter().map(|r| r.wire_bits).sum();
                let levels = reports.iter().map(|r| r.levels).sum::<usize>()
                    / n;
                let lr_k = lr.at(k);
                let (loss, acc) = if reports
                    .iter()
                    .all(|r| r.params.is_some())
                {
                    let mut avg = vec![0.0f32; param_count];
                    for r in &reports {
                        for (a, &p) in
                            avg.iter_mut().zip(r.params.as_ref().unwrap())
                        {
                            *a += p;
                        }
                    }
                    avg.iter_mut().for_each(|x| *x /= n as f32);
                    let cap = dataset.train_n().min(2048);
                    let idx: Vec<usize> = (0..cap).collect();
                    let (x, y) = dataset.gather_batch(&idx);
                    let (l, _) = eval_backend.evaluate(&avg, &x, &y)?;
                    let tcap = dataset.test_n().min(2048);
                    let acc = if tcap > 0 {
                        let tx = &dataset.test_x
                            [..tcap * dataset.feat_dim];
                        let ty = &dataset.test_y[..tcap];
                        let (_, c) =
                            eval_backend.evaluate(&avg, tx, ty)?;
                        c as f64 / tcap as f64
                    } else {
                        f64::NAN
                    };
                    (l, acc)
                } else {
                    (f64::NAN, f64::NAN)
                };
                // per-directed-link average of measured wire bits
                cum_bits += wire / links;
                cum_wire_bytes += wire / 8;
                log.push(RoundRecord {
                    round: k + 1,
                    loss,
                    accuracy: acc,
                    bits_per_link: cum_bits,
                    distortion: f64::NAN,
                    levels,
                    lr: lr_k,
                    wall_secs: 0.0,
                    virtual_secs: 0.0,
                    straggler_wait_secs: 0.0,
                    wire_bytes: cum_wire_bytes,
                });
                done_rounds += 1;
            }
        }
        log.records.sort_by_key(|r| r.round);
        Ok(log)
    });
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, LrSchedule, TopologyKind};
    use crate::dfl::backend::RustMlpBackend;

    fn cfg(quant: QuantizerKind) -> ExperimentConfig {
        ExperimentConfig {
            name: "net-test".into(),
            seed: 11,
            nodes: 4,
            tau: 2,
            rounds: 8,
            batch_size: 16,
            lr: LrSchedule::fixed(0.1),
            topology: TopologyKind::Ring,
            quantizer: quant,
            dataset: DatasetKind::Blobs {
                train: 200,
                test: 60,
                dim: 8,
                classes: 3,
            },
            backend: crate::config::BackendKind::RustMlp {
                hidden: vec![16],
            },
            noniid_fraction: 0.5,
            link_bps: 100e6,
            eval_every: 1,
            parallelism: crate::config::Parallelism::Auto,
            network: None,
            mode: Default::default(),
            encoding: Default::default(),
            agossip: None,
        }
    }

    fn run(c: &ExperimentConfig, opts: NetOptions) -> RunLog {
        let topo = Topology::build(&c.topology, c.nodes, c.seed);
        let data = Arc::new(Dataset::build(&c.dataset, c.seed));
        let feat = data.feat_dim;
        let classes = data.classes;
        let factory = move |_i: usize| {
            Ok(Box::new(RustMlpBackend::new(feat, &[16], classes))
                as Box<dyn LocalUpdate>)
        };
        run_threaded(c, &topo, Arc::clone(&data), &factory, opts).unwrap()
    }

    #[test]
    fn threaded_training_converges() {
        let c = cfg(QuantizerKind::LloydMax { s: 16, iters: 8 });
        let log = run(&c, NetOptions::default());
        assert_eq!(log.records.len(), 8);
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn wire_bits_measured_and_monotone() {
        let c = cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&c, NetOptions::default());
        let mut prev = 0;
        let mut prev_wire = 0;
        for r in &log.records {
            assert!(r.bits_per_link > prev);
            prev = r.bits_per_link;
            assert!(r.wire_bytes > prev_wire);
            prev_wire = r.wire_bytes;
        }
        // every per-copy payload is a whole wire frame: the per-round
        // total is divisible by the per-message length (fixed s ⇒ one
        // size), and a ring ships 2 messages × 2 links × n per round
        let d = {
            let m = crate::models::MlpModel::new(&[8, 16, 3]);
            m.param_count()
        };
        let msg = crate::quant::wire::encoded_len(d, 16, true) as u64;
        assert_eq!(
            log.records.first().unwrap().wire_bytes,
            msg * 2 * 2 * c.nodes as u64
        );
    }

    #[test]
    fn survives_dropped_messages() {
        let c = cfg(QuantizerKind::LloydMax { s: 16, iters: 6 });
        let log = run(&c, NetOptions::lossy(0.25));
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last.is_finite());
        assert!(last < first * 1.5, "diverged: {first} -> {last}");
    }

    #[test]
    fn matches_matrix_engine_bits_order() {
        // threaded wire bits ≈ paper C_s bits + small header/table overhead
        let c = cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&c, NetOptions::default());
        let d = {
            let m = crate::models::MlpModel::new(&[8, 16, 3]);
            m.param_count()
        };
        let per_round_paper =
            2 * crate::quant::bits::c_s(d, 16); // q1 + q2
        let total_paper = per_round_paper * c.rounds as u64;
        let measured = log.total_bits();
        let ratio = measured as f64 / total_paper as f64;
        assert!(
            (0.9..1.2).contains(&ratio),
            "wire/paper ratio {ratio} (measured {measured}, paper {total_paper})"
        );
    }
}
