//! Message-passing DFL runtime over pluggable transports.
//!
//! Where [`super::engine::DflEngine`] simulates the gossip in matrix
//! form, this runtime runs real nodes exchanging *encoded bitstreams*
//! (quant::codec) through the [`crate::net::Delivery`] abstraction —
//! the wire bytes are measured by the transport, per-link faults drop
//! real messages, and each node maintains its own per-neighbor
//! estimate state (no shared memory between nodes beyond the
//! transport). The same gossip loop ([`run_node`]) drives:
//!
//! * `run_threaded` — one OS thread per node over an in-process
//!   channel mesh (or in-process TCP sockets for parity testing),
//! * [`run_node_process`] — one OS *process* per node over localhost
//!   TCP (`lmdfl node --rank R`), rank 0 doubling as the coordinator.
//!
//! Protocol per round k (Algorithm 2 with estimate-referenced deltas —
//! see dfl::engine for the deviation note):
//!   phase 0: broadcast  q2 = Q(x_k − x̂_self)     → everyone x̂ += q2
//!   phase 1: τ local SGD steps
//!   phase 2: broadcast  q1 = Q(x_{k,τ} − x̂_self) → everyone x̂ += q1
//!   phase 3: x_{k+1} = Σ_j c_ji x̂_j               (neighbors ∪ self)
//!
//! Messages are tagged (round, phase) and buffered by the
//! [`crate::net::Mailbox`], so fast neighbors may run ahead one round
//! without corrupting a slow receiver. A header that contradicts its
//! envelope key is a typed [`CodecError`] (the decode-total contract),
//! never a panic.
//!
//! # Zero-alloc message path
//!
//! After warm-up a node allocates one `Arc<[u8]>` per *broadcast*
//! (shared by every peer): the encode scratch buffer, the decode-side
//! message buffer, the implied-level-table cache, and the batch
//! index/feature/label buffers are all reused across rounds, and the
//! mailbox stash only moves `Arc` handles around.

use std::collections::{BTreeMap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::config::{
    AttackKind, ExperimentConfig, LrSchedule, MixingKind, QuantizerKind,
};
use crate::data::{BatchSampler, Dataset};
use crate::dfl::backend::LocalUpdate;
use crate::error::LmdflError;
use crate::metrics::{
    LogSink, RecordSink, RoundRecord, RunLog, RunSummary,
};
use crate::net::{
    channel_mesh, connect_retry, Delivery, FaultDelivery, Frame, Mailbox,
    TcpDelivery, TcpOptions, TransportConfig, TransportKind,
};
use crate::quant::adaptive::AdaptiveLevels;
use crate::quant::codec::CodecError;
use crate::quant::wire;
use crate::quant::{build_quantizer, Quantizer};
use crate::simnet::LinkModel;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Max wait for one expected frame before declaring the peer dead.
const MAILBOX_DEADLINE: Duration = Duration::from_secs(120);

/// Reserved phase tag of report-plane frames (multi-process runs).
/// Gossip phases are 0..4, so reports can never collide with them.
const REPORT_PHASE: u8 = 0xFE;

/// Per-round report a node sends to the coordinator.
struct NodeReport {
    node: usize,
    round: usize,
    wire_bits: u64,
    /// paper-accounting bits (Eq. 12) — kept alongside the measured
    /// wire bits for the overhead cross-check in integration tests
    paper_bits: u64,
    levels: usize,
    local_loss: f64,
    /// params snapshot (only on eval rounds)
    params: Option<Vec<f32>>,
}

/// Fixed-size head of an encoded report (everything but the params).
const REPORT_HEAD: usize = 37;

/// Serialize a report for the TCP report plane (LE fields, optional
/// params block behind a presence flag).
fn encode_report(r: &NodeReport) -> Vec<u8> {
    let extra = r.params.as_ref().map_or(0, |p| 4 + p.len() * 4);
    let mut out = Vec::with_capacity(REPORT_HEAD + extra);
    out.extend_from_slice(&(r.node as u32).to_le_bytes());
    out.extend_from_slice(&(r.round as u32).to_le_bytes());
    out.extend_from_slice(&r.wire_bits.to_le_bytes());
    out.extend_from_slice(&r.paper_bits.to_le_bytes());
    out.extend_from_slice(&(r.levels as u32).to_le_bytes());
    out.extend_from_slice(&r.local_loss.to_le_bytes());
    match &r.params {
        None => out.push(0),
        Some(p) => {
            out.push(1);
            out.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for &x in p {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

/// Total decoder for report frames — hostile bytes are a typed
/// [`CodecError`], never a panic.
fn decode_report(bytes: &[u8]) -> Result<NodeReport, CodecError> {
    let trunc = |need: usize, have: usize| CodecError::Truncated {
        need_bits: need as u64 * 8,
        have_bits: have as u64 * 8,
    };
    if bytes.len() < REPORT_HEAD {
        return Err(trunc(REPORT_HEAD, bytes.len()));
    }
    let u32_at = |o: usize| {
        u32::from_le_bytes(bytes[o..o + 4].try_into().expect("4 bytes"))
    };
    let u64_at = |o: usize| {
        u64::from_le_bytes(bytes[o..o + 8].try_into().expect("8 bytes"))
    };
    let params = match bytes[REPORT_HEAD - 1] {
        0 => {
            if bytes.len() != REPORT_HEAD {
                return Err(CodecError::Malformed(format!(
                    "{} trailing bytes after a no-params report",
                    bytes.len() - REPORT_HEAD
                )));
            }
            None
        }
        1 => {
            if bytes.len() < REPORT_HEAD + 4 {
                return Err(trunc(REPORT_HEAD + 4, bytes.len()));
            }
            let len = u32_at(REPORT_HEAD) as usize;
            let need = REPORT_HEAD + 4 + len * 4;
            if bytes.len() < need {
                return Err(trunc(need, bytes.len()));
            }
            if bytes.len() > need {
                return Err(CodecError::Malformed(format!(
                    "{} trailing bytes after the params block",
                    bytes.len() - need
                )));
            }
            let mut p = Vec::with_capacity(len);
            for c in bytes[REPORT_HEAD + 4..].chunks_exact(4) {
                p.push(f32::from_le_bytes(
                    c.try_into().expect("4 bytes"),
                ));
            }
            Some(p)
        }
        f => {
            return Err(CodecError::Malformed(format!(
                "bad report params flag {f}"
            )))
        }
    };
    Ok(NodeReport {
        node: u32_at(0) as usize,
        round: u32_at(4) as usize,
        wire_bits: u64_at(8),
        paper_bits: u64_at(16),
        levels: u32_at(24) as usize,
        local_loss: f64::from_le_bytes(
            bytes[28..36].try_into().expect("8 bytes"),
        ),
        params,
    })
}

/// Where a node's per-round reports go: an in-process channel
/// (threaded runs, and rank 0 of a multi-process run) or the TCP
/// report plane (remote ranks).
trait ReportSink {
    fn report(&mut self, r: NodeReport) -> anyhow::Result<()>;
}

struct ChannelSink(Sender<anyhow::Result<NodeReport>>);

impl ReportSink for ChannelSink {
    fn report(&mut self, r: NodeReport) -> anyhow::Result<()> {
        // a coordinator that already exited is not the node's error
        let _ = self.0.send(Ok(r));
        Ok(())
    }
}

struct TcpReportSink {
    stream: TcpStream,
}

impl TcpReportSink {
    /// Dial rank 0's report plane (port `base_port + nodes`).
    fn connect(
        opts: &TcpOptions,
        nodes: usize,
    ) -> Result<TcpReportSink, LmdflError> {
        let port = opts.port_of(nodes)?;
        Ok(TcpReportSink { stream: connect_retry(opts, port)? })
    }
}

impl ReportSink for TcpReportSink {
    fn report(&mut self, r: NodeReport) -> anyhow::Result<()> {
        let payload = encode_report(&r);
        wire::write_frame(
            &mut self.stream,
            r.node as u32,
            r.round as u32,
            REPORT_PHASE,
            &payload,
        )?;
        Ok(())
    }
}

/// Options for the threaded runtime.
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// per-directed-link transmission model. The old `drop_prob` knob is
    /// `link.drop_prob` now; latency/jitter are applied in real time by
    /// the [`FaultDelivery`] wrapper (bandwidth shaping stays the
    /// virtual clock's job).
    pub link: LinkModel,
    /// evaluate (collect params) every this many rounds
    pub eval_every: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions { link: LinkModel::ideal(), eval_every: 1 }
    }
}

impl NetOptions {
    /// Back-compat constructor for the common "ideal link with losses"
    /// setup (the old `drop_prob` field).
    pub fn lossy(drop_prob: f64) -> Self {
        NetOptions { link: LinkModel::lossy(drop_prob), eval_every: 1 }
    }
}

/// Backend factory: called once per node *inside that node's thread*
/// (the PJRT types are not `Send`, so backends cannot cross threads).
pub(crate) type BackendFactory<'a> =
    &'a (dyn Fn(usize) -> anyhow::Result<Box<dyn LocalUpdate>> + Sync);

/// Everything one node needs to run its gossip loop, independent of
/// how its frames move or where its reports go.
struct NodeCtx<'a> {
    node: usize,
    neighbors: Vec<usize>,
    /// mixing weight c_ji per neighbor (column of the Metropolis C)
    weights: Vec<f32>,
    self_weight: f32,
    /// this node's sample indices (non-IID partition)
    part: Vec<usize>,
    dataset: &'a Dataset,
    /// shared initial params (identical on every node)
    init: &'a [f32],
    kind: QuantizerKind,
    rounds: usize,
    tau: usize,
    batch: usize,
    lr: LrSchedule,
    /// the experiment seed; the node derives its own streams from it
    seed: u64,
    eval_every: usize,
    /// this node's Byzantine role, if any (corrupts its own
    /// differential before quantization, exactly like the matrix
    /// engines' `NodeCore` path)
    attack: Option<AttackKind>,
    mixing: MixingKind,
}

fn node_ctx<'a>(
    cfg: &ExperimentConfig,
    topology: &Topology,
    dataset: &'a Dataset,
    init: &'a [f32],
    part: Vec<usize>,
    node: usize,
) -> NodeCtx<'a> {
    let neighbors: Vec<usize> = topology.neighbors(node).to_vec();
    // C is bitwise symmetric, so reading row `node` of the sparse form
    // gives the same f32 weights the dense column lookup produced
    let weights: Vec<f32> = neighbors
        .iter()
        .map(|&j| topology.weight(node, j) as f32)
        .collect();
    NodeCtx {
        node,
        neighbors,
        weights,
        self_weight: topology.sparse.self_weight(node) as f32,
        part,
        dataset,
        init,
        kind: cfg.quantizer.clone(),
        rounds: cfg.rounds,
        tau: cfg.tau,
        batch: cfg.batch_size,
        lr: cfg.lr.clone(),
        seed: cfg.seed,
        eval_every: cfg.eval_every,
        attack: cfg.attack.as_ref().and_then(|a| a.role(node)).cloned(),
        mixing: cfg.mixing,
    }
}

/// One node's full gossip loop — the protocol, with byte movement
/// behind `mailbox` and reporting behind `sink`.
fn run_node(
    ctx: NodeCtx<'_>,
    backend: &mut dyn LocalUpdate,
    mailbox: &mut Mailbox,
    sink: &mut dyn ReportSink,
) -> anyhow::Result<()> {
    let NodeCtx {
        node: i,
        neighbors,
        weights,
        self_weight,
        part,
        dataset,
        init,
        kind,
        rounds,
        tau,
        batch,
        lr,
        seed,
        eval_every,
        attack,
        mixing,
    } = ctx;
    let param_count = init.len();
    let mut rng = Rng::new(seed ^ (0xA000 + i as u64));
    let mut sampler = BatchSampler::new(part, rng.split(1));
    let mut quantizer = build_quantizer(&kind);
    let mut adaptive = match &kind {
        QuantizerKind::DoublyAdaptive { s1, s_max, .. } => {
            Some(AdaptiveLevels::new(*s1, *s_max))
        }
        _ => None,
    };
    let tag = wire::QuantTag::from_kind(&kind);
    let mut params = init.to_vec();
    // own + per-neighbor estimates x̂
    let mut hat_self = vec![0.0f32; param_count];
    let mut hat: Vec<Vec<f32>> =
        vec![vec![0.0f32; param_count]; neighbors.len()];
    let mut dq = vec![0.0f32; param_count];
    let mut diff = vec![0.0f32; param_count];
    let mut mix = vec![0.0f32; param_count];
    // reusable message buffers (zero-alloc path): encode scratch,
    // decode target, implied-table cache, and mini-batch scratch
    let mut msg_out = crate::quant::QuantizedVector::empty();
    let mut msg_in = crate::quant::QuantizedVector::empty();
    let mut enc_buf: Vec<u8> = Vec::new();
    let mut implied_cache = wire::ImpliedCache::new();
    let mut batch_idx: Vec<usize> = Vec::new();
    let mut batch_x: Vec<f32> = Vec::new();
    let mut batch_y: Vec<u32> = Vec::new();

    for k in 0..rounds {
        let _round_span = crate::obs::span("round");
        let bytes_before = mailbox.wire_bytes();
        let mut paper_bits = 0u64;

        // one broadcast phase: q = Q(target − x̂_self), everyone
        // (incl. self) applies x̂ += q
        let mut broadcast = |phase: u8,
                             params: &[f32],
                             hat_self: &mut [f32],
                             hat: &mut [Vec<f32>],
                             quantizer: &mut Box<dyn Quantizer>,
                             rng: &mut Rng,
                             mailbox: &mut Mailbox,
                             paper_bits: &mut u64|
         -> anyhow::Result<()> {
            let enc_span = crate::obs::span("encode");
            crate::quant::kernels::sub_into(&mut diff, params, hat_self);
            if let Some(kind) = &attack {
                super::core::apply_attack(kind, &mut diff, rng);
            }
            crate::quant::quantize_damped_into(
                quantizer.as_mut(), &diff, rng, &mut dq, &mut msg_out);
            let q = &msg_out;
            // the versioned wire frame: header (round / sender / tag /
            // bit-width) + codec body
            enc_buf = wire::encode_with_buf(
                &wire::WireHeader::new(
                    tag, phase, i as u32, k as u32, q.s(),
                ),
                q,
                std::mem::take(&mut enc_buf),
            );
            drop(enc_span);
            // one shared allocation per broadcast; the transport moves
            // Arc handles, not the bytes
            let bytes: Arc<[u8]> = Arc::from(enc_buf.as_slice());
            crate::obs::counter(
                "encoded_bytes",
                quantizer.name(),
                bytes.len() as u64,
            );
            let send_span = crate::obs::span("send");
            for &j in &neighbors {
                *paper_bits += q.paper_bits();
                mailbox.send(
                    j,
                    Frame::new(i, k as u32, phase, Arc::clone(&bytes)),
                )?;
            }
            drop(send_span);
            // re-dequantize from the (damped) wire message fused with
            // the estimate update, so sender and receivers apply
            // byte-identical deltas
            q.dequantize_accumulate_into(hat_self);
            let recv_span = crate::obs::span("recv");
            for (ni, &from) in neighbors.iter().enumerate() {
                let bytes = mailbox.recv(
                    from, k as u32, phase, MAILBOX_DEADLINE,
                )?;
                if bytes.is_empty() {
                    continue; // dropped: stale estimate
                }
                let decode_span = crate::obs::span("decode");
                let h = wire::decode_into(
                    &bytes,
                    &mut implied_cache,
                    &mut msg_in,
                )?;
                // a header contradicting the envelope key is a typed
                // decode error, not a panic
                wire::validate_frame(&h, from, k as u32, phase)?;
                msg_in.dequantize_accumulate_into(&mut hat[ni]);
                drop(decode_span);
            }
            drop(recv_span);
            Ok(())
        };

        // ---- phase 0: mixing-delta broadcast ----------
        broadcast(
            0, &params, &mut hat_self, &mut hat, &mut quantizer,
            &mut rng, mailbox, &mut paper_bits,
        )?;

        // ---- phase 1: τ local updates -----------------
        let train_span = crate::obs::span("train");
        let lr_k = lr.at(k) as f32;
        let mut local_loss = 0.0f64;
        for _ in 0..tau {
            sampler.next_batch_into(batch, &mut batch_idx);
            dataset.gather_batch_into(
                &batch_idx, &mut batch_x, &mut batch_y,
            );
            local_loss +=
                backend.step(&mut params, &batch_x, &batch_y, lr_k)?;
        }
        local_loss /= tau as f64;
        drop(train_span);
        if let Some(ad) = adaptive.as_mut() {
            let s = ad.update(local_loss);
            quantizer.set_levels(s);
        }

        // ---- phase 2: local-update-delta broadcast ----
        broadcast(
            2, &params, &mut hat_self, &mut hat, &mut quantizer,
            &mut rng, mailbox, &mut paper_bits,
        )?;

        // ---- phase 3: mixing ---------------------------
        // x += Σ c_ji x̂_j − x̂_self (consensus correction on true
        // params; = X̂C when estimates are exact)
        let mix_span = crate::obs::span("mix");
        if mixing.is_plain() {
            crate::quant::kernels::scaled_into(
                &mut mix, self_weight, &hat_self,
            );
            for (ni, _) in neighbors.iter().enumerate() {
                crate::quant::kernels::axpy(
                    &mut mix, weights[ni], &hat[ni],
                );
            }
        } else {
            let nbrs: Vec<(&[f32], f64)> = neighbors
                .iter()
                .enumerate()
                .map(|(ni, _)| (hat[ni].as_slice(), weights[ni] as f64))
                .collect();
            let drops = crate::topology::robust_mix_into(
                &mut mix,
                &hat_self,
                self_weight as f64,
                &nbrs,
                &mixing,
            );
            if drops > 0 {
                crate::obs::counter("trimmed_drops", "net", drops);
            }
        }
        crate::quant::kernels::add_delta(&mut params, &mix, &hat_self);
        drop(mix_span);

        // ---- report -----------------------------------
        // measured wire bits = the transport meter's delta this round
        // (payload bytes of every frame offered to the link)
        let wire_bits = (mailbox.wire_bytes() - bytes_before) * 8;
        let snapshot = if k % eval_every == 0 {
            Some(params.clone())
        } else {
            None
        };
        sink.report(NodeReport {
            node: i,
            round: k,
            wire_bits,
            paper_bits,
            levels: quantizer.levels(),
            local_loss,
            params: snapshot,
        })?;
    }
    Ok(())
}

/// Aggregate per-node round reports into streamed round records:
/// average the eval snapshots (sorted by node so float summation
/// order is identical on every transport), evaluate, accumulate wire
/// bits, and hand each finished [`RoundRecord`] to `sink` — nothing
/// is buffered beyond rounds still waiting on a straggler's report.
/// Records are emitted strictly in round order (a round may finish
/// ahead of an earlier one on the TCP report plane), and cumulative
/// bit accounting happens at emission so the running totals are in
/// round order too.
#[allow(clippy::too_many_arguments)]
fn coordinate(
    n: usize,
    rounds: usize,
    lr: &LrSchedule,
    links: u64,
    param_count: usize,
    dataset: &Dataset,
    eval_backend: &mut dyn LocalUpdate,
    report_rx: Receiver<anyhow::Result<NodeReport>>,
    sink: &mut dyn RecordSink,
) -> anyhow::Result<RunSummary> {
    let mut summary = RunSummary::default();
    let mut cum_bits = 0u64;
    let mut cum_wire_bytes = 0u64;
    let mut per_round: HashMap<usize, Vec<NodeReport>> = HashMap::new();
    /// One finished round waiting for its turn in the emit order.
    struct DoneRound {
        wire: u64,
        levels: usize,
        loss: f64,
        acc: f64,
    }
    let mut ready: BTreeMap<usize, DoneRound> = BTreeMap::new();
    let mut next_emit = 0usize;
    let mut done_rounds = 0usize;
    while done_rounds < rounds {
        let report = match report_rx.recv_timeout(MAILBOX_DEADLINE) {
            Ok(r) => r?,
            Err(RecvTimeoutError::Timeout) => {
                anyhow::bail!(
                    "timed out waiting for node reports \
                     ({done_rounds}/{rounds} rounds complete)"
                )
            }
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("all nodes exited early")
            }
        };
        let k = report.round;
        let entry = per_round.entry(k).or_default();
        entry.push(report);
        if entry.len() < n {
            continue;
        }
        let mut reports = per_round.remove(&k).unwrap();
        // deterministic float-summation order across transports
        reports.sort_by_key(|r| r.node);
        let wire: u64 = reports.iter().map(|r| r.wire_bits).sum();
        let levels =
            reports.iter().map(|r| r.levels).sum::<usize>() / n;
        let (loss, acc) = if reports
            .iter()
            .all(|r| r.params.is_some())
        {
            let mut avg = vec![0.0f32; param_count];
            for r in &reports {
                for (a, &p) in
                    avg.iter_mut().zip(r.params.as_ref().unwrap())
                {
                    *a += p;
                }
            }
            avg.iter_mut().for_each(|x| *x /= n as f32);
            let cap = dataset.train_n().min(2048);
            let idx: Vec<usize> = (0..cap).collect();
            let (x, y) = dataset.gather_batch(&idx);
            let (l, _) = eval_backend.evaluate(&avg, &x, &y)?;
            let tcap = dataset.test_n().min(2048);
            let acc = if tcap > 0 {
                let tx = &dataset.test_x[..tcap * dataset.feat_dim];
                let ty = &dataset.test_y[..tcap];
                let (_, c) = eval_backend.evaluate(&avg, tx, ty)?;
                c as f64 / tcap as f64
            } else {
                f64::NAN
            };
            (l, acc)
        } else {
            (f64::NAN, f64::NAN)
        };
        ready.insert(k, DoneRound { wire, levels, loss, acc });
        done_rounds += 1;
        while let Some(d) = ready.remove(&next_emit) {
            // per-directed-link average of measured wire bits
            cum_bits += d.wire / links;
            cum_wire_bytes += d.wire / 8;
            let rec = RoundRecord {
                round: next_emit + 1,
                loss: d.loss,
                accuracy: d.acc,
                bits_per_link: cum_bits,
                distortion: f64::NAN,
                levels: d.levels,
                lr: lr.at(next_emit),
                wall_secs: 0.0,
                virtual_secs: 0.0,
                straggler_wait_secs: 0.0,
                wire_bytes: cum_wire_bytes,
            };
            sink.record(&rec)?;
            summary.observe(&rec);
            next_emit += 1;
        }
    }
    summary.stamp_peak_rss();
    Ok(summary)
}

/// Build one fault-wrapped (when the link is non-ideal) endpoint.
fn wrap_link(
    endpoint: Box<dyn Delivery>,
    link: &LinkModel,
    seed: u64,
    node: usize,
) -> Box<dyn Delivery> {
    if *link == LinkModel::ideal() {
        return endpoint;
    }
    // separate rng stream so the node's quantization draws stay
    // byte-identical to a lossless run
    let rng = Rng::new(seed ^ (0xFA57 + node as u64));
    Box::new(FaultDelivery::new(endpoint, link.clone(), rng))
}

/// Run a full DFL training with one thread per node. Returns a
/// [`RunLog`] whose bits_per_link are MEASURED wire bits (cumulative,
/// averaged over directed links). The transport comes from the
/// config's `transport:` section (default: in-process channels).
pub(crate) fn run_threaded(
    cfg: &ExperimentConfig,
    topology: &Topology,
    dataset: Arc<Dataset>,
    factory: BackendFactory<'_>,
    opts: NetOptions,
) -> anyhow::Result<RunLog> {
    let mut sink = LogSink::new(&cfg.name);
    run_threaded_streamed(
        cfg, topology, dataset, factory, opts, &mut sink,
    )?;
    Ok(sink.0)
}

/// Streamed variant of [`run_threaded`]: the coordinator hands each
/// finished round record to `sink` instead of buffering a [`RunLog`]
/// — the threaded report plane no longer holds the whole run in
/// memory (the ROADMAP scale residual this closes). Byte-for-byte the
/// same records in the same order as the buffered wrapper.
pub(crate) fn run_threaded_streamed(
    cfg: &ExperimentConfig,
    topology: &Topology,
    dataset: Arc<Dataset>,
    factory: BackendFactory<'_>,
    opts: NetOptions,
    sink: &mut dyn RecordSink,
) -> anyhow::Result<RunSummary> {
    let n = cfg.nodes;
    // probe instance: shared init params + param_count (coordinator
    // reuses it for evaluation)
    let mut eval_backend = factory(n)?;
    let param_count = eval_backend.param_count();
    let mut seed_rng = Rng::new(cfg.seed);
    let init = eval_backend.init_params(&mut seed_rng.split(0xBEEF));
    let parts = crate::data::partition::partition_noniid(
        &dataset.train_y, n, cfg.noniid_fraction, cfg.seed);

    let transport = cfg.transport.clone().unwrap_or_default();
    let endpoints: Vec<Box<dyn Delivery>> = match transport.kind {
        TransportKind::Channel => channel_mesh(n)
            .into_iter()
            .map(|d| Box::new(d) as Box<dyn Delivery>)
            .collect(),
        TransportKind::Tcp => {
            let mut v: Vec<Box<dyn Delivery>> = Vec::with_capacity(n);
            for i in 0..n {
                v.push(Box::new(TcpDelivery::bind(
                    i,
                    transport.tcp.clone(),
                )?));
            }
            v
        }
    };

    let (report_tx, report_rx) = channel::<anyhow::Result<NodeReport>>();
    let result: anyhow::Result<RunSummary> = std::thread::scope(|scope| {
        for (i, endpoint) in endpoints.into_iter().enumerate() {
            let endpoint = wrap_link(endpoint, &opts.link, cfg.seed, i);
            let mut ctx = node_ctx(
                cfg, topology, &dataset, &init, parts[i].clone(), i,
            );
            ctx.eval_every = opts.eval_every;
            let report_tx = report_tx.clone();
            scope.spawn(move || {
                let mut mailbox = Mailbox::new(endpoint);
                let run = || -> anyhow::Result<()> {
                    let mut backend = factory(i)?;
                    let mut sink = ChannelSink(report_tx.clone());
                    run_node(
                        ctx, backend.as_mut(), &mut mailbox, &mut sink,
                    )
                };
                if let Err(e) = run() {
                    let _ = report_tx.send(Err(e));
                }
            });
        }
        drop(report_tx);

        let links = topology.directed_links().max(1) as u64;
        coordinate(
            n,
            cfg.rounds,
            &cfg.lr,
            links,
            param_count,
            &dataset,
            eval_backend.as_mut(),
            report_rx,
            sink,
        )
    });
    result
}

fn report_accept_loop(
    listener: TcpListener,
    tx: Sender<anyhow::Result<NodeReport>>,
    shutdown: Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let reader_tx = tx.clone();
                let _ = std::thread::Builder::new()
                    .name("lmdfl-report".to_string())
                    .spawn(move || report_read_loop(stream, reader_tx));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn report_read_loop(
    mut stream: TcpStream,
    tx: Sender<anyhow::Result<NodeReport>>,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(env)) if env.phase == REPORT_PHASE => {
                let msg = decode_report(&env.payload).map_err(|e| {
                    anyhow::anyhow!("report decode failed: {e}")
                });
                let failed = msg.is_err();
                if tx.send(msg).is_err() || failed {
                    return;
                }
            }
            Ok(Some(env)) => {
                let _ = tx.send(Err(anyhow::anyhow!(
                    "unexpected phase {} frame on the report plane",
                    env.phase
                )));
                return;
            }
            // clean EOF (rank finished) or a poisoned stream — the
            // coordinator's report deadline catches a silent death
            Ok(None) | Err(_) => return,
        }
    }
}

/// Run one node of a multi-process TCP training (`lmdfl node --rank
/// R`). Every rank builds the identical topology / dataset / init
/// (same seed), binds its gossip listener, and runs the same
/// [`run_node`] loop as the threaded runtime. Rank 0 additionally
/// hosts the report plane and the coordinator and returns
/// `Some(RunLog)`; other ranks stream their reports to rank 0 and
/// return `None`.
pub fn run_node_process(
    cfg: &ExperimentConfig,
    rank: usize,
) -> anyhow::Result<Option<RunLog>> {
    cfg.validate()?;
    let n = cfg.nodes;
    anyhow::ensure!(
        rank < n,
        "--rank {rank} out of range: config has {n} nodes"
    );
    let transport = cfg
        .transport
        .clone()
        .unwrap_or_else(TransportConfig::tcp_default);
    anyhow::ensure!(
        transport.kind == TransportKind::Tcp,
        "multi-process runs require transport kind 'tcp' \
         (got '{}')",
        transport.kind.name()
    );
    transport.validate(n)?;

    // identical derivations on every rank — this is what makes the
    // multi-process run reproduce the threaded trajectory exactly
    let topology = Topology::build(&cfg.topology, n, cfg.seed);
    let dataset = Arc::new(Dataset::build(&cfg.dataset, cfg.seed));
    let mut eval_backend = crate::dfl::build_backend(cfg, &dataset)?;
    let param_count = eval_backend.param_count();
    let mut seed_rng = Rng::new(cfg.seed);
    let init = eval_backend.init_params(&mut seed_rng.split(0xBEEF));
    let parts = crate::data::partition::partition_noniid(
        &dataset.train_y, n, cfg.noniid_fraction, cfg.seed);

    let link = cfg
        .network
        .as_ref()
        .map(|net| net.link.clone())
        .unwrap_or_else(LinkModel::ideal);
    let endpoint: Box<dyn Delivery> =
        Box::new(TcpDelivery::bind(rank, transport.tcp.clone())?);
    let endpoint = wrap_link(endpoint, &link, cfg.seed, rank);
    let mut mailbox = Mailbox::new(endpoint);
    let ctx = node_ctx(
        cfg, &topology, &dataset, &init, parts[rank].clone(), rank,
    );

    if rank != 0 {
        let mut backend = crate::dfl::build_backend(cfg, &dataset)?;
        let mut sink = TcpReportSink::connect(&transport.tcp, n)?;
        run_node(ctx, backend.as_mut(), &mut mailbox, &mut sink)?;
        return Ok(None);
    }

    // rank 0: host the report plane, run node 0 on a thread, and
    // coordinate on this one
    let report_port = transport.tcp.port_of(n)?;
    let addr = format!("{}:{report_port}", transport.tcp.host);
    let listener = TcpListener::bind(&addr).map_err(|e| {
        LmdflError::transport(
            None,
            format!("could not bind report plane {addr}: {e}"),
        )
    })?;
    listener
        .set_nonblocking(true)
        .map_err(LmdflError::from)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let (report_tx, report_rx) = channel::<anyhow::Result<NodeReport>>();
    let links = topology.directed_links().max(1) as u64;
    let mut sink = LogSink::new(&cfg.name);

    std::thread::scope(|scope| {
        {
            let flag = Arc::clone(&shutdown);
            let tx = report_tx.clone();
            scope.spawn(move || report_accept_loop(listener, tx, flag));
        }
        {
            let tx = report_tx.clone();
            let dataset = Arc::clone(&dataset);
            let mut mailbox = mailbox;
            scope.spawn(move || {
                // backends are not Send (PJRT), so node 0's is built
                // inside its own thread, like every other node's
                let run = || -> anyhow::Result<()> {
                    let mut backend =
                        crate::dfl::build_backend(cfg, &dataset)?;
                    let mut sink = ChannelSink(tx.clone());
                    run_node(
                        ctx, backend.as_mut(), &mut mailbox, &mut sink,
                    )
                };
                if let Err(e) = run() {
                    let _ = tx.send(Err(e));
                }
            });
        }
        drop(report_tx);
        let out = coordinate(
            n,
            cfg.rounds,
            &cfg.lr,
            links,
            param_count,
            &dataset,
            eval_backend.as_mut(),
            report_rx,
            &mut sink,
        );
        shutdown.store(true, Ordering::Relaxed);
        out
    })?;
    Ok(Some(sink.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, LrSchedule, TopologyKind};
    use crate::dfl::backend::RustMlpBackend;

    fn cfg(quant: QuantizerKind) -> ExperimentConfig {
        ExperimentConfig {
            name: "net-test".into(),
            seed: 11,
            nodes: 4,
            tau: 2,
            rounds: 8,
            batch_size: 16,
            lr: LrSchedule::fixed(0.1),
            topology: TopologyKind::Ring,
            quantizer: quant,
            dataset: DatasetKind::Blobs {
                train: 200,
                test: 60,
                dim: 8,
                classes: 3,
            },
            backend: crate::config::BackendKind::RustMlp {
                hidden: vec![16],
            },
            noniid_fraction: 0.5,
            link_bps: 100e6,
            eval_every: 1,
            parallelism: crate::config::Parallelism::Auto,
            network: None,
            mode: Default::default(),
            encoding: Default::default(),
            agossip: None,
            transport: None,
            observe: None,
            attack: None,
            mixing: Default::default(),
        }
    }

    fn run(c: &ExperimentConfig, opts: NetOptions) -> RunLog {
        let topo = Topology::build(&c.topology, c.nodes, c.seed);
        let data = Arc::new(Dataset::build(&c.dataset, c.seed));
        let feat = data.feat_dim;
        let classes = data.classes;
        let factory = move |_i: usize| {
            Ok(Box::new(RustMlpBackend::new(feat, &[16], classes))
                as Box<dyn LocalUpdate>)
        };
        run_threaded(c, &topo, Arc::clone(&data), &factory, opts).unwrap()
    }

    #[test]
    fn threaded_training_converges() {
        let c = cfg(QuantizerKind::LloydMax { s: 16, iters: 8 });
        let log = run(&c, NetOptions::default());
        assert_eq!(log.records.len(), 8);
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn wire_bits_measured_and_monotone() {
        let c = cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&c, NetOptions::default());
        let mut prev = 0;
        let mut prev_wire = 0;
        for r in &log.records {
            assert!(r.bits_per_link > prev);
            prev = r.bits_per_link;
            assert!(r.wire_bytes > prev_wire);
            prev_wire = r.wire_bytes;
        }
        // every per-copy payload is a whole wire frame: the per-round
        // total is divisible by the per-message length (fixed s ⇒ one
        // size), and a ring ships 2 messages × 2 links × n per round
        let d = {
            let m = crate::models::MlpModel::new(&[8, 16, 3]);
            m.param_count()
        };
        let msg = crate::quant::wire::encoded_len(d, 16, true) as u64;
        assert_eq!(
            log.records.first().unwrap().wire_bytes,
            msg * 2 * 2 * c.nodes as u64
        );
    }

    #[test]
    fn survives_dropped_messages() {
        let c = cfg(QuantizerKind::LloydMax { s: 16, iters: 6 });
        let log = run(&c, NetOptions::lossy(0.25));
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        assert!(last.is_finite());
        assert!(last < first * 1.5, "diverged: {first} -> {last}");
    }

    #[test]
    fn matches_matrix_engine_bits_order() {
        // threaded wire bits ≈ paper C_s bits + small header/table
        // overhead
        let c = cfg(QuantizerKind::Qsgd { s: 16 });
        let log = run(&c, NetOptions::default());
        let d = {
            let m = crate::models::MlpModel::new(&[8, 16, 3]);
            m.param_count()
        };
        let per_round_paper =
            2 * crate::quant::bits::c_s(d, 16); // q1 + q2
        let total_paper = per_round_paper * c.rounds as u64;
        let measured = log.total_bits();
        let ratio = measured as f64 / total_paper as f64;
        assert!(
            (0.9..1.2).contains(&ratio),
            "wire/paper ratio {ratio} \
             (measured {measured}, paper {total_paper})"
        );
    }

    #[test]
    fn trimmed_zero_matches_metropolis_bitwise_over_threads() {
        // trimmed(0) must route through the historical axpy path, so a
        // threaded run is bit-identical to plain Metropolis mixing
        let c = cfg(QuantizerKind::LloydMax { s: 16, iters: 6 });
        let mut t0 = c.clone();
        t0.mixing = crate::config::MixingKind::Trimmed { f: 0 };
        let a = run(&c, NetOptions::default());
        let b = run(&t0, NetOptions::default());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.wire_bytes, rb.wire_bytes);
        }
    }

    #[test]
    fn attacked_threaded_run_stays_finite_under_robust_mixing() {
        // a sign-flipping minority on the socket-free transport: the
        // trimmed rule keeps every honest trajectory finite
        let mut c = cfg(QuantizerKind::LloydMax { s: 16, iters: 6 });
        c.attack = Some(crate::config::AttackConfig {
            kind: AttackKind::SignFlip,
            f: 1,
        });
        c.mixing = crate::config::MixingKind::Trimmed { f: 1 };
        let log = run(&c, NetOptions::default());
        assert_eq!(log.records.len(), 8);
        for r in &log.records {
            assert!(r.loss.is_finite(), "round {} diverged", r.round);
        }
        // same adversary, same seed: the run replays bit-identically
        let again = run(&c, NetOptions::default());
        for (ra, rb) in log.records.iter().zip(&again.records) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        }
    }

    #[test]
    fn report_codec_roundtrips_and_rejects_garbage() {
        let r = NodeReport {
            node: 3,
            round: 17,
            wire_bits: 99_000,
            paper_bits: 88_000,
            levels: 16,
            local_loss: 0.625,
            params: Some(vec![1.0, -2.5, 0.0]),
        };
        let bytes = encode_report(&r);
        let back = decode_report(&bytes).unwrap();
        assert_eq!(back.node, 3);
        assert_eq!(back.round, 17);
        assert_eq!(back.wire_bits, 99_000);
        assert_eq!(back.paper_bits, 88_000);
        assert_eq!(back.levels, 16);
        assert_eq!(back.local_loss, 0.625);
        assert_eq!(back.params.as_deref(), Some(&[1.0, -2.5, 0.0][..]));

        let none = NodeReport { params: None, ..r };
        let nb = encode_report(&none);
        assert_eq!(nb.len(), REPORT_HEAD);
        assert!(decode_report(&nb).unwrap().params.is_none());

        // truncation, trailing garbage, and a bad flag are all typed
        assert!(matches!(
            decode_report(&bytes[..10]),
            Err(CodecError::Truncated { .. })
        ));
        assert!(matches!(
            decode_report(&bytes[..bytes.len() - 1]),
            Err(CodecError::Truncated { .. })
        ));
        let mut trailing = nb.clone();
        trailing.push(0xFF);
        assert!(matches!(
            decode_report(&trailing),
            Err(CodecError::Malformed(_))
        ));
        let mut bad_flag = nb;
        bad_flag[REPORT_HEAD - 1] = 7;
        assert!(matches!(
            decode_report(&bad_flag),
            Err(CodecError::Malformed(_))
        ));
    }
}
