//! Top-k sparsification [12] extension baseline (paper §I): keep the k
//! largest-magnitude coordinates at full precision, drop the rest.
//!
//! Messages ship through the canonical sparse wire body of
//! [`crate::quant::codec`]: a level table holding the k surviving
//! normalized magnitudes plus one (position, sign, index) entry per
//! survivor. Dropped coordinates are emitted as canonical index-0 /
//! positive-sign slots, which is exactly what makes the message
//! sparse-eligible — the encoded bytes are the measured cost, and
//! [`TopKQuantizer::sparse_bits`] reproduces that size analytically.

use super::{QuantizedVector, Quantizer};
use crate::util::rng::Rng;
use crate::util::stats::l2_norm;

#[derive(Clone, Debug)]
pub struct TopKQuantizer {
    /// fraction of coordinates kept, in (0, 1]
    pub keep: f64,
}

impl TopKQuantizer {
    pub fn new(keep: f64) -> Self {
        assert!(keep > 0.0 && keep <= 1.0);
        TopKQuantizer { keep }
    }

    /// Sparse wire-body bit cost for a d-dimensional message keeping k
    /// coordinates (the codec's exact sparse accounting: shipped table
    /// of k+1 levels plus one position/sign/index entry per survivor).
    pub fn sparse_bits(&self, d: usize) -> u64 {
        let k = ((d as f64 * self.keep).ceil() as usize).max(1);
        crate::quant::codec::sparse_encoded_bits(d, k + 1, false, k)
    }
}

impl Quantizer for TopKQuantizer {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn levels(&self) -> usize {
        // not level-based; report 2 so C_s accounting stays defined
        2
    }

    fn quantize(&mut self, v: &[f32], _rng: &mut Rng) -> QuantizedVector {
        let d = v.len();
        let k = ((d as f64 * self.keep).ceil() as usize).clamp(1, d.max(1));
        let norm = l2_norm(v) as f32;
        // threshold = k-th largest |v_i| via select_nth
        let mut mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        let thresh = if k < d {
            let idx = d - k;
            mags.select_nth_unstable_by(idx, |a, b| {
                a.partial_cmp(b).unwrap()
            });
            mags[idx]
        } else {
            0.0
        };
        // level table: 0 plus each kept magnitude (normalized); index i
        // selects its own slot. Ties at the threshold may keep a few
        // extra coordinates — harmless for the baseline. Dropped
        // coordinates get the canonical index-0/positive-sign slot so
        // the codec's sparse body applies.
        let safe = if norm > 0.0 { norm } else { 1.0 };
        let mut levels = vec![0.0f32];
        let mut indices = Vec::with_capacity(d);
        let mut negative = Vec::with_capacity(d);
        for &x in v {
            if x.abs() >= thresh && x != 0.0 {
                negative.push(x < 0.0);
                levels.push(x.abs() / safe);
                indices.push((levels.len() - 1) as u32);
            } else {
                negative.push(false);
                indices.push(0);
            }
        }
        QuantizedVector { norm, negative, indices, levels, implied_table: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes_exactly() {
        let mut q = TopKQuantizer::new(0.25);
        let mut rng = Rng::new(0);
        let v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 0.01, 4.0, 0.3];
        let dq = q.quantize(&v, &mut rng).dequantize();
        // top-2 of 8 = 25%: -5.0 and 4.0 survive exactly
        assert!((dq[1] + 5.0).abs() < 1e-4);
        assert!((dq[6] - 4.0).abs() < 1e-4);
        assert_eq!(dq[0], 0.0);
        assert_eq!(dq[5], 0.0);
    }

    #[test]
    fn keep_all_is_lossless() {
        let mut q = TopKQuantizer::new(1.0);
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) / 7.0).collect();
        let dq = q.quantize(&v, &mut rng).dequantize();
        for (a, b) in dq.iter().zip(&v) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_bits_smaller_than_dense_for_small_keep() {
        let q = TopKQuantizer::new(0.01);
        assert!(q.sparse_bits(100_000)
            < crate::quant::bits::full_precision_bits(100_000) / 50);
    }

    #[test]
    fn engine_trains_with_topk() {
        use crate::config::*;
        use crate::data::Dataset;
        use crate::dfl::backend::{LocalUpdate, RustMlpBackend};
        use crate::dfl::{DflEngine, EngineOptions};
        use crate::topology::Topology;
        let cfg = ExperimentConfig {
            nodes: 3,
            rounds: 10,
            tau: 2,
            dataset: DatasetKind::Blobs {
                train: 150, test: 50, dim: 8, classes: 3,
            },
            lr: LrSchedule::fixed(0.1),
            ..Default::default()
        };
        let topo = Topology::build(&cfg.topology, cfg.nodes, 0);
        let data = Dataset::build(&cfg.dataset, 0);
        let backends: Vec<Box<dyn LocalUpdate>> = (0..3)
            .map(|_| {
                Box::new(RustMlpBackend::new(8, &[16], 3))
                    as Box<dyn LocalUpdate>
            })
            .collect();
        let mut engine = DflEngine::new(
            cfg, topo, data, backends, EngineOptions::default()).unwrap();
        engine.set_all_quantizers(|| Box::new(TopKQuantizer::new(0.3)));
        let log = engine.run().unwrap();
        assert!(
            log.records.last().unwrap().loss
                < log.records.first().unwrap().loss
        );
    }
}
