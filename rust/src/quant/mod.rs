//! Quantizers (paper §III): the LM-DFL Lloyd-Max vector quantizer, the
//! QSGD / natural-compression / ALQ baselines, and full precision.
//!
//! All quantizers share the paper's vector decomposition (Eq. 10–11):
//! a vector v is sent as (‖v‖, sign(v_i), q(r_i)) with r_i = |v_i|/‖v‖.
//! [`QuantizedVector`] is that wire message; [`codec`] packs it into an
//! actual bitstream (what the threaded runtime ships over channels), and
//! [`bits`] implements the paper's C_s accounting (Eq. 12).

pub mod adaptive;
pub mod alq;
pub mod bits;
pub mod codec;
pub mod distortion;
pub mod full;
pub mod kernels;
pub mod lloyd_max;
pub mod natural;
pub mod qsgd;
pub mod terngrad;
pub mod topk;
pub mod wire;

pub use adaptive::AdaptiveLevels;
pub use alq::AlqQuantizer;
pub use full::FullPrecision;
pub use lloyd_max::LloydMaxQuantizer;
pub use natural::NaturalQuantizer;
pub use qsgd::QsgdQuantizer;
pub use terngrad::TernGradQuantizer;
pub use topk::TopKQuantizer;

use crate::config::QuantizerKind;
use crate::util::rng::Rng;

/// The quantized form of a vector — everything a receiver needs.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedVector {
    /// ‖v‖₂, sent at full precision (32 bits)
    pub norm: f32,
    /// per-element sign bits (true = negative)
    pub negative: Vec<bool>,
    /// per-element level index into `levels`
    pub indices: Vec<u32>,
    /// normalized level table in [0, 1]; `levels[indices[i]]` reconstructs
    /// r_i. Adaptive quantizers ship this table; fixed-grid quantizers
    /// (QSGD/natural) regenerate it from `s` on the receive side, so the
    /// codec does not charge for it.
    pub levels: Vec<f32>,
    /// whether the level table is implied by (kind, s) — affects wire size
    pub implied_table: bool,
}

impl Default for QuantizedVector {
    fn default() -> Self {
        Self::empty()
    }
}

impl QuantizedVector {
    /// An empty message buffer, ready to be filled by
    /// [`Quantizer::quantize_into`] (capacity grows on first use and is
    /// then reused).
    pub fn empty() -> Self {
        QuantizedVector {
            norm: 0.0,
            negative: Vec::new(),
            indices: Vec::new(),
            levels: Vec::new(),
            implied_table: false,
        }
    }

    pub fn dim(&self) -> usize {
        self.indices.len()
    }

    pub fn s(&self) -> usize {
        self.levels.len()
    }

    /// Reconstruct the (lossy) vector: ‖v‖ · sign · ℓ_idx. This is the
    /// scalar reference path; the hot engines use
    /// [`dequantize_into`](Self::dequantize_into) /
    /// [`dequantize_accumulate_into`](Self::dequantize_accumulate_into),
    /// which are bit-identical batch kernels (see [`kernels`]).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.indices.len());
        for (i, &idx) in self.indices.iter().enumerate() {
            let mag = self.norm * self.levels[idx as usize];
            out.push(if self.negative[i] { -mag } else { mag });
        }
        out
    }

    /// Dequantize into an existing buffer (hot path; no allocation,
    /// vectorized batch kernel).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        kernels::dequantize_into(
            self.norm,
            &self.negative,
            &self.indices,
            &self.levels,
            out,
        );
    }

    /// Fused dequantize-accumulate: `acc_i += ±‖v‖·ℓ_{idx_i}` — the
    /// gossip estimate recursion (x̂ += Q(δ)) in one pass, bit-identical
    /// to [`dequantize_into`](Self::dequantize_into) followed by an
    /// element-wise add.
    pub fn dequantize_accumulate_into(&self, acc: &mut [f32]) {
        kernels::dequantize_accumulate(
            self.norm,
            &self.negative,
            &self.indices,
            &self.levels,
            acc,
        );
    }

    /// Paper bit accounting C_s = d⌈log₂ s⌉ + d + 32 (Eq. 12).
    pub fn paper_bits(&self) -> u64 {
        bits::c_s(self.dim(), self.s())
    }

    /// Exact bytes of the versioned transport message ([`wire`]) that
    /// carries this vector — the engines' byte-accounting truth. (For
    /// the bare codec body size use [`codec::encoded_bits`] directly.)
    pub fn wire_message_bytes(&self) -> u64 {
        wire::message_len(self) as u64
    }
}

/// Common interface for all quantizers. `quantize` may adapt internal state
/// (Lloyd-Max levels, ALQ coordinate descent) based on the observed data —
/// that is precisely the paper's "adaptive sequence of quantization levels".
///
/// `Send + Sync` is required so per-node quantizers can be partitioned
/// across the round executor's worker pool (every implementation is plain
/// owned data; `&self` is only ever shared for reads).
pub trait Quantizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// Quantize `v`. Stochastic quantizers draw from `rng` (unbiasedness);
    /// deterministic quantizers ignore it.
    fn quantize(&mut self, v: &[f32], rng: &mut Rng) -> QuantizedVector;

    /// Quantize `v` into an existing message buffer (hot path): must
    /// produce results bit-identical to [`quantize`](Quantizer::quantize),
    /// including the `rng` draw sequence. The default implementation
    /// delegates to the allocating path; hot quantizers (Lloyd-Max, QSGD,
    /// natural, full) override it to reuse `out`'s vectors.
    fn quantize_into(
        &mut self,
        v: &[f32],
        rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        *out = self.quantize(v, rng);
    }

    /// Current number of quantization levels s.
    fn levels(&self) -> usize;

    /// Change s (doubly-adaptive controller). Default: unsupported no-op.
    fn set_levels(&mut self, _s: usize) {}
}

/// Instantiate a quantizer from config.
pub fn build_quantizer(kind: &QuantizerKind) -> Box<dyn Quantizer> {
    match kind {
        QuantizerKind::Full => Box::new(FullPrecision::new()),
        QuantizerKind::Qsgd { s } => Box::new(QsgdQuantizer::new(*s)),
        QuantizerKind::Natural { s } => Box::new(NaturalQuantizer::new(*s)),
        QuantizerKind::Alq { s } => Box::new(AlqQuantizer::new(*s)),
        QuantizerKind::LloydMax { s, iters } => {
            Box::new(LloydMaxQuantizer::new(*s, *iters))
        }
        // The doubly-adaptive quantizer starts from s1; the DFL engine's
        // AdaptiveLevels controller drives set_levels() per round (Eq. 37).
        QuantizerKind::DoublyAdaptive { s1, iters, .. } => {
            Box::new(LloydMaxQuantizer::new(*s1, *iters))
        }
        QuantizerKind::TernGrad => Box::new(TernGradQuantizer::new()),
        QuantizerKind::TopK { keep } => Box::new(TopKQuantizer::new(*keep)),
    }
}

/// Quantize `diff` and damp the message by the optimal estimate-tracking
/// step γ* = 1/(1+ω̂), where ω̂ = ‖Q(diff)−diff‖²/‖diff‖² is the measured
/// relative distortion of THIS message.
///
/// Applying x̂ += γ·Q(x−x̂) contracts E‖x−x̂‖² by ω̂/(1+ω̂) < 1 for ANY ω̂,
/// which keeps coarse unbiased quantizers (e.g. 2-bit QSGD, whose
/// Table-I bound √d/s ≫ 1 at model scale) stable inside the differential
/// gossip loop; for low-distortion quantizers (LM) γ ≈ 1 and this is a
/// no-op. γ is folded into the shipped norm, so receivers need no extra
/// state and the wire format is unchanged. Returns (message, dequantized
/// damped delta, ω̂).
pub fn quantize_damped(
    q: &mut dyn Quantizer,
    diff: &[f32],
    rng: &mut Rng,
    dq: &mut [f32],
) -> (QuantizedVector, f64) {
    let mut msg = QuantizedVector::empty();
    let omega = quantize_damped_into(q, diff, rng, dq, &mut msg);
    (msg, omega)
}

/// Allocation-free [`quantize_damped`]: the message is built in `msg`
/// (reusing its buffers) and the damped dequantized delta in `dq`. Returns
/// the measured relative distortion ω̂. Both engines call this on the
/// per-round hot path.
pub fn quantize_damped_into(
    q: &mut dyn Quantizer,
    diff: &[f32],
    rng: &mut Rng,
    dq: &mut [f32],
    msg: &mut QuantizedVector,
) -> f64 {
    q.quantize_into(diff, rng, msg);
    msg.dequantize_into(dq);
    let omega = crate::quant::distortion::normalized_distortion(diff, dq);
    let gamma = (1.0 / (1.0 + omega)) as f32;
    if gamma < 0.999 {
        msg.norm *= gamma;
        // re-derive the damped delta from the damped MESSAGE (not by
        // scaling dq in place): f32 products don't reassociate, and dq
        // must be bit-identical to what a receiver reconstructs from
        // the wire bytes — the matrix engines apply dq while the
        // bitstream/threaded paths apply the decoded message, and the
        // encoding parity contract says those trajectories match
        msg.dequantize_into(dq);
    }
    omega
}

/// Split v into (norm, signs, normalized magnitudes r) — shared by every
/// quantizer implementation (Eq. 10-11).
pub(crate) fn decompose(v: &[f32]) -> (f32, Vec<bool>, Vec<f32>) {
    let mut negative = Vec::new();
    let norm = norm_and_signs_into(v, &mut negative);
    let r: Vec<f32> = if norm > 0.0 {
        v.iter().map(|&x| x.abs() / norm).collect()
    } else {
        vec![0.0; v.len()]
    };
    (norm, negative, r)
}

/// Allocation-free prologue of [`decompose`] shared by the
/// `quantize_into` overrides: computes ‖v‖ and refills the sign buffer —
/// bit-for-bit the first two components of `decompose`, so the two paths
/// cannot drift. Per-element `r_i` is `normalized_magnitude(x, norm)`.
pub(crate) fn norm_and_signs_into(
    v: &[f32],
    negative: &mut Vec<bool>,
) -> f32 {
    let norm = crate::util::stats::l2_norm(v) as f32;
    negative.clear();
    negative.extend(v.iter().map(|&x| x < 0.0));
    norm
}

/// `r_i = |x|/‖v‖` (0 when the norm is zero) — the per-element third
/// component of [`decompose`], used by the streaming `quantize_into`
/// overrides that never materialize the full r vector.
#[inline]
pub(crate) fn normalized_magnitude(x: f32, norm: f32) -> f32 {
    if norm > 0.0 {
        x.abs() / norm
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn decompose_normalizes() {
        let v = [3.0f32, -4.0];
        let (norm, neg, r) = decompose(&v);
        assert!((norm - 5.0).abs() < 1e-6);
        assert_eq!(neg, vec![false, true]);
        assert!((r[0] - 0.6).abs() < 1e-6);
        assert!((r[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn decompose_zero_vector() {
        let v = [0.0f32; 4];
        let (norm, _, r) = decompose(&v);
        assert_eq!(norm, 0.0);
        assert!(r.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dequantize_roundtrip_identity_levels() {
        let qv = QuantizedVector {
            norm: 2.0,
            negative: vec![false, true, false],
            indices: vec![0, 1, 2],
            levels: vec![0.0, 0.5, 1.0],
            implied_table: false,
        };
        assert_eq!(qv.dequantize(), vec![0.0, -1.0, 2.0]);
        let mut buf = vec![0.0f32; 3];
        qv.dequantize_into(&mut buf);
        assert_eq!(buf, vec![0.0, -1.0, 2.0]);
        // fused accumulate adds the same values on top
        qv.dequantize_accumulate_into(&mut buf);
        assert_eq!(buf, vec![0.0, -2.0, 4.0]);
    }

    #[test]
    fn all_quantizers_buildable_and_named() {
        let kinds = [
            QuantizerKind::Full,
            QuantizerKind::Qsgd { s: 16 },
            QuantizerKind::Natural { s: 16 },
            QuantizerKind::Alq { s: 16 },
            QuantizerKind::LloydMax { s: 16, iters: 4 },
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 4, s_max: 64 },
            QuantizerKind::TernGrad,
            QuantizerKind::TopK { keep: 0.1 },
        ];
        for k in &kinds {
            let q = build_quantizer(k);
            assert!(!q.name().is_empty());
            assert!(q.levels() >= 2 || matches!(k, QuantizerKind::Full));
        }
    }

    #[test]
    fn prop_dequantize_magnitude_bounded_by_norm() {
        check("dequantized magnitudes <= norm", 50, |g| {
            let v = g.vec_normal(1..200, 1.0);
            let mut q = QsgdQuantizer::new(8);
            let mut rng = crate::util::rng::Rng::new(g.seed);
            let qv = q.quantize(&v, &mut rng);
            for x in qv.dequantize() {
                assert!(x.abs() <= qv.norm * 1.0001);
            }
        });
    }
}
