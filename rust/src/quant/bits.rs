//! Bit accounting (paper Eq. 12): C_s = d⌈log₂ s⌉ + d + 32.
//!
//! Transmitting one quantized vector costs: ⌈log₂ s⌉ bits per element for
//! the level index, 1 bit per element for the sign, and 32 bits for the
//! full-precision ‖v‖. The paper measures "communicated bits" as the
//! cumulative C_s over a single directed link.

/// ⌈log₂ s⌉ for s >= 1.
pub fn ceil_log2(s: usize) -> u32 {
    assert!(s >= 1);
    if s == 1 {
        0
    } else {
        (usize::BITS - (s - 1).leading_zeros()) as u32
    }
}

/// C_s (Eq. 12) for a d-dimensional vector with s levels.
pub fn c_s(d: usize, s: usize) -> u64 {
    d as u64 * ceil_log2(s) as u64 + d as u64 + 32
}

/// Bits for a full-precision (unquantized) exchange of d f32 elements.
pub fn full_precision_bits(d: usize) -> u64 {
    d as u64 * 32 + 32
}

/// Bytes needed to hold a `bits`-long stream (padded to a whole byte) —
/// the codec's exact preallocation size for one encoded message.
pub fn stream_bytes(bits: u64) -> usize {
    ((bits + 7) / 8) as usize
}

/// Bits-per-element for the quantized message (paper Fig. 8c/f series is
/// ⌈log₂ s_k⌉).
pub fn bits_per_element(s: usize) -> u32 {
    ceil_log2(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(16), 4);
        assert_eq!(ceil_log2(17), 5);
        assert_eq!(ceil_log2(256), 8);
        assert_eq!(ceil_log2(16000), 14);
    }

    #[test]
    fn c_s_matches_paper_formula() {
        // d=100, s=16: 100*4 + 100 + 32 = 532
        assert_eq!(c_s(100, 16), 532);
        // s=4 => 2 bits/elem (paper's "2 bits quantization")
        assert_eq!(c_s(10, 4), 10 * 2 + 10 + 32);
        // s=256 => 8 bits/elem
        assert_eq!(c_s(10, 256), 10 * 8 + 10 + 32);
    }

    #[test]
    fn quantized_cheaper_than_full_precision() {
        let d = 10_000;
        for s in [2usize, 4, 16, 256, 1024] {
            assert!(c_s(d, s) < full_precision_bits(d));
        }
    }

    #[test]
    fn monotone_in_s_and_d() {
        assert!(c_s(100, 4) <= c_s(100, 16));
        assert!(c_s(100, 16) <= c_s(1000, 16));
    }

    #[test]
    fn stream_bytes_pads_to_whole_bytes() {
        assert_eq!(stream_bytes(0), 0);
        assert_eq!(stream_bytes(1), 1);
        assert_eq!(stream_bytes(8), 1);
        assert_eq!(stream_bytes(9), 2);
        assert_eq!(stream_bytes(64), 8);
    }
}
