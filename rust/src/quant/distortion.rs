//! Quantization distortion: empirical measurement + Table I analytical
//! bounds. The Table I bench (`table1_distortion`) cross-checks every
//! quantizer's measured normalized distortion against its bound.

use crate::util::stats::{l2_norm, sq_dist};

/// Measured normalized distortion ‖Q(v) − v‖² / ‖v‖² (Eq. 13-14).
pub fn normalized_distortion(v: &[f32], dequantized: &[f32]) -> f64 {
    let nsq = l2_norm(v).powi(2);
    if nsq == 0.0 {
        return 0.0;
    }
    sq_dist(dequantized, v) / nsq
}

/// Table I bound for QSGD: min(d/s², √d/s).
pub fn qsgd_bound(d: usize, s: usize) -> f64 {
    let d = d as f64;
    let s = s as f64;
    (d / (s * s)).min(d.sqrt() / s)
}

/// Table I bound for natural compression: 1/8 + min(√d/2^{s−1}, d/2^{2(s−1)}).
pub fn natural_bound(d: usize, s: usize) -> f64 {
    let d = d as f64;
    let p = 2f64.powi(s as i32 - 1);
    0.125 + (d.sqrt() / p).min(d / (p * p))
}

/// Table I bound for LM-DFL (Theorem 2): d/(12 s²).
pub fn lm_bound(d: usize, s: usize) -> f64 {
    d as f64 / (12.0 * (s * s) as f64)
}

/// Worst adjacent-level ratio ρ = max_j ℓ_{j+1}/ℓ_j over strictly positive
/// levels — the quantity both the ALQ bound and Theorem 6 are written in.
pub fn max_level_ratio(levels: &[f32]) -> f64 {
    let mut rho: f64 = 1.0;
    for w in levels.windows(2) {
        if w[0] > 0.0 && w[1] > w[0] {
            rho = rho.max(w[1] as f64 / w[0] as f64);
        }
    }
    rho
}

/// Table I bound for ALQ: (ρ − 1)² / (4ρ).
pub fn alq_bound(levels: &[f32]) -> f64 {
    let rho = max_level_ratio(levels);
    (rho - 1.0).powi(2) / (4.0 * rho)
}

/// Theorem 6 alternative LM-DFL expression: ((ρ − 1)/(ρ + 1))².
pub fn lm_ratio_bound(levels: &[f32]) -> f64 {
    let rho = max_level_ratio(levels);
    ((rho - 1.0) / (rho + 1.0)).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_vector_distortion_zero() {
        assert_eq!(normalized_distortion(&[0.0; 4], &[0.0; 4]), 0.0);
    }

    #[test]
    fn identical_vectors_zero() {
        let v = [1.0f32, -2.0, 3.0];
        assert_eq!(normalized_distortion(&v, &v), 0.0);
    }

    #[test]
    fn known_distortion() {
        let v = [1.0f32, 0.0];
        let q = [0.0f32, 0.0];
        assert!((normalized_distortion(&v, &q) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lm_bound_beats_qsgd_bound() {
        // "for the same degree of distortion LM-DFL uses only 0.29 s levels"
        for (d, s) in [(1000, 16), (10_000, 64), (100_000, 256)] {
            assert!(lm_bound(d, s) < qsgd_bound(d, s));
            // the 12x factor: d/12s^2 vs d/s^2
            let ratio = qsgd_bound(d, s) / lm_bound(d, s);
            if (d as f64) / ((s * s) as f64) < (d as f64).sqrt() / s as f64 {
                assert!((ratio - 12.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn natural_bound_floor_at_one_eighth() {
        // fine-grained s: natural compression stalls at 1/8, LM keeps
        // improving (paper's comparison after Table I)
        let d = 10_000;
        assert!(natural_bound(d, 30) >= 0.125);
        assert!(lm_bound(d, 1000) < 0.125);
    }

    #[test]
    fn alq_vs_lm_ratio_bound() {
        // Theorem 6 discussion: ((ρ-1)/(ρ+1))^2 <= (ρ-1)^2/(4ρ) because
        // (ρ+1)^2 >= 4ρ
        for levels in [
            vec![0.0f32, 0.1, 0.3, 1.0],
            vec![0.0f32, 0.01, 0.5, 1.0],
            vec![0.0f32, 0.25, 0.5, 0.75, 1.0],
        ] {
            assert!(lm_ratio_bound(&levels) <= alq_bound(&levels) + 1e-12);
        }
    }

    #[test]
    fn max_level_ratio_ignores_zero() {
        let levels = [0.0f32, 0.1, 0.4, 1.0];
        assert!((max_level_ratio(&levels) - 4.0).abs() < 1e-6);
    }
}
