//! QSGD quantizer [14] (paper §III-B1): uniform levels, stochastic
//! (unbiased) rounding.
//!
//! Levels are the uniform grid ℓ_j = j/(s-1), j = 0..s-1. An element r is
//! rounded to one of its two bracketing grid points with probabilities
//! proportional to proximity, so E[q(r)] = r. Distortion bound (Table I):
//! min(d/s², √d/s)·‖v‖².

use super::{decompose, QuantizedVector, Quantizer};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QsgdQuantizer {
    s: usize,
    table: Vec<f32>,
    /// pre-drawn per-element uniforms (hot-path scratch): drawing them
    /// up front keeps the rng sequence identical to the per-element
    /// loop while letting the assignment kernel vectorize
    u_scratch: Vec<f32>,
}

impl QsgdQuantizer {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2, "QSGD needs at least 2 levels");
        QsgdQuantizer {
            s,
            table: Self::level_table(s),
            u_scratch: Vec::new(),
        }
    }

    /// The implied uniform grid (receivers regenerate it from s).
    pub fn level_table(s: usize) -> Vec<f32> {
        (0..s).map(|j| j as f32 / (s - 1) as f32).collect()
    }
}

impl Quantizer for QsgdQuantizer {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn levels(&self) -> usize {
        self.s
    }

    fn set_levels(&mut self, s: usize) {
        assert!(s >= 2);
        self.s = s;
        self.table = Self::level_table(s);
    }

    fn quantize(&mut self, v: &[f32], rng: &mut Rng) -> QuantizedVector {
        let (norm, negative, r) = decompose(v);
        let scale = (self.s - 1) as f32;
        let indices: Vec<u32> = r
            .iter()
            .map(|&ri| {
                let x = (ri * scale).clamp(0.0, scale);
                let lo = x.floor();
                let frac = x - lo;
                let up = (rng.uniform_f32() < frac) as u32;
                (lo as u32 + up).min(self.s as u32 - 1)
            })
            .collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels: self.table.clone(),
            implied_table: true,
        }
    }

    /// Allocation-free batch path: same per-element math and the same
    /// `rng` draw sequence as [`quantize`] (one uniform per element,
    /// including zero-norm inputs) — the uniforms are pre-drawn into a
    /// scratch buffer so [`super::kernels::qsgd_assign_slice`] runs
    /// branchless and vectorized. [`quantize`] stays the per-element
    /// reference this path is property-tested against.
    fn quantize_into(
        &mut self,
        v: &[f32],
        rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        let norm = super::norm_and_signs_into(v, &mut out.negative);
        out.norm = norm;
        self.u_scratch.resize(v.len(), 0.0);
        rng.fill_uniform_f32(&mut self.u_scratch);
        super::kernels::qsgd_assign_slice(
            v,
            norm,
            self.s as u32,
            &self.u_scratch,
            &mut out.indices,
        );
        out.levels.clear();
        out.levels.extend_from_slice(&self.table);
        out.implied_table = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::stats::{l2_norm, sq_dist};

    #[test]
    fn level_table_endpoints() {
        let t = QsgdQuantizer::level_table(5);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[4], 1.0);
        assert!((t[1] - 0.25).abs() < 1e-7);
    }

    #[test]
    fn grid_points_are_fixed_points() {
        // values exactly on the grid are never moved
        let mut q = QsgdQuantizer::new(5);
        let mut rng = Rng::new(0);
        let v = vec![0.0f32, 0.25, 0.5, 0.75, 1.0];
        // norm != 1, so normalize a vector whose r are grid points:
        // use unit basis vector scaled — simpler: v with one element
        let one = vec![2.5f32];
        let qv = q.quantize(&one, &mut rng);
        assert_eq!(qv.dequantize(), vec![2.5f32]);
        let _ = v;
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut q = QsgdQuantizer::new(4);
        let mut rng = Rng::new(42);
        let v = vec![0.3f32, -0.9, 0.1, 0.7];
        let n = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..n {
            let dq = q.quantize(&v, &mut rng).dequantize();
            for (a, x) in acc.iter_mut().zip(&dq) {
                *a += *x as f64;
            }
        }
        for (a, &want) in acc.iter().zip(&v) {
            let mean = a / n as f64;
            assert!(
                (mean - want as f64).abs() < 0.01,
                "mean {mean} vs {want}"
            );
        }
    }

    #[test]
    fn distortion_within_table1_bound() {
        check("qsgd distortion bound", 30, |g| {
            let v = g.vec_normal(10..2000, 1.0);
            if l2_norm(&v) == 0.0 {
                return;
            }
            let s = *g.pick(&[2usize, 4, 16, 64]);
            let mut q = QsgdQuantizer::new(s);
            let mut rng = Rng::new(g.seed);
            let dq = q.quantize(&v, &mut rng).dequantize();
            let d = v.len() as f64;
            let nsq = l2_norm(&v).powi(2);
            // Table I bound with our grid step 1/(s-1); add slack for the
            // stochastic single-draw (bound is on expectation)
            let s1 = (s - 1) as f64;
            let bound = (d / (s1 * s1)).min(d.sqrt() / s1) * nsq;
            assert!(
                sq_dist(&dq, &v) <= bound * 3.0 + 1e-9,
                "distortion {} > bound {}",
                sq_dist(&dq, &v),
                bound
            );
        });
    }

    #[test]
    fn signs_preserved() {
        let mut q = QsgdQuantizer::new(16);
        let mut rng = Rng::new(3);
        let v = vec![1.0f32, -1.0, 0.5, -0.5];
        let dq = q.quantize(&v, &mut rng).dequantize();
        for (a, b) in dq.iter().zip(&v) {
            assert!(a * b >= 0.0, "sign flipped: {a} vs {b}");
        }
    }

    #[test]
    fn set_levels_rebuilds_table() {
        let mut q = QsgdQuantizer::new(4);
        q.set_levels(8);
        assert_eq!(q.levels(), 8);
        let mut rng = Rng::new(0);
        let qv = q.quantize(&[1.0, 2.0], &mut rng);
        assert_eq!(qv.s(), 8);
    }
}
