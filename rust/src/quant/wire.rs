//! The versioned transport frame both gossip engines put on the wire.
//!
//! A [`WireMessage`](self) is a fixed 12-byte header followed by the
//! self-describing codec body of [`super::codec`] (the packed sign/index
//! bitstream of one [`QuantizedVector`]). The header carries everything
//! a receiver needs to route and reconstruct the payload without
//! out-of-band context — the protocol round key, the sender, the
//! quantizer tag (from which implied level tables are regenerated), and
//! the payload's index bit-width:
//!
//! ```text
//! u8   version    wire format version (WIRE_VERSION = 2)
//! u8   tag        quantizer tag (QuantTag)
//! u8   phase      protocol phase (sync: 0 = q2 mixing delta,
//!                 2 = q1 local-update delta; async: 0)
//! u8   idx_bits   payload index bit-width ⌈log₂ s⌉ (validated)
//! u32  sender     sending node id (little-endian)
//! u32  round      global round (sync) / sender local round (async)
//! -- codec body (quant::codec::encode_body) --
//! u32  d; u16 s; u8 flags; f32 norm; [f32; s] table (if shipped);
//! then either the dense element stream (d sign bits; d·idx_bits
//! index bits) or, when flags bit 1 is set, the sparse one
//! (u32 k; k × [position, sign, index] entries); zero padding to a
//! whole byte
//! ```
//!
//! Version history: v1 shipped dense bodies only; v2 added the sparse
//! body (flags bit 1) that lets the top-k and TernGrad sparsifiers ship
//! only their surviving coordinates. The body encoding is canonical
//! (see [`super::codec`]), so a message's length is a pure function of
//! its decoded content and byte meters can re-derive it.
//!
//! Versioning rule: any change to the header layout or the body format
//! bumps [`WIRE_VERSION`]; decoders reject unknown versions with an
//! error (never a panic), and the golden fixtures of
//! `rust/tests/wire_conformance.rs` pin the byte stream of the current
//! version so drift cannot land silently.
//!
//! Decoding is total: truncated buffers, unknown versions/tags,
//! inconsistent bit-widths, out-of-range indices, and trailing garbage
//! all return [`CodecError`]. A full-zero delta still encodes to a
//! header + body ([`MIN_ENCODED_BYTES`] is the floor), which is what
//! lets the simnet fabric distinguish "offline sender" (zero bytes)
//! from "legitimately empty message".

use std::collections::HashMap;

use super::codec::{self, BitReader, BitWriter, CodecError};
use super::QuantizedVector;
use crate::config::QuantizerKind;
use crate::quant::bits::{ceil_log2, stream_bytes};
use crate::quant::{FullPrecision, NaturalQuantizer, QsgdQuantizer};

/// Current wire format version (see the module docs for the rule).
pub const WIRE_VERSION: u8 = 2;

/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 12;

/// Fixed header size in bits.
pub const HEADER_BITS: u64 = 8 * HEADER_BYTES as u64;

/// Smallest possible encoded message: header + the body of a d = 0,
/// s = 1, implied-table vector. Every live broadcast is at least this
/// long — the simnet fabric's "0 bytes = nothing transmitted" sentinel
/// can never collide with a real message.
pub const MIN_ENCODED_BYTES: usize = HEADER_BYTES + 11;

/// Wire tag identifying the quantizer family that produced a message.
/// Fixed-grid families imply their level table (receivers regenerate it
/// from s); adaptive families — including the TernGrad / top-k
/// extension baselines installed via
/// [`crate::dfl::DflEngine::set_all_quantizers`] — ship the table in
/// the body.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum QuantTag {
    Full = 0,
    Qsgd = 1,
    Natural = 2,
    Alq = 3,
    LloydMax = 4,
    DoublyAdaptive = 5,
    TernGrad = 6,
    TopK = 7,
}

impl QuantTag {
    /// The tag of a configured quantizer kind.
    pub fn from_kind(kind: &QuantizerKind) -> QuantTag {
        match kind {
            QuantizerKind::Full => QuantTag::Full,
            QuantizerKind::Qsgd { .. } => QuantTag::Qsgd,
            QuantizerKind::Natural { .. } => QuantTag::Natural,
            QuantizerKind::Alq { .. } => QuantTag::Alq,
            QuantizerKind::LloydMax { .. } => QuantTag::LloydMax,
            QuantizerKind::DoublyAdaptive { .. } => {
                QuantTag::DoublyAdaptive
            }
            QuantizerKind::TernGrad => QuantTag::TernGrad,
            QuantizerKind::TopK { .. } => QuantTag::TopK,
        }
    }

    /// Parse a wire byte; unknown tags are a decode error, not a panic.
    pub fn from_u8(v: u8) -> Result<QuantTag, CodecError> {
        Ok(match v {
            0 => QuantTag::Full,
            1 => QuantTag::Qsgd,
            2 => QuantTag::Natural,
            3 => QuantTag::Alq,
            4 => QuantTag::LloydMax,
            5 => QuantTag::DoublyAdaptive,
            6 => QuantTag::TernGrad,
            7 => QuantTag::TopK,
            other => {
                return Err(CodecError::Malformed(format!(
                    "unknown quantizer tag {other}"
                )))
            }
        })
    }

    /// Tag from a [`crate::quant::Quantizer::name`] string — how the
    /// encode path labels frames from the ACTIVE quantizer, which
    /// [`crate::dfl::DflEngine::set_all_quantizers`] may have swapped
    /// away from the configured kind.
    pub fn from_name(name: &str) -> Option<QuantTag> {
        Some(match name {
            "full" => QuantTag::Full,
            "qsgd" => QuantTag::Qsgd,
            "natural" => QuantTag::Natural,
            "alq" => QuantTag::Alq,
            "lloyd_max" => QuantTag::LloydMax,
            "doubly_adaptive" => QuantTag::DoublyAdaptive,
            "terngrad" => QuantTag::TernGrad,
            "topk" => QuantTag::TopK,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantTag::Full => "full",
            QuantTag::Qsgd => "qsgd",
            QuantTag::Natural => "natural",
            QuantTag::Alq => "alq",
            QuantTag::LloydMax => "lloyd_max",
            QuantTag::DoublyAdaptive => "doubly_adaptive",
            QuantTag::TernGrad => "terngrad",
            QuantTag::TopK => "topk",
        }
    }

    /// Regenerate the implied level table for tag + s, or `None` for
    /// families that always ship their (data-adapted) table.
    pub fn implied_levels(self, s: usize) -> Option<Vec<f32>> {
        match self {
            QuantTag::Full => Some(FullPrecision::level_table(s)),
            QuantTag::Qsgd => Some(QsgdQuantizer::level_table(s)),
            QuantTag::Natural => Some(NaturalQuantizer::level_table(s)),
            QuantTag::Alq
            | QuantTag::LloydMax
            | QuantTag::DoublyAdaptive
            | QuantTag::TernGrad
            | QuantTag::TopK => None,
        }
    }
}

/// The fixed-size message header (see the module docs for the layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireHeader {
    pub version: u8,
    pub tag: QuantTag,
    pub phase: u8,
    /// payload index bit-width ⌈log₂ s⌉ (validated against the body)
    pub idx_bits: u8,
    pub sender: u32,
    /// global round (sync engines) / sender local round (async)
    pub round: u32,
}

impl WireHeader {
    /// Header for the current version, with `idx_bits` derived from the
    /// payload's level count.
    pub fn new(
        tag: QuantTag,
        phase: u8,
        sender: u32,
        round: u32,
        s: usize,
    ) -> WireHeader {
        WireHeader {
            version: WIRE_VERSION,
            tag,
            phase,
            idx_bits: ceil_log2(s) as u8,
            sender,
            round,
        }
    }
}

/// Receive-side cache of regenerated implied level tables, keyed by
/// (tag, s) — one per receiver, so repeated messages from fixed-grid
/// quantizers never re-materialize the table.
#[derive(Debug, Default)]
pub struct ImpliedCache {
    map: HashMap<(u8, usize), Vec<f32>>,
}

impl ImpliedCache {
    pub fn new() -> ImpliedCache {
        ImpliedCache { map: HashMap::new() }
    }

    /// Append the implied table for (tag, s) to `out`; false when the
    /// tag never implies a table (a malformed message).
    fn fill(&mut self, tag: QuantTag, s: usize, out: &mut Vec<f32>) -> bool {
        use std::collections::hash_map::Entry;
        let table = match self.map.entry((tag as u8, s)) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(e) => match tag.implied_levels(s) {
                Some(t) => e.insert(t),
                None => return false,
            },
        };
        out.extend_from_slice(table);
        true
    }
}

/// Exact encoded size in bits of a *dense-body* message for
/// (d, s, implied_table). For the canonical (possibly sparse) size of a
/// concrete message use [`message_len`].
pub fn encoded_bits(d: usize, s: usize, implied_table: bool) -> u64 {
    HEADER_BITS + codec::encoded_bits(d, s, implied_table)
}

/// Exact encoded size in bytes of a *dense-body* message.
pub fn encoded_len(d: usize, s: usize, implied_table: bool) -> usize {
    HEADER_BYTES + stream_bytes(codec::encoded_bits(d, s, implied_table))
}

/// Exact encoded size in bytes of the message carrying `qv` — the
/// canonical body form ([`codec::body_bits`]), so this equals the
/// measured length of the bytes [`encode`] produces.
pub fn message_len(qv: &QuantizedVector) -> usize {
    HEADER_BYTES + stream_bytes(codec::body_bits(qv))
}

/// Encode one message to fresh bytes.
pub fn encode(h: &WireHeader, qv: &QuantizedVector) -> Vec<u8> {
    encode_with_buf(h, qv, Vec::new())
}

/// Zero-alloc [`encode`]: reuse `buf` as the backing storage (grown at
/// most once, to the exact message size).
pub fn encode_with_buf(
    h: &WireHeader,
    qv: &QuantizedVector,
    buf: Vec<u8>,
) -> Vec<u8> {
    debug_assert_eq!(h.version, WIRE_VERSION);
    debug_assert_eq!(h.idx_bits as u32, ceil_log2(qv.s()));
    let mut w = BitWriter::with_capacity_bits(
        buf,
        HEADER_BITS + codec::body_bits(qv),
    );
    w.write_u8(h.version);
    w.write_u8(h.tag as u8);
    w.write_u8(h.phase);
    w.write_u8(h.idx_bits);
    w.write_u32(h.sender);
    w.write_u32(h.round);
    codec::encode_body(&mut w, qv);
    w.into_bytes()
}

/// Decode one message into `out`, regenerating implied level tables via
/// `cache`, and return the validated header. Every malformed input —
/// truncation, unknown version/tag, bit-width mismatch, length mismatch
/// — is a [`CodecError`]; decoding never panics. On error `out` may be
/// partially overwritten — discard it.
pub fn decode_into(
    bytes: &[u8],
    cache: &mut ImpliedCache,
    out: &mut QuantizedVector,
) -> Result<WireHeader, CodecError> {
    let mut r = BitReader::new(bytes);
    let version = r.read_u8()?;
    if version != WIRE_VERSION {
        return Err(CodecError::Version {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let tag = QuantTag::from_u8(r.read_u8()?)?;
    let phase = r.read_u8()?;
    let idx_bits = r.read_u8()?;
    let sender = r.read_u32()?;
    let round = r.read_u32()?;
    let mut bad_tag = false;
    let body = codec::decode_body(
        &mut r,
        |s, table: &mut Vec<f32>| {
            if !cache.fill(tag, s, table) {
                bad_tag = true;
            }
        },
        out,
    );
    if bad_tag {
        return Err(CodecError::Malformed(format!(
            "quantizer '{}' never implies a level table",
            tag.name()
        )));
    }
    body?;
    if idx_bits as u32 != ceil_log2(out.s()) {
        return Err(CodecError::Malformed(format!(
            "header idx_bits {idx_bits} != ceil_log2({}) = {}",
            out.s(),
            ceil_log2(out.s())
        )));
    }
    let want = HEADER_BYTES + stream_bytes(codec::body_bits(out));
    if bytes.len() != want {
        return Err(CodecError::Malformed(format!(
            "message is {} bytes, format says {want}",
            bytes.len()
        )));
    }
    Ok(WireHeader { version, tag, phase, idx_bits, sender, round })
}

/// Cross-validate a decoded header against the transport envelope that
/// carried it. The gossip engines route on the envelope key (sender,
/// round, phase); a message whose *decoded* header contradicts its
/// envelope is corrupt or forged and must fail as a total decode error
/// (never a panic) — same contract as [`decode_into`].
pub fn validate_frame(
    h: &WireHeader,
    sender: usize,
    round: u32,
    phase: u8,
) -> Result<(), CodecError> {
    if h.sender as usize != sender || h.round != round || h.phase != phase
    {
        return Err(CodecError::Malformed(format!(
            "wire header (sender {}, round {}, phase {}) contradicts \
             envelope key ({sender}, {round}, {phase})",
            h.sender, h.round, h.phase
        )));
    }
    Ok(())
}

// ---- transport envelope (byte-stream framing) --------------------------
//
// Stream transports (net::TcpDelivery) cannot rely on datagram
// boundaries, so each frame travels in a length-prefixed envelope:
//
// ```text
// u32  len     little-endian; bytes after this field (9 + payload)
// u32  from    sending node id
// u32  round   protocol round key
// u8   phase   protocol phase (or a transport-private control tag)
// [u8] payload encoded WireMessage (empty = drop tombstone / control)
// ```
//
// The envelope is pure framing: payload bytes are the exact encoded
// WireMessage, so byte meters that count payload lengths still equal
// the sum of encoded message lengths (the simnet accounting contract).

/// Envelope overhead per frame in bytes (len + from + round + phase).
pub const ENVELOPE_BYTES: usize = 13;

/// Hostile-length bound: a frame claiming a larger payload is rejected
/// before any allocation (same defense as the codec's payload bound).
pub const MAX_FRAME_PAYLOAD_BYTES: usize = 1 << 28;

/// One parsed transport envelope.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Envelope {
    pub from: u32,
    pub round: u32,
    pub phase: u8,
    pub payload: Vec<u8>,
}

/// Write one length-prefixed frame to a byte stream.
pub fn write_frame(
    w: &mut impl std::io::Write,
    from: u32,
    round: u32,
    phase: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    let mut head = [0u8; ENVELOPE_BYTES];
    head[0..4].copy_from_slice(&((payload.len() + 9) as u32).to_le_bytes());
    head[4..8].copy_from_slice(&from.to_le_bytes());
    head[8..12].copy_from_slice(&round.to_le_bytes());
    head[12] = phase;
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// Fill `buf` from `r`; `Ok(false)` when the stream was already at EOF
/// (no byte read), `UnexpectedEof` when it ended mid-buffer.
fn read_full_or_eof(
    r: &mut impl std::io::Read,
    buf: &mut [u8],
) -> std::io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(false),
            Ok(0) => {
                return Err(std::io::Error::from(
                    std::io::ErrorKind::UnexpectedEof,
                ))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one length-prefixed frame from a byte stream. `Ok(None)` means
/// the stream closed cleanly at a frame boundary; a stream that ends
/// mid-frame is [`CodecError::Truncated`], a hostile or undersized
/// length field is [`CodecError::Malformed`], and any other I/O failure
/// surfaces as [`LmdflError::Io`](crate::error::LmdflError::Io).
pub fn read_frame(
    r: &mut impl std::io::Read,
) -> Result<Option<Envelope>, crate::error::LmdflError> {
    use crate::error::LmdflError;
    let mut len4 = [0u8; 4];
    match read_full_or_eof(r, &mut len4) {
        Ok(false) => return Ok(None),
        Ok(true) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(LmdflError::Codec(CodecError::Truncated {
                need_bits: 32,
                have_bits: 0,
            }))
        }
        Err(e) => return Err(LmdflError::Io(e)),
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len < 9 {
        return Err(LmdflError::Codec(CodecError::Malformed(format!(
            "envelope length {len} below the 9-byte frame meta"
        ))));
    }
    if len - 9 > MAX_FRAME_PAYLOAD_BYTES {
        return Err(LmdflError::Codec(CodecError::Malformed(format!(
            "envelope claims a {} byte payload (cap {})",
            len - 9,
            MAX_FRAME_PAYLOAD_BYTES
        ))));
    }
    let mut rest = vec![0u8; len];
    if !read_full_or_eof(r, &mut rest)
        .map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof => {
                LmdflError::Codec(CodecError::Truncated {
                    need_bits: len as u64 * 8,
                    have_bits: 0,
                })
            }
            _ => LmdflError::Io(e),
        })?
    {
        // EOF exactly between the length field and the frame meta
        return Err(LmdflError::Codec(CodecError::Truncated {
            need_bits: len as u64 * 8,
            have_bits: 0,
        }));
    }
    let from = u32::from_le_bytes(rest[0..4].try_into().unwrap());
    let round = u32::from_le_bytes(rest[4..8].try_into().unwrap());
    let phase = rest[8];
    let payload = rest.split_off(9);
    Ok(Some(Envelope { from, round, phase, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{LloydMaxQuantizer, Quantizer};
    use crate::util::rng::Rng;

    fn sample_msg() -> QuantizedVector {
        let mut q = LloydMaxQuantizer::new(8, 6);
        let mut rng = Rng::new(3);
        let v: Vec<f32> =
            (0..97).map(|i| (i as f32 * 0.31).sin()).collect();
        q.quantize(&v, &mut rng)
    }

    #[test]
    fn roundtrip_preserves_header_and_message() {
        let qv = sample_msg();
        let h = WireHeader::new(QuantTag::LloydMax, 2, 7, 41, qv.s());
        let bytes = encode(&h, &qv);
        assert_eq!(bytes.len(), message_len(&qv));
        assert_eq!(bytes.len() as u64 * 8, encoded_bits(97, 8, false));
        let mut cache = ImpliedCache::new();
        let mut out = QuantizedVector::empty();
        let back = decode_into(&bytes, &mut cache, &mut out).unwrap();
        assert_eq!(back, h);
        assert_eq!(out, qv);
    }

    #[test]
    fn implied_table_regenerated_from_tag() {
        let mut q = crate::quant::QsgdQuantizer::new(16);
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..50).map(|i| (i as f32).cos()).collect();
        let qv = q.quantize(&v, &mut rng);
        assert!(qv.implied_table);
        let h = WireHeader::new(QuantTag::Qsgd, 0, 1, 2, qv.s());
        let bytes = encode(&h, &qv);
        let mut cache = ImpliedCache::new();
        let mut out = QuantizedVector::empty();
        decode_into(&bytes, &mut cache, &mut out).unwrap();
        assert_eq!(out, qv);
        // second decode hits the cache (same result)
        let mut again = QuantizedVector::empty();
        decode_into(&bytes, &mut cache, &mut again).unwrap();
        assert_eq!(again, qv);
    }

    #[test]
    fn malformed_inputs_error_not_panic() {
        let qv = sample_msg();
        let h = WireHeader::new(QuantTag::LloydMax, 0, 0, 0, qv.s());
        let bytes = encode(&h, &qv);
        let mut cache = ImpliedCache::new();
        let mut out = QuantizedVector::empty();
        // every truncation of the valid message fails cleanly
        for cut in [0, 1, HEADER_BYTES - 1, HEADER_BYTES, bytes.len() - 1]
        {
            assert!(
                decode_into(&bytes[..cut], &mut cache, &mut out).is_err(),
                "truncation to {cut} bytes decoded"
            );
        }
        // trailing garbage is rejected (exact-length contract)
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_into(&long, &mut cache, &mut out).is_err());
        // unknown version / tag / bit-width are rejected
        let mut bad = bytes.clone();
        bad[0] = 99;
        assert!(decode_into(&bad, &mut cache, &mut out).is_err());
        let mut bad = bytes.clone();
        bad[1] = 250;
        assert!(decode_into(&bad, &mut cache, &mut out).is_err());
        let mut bad = bytes.clone();
        bad[3] ^= 0x1;
        assert!(decode_into(&bad, &mut cache, &mut out).is_err());
        // a shipped-table tag on an implied-table body is malformed
        let mut q = crate::quant::QsgdQuantizer::new(16);
        let mut rng = Rng::new(9);
        let iv: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let iqv = q.quantize(&iv, &mut rng);
        let ih = WireHeader::new(QuantTag::LloydMax, 0, 0, 0, iqv.s());
        let ibytes = encode(&ih, &iqv);
        let err = decode_into(&ibytes, &mut cache, &mut out).unwrap_err();
        assert!(err.to_string().contains("never implies"), "{err}");
    }

    #[test]
    fn decode_errors_are_typed() {
        let qv = sample_msg();
        let h = WireHeader::new(QuantTag::LloydMax, 0, 0, 0, qv.s());
        let bytes = encode(&h, &qv);
        let mut cache = ImpliedCache::new();
        let mut out = QuantizedVector::empty();
        // truncation → Truncated
        let err = decode_into(&bytes[..5], &mut cache, &mut out)
            .unwrap_err();
        assert!(matches!(err, CodecError::Truncated { .. }), "{err}");
        // version bump → Version carrying both bytes
        let mut bad = bytes.clone();
        bad[0] = 99;
        let err = decode_into(&bad, &mut cache, &mut out).unwrap_err();
        assert_eq!(
            err,
            CodecError::Version { got: 99, want: WIRE_VERSION }
        );
        // structural corruption → Malformed
        let mut bad = bytes.clone();
        bad[1] = 250;
        let err = decode_into(&bad, &mut cache, &mut out).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)), "{err}");
    }

    #[test]
    fn validate_frame_matches_envelope_key() {
        let h = WireHeader::new(QuantTag::Qsgd, 2, 7, 41, 16);
        assert!(validate_frame(&h, 7, 41, 2).is_ok());
        for (s, r, p) in [(6, 41, 2), (7, 40, 2), (7, 41, 0)] {
            let err = validate_frame(&h, s, r, p).unwrap_err();
            assert!(matches!(err, CodecError::Malformed(_)), "{err}");
            assert!(err.to_string().contains("contradicts"), "{err}");
        }
    }

    #[test]
    fn envelope_roundtrip_over_a_stream() {
        let mut stream = Vec::new();
        write_frame(&mut stream, 3, 9, 2, b"abc").unwrap();
        write_frame(&mut stream, 1, 10, 0, b"").unwrap();
        assert_eq!(stream.len(), 2 * ENVELOPE_BYTES + 3);
        let mut r = std::io::Cursor::new(stream);
        let a = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(
            a,
            Envelope {
                from: 3,
                round: 9,
                phase: 2,
                payload: b"abc".to_vec()
            }
        );
        let b = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(b.payload, Vec::<u8>::new());
        // clean EOF at a frame boundary
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn envelope_rejects_truncation_and_hostile_lengths() {
        use crate::error::LmdflError;
        let mut stream = Vec::new();
        write_frame(&mut stream, 3, 9, 2, b"abcdef").unwrap();
        // mid-frame cut → Truncated (both inside the length field and
        // inside the body)
        for cut in [2, ENVELOPE_BYTES - 1, stream.len() - 1] {
            let mut r = std::io::Cursor::new(&stream[..cut]);
            let err = read_frame(&mut r).unwrap_err();
            assert!(
                matches!(
                    err,
                    LmdflError::Codec(CodecError::Truncated { .. })
                ),
                "cut {cut}: {err}"
            );
        }
        // undersized length field → Malformed
        let mut bad = stream.clone();
        bad[0..4].copy_from_slice(&3u32.to_le_bytes());
        let err =
            read_frame(&mut std::io::Cursor::new(bad)).unwrap_err();
        assert!(
            matches!(err, LmdflError::Codec(CodecError::Malformed(_))),
            "{err}"
        );
        // hostile length → Malformed before any allocation
        let mut bad = stream.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err =
            read_frame(&mut std::io::Cursor::new(bad)).unwrap_err();
        assert!(
            matches!(err, LmdflError::Codec(CodecError::Malformed(_))),
            "{err}"
        );
    }

    #[test]
    fn min_encoded_bytes_is_the_true_floor() {
        // the degenerate d = 0, s = 1, implied-table message is the
        // shortest encodable frame
        assert_eq!(encoded_len(0, 1, true), MIN_ENCODED_BYTES);
        assert!(encoded_len(1, 1, true) >= MIN_ENCODED_BYTES);
        assert!(encoded_len(0, 2, false) > MIN_ENCODED_BYTES);
    }
}
