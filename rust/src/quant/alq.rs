//! ALQ baseline [18] (paper §III-B3): adaptive levels by coordinate
//! descent, stochastic (unbiased) rounding.
//!
//! Level partition 0 = ℓ_0 < ℓ_1 < … < ℓ_{s-1} = 1; the interior levels
//! are updated one coordinate at a time with the paper's rule
//!
//!   ℓ_j ← Φ⁻¹( Φ(ℓ_{j+1}) − ∫_{ℓ_{j-1}}^{ℓ_{j+1}}
//!                (r − ℓ_{j-1})/(ℓ_{j+1} − ℓ_{j-1}) dΦ(r) )
//!
//! evaluated on the *empirical* CDF of the observed magnitudes (sorted r +
//! prefix sums). One coordinate-descent sweep per quantize call — matching
//! the paper's description that ALQ "updates quantization levels during
//! iterations" and is only asymptotically optimal (vs. LM-DFL's per-round
//! refit), which is exactly the gap Fig. 6d/h plots.

use super::{decompose, QuantizedVector, Quantizer};
use crate::util::rng::Rng;

/// LUT resolution for the batch bracket locator of `quantize_into`.
const LUT_BINS: usize = 1024;

#[derive(Clone, Debug)]
pub struct AlqQuantizer {
    s: usize,
    /// level table, ℓ_0 = 0 and ℓ_{s-1} = 1 fixed
    levels: Vec<f32>,
    /// coordinate-descent sweeps per quantize() call
    pub sweeps_per_call: usize,
    // ---- batch-path scratch (quantize_into allocates nothing) ----------
    r_scratch: Vec<f32>,
    sorted_scratch: Vec<f32>,
    prefix_scratch: Vec<f64>,
    cnt_scratch: Vec<u32>,
    lut: Vec<u32>,
}

impl AlqQuantizer {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2);
        AlqQuantizer {
            s,
            levels: Self::uniform_table(s),
            sweeps_per_call: 1,
            r_scratch: Vec::new(),
            sorted_scratch: Vec::new(),
            prefix_scratch: Vec::new(),
            cnt_scratch: Vec::new(),
            lut: Vec::new(),
        }
    }

    fn uniform_table(s: usize) -> Vec<f32> {
        (0..s).map(|j| j as f32 / (s - 1) as f32).collect()
    }

    pub fn level_table(&self) -> &[f32] {
        &self.levels
    }

    /// One full coordinate-descent sweep over the interior levels, using
    /// the empirical CDF of `sorted_r` (ascending) with prefix sums.
    fn sweep(&mut self, sorted_r: &[f32], prefix: &[f64]) {
        let d = sorted_r.len();
        if d == 0 || self.s < 3 {
            return;
        }
        let cdf_count = |x: f32| -> usize {
            // #{ r_i <= x }
            sorted_r.partition_point(|&r| r <= x)
        };
        for j in 1..self.s - 1 {
            let lo = self.levels[j - 1];
            let hi = self.levels[j + 1];
            if hi - lo <= f32::EPSILON {
                continue;
            }
            let a = cdf_count(lo); // #r <= lo
            let b = cdf_count(hi); // #r <= hi
            // ∫_(lo,hi] (r - lo)/(hi - lo) dΦ(r)  (empirical)
            let sum_r = prefix[b] - prefix[a];
            let integral = (sum_r - lo as f64 * (b - a) as f64)
                / ((hi - lo) as f64 * d as f64);
            // target CDF mass: Φ(hi) - integral
            let target = (b as f64 / d as f64 - integral).clamp(0.0, 1.0);
            // empirical quantile Φ^{-1}(target)
            let k = ((target * d as f64).ceil() as usize).clamp(1, d) - 1;
            let mut cand = sorted_r[k];
            // keep strict ordering
            let eps = 1e-6;
            cand = cand.clamp(lo + eps, hi - eps);
            if cand.is_finite() {
                self.levels[j] = cand;
            }
        }
    }
}

impl Quantizer for AlqQuantizer {
    fn name(&self) -> &'static str {
        "alq"
    }

    fn levels(&self) -> usize {
        self.s
    }

    fn set_levels(&mut self, s: usize) {
        assert!(s >= 2);
        if s != self.s {
            self.s = s;
            self.levels = Self::uniform_table(s);
        }
    }

    fn quantize(&mut self, v: &[f32], rng: &mut Rng) -> QuantizedVector {
        let (norm, negative, r) = decompose(v);
        // coordinate descent on the empirical distribution
        if norm > 0.0 {
            let mut sorted = r.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut prefix = Vec::with_capacity(sorted.len() + 1);
            prefix.push(0.0f64);
            let mut acc = 0.0f64;
            for &x in &sorted {
                acc += x as f64;
                prefix.push(acc);
            }
            for _ in 0..self.sweeps_per_call {
                self.sweep(&sorted, &prefix);
            }
        }
        // stochastic rounding between bracketing levels (unbiased)
        let t = &self.levels;
        let indices: Vec<u32> = r
            .iter()
            .map(|&ri| {
                let ri = ri.clamp(0.0, 1.0);
                let j = match t
                    .binary_search_by(|x| x.partial_cmp(&ri).unwrap())
                {
                    Ok(exact) => return exact as u32,
                    Err(ins) => (ins - 1).min(self.s - 2),
                };
                let lo = t[j];
                let hi = t[j + 1];
                let p_hi = ((ri - lo) / (hi - lo)).clamp(0.0, 1.0);
                if rng.uniform_f32() < p_hi {
                    (j + 1) as u32
                } else {
                    j as u32
                }
            })
            .collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels: t.clone(),
            implied_table: false,
        }
    }

    /// Allocation-free batch path: identical sweep trajectory and the
    /// same `rng` draw sequence as [`quantize`] (exact level hits draw
    /// nothing). The magnitude prepass and the bracket location run as
    /// slice kernels ([`super::kernels::assign_lut_slice`] counts levels
    /// below each element — the reference binary search's Ok/Err split
    /// on the strictly sorted table); the conditional stochastic
    /// epilogue stays per-element.
    fn quantize_into(
        &mut self,
        v: &[f32],
        rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        let norm = super::norm_and_signs_into(v, &mut out.negative);
        out.norm = norm;
        let mut r = std::mem::take(&mut self.r_scratch);
        super::kernels::normalized_magnitudes_into(v, norm, &mut r);
        // coordinate descent on the empirical distribution — exactly the
        // reference's sort + prefix sums + sweeps, on reused scratch
        if norm > 0.0 {
            let mut sorted = std::mem::take(&mut self.sorted_scratch);
            sorted.clear();
            sorted.extend_from_slice(&r);
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut prefix = std::mem::take(&mut self.prefix_scratch);
            prefix.clear();
            prefix.reserve(sorted.len() + 1);
            prefix.push(0.0f64);
            let mut acc = 0.0f64;
            for &x in &sorted {
                acc += x as f64;
                prefix.push(acc);
            }
            for _ in 0..self.sweeps_per_call {
                self.sweep(&sorted, &prefix);
            }
            self.sorted_scratch = sorted;
            self.prefix_scratch = prefix;
        }
        // assignment clamps each magnitude like the reference does
        for x in r.iter_mut() {
            *x = x.clamp(0.0, 1.0);
        }
        super::kernels::build_count_lut(
            &self.levels,
            1.0,
            LUT_BINS,
            &mut self.lut,
        );
        super::kernels::assign_lut_slice(
            &self.levels,
            &self.lut,
            LUT_BINS as f32,
            &r,
            &mut self.cnt_scratch,
        );
        let t = &self.levels;
        out.indices.clear();
        out.indices.reserve(v.len());
        for (&ri, &c) in r.iter().zip(&self.cnt_scratch) {
            let c = c as usize;
            let idx = if c < t.len() && t[c] == ri {
                c as u32
            } else {
                // t[c-1] < ri < t[c]; c >= 1 because ri >= 0 = t[0]
                let j = (c - 1).min(self.s - 2);
                let lo = t[j];
                let hi = t[j + 1];
                let p_hi = ((ri - lo) / (hi - lo)).clamp(0.0, 1.0);
                if rng.uniform_f32() < p_hi {
                    (j + 1) as u32
                } else {
                    j as u32
                }
            };
            out.indices.push(idx);
        }
        self.r_scratch = r;
        out.levels.clear();
        out.levels.extend_from_slice(t);
        out.implied_table = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{l2_norm, sq_dist};

    fn normalized_distortion(v: &[f32], dq: &[f32]) -> f64 {
        sq_dist(dq, v) / l2_norm(v).powi(2)
    }

    #[test]
    fn starts_uniform_with_fixed_endpoints() {
        let q = AlqQuantizer::new(5);
        let t = q.level_table();
        assert_eq!(t[0], 0.0);
        assert_eq!(t[4], 1.0);
        assert!((t[2] - 0.5).abs() < 1e-7);
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut q = AlqQuantizer::new(6);
        let mut rng = Rng::new(21);
        let v = vec![0.4f32, -0.8, 0.15, 0.6];
        let n = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..n {
            for (a, x) in acc.iter_mut().zip(q.quantize(&v, &mut rng).dequantize()) {
                *a += x as f64;
            }
        }
        for (a, &want) in acc.iter().zip(&v) {
            let mean = a / n as f64;
            assert!((mean - want as f64).abs() < 0.02, "{mean} vs {want}");
        }
    }

    #[test]
    fn levels_stay_sorted_after_sweeps() {
        let mut q = AlqQuantizer::new(8);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let v: Vec<f32> =
                (0..2000).map(|_| rng.laplace(0.3) as f32).collect();
            let _ = q.quantize(&v, &mut rng);
            let t = q.level_table();
            for w in t.windows(2) {
                assert!(w[0] < w[1], "levels unsorted: {t:?}");
            }
            assert_eq!(t[0], 0.0);
            assert_eq!(*t.last().unwrap(), 1.0);
        }
    }

    #[test]
    fn adapts_toward_lower_distortion_on_skewed_data() {
        // repeated sweeps on a stable skewed distribution should reduce
        // distortion below the uniform-grid starting point
        let mut rng = Rng::new(17);
        let v: Vec<f32> = (0..20_000)
            .map(|_| (rng.normal() * 0.05) as f32)
            .collect();

        // distortion with the fixed uniform table (fresh quantizer, no sweep
        // effect on first call is small, so use many-sample comparison)
        let mut fresh = AlqQuantizer::new(8);
        fresh.sweeps_per_call = 0;
        let d0 = normalized_distortion(
            &v, &fresh.quantize(&v, &mut rng).dequantize());

        let mut adapted = AlqQuantizer::new(8);
        adapted.sweeps_per_call = 3;
        // several rounds of coordinate descent (asymptotic adaptation)
        let mut dq = Vec::new();
        for _ in 0..10 {
            dq = adapted.quantize(&v, &mut rng).dequantize();
        }
        let d1 = normalized_distortion(&v, &dq);
        assert!(d1 < d0, "adapted {d1} should beat uniform {d0}");
    }

    #[test]
    fn indices_in_range_and_deterministic_extremes() {
        let mut q = AlqQuantizer::new(4);
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..300).map(|i| (i as f32 / 300.0) - 0.5).collect();
        let qv = q.quantize(&v, &mut rng);
        assert!(qv.indices.iter().all(|&i| (i as usize) < 4));
    }
}
