//! Batch kernels for the quantize / pack / dequantize hot path.
//!
//! Every DFL round spends its CPU budget in a handful of per-element
//! loops: level assignment (Lloyd-Max LUT walk, QSGD stochastic
//! rounding), sign/index bit-packing, and dequantize-accumulate in the
//! gossip mix. This module hosts those loops as slice kernels in three
//! tiers:
//!
//! * a **scalar reference** ([`reference`]) — the original per-element
//!   loops, kept in-tree as the property-test oracle and the bench
//!   baseline (`cargo bench --bench micro_quant` reports kernel vs
//!   reference rows);
//! * **portable chunked** implementations — branchless two-pass loops
//!   (pre-drawn randomness, hoisted norm gates, split gather/arith
//!   passes) that LLVM autovectorizes without changing IEEE semantics;
//! * **runtime-feature-gated AVX2** paths for the gather-heavy kernels
//!   (level-table dequantize, LUT assignment) where autovectorization
//!   cannot help, selected per call via `is_x86_64_feature_detected!`
//!   with the portable path as the fallback on every other target.
//!
//! # Bit-identity contract
//!
//! Every kernel is **bit-identical** to its scalar reference on every
//! input: only IEEE-exact element-wise operations are used (add, mul,
//! div, floor, compare, min/max — never FMA, never a reassociated
//! reduction), stochastic kernels consume exactly the same RNG draw
//! sequence per element, and index/tie-breaking logic is identical.
//! The engine equivalence gates (`rust/tests/engine_parallel.rs`) and
//! the simnet replay digests (`rust/tests/simnet_determinism.rs`) rely
//! on this; the property tests below enforce it kernel by kernel.

// ---------------------------------------------------------------------------
// feature detection
// ---------------------------------------------------------------------------

/// True when the AVX2 fast paths are compiled in *and* the running CPU
/// supports them (checked once per call; `std` caches the cpuid probe).
#[inline]
pub fn avx2_enabled() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_64_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// ---------------------------------------------------------------------------
// element-wise float kernels (autovectorized; exact by construction)
// ---------------------------------------------------------------------------

/// `out = |v_i| / ‖v‖` (zeros when the norm is not positive) — the
/// vectorizable prologue shared by the `quantize_into` overrides.
/// Bit-identical to mapping [`super::normalized_magnitude`] per element.
pub fn normalized_magnitudes_into(v: &[f32], norm: f32, out: &mut Vec<f32>) {
    out.clear();
    if norm > 0.0 {
        out.reserve(v.len());
        out.extend(v.iter().map(|&x| x.abs() / norm));
    } else {
        out.resize(v.len(), 0.0);
    }
}

/// As [`normalized_magnitudes_into`] with a `[0, 1]` clamp per element
/// (the natural/ALQ assignment prologue).
pub fn normalized_magnitudes_clamped_into(
    v: &[f32],
    norm: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    if norm > 0.0 {
        out.reserve(v.len());
        out.extend(v.iter().map(|&x| (x.abs() / norm).clamp(0.0, 1.0)));
    } else {
        out.resize(v.len(), 0.0);
    }
}

/// `dst_i += src_i` (estimate-recursion apply).
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (a, &b) in dst.iter_mut().zip(src) {
        *a += b;
    }
}

/// `out_i = a_i - b_i` (differential delta).
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// `dst_i += w * src_i` — the gossip mix accumulate. Mul-then-add
/// (never FMA), matching the scalar engine loop bit for bit.
pub fn axpy(dst: &mut [f32], w: f32, src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (a, &b) in dst.iter_mut().zip(src) {
        *a += w * b;
    }
}

/// `out_i = w * src_i` (mix initialization with the self weight).
pub fn scaled_into(out: &mut [f32], w: f32, src: &[f32]) {
    assert_eq!(out.len(), src.len());
    for (o, &x) in out.iter_mut().zip(src) {
        *o = w * x;
    }
}

/// `dst_i += a_i - b_i` — the consensus correction apply (Eq. 21).
pub fn add_delta(dst: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d += x - y;
    }
}

// ---------------------------------------------------------------------------
// dequantize / dequantize-accumulate
// ---------------------------------------------------------------------------

/// Gather-safety pre-scan for the AVX2 path only (the portable loop's
/// slice indexing already bounds-checks per element).
#[cfg(target_arch = "x86_64")]
#[inline]
fn indices_in_range(indices: &[u32], len: usize) -> bool {
    // branch-free max-scan
    let mut max = 0u32;
    for &i in indices {
        max = max.max(i);
    }
    (max as usize) < len || indices.is_empty()
}

/// `out_i = ±‖v‖·ℓ_{idx_i}` — batch dequantize. Bit-identical to
/// [`reference::dequantize_into`] (sign application is an exact
/// sign-bit flip, multiplication order unchanged).
pub fn dequantize_into(
    norm: f32,
    negative: &[bool],
    indices: &[u32],
    levels: &[f32],
    out: &mut [f32],
) {
    dequantize_core(norm, negative, indices, levels, out, false);
}

/// `acc_i += ±‖v‖·ℓ_{idx_i}` — fused dequantize-accumulate used by the
/// gossip estimate recursion (x̂ += Q(...)); bit-identical to
/// dequantize-into-scratch followed by an element-wise add.
pub fn dequantize_accumulate(
    norm: f32,
    negative: &[bool],
    indices: &[u32],
    levels: &[f32],
    acc: &mut [f32],
) {
    dequantize_core(norm, negative, indices, levels, acc, true);
}

fn dequantize_core(
    norm: f32,
    negative: &[bool],
    indices: &[u32],
    levels: &[f32],
    out: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(out.len(), indices.len());
    assert_eq!(negative.len(), indices.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_enabled() && indices_in_range(indices, levels.len()) {
        // SAFETY: AVX2 is available and all indices are < levels.len()
        // (the pre-scan makes the gathers in-bounds; an out-of-range
        // message instead falls through to the portable loop, which
        // panics at the offending element like the reference)
        unsafe {
            avx2::dequantize(norm, negative, indices, levels, out, accumulate)
        };
        return;
    }
    // portable: branchless sign application via an exact sign-bit XOR so
    // the arithmetic lanes vectorize; slice indexing bounds-checks per
    // element, panicking exactly where the reference would
    if accumulate {
        for i in 0..out.len() {
            let mag = norm * levels[indices[i] as usize];
            let bits = mag.to_bits() ^ ((negative[i] as u32) << 31);
            out[i] += f32::from_bits(bits);
        }
    } else {
        for i in 0..out.len() {
            let mag = norm * levels[indices[i] as usize];
            let bits = mag.to_bits() ^ ((negative[i] as u32) << 31);
            out[i] = f32::from_bits(bits);
        }
    }
}

// ---------------------------------------------------------------------------
// LUT level assignment (Lloyd-Max / ALQ / natural bracketing)
// ---------------------------------------------------------------------------

/// Build the histogram-bin → first-candidate LUT for [`assign_lut_slice`]:
/// `lut[b] = #{ inner[k] < b · range_max / bins }` for the ascending
/// `inner` table. One forward merge over (bins, inner).
pub fn build_count_lut(
    inner: &[f32],
    range_max: f32,
    bins: usize,
    lut: &mut Vec<u32>,
) {
    lut.clear();
    lut.resize(bins, 0);
    let w = range_max / bins as f32;
    let mut j = 0usize;
    for (b, slot) in lut.iter_mut().enumerate() {
        let edge = b as f32 * w;
        while j < inner.len() && inner[j] < edge {
            j += 1;
        }
        *slot = j as u32;
    }
}

/// Batch `#{ inner[k] < r_i }` via LUT + fix-up walk — the Lloyd-Max
/// deterministic assignment (with `inner = boundaries[1..s]` the result
/// IS the level index) and the natural/ALQ bracket locator (with
/// `inner = level table`). `scale` must be `bins / range_max` for the
/// LUT built by [`build_count_lut`]. Bit-identical to
/// [`reference::assign_lut_slice`].
pub fn assign_lut_slice(
    inner: &[f32],
    lut: &[u32],
    scale: f32,
    r: &[f32],
    out: &mut Vec<u32>,
) {
    assert!(!lut.is_empty());
    #[cfg(target_arch = "x86_64")]
    if avx2_enabled() {
        // SAFETY: AVX2 available; bins are clamped to lut's range and
        // lut values never exceed inner.len() by construction
        unsafe { avx2::assign_lut(inner, lut, scale, r, out) };
        return;
    }
    out.clear();
    out.reserve(r.len());
    let top = lut.len() - 1;
    // chunked two-pass: the bin computation (mul + trunc-cast + min)
    // vectorizes; the LUT load + fix-up walk runs scalar per lane
    let mut bins = [0usize; 64];
    for chunk in r.chunks(64) {
        for (slot, &ri) in bins.iter_mut().zip(chunk) {
            *slot = ((ri * scale) as usize).min(top);
        }
        for (lane, &ri) in chunk.iter().enumerate() {
            let mut j = lut[bins[lane]] as usize;
            while j < inner.len() && inner[j] < ri {
                j += 1;
            }
            out.push(j as u32);
        }
    }
}

// ---------------------------------------------------------------------------
// QSGD stochastic rounding
// ---------------------------------------------------------------------------

/// Batch QSGD assignment over the uniform grid with `s` levels and
/// pre-drawn per-element uniforms `u` (one per element, in element
/// order — exactly the draw sequence of the per-element loop). The
/// whole loop is branchless, so it vectorizes: div, floor, compare and
/// saturating casts all keep their scalar IEEE semantics lane-wise.
pub fn qsgd_assign_slice(
    v: &[f32],
    norm: f32,
    s: u32,
    u: &[f32],
    out: &mut Vec<u32>,
) {
    assert!(s >= 2);
    assert_eq!(u.len(), v.len());
    out.clear();
    out.reserve(v.len());
    let scale = (s - 1) as f32;
    if norm > 0.0 {
        out.extend(v.iter().zip(u).map(|(&x, &ui)| {
            let xq = ((x.abs() / norm) * scale).clamp(0.0, scale);
            let lo = xq.floor();
            let up = (ui < xq - lo) as u32;
            (lo as u32 + up).min(s - 1)
        }));
    } else {
        // zero norm: r_i = 0 → frac = 0 → never rounds up (the uniforms
        // are still consumed so the rng stream stays in lockstep)
        out.extend(u.iter().map(|&ui| {
            let up = (ui < 0.0) as u32;
            up.min(s - 1)
        }));
    }
}

// ---------------------------------------------------------------------------
// u64 word-at-a-time bit pack / unpack
// ---------------------------------------------------------------------------

/// The bit stream ended before the requested items could be read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutOfBits;

/// Append up to 64 low bits of `value` to the LSB-first stream tail
/// `(acc, nacc)` (invariant `nacc < 8`), spilling whole 8-byte words.
#[inline]
fn push_wide(
    value: u64,
    nbits: u32,
    mut acc: u64,
    mut nacc: u32,
    buf: &mut Vec<u8>,
) -> (u64, u32) {
    debug_assert!(nacc < 8);
    debug_assert!(nbits <= 64);
    if nbits == 0 {
        return (acc, nacc);
    }
    let value = if nbits == 64 {
        value
    } else {
        value & ((1u64 << nbits) - 1)
    };
    // bits above 63 fall off the top here; they are re-staged below
    acc |= value << nacc;
    let fit = 64 - nacc;
    if nbits <= fit {
        nacc += nbits;
        if nacc == 64 {
            buf.extend_from_slice(&acc.to_le_bytes());
            acc = 0;
            nacc = 0;
        } else {
            while nacc >= 8 {
                buf.push(acc as u8);
                acc >>= 8;
                nacc -= 8;
            }
        }
    } else {
        // nbits > fit implies nacc > 0, so fit <= 63 and both shifts
        // below are in range
        buf.extend_from_slice(&acc.to_le_bytes());
        acc = value >> fit;
        nacc = nbits - fit;
    }
    (acc, nacc)
}

/// Pack a bool slice (1 bit each, LSB-first) into `buf`, continuing the
/// stream tail `(acc, nacc < 8)`; returns the new tail. Produces exactly
/// the bytes of the historical bit-at-a-time writer
/// ([`reference::pack_bools`]), 64 bits per staged word.
pub fn pack_bools(
    bits: &[bool],
    acc: u64,
    nacc: u32,
    buf: &mut Vec<u8>,
) -> (u64, u32) {
    debug_assert!(nacc < 8);
    // exact byte count this call will push (the sub-byte tail stays
    // staged), so a preallocated encode buffer never regrows
    buf.reserve((nacc as usize + bits.len()) / 8);
    let mut state = (acc, nacc);
    let mut chunks = bits.chunks_exact(64);
    for chunk in &mut chunks {
        let mut word = 0u64;
        for (i, &b) in chunk.iter().enumerate() {
            word |= (b as u64) << i;
        }
        state = push_wide(word, 64, state.0, state.1, buf);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut word = 0u64;
        for (i, &b) in rem.iter().enumerate() {
            word |= (b as u64) << i;
        }
        state = push_wide(word, rem.len() as u32, state.0, state.1, buf);
    }
    state
}

/// Pack `nbits`-wide values (LSB-first concatenation, `nbits <= 32`)
/// into `buf`, continuing the stream tail; returns the new tail.
/// Multiple values are staged per u64 word (`⌊64 / nbits⌋` at a time).
/// Bit-identical to [`reference::pack_values`].
pub fn pack_values(
    vals: &[u32],
    nbits: u32,
    acc: u64,
    nacc: u32,
    buf: &mut Vec<u8>,
) -> (u64, u32) {
    debug_assert!(nacc < 8);
    debug_assert!(nbits <= 32);
    if nbits == 0 || vals.is_empty() {
        return (acc, nacc);
    }
    buf.reserve((nacc as usize + vals.len() * nbits as usize) / 8);
    let mask = if nbits == 32 {
        u32::MAX as u64
    } else {
        (1u64 << nbits) - 1
    };
    let per = (64 / nbits) as usize;
    let mut state = (acc, nacc);
    let mut chunks = vals.chunks_exact(per);
    for chunk in &mut chunks {
        let mut word = 0u64;
        let mut off = 0u32;
        for &v in chunk {
            word |= (v as u64 & mask) << off;
            off += nbits;
        }
        state = push_wide(word, off, state.0, state.1, buf);
    }
    for &v in chunks.remainder() {
        state = push_wide(v as u64 & mask, nbits, state.0, state.1, buf);
    }
    state
}

/// Unpack `d` bools from the LSB-first stream, continuing reader state
/// `(pos, acc, nacc)` (appends to `out`; returns the new state).
/// Consumes exactly the bits the bit-at-a-time reader would.
pub fn unpack_bools(
    buf: &[u8],
    mut pos: usize,
    mut acc: u64,
    mut nacc: u32,
    d: usize,
    out: &mut Vec<bool>,
) -> Result<(usize, u64, u32), OutOfBits> {
    out.reserve(d);
    let mut remaining = d;
    while remaining > 0 {
        while nacc <= 56 && pos < buf.len() {
            acc |= (buf[pos] as u64) << nacc;
            pos += 1;
            nacc += 8;
        }
        if nacc == 0 {
            return Err(OutOfBits);
        }
        let take = remaining.min(nacc as usize);
        for _ in 0..take {
            out.push(acc & 1 == 1);
            acc >>= 1;
        }
        nacc -= take as u32;
        remaining -= take;
    }
    Ok((pos, acc, nacc))
}

/// Unpack `d` values of `nbits` each (`nbits <= 32`), continuing reader
/// state `(pos, acc, nacc)`; appends to `out` and returns the new state.
pub fn unpack_values(
    buf: &[u8],
    mut pos: usize,
    mut acc: u64,
    mut nacc: u32,
    nbits: u32,
    d: usize,
    out: &mut Vec<u32>,
) -> Result<(usize, u64, u32), OutOfBits> {
    debug_assert!(nbits <= 32);
    if nbits == 0 {
        let fill = out.len() + d;
        out.resize(fill, 0);
        return Ok((pos, acc, nacc));
    }
    out.reserve(d);
    let mask = if nbits == 32 {
        u32::MAX as u64
    } else {
        (1u64 << nbits) - 1
    };
    let mut remaining = d;
    while remaining > 0 {
        while nacc <= 56 && pos < buf.len() {
            acc |= (buf[pos] as u64) << nacc;
            pos += 1;
            nacc += 8;
        }
        if nacc < nbits {
            return Err(OutOfBits);
        }
        let take = remaining.min((nacc / nbits) as usize);
        for _ in 0..take {
            out.push((acc & mask) as u32);
            acc >>= nbits;
        }
        nacc -= take as u32 * nbits;
        remaining -= take;
    }
    Ok((pos, acc, nacc))
}

// ---------------------------------------------------------------------------
// AVX2 fast paths
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Batch dequantize(-accumulate) with level-table gathers.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available, every index is
    /// `< levels.len()`, and the three input slices share `out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize(
        norm: f32,
        negative: &[bool],
        indices: &[u32],
        levels: &[f32],
        out: &mut [f32],
        accumulate: bool,
    ) {
        let d = out.len();
        let nv = _mm256_set1_ps(norm);
        let lev = levels.as_ptr();
        let neg = negative.as_ptr() as *const u8;
        let idx = indices.as_ptr();
        let dst = out.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= d {
            let iv = _mm256_loadu_si256(idx.add(i) as *const __m256i);
            let lv = _mm256_i32gather_ps::<4>(lev, iv);
            let mag = _mm256_mul_ps(nv, lv);
            // 0/1 sign bytes -> lane sign-bit masks; XOR is the exact
            // equivalent of the scalar `if neg { -mag } else { mag }`
            let nb = _mm_loadl_epi64(neg.add(i) as *const __m128i);
            let n32 = _mm256_cvtepu8_epi32(nb);
            let sign = _mm256_castsi256_ps(_mm256_slli_epi32::<31>(n32));
            let val = _mm256_xor_ps(mag, sign);
            if accumulate {
                let prev = _mm256_loadu_ps(dst.add(i));
                _mm256_storeu_ps(dst.add(i), _mm256_add_ps(prev, val));
            } else {
                _mm256_storeu_ps(dst.add(i), val);
            }
            i += 8;
        }
        while i < d {
            let mag = norm * levels[indices[i] as usize];
            let bits = mag.to_bits() ^ ((negative[i] as u32) << 31);
            if accumulate {
                out[i] += f32::from_bits(bits);
            } else {
                out[i] = f32::from_bits(bits);
            }
            i += 1;
        }
    }

    /// Batch LUT assignment: vector bin computation + LUT gather, scalar
    /// fix-up walk per lane.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available, `lut` is non-empty, and
    /// every `lut` value is `<= inner.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn assign_lut(
        inner: &[f32],
        lut: &[u32],
        scale: f32,
        r: &[f32],
        out: &mut Vec<u32>,
    ) {
        let d = r.len();
        out.clear();
        out.reserve(d);
        let sv = _mm256_set1_ps(scale);
        let zero = _mm256_setzero_si256();
        let top = _mm256_set1_epi32(lut.len() as i32 - 1);
        let rp = r.as_ptr();
        let lp = lut.as_ptr() as *const i32;
        let mut i = 0usize;
        while i + 8 <= d {
            let rv = _mm256_loadu_ps(rp.add(i));
            // trunc-cast matches the scalar `as usize` here: r >= 0 and
            // r*scale <= bins by construction; NaN truncates to i32::MIN
            // and the max-with-zero mirrors the scalar saturate-to-0
            let b = _mm256_cvttps_epi32(_mm256_mul_ps(rv, sv));
            let b = _mm256_min_epi32(_mm256_max_epi32(b, zero), top);
            let j8 = _mm256_i32gather_epi32::<4>(lp, b);
            let mut js = [0i32; 8];
            _mm256_storeu_si256(js.as_mut_ptr() as *mut __m256i, j8);
            for (lane, &j0) in js.iter().enumerate() {
                let ri = r[i + lane];
                let mut j = j0 as usize;
                while j < inner.len() && inner[j] < ri {
                    j += 1;
                }
                out.push(j as u32);
            }
            i += 8;
        }
        let tail_top = lut.len() - 1;
        while i < d {
            let ri = r[i];
            let b = ((ri * scale) as usize).min(tail_top);
            let mut j = lut[b] as usize;
            while j < inner.len() && inner[j] < ri {
                j += 1;
            }
            out.push(j as u32);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// scalar reference (property-test oracle, bench baseline)
// ---------------------------------------------------------------------------

/// The original per-element loops, unchanged: every batch kernel above
/// must match these bit for bit on any input. Kept public so the
/// property tests and `benches/micro_quant.rs` can drive them directly.
pub mod reference {
    use super::OutOfBits;

    /// Per-element dequantize (the historical `dequantize_into` loop).
    pub fn dequantize_into(
        norm: f32,
        negative: &[bool],
        indices: &[u32],
        levels: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), indices.len());
        for i in 0..out.len() {
            let mag = norm * levels[indices[i] as usize];
            out[i] = if negative[i] { -mag } else { mag };
        }
    }

    /// Per-element dequantize-accumulate.
    pub fn dequantize_accumulate(
        norm: f32,
        negative: &[bool],
        indices: &[u32],
        levels: &[f32],
        acc: &mut [f32],
    ) {
        assert_eq!(acc.len(), indices.len());
        for i in 0..acc.len() {
            let mag = norm * levels[indices[i] as usize];
            acc[i] += if negative[i] { -mag } else { mag };
        }
    }

    /// Per-element LUT assignment (the historical `assign_fast` walk).
    pub fn assign_lut_slice(
        inner: &[f32],
        lut: &[u32],
        scale: f32,
        r: &[f32],
        out: &mut Vec<u32>,
    ) {
        out.clear();
        let top = lut.len() - 1;
        out.extend(r.iter().map(|&ri| {
            let b = ((ri * scale) as usize).min(top);
            let mut j = lut[b] as usize;
            while j < inner.len() && inner[j] < ri {
                j += 1;
            }
            j as u32
        }));
    }

    /// Per-element QSGD stochastic rounding with pre-drawn uniforms.
    pub fn qsgd_assign_slice(
        v: &[f32],
        norm: f32,
        s: u32,
        u: &[f32],
        out: &mut Vec<u32>,
    ) {
        assert_eq!(u.len(), v.len());
        out.clear();
        let scale = (s - 1) as f32;
        for (&x, &ui) in v.iter().zip(u) {
            let ri = if norm > 0.0 { x.abs() / norm } else { 0.0 };
            let xq = (ri * scale).clamp(0.0, scale);
            let lo = xq.floor();
            let frac = xq - lo;
            let up = (ui < frac) as u32;
            out.push((lo as u32 + up).min(s - 1));
        }
    }

    /// Bit-at-a-time bool packing (the historical `write_bit` loop).
    pub fn pack_bools(
        bits: &[bool],
        mut acc: u64,
        mut nacc: u32,
        buf: &mut Vec<u8>,
    ) -> (u64, u32) {
        for &b in bits {
            acc |= (b as u64) << nacc;
            nacc += 1;
            while nacc >= 8 {
                buf.push(acc as u8);
                acc >>= 8;
                nacc -= 8;
            }
        }
        (acc, nacc)
    }

    /// Value-at-a-time packing (the historical `write_bits` loop).
    pub fn pack_values(
        vals: &[u32],
        nbits: u32,
        mut acc: u64,
        mut nacc: u32,
        buf: &mut Vec<u8>,
    ) -> (u64, u32) {
        if nbits == 0 {
            return (acc, nacc);
        }
        let mask = if nbits == 32 {
            u32::MAX as u64
        } else {
            (1u64 << nbits) - 1
        };
        for &v in vals {
            acc |= (v as u64 & mask) << nacc;
            nacc += nbits;
            while nacc >= 8 {
                buf.push(acc as u8);
                acc >>= 8;
                nacc -= 8;
            }
        }
        (acc, nacc)
    }

    /// Bit-at-a-time bool unpacking (the historical `read_bit` loop).
    pub fn unpack_bools(
        buf: &[u8],
        mut pos: usize,
        mut acc: u64,
        mut nacc: u32,
        d: usize,
        out: &mut Vec<bool>,
    ) -> Result<(usize, u64, u32), OutOfBits> {
        for _ in 0..d {
            while nacc < 1 {
                if pos >= buf.len() {
                    return Err(OutOfBits);
                }
                acc |= (buf[pos] as u64) << nacc;
                pos += 1;
                nacc += 8;
            }
            out.push(acc & 1 == 1);
            acc >>= 1;
            nacc -= 1;
        }
        Ok((pos, acc, nacc))
    }

    /// Value-at-a-time unpacking (the historical `read_bits` loop).
    pub fn unpack_values(
        buf: &[u8],
        mut pos: usize,
        mut acc: u64,
        mut nacc: u32,
        nbits: u32,
        d: usize,
        out: &mut Vec<u32>,
    ) -> Result<(usize, u64, u32), OutOfBits> {
        if nbits == 0 {
            let fill = out.len() + d;
            out.resize(fill, 0);
            return Ok((pos, acc, nacc));
        }
        let mask = if nbits == 32 {
            u32::MAX as u64
        } else {
            (1u64 << nbits) - 1
        };
        for _ in 0..d {
            while nacc < nbits {
                if pos >= buf.len() {
                    return Err(OutOfBits);
                }
                acc |= (buf[pos] as u64) << nacc;
                pos += 1;
                nacc += 8;
            }
            out.push((acc & mask) as u32);
            acc >>= nbits;
            nacc -= nbits;
        }
        Ok((pos, acc, nacc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn prop_dequantize_matches_reference() {
        check("dequantize kernel == reference", 60, |g| {
            let d = g.usize_in(0..700);
            let s = g.usize_in(1..65);
            let norm = g.f32_in(0.0..10.0);
            let levels: Vec<f32> =
                (0..s).map(|j| j as f32 / s as f32).collect();
            let mut rng = Rng::new(g.seed);
            let indices: Vec<u32> =
                (0..d).map(|_| rng.below(s) as u32).collect();
            let negative: Vec<bool> =
                (0..d).map(|_| rng.next_u64() & 1 == 1).collect();
            let mut want = vec![0.0f32; d];
            reference::dequantize_into(
                norm, &negative, &indices, &levels, &mut want,
            );
            let mut got = vec![0.0f32; d];
            dequantize_into(norm, &negative, &indices, &levels, &mut got);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // fused accumulate == dequantize + add
            let base: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            let mut acc_want = base.clone();
            add_assign(&mut acc_want, &want);
            let mut acc_got = base;
            dequantize_accumulate(
                norm, &negative, &indices, &levels, &mut acc_got,
            );
            for (a, b) in acc_want.iter().zip(&acc_got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn prop_assign_lut_matches_reference() {
        check("assign_lut kernel == reference", 60, |g| {
            let s = g.usize_in(2..65);
            let bins = *g.pick(&[16usize, 256, 8192]);
            let range = g.f32_in(0.01..2.0);
            let mut rng = Rng::new(g.seed);
            // ascending interior table inside [0, range]
            let mut inner: Vec<f32> = (0..s - 1)
                .map(|_| rng.uniform_f32() * range)
                .collect();
            inner.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut lut = Vec::new();
            build_count_lut(&inner, range, bins, &mut lut);
            let scale = bins as f32 / range;
            let d = g.usize_in(0..900);
            let r: Vec<f32> =
                (0..d).map(|_| rng.uniform_f32() * range).collect();
            let mut want = Vec::new();
            reference::assign_lut_slice(&inner, &lut, scale, &r, &mut want);
            let mut got = Vec::new();
            assign_lut_slice(&inner, &lut, scale, &r, &mut got);
            assert_eq!(want, got);
            // the LUT walk equals a direct count of inner < r
            for (&ri, &j) in r.iter().zip(&want) {
                let direct =
                    inner.iter().filter(|&&b| b < ri).count() as u32;
                assert_eq!(j, direct, "ri={ri}");
            }
        });
    }

    #[test]
    fn prop_qsgd_kernel_matches_reference() {
        check("qsgd kernel == reference", 60, |g| {
            let s = *g.pick(&[2usize, 3, 8, 64]) as u32;
            let v = g.vec_normal(0..600, 1.0);
            let norm = crate::util::stats::l2_norm(&v) as f32;
            let mut rng = Rng::new(g.seed);
            let mut u = vec![0.0f32; v.len()];
            rng.fill_uniform_f32(&mut u);
            let mut want = Vec::new();
            reference::qsgd_assign_slice(&v, norm, s, &u, &mut want);
            let mut got = Vec::new();
            qsgd_assign_slice(&v, norm, s, &u, &mut got);
            assert_eq!(want, got);
        });
    }

    #[test]
    fn prop_pack_matches_reference_and_roundtrips() {
        check("word packer == bit packer", 80, |g| {
            let nbits = g.usize_in(1..33) as u32;
            let n = g.usize_in(0..500);
            let mut rng = Rng::new(g.seed);
            let vals: Vec<u32> = (0..n)
                .map(|_| (rng.next_u64() as u32) & mask32(nbits))
                .collect();
            let bools: Vec<bool> =
                (0..n).map(|_| rng.next_u64() & 1 == 1).collect();
            // random starting tail state, as mid-message packing sees
            let nacc0 = (rng.next_u64() % 8) as u32;
            let acc0 = rng.next_u64() & ((1u64 << nacc0.max(1)) - 1);
            let acc0 = if nacc0 == 0 { 0 } else { acc0 };

            let mut want_buf = Vec::new();
            let st =
                reference::pack_bools(&bools, acc0, nacc0, &mut want_buf);
            let st = reference::pack_values(
                &vals, nbits, st.0, st.1, &mut want_buf,
            );
            finish(st, &mut want_buf);

            let mut got_buf = Vec::new();
            let st = pack_bools(&bools, acc0, nacc0, &mut got_buf);
            let st = pack_values(&vals, nbits, st.0, st.1, &mut got_buf);
            finish(st, &mut got_buf);
            assert_eq!(want_buf, got_buf, "nbits={nbits} n={n}");

            // word-wise unpack returns the original items (skipping the
            // synthetic tail seed first)
            let mut seed_bits = Vec::new();
            let state = unpack_values(
                &got_buf,
                0,
                0,
                0,
                nacc0,
                usize::from(nacc0 > 0),
                &mut seed_bits,
            )
            .unwrap();
            let mut back_bools = Vec::new();
            let state = unpack_bools(
                &got_buf, state.0, state.1, state.2, n, &mut back_bools,
            )
            .unwrap();
            let mut back_vals = Vec::new();
            unpack_values(
                &got_buf, state.0, state.1, state.2, nbits, n,
                &mut back_vals,
            )
            .unwrap();
            assert_eq!(back_bools, bools);
            assert_eq!(back_vals, vals);
        });
    }

    /// Consumed bits implied by a reader state (bytes read minus staged).
    fn bit_cursor(state: (usize, u64, u32)) -> usize {
        state.0 * 8 - state.2 as usize
    }

    fn mask32(nbits: u32) -> u32 {
        if nbits == 32 {
            u32::MAX
        } else {
            (1u32 << nbits) - 1
        }
    }

    fn finish(state: (u64, u32), buf: &mut Vec<u8>) {
        if state.1 > 0 {
            buf.push(state.0 as u8);
        }
    }

    #[test]
    fn prop_unpack_matches_reference() {
        check("word unpacker == bit unpacker", 60, |g| {
            let nbits = g.usize_in(1..25) as u32;
            let len = g.usize_in(0..200);
            let mut rng = Rng::new(g.seed);
            let buf: Vec<u8> =
                (0..len).map(|_| rng.next_u64() as u8).collect();
            let d = g.usize_in(0..300);
            let mut want = Vec::new();
            let ref_res = reference::unpack_values(
                &buf, 0, 0, 0, nbits, d, &mut want,
            );
            let mut got = Vec::new();
            let got_res = unpack_values(&buf, 0, 0, 0, nbits, d, &mut got);
            assert_eq!(ref_res.is_ok(), got_res.is_ok());
            if let (Ok(a), Ok(b)) = (ref_res, got_res) {
                // the word unpacker prefetches bytes into `acc` more
                // greedily, so compare the logical bit cursor, not the
                // raw staging state
                assert_eq!(bit_cursor(a), bit_cursor(b), "cursor diverged");
                assert_eq!(want, got);
            }
            let mut want_b = Vec::new();
            let ref_res =
                reference::unpack_bools(&buf, 0, 0, 0, d, &mut want_b);
            let mut got_b = Vec::new();
            let got_res = unpack_bools(&buf, 0, 0, 0, d, &mut got_b);
            assert_eq!(ref_res.is_ok(), got_res.is_ok());
            if let (Ok(a), Ok(b)) = (ref_res, got_res) {
                assert_eq!(bit_cursor(a), bit_cursor(b));
                assert_eq!(want_b, got_b);
            }
        });
    }

    #[test]
    fn prop_magnitude_prepass_matches_per_element() {
        check("magnitude prepass == per-element", 40, |g| {
            let v = g.vec_normal(0..500, 2.0);
            let norm = crate::util::stats::l2_norm(&v) as f32;
            for flip in [1.0f32, 0.0] {
                let norm = norm * flip; // exercise the zero-norm gate
                let mut out = Vec::new();
                normalized_magnitudes_into(&v, norm, &mut out);
                for (&x, &got) in v.iter().zip(&out) {
                    let want = crate::quant::normalized_magnitude(x, norm);
                    assert_eq!(want.to_bits(), got.to_bits());
                }
                let mut outc = Vec::new();
                normalized_magnitudes_clamped_into(&v, norm, &mut outc);
                for (&x, &got) in v.iter().zip(&outc) {
                    let want = crate::quant::normalized_magnitude(x, norm)
                        .clamp(0.0, 1.0);
                    assert_eq!(want.to_bits(), got.to_bits());
                }
            }
        });
    }

    #[test]
    fn build_count_lut_counts_below_edges() {
        let inner = [0.1f32, 0.4, 0.4001, 0.9];
        let mut lut = Vec::new();
        build_count_lut(&inner, 1.0, 10, &mut lut);
        assert_eq!(lut.len(), 10);
        for (b, &c) in lut.iter().enumerate() {
            let edge = b as f32 * 0.1;
            let direct =
                inner.iter().filter(|&&x| x < edge).count() as u32;
            assert_eq!(c, direct, "bin {b}");
        }
    }

    #[test]
    fn elementwise_helpers_match_loops() {
        let a: Vec<f32> = (0..100).map(|i| i as f32 * 0.31).collect();
        let b: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let mut dst = a.clone();
        add_assign(&mut dst, &b);
        for i in 0..100 {
            assert_eq!(dst[i].to_bits(), (a[i] + b[i]).to_bits());
        }
        let mut out = vec![0.0f32; 100];
        sub_into(&mut out, &a, &b);
        for i in 0..100 {
            assert_eq!(out[i].to_bits(), (a[i] - b[i]).to_bits());
        }
        let mut dst = a.clone();
        axpy(&mut dst, 0.37, &b);
        for i in 0..100 {
            assert_eq!(dst[i].to_bits(), (a[i] + 0.37 * b[i]).to_bits());
        }
        let mut dst = a.clone();
        add_delta(&mut dst, &b, &a);
        for i in 0..100 {
            assert_eq!(dst[i].to_bits(), (a[i] + (b[i] - a[i])).to_bits());
        }
        let mut out = vec![0.0f32; 100];
        scaled_into(&mut out, 2.5, &b);
        for i in 0..100 {
            assert_eq!(out[i].to_bits(), (2.5 * b[i]).to_bits());
        }
    }

    #[test]
    fn out_of_range_indices_panic_like_reference() {
        let res = std::panic::catch_unwind(|| {
            let mut out = vec![0.0f32; 2];
            dequantize_into(1.0, &[false, false], &[0, 7], &[0.5], &mut out);
        });
        assert!(res.is_err(), "OOB index must panic, not gather garbage");
    }
}
