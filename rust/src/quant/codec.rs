//! Wire codec: packs a [`QuantizedVector`] into an actual bitstream.
//!
//! The threaded DFL runtime (dfl::net) ships these bytes over channels, so
//! reported wire sizes are *measured*, not estimated. Format (little-endian
//! bit order within bytes):
//!
//! ```text
//! u32  d                 element count
//! u16  s                 level count
//! u8   flags             bit0: table present (1) or implied (0)
//!                        bit1: sparse body (1) or dense (0)
//! f32  norm
//! [f32; s]               level table   (only if table present)
//! -- dense body (flags bit1 = 0) --
//! d bits                 signs (1 = negative)
//! d * ceil_log2(s) bits  level indices
//! -- sparse body (flags bit1 = 1) --
//! u32  k                 listed (index != 0) element count
//! k entries, each:       position (ceil_log2(d) bits, strictly
//!                        increasing), sign (1 bit), level index
//!                        (ceil_log2(s) bits, never 0)
//! -- either body --
//! padding to byte
//! ```
//!
//! The encoding is *canonical*: a message uses the sparse body exactly
//! when [`sparse_nnz`] says it may (level 0 is +0.0, every unlisted
//! element is an index-0/positive-sign slot, `d` is within
//! [`MAX_SPARSE_DIM`], and the sparse form is strictly smaller than the
//! dense one). Decoders enforce the same rule in both directions, so
//! every `QuantizedVector` has exactly one byte encoding and byte
//! meters can recompute message sizes from decoded content
//! ([`body_bits`]). Sparsifiers (top-k, TernGrad) emit index-0 slots
//! for dropped coordinates, which is what makes their messages
//! sparse-eligible.

use super::QuantizedVector;
use crate::quant::bits::{ceil_log2, stream_bytes};
use crate::quant::kernels;

/// Total-decode failure. Decoding never panics on hostile bytes; every
/// malformed input maps to one of these variants, so callers (and the
/// [`crate::error::LmdflError::Codec`] wrapper) can match on truncation
/// vs version-mismatch vs structural corruption instead of parsing
/// message strings.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The stream ended before the format was satisfied, or the body
    /// claims more payload than the buffer holds. `have_bits` is 0 when
    /// the short side is an unbounded byte stream.
    Truncated { need_bits: u64, have_bits: u64 },
    /// The wire version byte is unknown to this decoder.
    Version { got: u8, want: u8 },
    /// Any other structural violation: unknown tag, inconsistent
    /// bit-width, bad length, out-of-range index.
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { need_bits, have_bits } => write!(
                f,
                "codec error: truncated stream (needs {need_bits} more \
                 bits, {have_bits} available)"
            ),
            CodecError::Version { got, want } => write!(
                f,
                "codec error: unsupported wire version {got} \
                 (expected {want})"
            ),
            CodecError::Malformed(msg) => {
                write!(f, "codec error: {msg}")
            }
        }
    }
}

impl std::error::Error for CodecError {}

/// Bit-level writer, LSB-first within each byte. Word-wise accumulator —
/// bits are staged in a u64 and flushed a byte at a time, so `write_bits`
/// is O(bytes), not O(bits) (the encode hot path; see DESIGN.md §Perf).
/// The bulk entry points ([`write_bools`](BitWriter::write_bools),
/// [`write_packed`](BitWriter::write_packed)) run the u64 word-at-a-time
/// packer from [`crate::quant::kernels`] — identical bitstream, several
/// values per staged word — and
/// [`with_capacity_bits`](BitWriter::with_capacity_bits) preallocates
/// from the exact `encoded_bits` size instead of growing.
pub struct BitWriter {
    buf: Vec<u8>,
    /// staged bits (LSB-first), `nacc` of them valid
    acc: u64,
    nacc: u32,
    bitpos: usize,
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl BitWriter {
    pub fn new() -> Self {
        Self::with_buf(Vec::new())
    }

    /// Writer over a caller-owned buffer (cleared first) — the zero-alloc
    /// encode path reuses one buffer across messages.
    pub fn with_buf(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter { buf, acc: 0, nacc: 0, bitpos: 0 }
    }

    /// Writer over a caller-owned buffer, preallocated for a known
    /// message size (`encoded_bits`): the encode path grows the buffer
    /// at most once, up front, instead of amortized doubling.
    pub fn with_capacity_bits(buf: Vec<u8>, bits: u64) -> Self {
        let mut w = Self::with_buf(buf);
        w.buf.reserve(stream_bytes(bits));
        w
    }

    #[inline]
    fn flush_bytes(&mut self) {
        while self.nacc >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nacc -= 8;
        }
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write the low `nbits` of `value`.
    #[inline]
    pub fn write_bits(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 56, "write_bits supports up to 56 bits");
        let value = if nbits == 0 {
            return;
        } else {
            value & (u64::MAX >> (64 - nbits))
        };
        // nacc < 8 after every flush, so nacc + nbits <= 63 always fits
        self.acc |= value << self.nacc;
        self.nacc += nbits;
        self.bitpos += nbits as usize;
        self.flush_bytes();
    }

    /// Append a bool slice (1 bit each) via the u64 word-at-a-time
    /// packer — same bitstream as repeated [`write_bit`](Self::write_bit)
    /// calls, ~64 bits per staged word instead of one.
    pub fn write_bools(&mut self, bits: &[bool]) {
        let (acc, nacc) =
            kernels::pack_bools(bits, self.acc, self.nacc, &mut self.buf);
        self.acc = acc;
        self.nacc = nacc;
        self.bitpos += bits.len();
    }

    /// Append `nbits`-wide values (`nbits <= 32`) via the word-at-a-time
    /// packer — same bitstream as repeated
    /// [`write_bits`](Self::write_bits) calls.
    pub fn write_packed(&mut self, vals: &[u32], nbits: u32) {
        let (acc, nacc) = kernels::pack_values(
            vals, nbits, self.acc, self.nacc, &mut self.buf,
        );
        self.acc = acc;
        self.nacc = nacc;
        self.bitpos += vals.len() * nbits as usize;
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write_bits(v as u64, 8);
    }

    pub fn write_u16(&mut self, v: u16) {
        self.write_bits(v as u64, 16);
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write_bits(v as u64, 32);
    }

    pub fn write_f32(&mut self, v: f32) {
        self.write_u32(v.to_bits());
    }

    pub fn bit_len(&self) -> usize {
        self.bitpos
    }

    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.nacc > 0 {
            self.buf.push(self.acc as u8);
        }
        self.buf
    }
}

/// Bit-level reader matching [`BitWriter`] — same u64 staging.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// next unread byte
    pos: usize,
    acc: u64,
    nacc: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nacc: 0 }
    }

    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, CodecError> {
        Ok(self.read_bits(1)? == 1)
    }

    #[inline]
    pub fn read_bits(&mut self, nbits: u32) -> Result<u64, CodecError> {
        debug_assert!(nbits <= 56);
        while self.nacc < nbits {
            if self.pos >= self.buf.len() {
                return Err(CodecError::Truncated {
                    need_bits: nbits as u64,
                    have_bits: self.nacc as u64,
                });
            }
            self.acc |= (self.buf[self.pos] as u64) << self.nacc;
            self.pos += 1;
            self.nacc += 8;
        }
        if nbits == 0 {
            return Ok(0);
        }
        let v = self.acc & (u64::MAX >> (64 - nbits));
        self.acc >>= nbits;
        self.nacc -= nbits;
        Ok(v)
    }

    /// Append `d` sign bits to `out` via the word-at-a-time unpacker —
    /// consumes exactly the bits repeated
    /// [`read_bit`](Self::read_bit) calls would.
    pub fn read_bools_into(
        &mut self,
        d: usize,
        out: &mut Vec<bool>,
    ) -> Result<(), CodecError> {
        let (pos, acc, nacc) = kernels::unpack_bools(
            self.buf, self.pos, self.acc, self.nacc, d, out,
        )
        .map_err(|_| CodecError::Truncated {
            need_bits: d as u64,
            have_bits: self.bits_remaining(),
        })?;
        self.pos = pos;
        self.acc = acc;
        self.nacc = nacc;
        Ok(())
    }

    /// Append `d` values of `nbits` each (`nbits <= 32`) to `out` via
    /// the word-at-a-time unpacker.
    pub fn read_packed_into(
        &mut self,
        nbits: u32,
        d: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), CodecError> {
        let (pos, acc, nacc) = kernels::unpack_values(
            self.buf, self.pos, self.acc, self.nacc, nbits, d, out,
        )
        .map_err(|_| CodecError::Truncated {
            need_bits: d as u64 * nbits as u64,
            have_bits: self.bits_remaining(),
        })?;
        self.pos = pos;
        self.acc = acc;
        self.nacc = nacc;
        Ok(())
    }

    /// Unread bits left in the stream (staged + unconsumed bytes).
    pub fn bits_remaining(&self) -> u64 {
        (self.buf.len() - self.pos) as u64 * 8 + self.nacc as u64
    }

    pub fn read_u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.read_bits(8)? as u8)
    }

    pub fn read_u16(&mut self) -> Result<u16, CodecError> {
        Ok(self.read_bits(16)? as u16)
    }

    pub fn read_u32(&mut self) -> Result<u32, CodecError> {
        Ok(self.read_bits(32)? as u32)
    }

    pub fn read_f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.read_u32()?))
    }
}

/// Largest element count a sparse body may claim. Decoding a sparse
/// body materializes `d`-length index/sign vectors from a payload that
/// is only O(k) bytes, so — unlike the dense body, whose `d` is bounded
/// by the payload itself — a hostile `d` must be capped explicitly
/// before any allocation.
pub const MAX_SPARSE_DIM: usize = 1 << 24;

/// Exact encoded size in bits of the *dense* body for
/// (d, s, implied_table).
pub fn encoded_bits(d: usize, s: usize, implied_table: bool) -> u64 {
    let header = 32 + 16 + 8 + 32u64;
    let table = if implied_table { 0 } else { 32 * s as u64 };
    let signs = d as u64;
    let indices = d as u64 * ceil_log2(s) as u64;
    let total = header + table + signs + indices;
    // padding to byte boundary
    (total + 7) / 8 * 8
}

/// Bit-width of one sparse-body position field for dimension `d`.
#[inline]
fn pos_bits(d: usize) -> u32 {
    if d <= 1 {
        0
    } else {
        ceil_log2(d)
    }
}

/// Exact encoded size in bits of the *sparse* body for
/// (d, s, implied_table) carrying `k` listed elements.
pub fn sparse_encoded_bits(
    d: usize,
    s: usize,
    implied_table: bool,
    k: usize,
) -> u64 {
    let header = 32 + 16 + 8 + 32u64;
    let table = if implied_table { 0 } else { 32 * s as u64 };
    let count = 32u64;
    let entry = pos_bits(d) as u64 + 1 + ceil_log2(s) as u64;
    let total = header + table + count + k as u64 * entry;
    (total + 7) / 8 * 8
}

/// `Some(k)` (the listed-element count) when the canonical encoding of
/// `qv` is the sparse body; `None` when it is the dense one. Sparse is
/// chosen exactly when level 0 is +0.0, every index-0 element carries a
/// positive sign (so unlisted elements reconstruct bit-exactly), `d`
/// fits [`MAX_SPARSE_DIM`], and the sparse form is strictly smaller.
pub fn sparse_nnz(qv: &QuantizedVector) -> Option<usize> {
    let d = qv.dim();
    if d == 0 || d > MAX_SPARSE_DIM {
        return None;
    }
    if qv.levels.first().map(|l| l.to_bits()) != Some(0) {
        return None;
    }
    let mut k = 0usize;
    for (&idx, &neg) in qv.indices.iter().zip(&qv.negative) {
        if idx == 0 {
            if neg {
                return None;
            }
        } else {
            k += 1;
        }
    }
    let s = qv.s();
    if sparse_encoded_bits(d, s, qv.implied_table, k)
        < encoded_bits(d, s, qv.implied_table)
    {
        Some(k)
    } else {
        None
    }
}

/// Exact encoded size in bits of the canonical body for `qv` — the
/// sparse form when [`sparse_nnz`] elects it, the dense form otherwise.
pub fn body_bits(qv: &QuantizedVector) -> u64 {
    match sparse_nnz(qv) {
        Some(k) => {
            sparse_encoded_bits(qv.dim(), qv.s(), qv.implied_table, k)
        }
        None => encoded_bits(qv.dim(), qv.s(), qv.implied_table),
    }
}

/// Encode a quantized vector to bytes.
pub fn encode(qv: &QuantizedVector) -> Vec<u8> {
    encode_with_buf(qv, Vec::new())
}

/// Zero-alloc [`encode`]: reuse `out` as the backing buffer (the encoded
/// bytes land in the returned `Vec`, which is `out`'s storage, grown at
/// most once to the message size). Callers in the threaded runtime swap
/// the buffer back in after shipping the bytes.
pub fn encode_with_buf(qv: &QuantizedVector, out: Vec<u8>) -> Vec<u8> {
    // preallocate the exact message size so the buffer grows at most once
    let mut w = BitWriter::with_capacity_bits(out, body_bits(qv));
    encode_body(&mut w, qv);
    w.into_bytes()
}

/// Write the self-describing message body (d, s, flags, norm, optional
/// level table, then the dense or sparse element stream — whichever the
/// canonical rule [`sparse_nnz`] elects) into `w`. Shared by the bare
/// [`encode`] framing and the versioned transport frames of
/// [`crate::quant::wire`], so the two formats cannot drift.
pub fn encode_body(w: &mut BitWriter, qv: &QuantizedVector) {
    let sparse = sparse_nnz(qv);
    w.write_u32(qv.dim() as u32);
    w.write_u16(qv.s() as u16);
    let mut flags = if qv.implied_table { 0u8 } else { 1 };
    if sparse.is_some() {
        flags |= 2;
    }
    w.write_u8(flags);
    w.write_f32(qv.norm);
    if !qv.implied_table {
        for &l in &qv.levels {
            w.write_f32(l);
        }
    }
    if sparse.is_some() {
        let pbits = pos_bits(qv.dim());
        let ibits = ceil_log2(qv.s());
        let k = qv.indices.iter().filter(|&&i| i != 0).count();
        w.write_u32(k as u32);
        for (p, &idx) in qv.indices.iter().enumerate() {
            if idx == 0 {
                continue;
            }
            w.write_bits(p as u64, pbits);
            w.write_bit(qv.negative[p]);
            w.write_bits(idx as u64, ibits);
        }
    } else {
        // signs and indices are the bulk of the stream: word-at-a-time
        w.write_bools(&qv.negative);
        w.write_packed(&qv.indices, ceil_log2(qv.s()));
    }
}

/// Decode. `implied_levels` supplies the level table when the flag says it
/// was not shipped (fixed-grid quantizers): callback from s -> table.
pub fn decode(
    bytes: &[u8],
    implied_levels: impl Fn(usize) -> Vec<f32>,
) -> Result<QuantizedVector, CodecError> {
    let mut out = QuantizedVector::empty();
    decode_into(
        bytes,
        |s, table: &mut Vec<f32>| *table = implied_levels(s),
        &mut out,
    )?;
    Ok(out)
}

/// Zero-alloc [`decode`]: parse into an existing message buffer, reusing
/// its vectors (the threaded runtime's per-message receive path).
/// `fill_implied` writes the implied level table into the provided
/// (cleared) buffer when the message did not ship one. On error `out`
/// may be partially overwritten — discard it.
pub fn decode_into(
    bytes: &[u8],
    fill_implied: impl FnMut(usize, &mut Vec<f32>),
    out: &mut QuantizedVector,
) -> Result<(), CodecError> {
    let mut r = BitReader::new(bytes);
    decode_body(&mut r, fill_implied, out)
}

/// Parse the message body (see [`encode_body`]) from `r`'s current
/// position. On error `out` may be partially overwritten — discard it.
pub fn decode_body(
    r: &mut BitReader<'_>,
    mut fill_implied: impl FnMut(usize, &mut Vec<f32>),
    out: &mut QuantizedVector,
) -> Result<(), CodecError> {
    let d = r.read_u32()? as usize;
    let s = r.read_u16()? as usize;
    if s == 0 {
        return Err(CodecError::Malformed("s must be >= 1".into()));
    }
    let flags = r.read_u8()?;
    if flags > 3 {
        return Err(CodecError::Malformed(format!(
            "unknown flag bits 0x{flags:02x}"
        )));
    }
    let has_table = flags & 1 == 1;
    let sparse = flags & 2 != 0;
    out.norm = r.read_f32()?;
    if sparse && d > MAX_SPARSE_DIM {
        // a sparse body's payload is O(k), so d must be capped before
        // the d-sized materialization below — the dense payload bound
        // cannot protect this branch
        return Err(CodecError::Malformed(format!(
            "sparse body claims d={d} (cap {MAX_SPARSE_DIM})"
        )));
    }
    // bound the claimed payload BEFORE any d-sized reservation: a
    // corrupt/hostile d (u32, up to ~4e9) must fail here, not drive a
    // multi-gigabyte allocation on its way to "out of bits"
    let table_bits = if has_table { 32 * s as u64 } else { 0 };
    let need = if sparse {
        table_bits + 32
    } else {
        table_bits + d as u64 * (1 + ceil_log2(s) as u64)
    };
    if need > r.bits_remaining() {
        return Err(CodecError::Truncated {
            need_bits: need,
            have_bits: r.bits_remaining(),
        });
    }
    out.levels.clear();
    if has_table {
        out.levels.reserve(s);
        for _ in 0..s {
            out.levels.push(r.read_f32()?);
        }
    } else {
        fill_implied(s, &mut out.levels);
        if out.levels.len() != s {
            return Err(CodecError::Malformed(format!(
                "implied table has {} levels, message says {s}",
                out.levels.len()
            )));
        }
    }
    let idx_bits = ceil_log2(s);
    if sparse {
        if out.levels[0].to_bits() != 0 {
            return Err(CodecError::Malformed(
                "sparse body requires level 0 == +0.0".into(),
            ));
        }
        let k = r.read_u32()? as usize;
        if k > d {
            return Err(CodecError::Malformed(format!(
                "sparse body lists k={k} of d={d} elements"
            )));
        }
        let pbits = pos_bits(d);
        let entry_bits = pbits as u64 + 1 + idx_bits as u64;
        let need = k as u64 * entry_bits;
        if need > r.bits_remaining() {
            return Err(CodecError::Truncated {
                need_bits: need,
                have_bits: r.bits_remaining(),
            });
        }
        out.negative.clear();
        out.negative.resize(d, false);
        out.indices.clear();
        out.indices.resize(d, 0);
        let mut prev: i64 = -1;
        for _ in 0..k {
            let p = r.read_bits(pbits)? as usize;
            if (p as i64) <= prev || p >= d {
                return Err(CodecError::Malformed(format!(
                    "sparse position {p} not strictly increasing in \
                     range d={d}"
                )));
            }
            let neg = r.read_bit()?;
            let idx = r.read_bits(idx_bits)? as u32;
            if idx == 0 || idx as usize >= s {
                return Err(CodecError::Malformed(format!(
                    "sparse level index {idx} out of range 1..{s}"
                )));
            }
            out.negative[p] = neg;
            out.indices[p] = idx;
            prev = p as i64;
        }
        // canonical-form enforcement: a sparse body that is not
        // strictly smaller than its dense equivalent has exactly one
        // other (dense) encoding and must use it
        if sparse_encoded_bits(d, s, !has_table, k)
            >= encoded_bits(d, s, !has_table)
        {
            return Err(CodecError::Malformed(
                "non-canonical sparse body: dense form is no larger"
                    .into(),
            ));
        }
    } else {
        out.negative.clear();
        r.read_bools_into(d, &mut out.negative)?;
        out.indices.clear();
        r.read_packed_into(idx_bits, d, &mut out.indices)?;
        // range-check after the bulk unpack (one vectorizable scan
        // instead of a branch per element)
        if let Some(&i) = out.indices.iter().find(|&&i| i as usize >= s)
        {
            return Err(CodecError::Malformed(format!(
                "index {i} out of range s={s}"
            )));
        }
    }
    out.implied_table = !has_table;
    if !sparse && sparse_nnz(out).is_some() {
        // the mirror of the check above: a dense body whose content
        // elects the sparse form is the non-canonical twin of a
        // shorter message
        return Err(CodecError::Malformed(
            "non-canonical dense body: sparse form is smaller".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{
        LloydMaxQuantizer, QsgdQuantizer, Quantizer, TernGradQuantizer,
        TopKQuantizer,
    };
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn bitwriter_reader_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bits(0b1011, 4);
        w.write_u8(0xAB);
        w.write_u16(0x1234);
        w.write_u32(0xDEADBEEF);
        w.write_f32(3.75);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit().unwrap());
        assert_eq!(r.read_bits(4).unwrap(), 0b1011);
        assert_eq!(r.read_u8().unwrap(), 0xAB);
        assert_eq!(r.read_u16().unwrap(), 0x1234);
        assert_eq!(r.read_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.read_f32().unwrap(), 3.75);
        // 93 payload bits were written → 3 zero padding bits remain in the
        // final byte, then the stream ends
        assert_eq!(r.read_bits(3).unwrap(), 0);
        assert!(r.read_bit().is_err());
    }

    #[test]
    fn encode_decode_roundtrip_with_table() {
        let mut q = LloydMaxQuantizer::new(8, 6);
        let mut rng = Rng::new(0);
        let v: Vec<f32> = (0..257).map(|i| ((i as f32) - 128.0) / 7.0).collect();
        let qv = q.quantize(&v, &mut rng);
        assert!(!qv.implied_table);
        let bytes = encode(&qv);
        assert_eq!(bytes.len() as u64 * 8, encoded_bits(257, 8, false));
        let back = decode(&bytes, |_| unreachable!()).unwrap();
        assert_eq!(back, qv);
        assert_eq!(back.dequantize(), qv.dequantize());
    }

    #[test]
    fn encode_decode_roundtrip_implied_table() {
        let mut q = QsgdQuantizer::new(16);
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let qv = q.quantize(&v, &mut rng);
        assert!(qv.implied_table);
        let bytes = encode(&qv);
        assert_eq!(bytes.len() as u64 * 8, encoded_bits(100, 16, true));
        let back =
            decode(&bytes, |s| QsgdQuantizer::level_table(s)).unwrap();
        assert_eq!(back, qv);
    }

    #[test]
    fn zero_alloc_paths_match_allocating_ones() {
        let mut q = LloydMaxQuantizer::new(8, 6);
        let mut rng = Rng::new(4);
        let v: Vec<f32> =
            (0..300).map(|i| (i as f32 * 0.37).cos()).collect();
        let qv = q.quantize(&v, &mut rng);
        let bytes = encode(&qv);
        // encode_with_buf reuses storage and produces identical bytes
        let buf = encode_with_buf(&qv, Vec::with_capacity(bytes.len()));
        assert_eq!(buf, bytes);
        let again = encode_with_buf(&qv, buf);
        assert_eq!(again, bytes);
        // decode_into matches decode, reusing the target's vectors
        let mut out = QuantizedVector::empty();
        decode_into(&bytes, |_, _| unreachable!(), &mut out).unwrap();
        assert_eq!(out, qv);
        decode_into(&bytes, |_, _| unreachable!(), &mut out).unwrap();
        assert_eq!(out, qv);
    }

    #[test]
    fn bulk_writes_match_per_bit_writes() {
        check("write_bools/packed == write_bit/bits", 40, |g| {
            let n = g.usize_in(0..300);
            let nbits = g.usize_in(0..25) as u32;
            let mut rng = Rng::new(g.seed);
            let bools: Vec<bool> =
                (0..n).map(|_| rng.next_u64() & 1 == 1).collect();
            let mask = if nbits == 0 { 0 } else { (1u64 << nbits) - 1 };
            let vals: Vec<u32> = (0..n)
                .map(|_| (rng.next_u64() & mask) as u32)
                .collect();
            // desync the byte boundary with a random-width header
            let head = g.usize_in(0..13) as u32;

            let mut a = BitWriter::new();
            a.write_bits(0x5A5, head);
            for &b in &bools {
                a.write_bit(b);
            }
            for &v in &vals {
                a.write_bits(v as u64, nbits);
            }
            let mut b = BitWriter::new();
            b.write_bits(0x5A5, head);
            b.write_bools(&bools);
            b.write_packed(&vals, nbits);
            assert_eq!(a.bit_len(), b.bit_len());
            assert_eq!(a.into_bytes(), b.into_bytes());
        });
    }

    #[test]
    fn bulk_reads_match_per_bit_reads() {
        check("read_bools/packed == read_bit/bits", 40, |g| {
            let n = g.usize_in(0..300);
            let nbits = g.usize_in(1..25) as u32;
            let head = g.usize_in(0..13) as u32;
            let mut rng = Rng::new(g.seed);
            let mut w = BitWriter::new();
            w.write_bits(0x123, head);
            let bools: Vec<bool> =
                (0..n).map(|_| rng.next_u64() & 1 == 1).collect();
            let mask = (1u64 << nbits) - 1;
            let vals: Vec<u32> = (0..n)
                .map(|_| (rng.next_u64() & mask) as u32)
                .collect();
            w.write_bools(&bools);
            w.write_packed(&vals, nbits);
            let bytes = w.into_bytes();

            let mut r1 = BitReader::new(&bytes);
            r1.read_bits(head).unwrap();
            let got_bools: Vec<bool> =
                (0..n).map(|_| r1.read_bit().unwrap()).collect();
            let got_vals: Vec<u32> = (0..n)
                .map(|_| r1.read_bits(nbits).unwrap() as u32)
                .collect();
            assert_eq!(got_bools, bools);
            assert_eq!(got_vals, vals);

            let mut r2 = BitReader::new(&bytes);
            r2.read_bits(head).unwrap();
            let mut bulk_bools = Vec::new();
            r2.read_bools_into(n, &mut bulk_bools).unwrap();
            let mut bulk_vals = Vec::new();
            r2.read_packed_into(nbits, n, &mut bulk_vals).unwrap();
            assert_eq!(bulk_bools, bools);
            assert_eq!(bulk_vals, vals);
        });
    }

    #[test]
    fn encode_preallocates_exactly_once() {
        let mut q = LloydMaxQuantizer::new(16, 6);
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let qv = q.quantize(&v, &mut rng);
        let need = (encoded_bits(qv.dim(), qv.s(), qv.implied_table) / 8)
            as usize;
        let bytes = encode(&qv);
        assert_eq!(bytes.len(), need);
        // a fresh buffer is reserved up front: capacity never exceeds a
        // single exact reservation (no amortized doubling overshoot)
        assert!(
            bytes.capacity() >= need && bytes.capacity() <= need * 2,
            "capacity {} for {} bytes suggests growth-by-doubling",
            bytes.capacity(),
            need
        );
    }

    #[test]
    fn topk_messages_take_the_sparse_body_and_roundtrip() {
        let mut q = TopKQuantizer::new(0.05);
        let mut rng = Rng::new(7);
        let v: Vec<f32> =
            (0..800).map(|i| (i as f32 * 0.71).sin() * 0.3).collect();
        let qv = q.quantize(&v, &mut rng);
        let k = sparse_nnz(&qv).expect("top-k message is sparse-eligible");
        assert_eq!(k, qv.indices.iter().filter(|&&i| i != 0).count());
        let bytes = encode(&qv);
        assert_eq!(
            bytes.len() as u64 * 8,
            sparse_encoded_bits(qv.dim(), qv.s(), false, k)
        );
        assert!(
            (bytes.len() as u64 * 8) < encoded_bits(qv.dim(), qv.s(), false),
            "sparse body must beat the dense one at keep=0.05"
        );
        let back = decode(&bytes, |_| unreachable!()).unwrap();
        assert_eq!(back, qv);
        assert_eq!(back.dequantize(), qv.dequantize());
    }

    #[test]
    fn terngrad_messages_roundtrip_whichever_body_wins() {
        let mut q = TernGradQuantizer::new();
        let mut rng = Rng::new(8);
        // mostly-small coordinates → few survivors → sparse wins
        let v: Vec<f32> = (0..600)
            .map(|i| if i % 97 == 0 { 1.0 } else { 1e-3 })
            .collect();
        let qv = q.quantize(&v, &mut rng);
        let bytes = encode(&qv);
        assert_eq!(bytes.len() as u64 * 8, body_bits(&qv));
        let back = decode(&bytes, |_| unreachable!()).unwrap();
        assert_eq!(back, qv);
    }

    #[test]
    fn empty_topk_message_still_encodes_a_body() {
        // a zero vector keeps nothing: k = 0, s = 1 — the sparse body
        // must still ship (and stay decodable), not vanish to 0 bytes
        let mut q = TopKQuantizer::new(0.1);
        let mut rng = Rng::new(9);
        let qv = q.quantize(&[0.0f32; 512], &mut rng);
        assert_eq!(sparse_nnz(&qv), Some(0));
        let bytes = encode(&qv);
        assert_eq!(
            bytes.len() as u64 * 8,
            sparse_encoded_bits(512, 1, false, 0)
        );
        assert!(!bytes.is_empty());
        let back = decode(&bytes, |_| unreachable!()).unwrap();
        assert_eq!(back, qv);
        assert!(back.dequantize().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sparse_body_rejects_hostile_inputs() {
        let mut q = TopKQuantizer::new(0.05);
        let mut rng = Rng::new(10);
        let v: Vec<f32> =
            (0..400).map(|i| (i as f32 * 0.13).cos()).collect();
        let qv = q.quantize(&v, &mut rng);
        let bytes = encode(&qv);
        // every truncation fails cleanly
        for cut in 0..bytes.len() {
            assert!(
                decode(&bytes[..cut], |_| vec![]).is_err(),
                "truncation to {cut} bytes decoded"
            );
        }
        // hostile d on a sparse body is capped before materialization
        let mut w = BitWriter::new();
        w.write_u32(u32::MAX); // d
        w.write_u16(1); // s
        w.write_u8(2); // sparse, implied table
        w.write_f32(1.0); // norm
        w.write_u32(0); // k
        let err = decode(&w.into_bytes(), |s| vec![0.0; s]).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)), "{err}");
        // k > d is structural corruption
        let mut w = BitWriter::new();
        w.write_u32(64);
        w.write_u16(2);
        w.write_u8(3); // sparse, shipped table
        w.write_f32(1.0);
        w.write_f32(0.0);
        w.write_f32(0.5);
        w.write_u32(65); // k > d
        let err = decode(&w.into_bytes(), |_| vec![]).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)), "{err}");
        // unknown flag bits are rejected
        let mut w = BitWriter::new();
        w.write_u32(0);
        w.write_u16(1);
        w.write_u8(4);
        w.write_f32(0.0);
        let err = decode(&w.into_bytes(), |s| vec![0.0; s]).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)), "{err}");
    }

    #[test]
    fn non_canonical_bodies_are_rejected() {
        let mut q = TopKQuantizer::new(0.05);
        let mut rng = Rng::new(11);
        let v: Vec<f32> =
            (0..500).map(|i| (i as f32 * 0.29).sin()).collect();
        let qv = q.quantize(&v, &mut rng);
        // force the dense body for a message whose canonical form is
        // sparse: hand-write it and expect the mirror check to fire
        let mut w = BitWriter::new();
        w.write_u32(qv.dim() as u32);
        w.write_u16(qv.s() as u16);
        w.write_u8(1); // dense, shipped table
        w.write_f32(qv.norm);
        for &l in &qv.levels {
            w.write_f32(l);
        }
        w.write_bools(&qv.negative);
        w.write_packed(&qv.indices, ceil_log2(qv.s()));
        let err = decode(&w.into_bytes(), |_| vec![]).unwrap_err();
        assert!(
            err.to_string().contains("non-canonical dense"),
            "{err}"
        );
        // and the reverse: a sparse body that is not smaller than its
        // dense twin (tiny d) is equally rejected
        let mut w = BitWriter::new();
        w.write_u32(2); // d
        w.write_u16(2); // s
        w.write_u8(3); // sparse, shipped table
        w.write_f32(1.0);
        w.write_f32(0.0);
        w.write_f32(0.5);
        w.write_u32(1); // k
        w.write_bits(0, 1); // position 0
        w.write_bit(false); // sign
        w.write_bits(1, 1); // level index 1
        let err = decode(&w.into_bytes(), |_| vec![]).unwrap_err();
        assert!(
            err.to_string().contains("non-canonical sparse"),
            "{err}"
        );
    }

    #[test]
    fn prop_sparse_roundtrip_arbitrary_vectors() {
        check("sparse codec roundtrip", 40, |g| {
            let v = g.vec_normal(1..400, 1.0);
            let keep = *g.pick(&[0.01f64, 0.05, 0.2, 1.0]);
            let mut q = TopKQuantizer::new(keep);
            let mut rng = Rng::new(g.seed);
            let qv = q.quantize(&v, &mut rng);
            let bytes = encode(&qv);
            assert_eq!(bytes.len() as u64 * 8, body_bits(&qv));
            let back = decode(&bytes, |_| unreachable!()).unwrap();
            assert_eq!(back, qv);
        });
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[1, 2, 3], |_| vec![]).is_err());
        // valid header but truncated payload
        let mut q = QsgdQuantizer::new(4);
        let mut rng = Rng::new(2);
        let v = vec![1.0f32; 50];
        let bytes = encode(&q.quantize(&v, &mut rng));
        let truncated = &bytes[..bytes.len() - 4];
        assert!(
            decode(truncated, |s| QsgdQuantizer::level_table(s)).is_err()
        );
    }

    #[test]
    fn hostile_dimension_rejected_without_allocation() {
        // a tiny buffer whose d field claims ~4 billion elements must
        // be rejected by the payload bound, not by an OOM on the way
        // to "out of bits"
        let mut w = BitWriter::new();
        w.write_u32(u32::MAX); // d
        w.write_u16(4); // s
        w.write_u8(0); // implied table
        w.write_f32(1.0); // norm
        let bytes = w.into_bytes();
        let err = decode(&bytes, |s| vec![0.0; s]).unwrap_err();
        assert!(
            matches!(err, CodecError::Truncated { .. }),
            "expected Truncated, got {err}"
        );
    }

    #[test]
    fn prop_roundtrip_arbitrary_vectors() {
        check("codec roundtrip", 40, |g| {
            let v = g.vec_normal(1..400, 2.0);
            let s = *g.pick(&[2usize, 3, 8, 16, 100]);
            let mut q = LloydMaxQuantizer::new(s, 4);
            let mut rng = Rng::new(g.seed);
            let qv = q.quantize(&v, &mut rng);
            let back = decode(&encode(&qv), |_| unreachable!()).unwrap();
            assert_eq!(back, qv);
        });
    }

    #[test]
    fn wire_bits_close_to_paper_bits() {
        // wire overhead (header+table) must be small relative to payload
        // for realistic d
        let d = 100_000;
        let s = 64;
        let paper = crate::quant::bits::c_s(d, s);
        let wire = encoded_bits(d, s, false);
        let overhead = wire as f64 / paper as f64;
        assert!(overhead < 1.01, "overhead ratio {overhead}");
    }
}
