//! Natural compression [16] (paper §III-B2): binary-geometric levels,
//! stochastic (unbiased) rounding.
//!
//! Levels: ℓ = [0, 2^{2-s}, 2^{3-s}, …, 2^{-1}, 1] (s values; the paper's
//! binary geometric partition). Rounding between bracketing levels with
//! proximity probabilities — unbiased. Distortion bound (Table I):
//! 1/8 + min(√d/2^{s-1}, d/2^{2(s-1)}).

use super::{decompose, QuantizedVector, Quantizer};
use crate::util::rng::Rng;

/// LUT resolution for the batch bracket locator (coarse is fine: the
/// fix-up walk makes the count exact regardless).
const LUT_BINS: usize = 512;

#[derive(Clone, Debug)]
pub struct NaturalQuantizer {
    s: usize,
    table: Vec<f32>,
    /// bin → #levels-below LUT for the batch bracket locator
    lut: Vec<u32>,
    /// normalized-magnitude scratch (batch path)
    r_scratch: Vec<f32>,
    /// per-element level-below counts (batch path)
    cnt_scratch: Vec<u32>,
}

impl NaturalQuantizer {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2);
        let table = Self::level_table(s);
        let mut lut = Vec::new();
        super::kernels::build_count_lut(&table, 1.0, LUT_BINS, &mut lut);
        NaturalQuantizer {
            s,
            table,
            lut,
            r_scratch: Vec::new(),
            cnt_scratch: Vec::new(),
        }
    }

    /// ℓ_0 = 0, ℓ_j = 2^(j+1-s) for j = 1..s-1 (so ℓ_{s-1} = 1).
    pub fn level_table(s: usize) -> Vec<f32> {
        let mut t = Vec::with_capacity(s);
        t.push(0.0);
        for j in 1..s {
            t.push((2.0f32).powi(j as i32 + 1 - s as i32));
        }
        t
    }
}

impl Quantizer for NaturalQuantizer {
    fn name(&self) -> &'static str {
        "natural"
    }

    fn levels(&self) -> usize {
        self.s
    }

    fn set_levels(&mut self, s: usize) {
        assert!(s >= 2);
        self.s = s;
        self.table = Self::level_table(s);
        super::kernels::build_count_lut(
            &self.table,
            1.0,
            LUT_BINS,
            &mut self.lut,
        );
    }

    fn quantize(&mut self, v: &[f32], rng: &mut Rng) -> QuantizedVector {
        let (norm, negative, r) = decompose(v);
        let t = &self.table;
        let indices: Vec<u32> = r
            .iter()
            .map(|&ri| {
                let ri = ri.clamp(0.0, 1.0);
                // find bracketing levels [t[j], t[j+1]] containing ri
                let j = match t
                    .binary_search_by(|x| x.partial_cmp(&ri).unwrap())
                {
                    Ok(exact) => return exact as u32,
                    Err(ins) => ins - 1, // t[j] < ri < t[j+1]
                };
                let lo = t[j];
                let hi = t[j + 1];
                let p_hi = (ri - lo) / (hi - lo);
                if rng.uniform_f32() < p_hi {
                    (j + 1) as u32
                } else {
                    j as u32
                }
            })
            .collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels: t.clone(),
            implied_table: true,
        }
    }

    /// Allocation-free batch path: same per-element bracketing and the
    /// same `rng` draw sequence as [`quantize`] (exact level hits draw
    /// nothing). The magnitude prepass and the bracket location (a
    /// levels-below count via the LUT kernel — identical Ok/Err
    /// classification to the reference binary search on the strictly
    /// sorted table) are batch kernels; only the stochastic epilogue
    /// stays per-element because its draws are conditional.
    fn quantize_into(
        &mut self,
        v: &[f32],
        rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        let norm = super::norm_and_signs_into(v, &mut out.negative);
        out.norm = norm;
        super::kernels::normalized_magnitudes_clamped_into(
            v,
            norm,
            &mut self.r_scratch,
        );
        super::kernels::assign_lut_slice(
            &self.table,
            &self.lut,
            LUT_BINS as f32,
            &self.r_scratch,
            &mut self.cnt_scratch,
        );
        let t = &self.table;
        out.indices.clear();
        out.indices.reserve(v.len());
        for (&ri, &c) in self.r_scratch.iter().zip(&self.cnt_scratch) {
            let c = c as usize;
            // c = #{levels < ri}; t[c] == ri is the reference's Ok(c)
            let idx = if c < t.len() && t[c] == ri {
                c as u32
            } else {
                // t[c-1] < ri < t[c]; c >= 1 because ri >= 0 = t[0]
                let j = c - 1;
                let lo = t[j];
                let hi = t[j + 1];
                let p_hi = (ri - lo) / (hi - lo);
                if rng.uniform_f32() < p_hi {
                    (j + 1) as u32
                } else {
                    j as u32
                }
            };
            out.indices.push(idx);
        }
        out.levels.clear();
        out.levels.extend_from_slice(t);
        out.implied_table = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::l2_norm;

    #[test]
    fn table_is_binary_geometric() {
        let t = NaturalQuantizer::level_table(5);
        assert_eq!(t, vec![0.0, 0.0625 * 2.0, 0.25, 0.5, 1.0]);
        assert_eq!(*t.last().unwrap(), 1.0);
        for w in t[1..].windows(2) {
            assert!((w[1] / w[0] - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn exact_levels_are_fixed_points() {
        let mut q = NaturalQuantizer::new(6);
        let mut rng = Rng::new(0);
        // single-element vector: r = 1 exactly (top level)
        let qv = q.quantize(&[3.0f32], &mut rng);
        assert_eq!(qv.dequantize(), vec![3.0f32]);
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut q = NaturalQuantizer::new(8);
        let mut rng = Rng::new(7);
        let v = vec![0.3f32, -0.77, 0.05, 0.9];
        let n = 20_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..n {
            for (a, x) in acc.iter_mut().zip(q.quantize(&v, &mut rng).dequantize()) {
                *a += x as f64;
            }
        }
        for (a, &want) in acc.iter().zip(&v) {
            let mean = a / n as f64;
            assert!(
                (mean - want as f64).abs() < 0.02,
                "mean {mean} vs {want}"
            );
        }
    }

    #[test]
    fn small_values_quantize_coarsely_but_bounded() {
        let mut q = NaturalQuantizer::new(8);
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..1000).map(|i| ((i * 37) % 1000) as f32 / 1000.0 - 0.5).collect();
        let dq = q.quantize(&v, &mut rng).dequantize();
        let nsq = l2_norm(&v).powi(2);
        let dist = crate::util::stats::sq_dist(&dq, &v);
        // Table I: 1/8 + min(...) — generous slack for single draw
        assert!(dist <= nsq * (0.125 + 1.0), "dist {dist} nsq {nsq}");
    }

    #[test]
    fn indices_in_range() {
        let mut q = NaturalQuantizer::new(4);
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..500).map(|i| (i as f32 * 0.017).sin()).collect();
        let qv = q.quantize(&v, &mut rng);
        assert!(qv.indices.iter().all(|&i| (i as usize) < 4));
    }
}
