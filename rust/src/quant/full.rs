//! "DFL without quantization" baseline.
//!
//! The paper emulates full precision with s = 16,000 levels (§VI-A1a); we
//! use the next power of two, s = 2¹⁴ = 16,384, on a deterministic uniform
//! grid — relative magnitude error ≤ 2⁻¹⁵, far below f32 training noise,
//! while keeping the same (norm, sign, index) wire shape so the bit
//! accounting of Eq. 12 applies uniformly (14 index bits + 1 sign bit per
//! element + 32-bit norm).

use super::{decompose, QuantizedVector, Quantizer};
use crate::util::rng::Rng;

pub const FULL_PRECISION_LEVELS: usize = 16_384;

#[derive(Clone, Debug)]
pub struct FullPrecision {
    table: Vec<f32>,
}

impl Default for FullPrecision {
    fn default() -> Self {
        Self::new()
    }
}

impl FullPrecision {
    pub fn new() -> Self {
        FullPrecision { table: Self::level_table(FULL_PRECISION_LEVELS) }
    }

    pub fn level_table(s: usize) -> Vec<f32> {
        (0..s).map(|j| j as f32 / (s - 1) as f32).collect()
    }
}

impl Quantizer for FullPrecision {
    fn name(&self) -> &'static str {
        "full"
    }

    fn levels(&self) -> usize {
        FULL_PRECISION_LEVELS
    }

    fn quantize(&mut self, v: &[f32], _rng: &mut Rng) -> QuantizedVector {
        let (norm, negative, r) = decompose(v);
        let scale = (FULL_PRECISION_LEVELS - 1) as f32;
        let indices: Vec<u32> = r
            .iter()
            .map(|&ri| {
                (ri * scale + 0.5).clamp(0.0, scale) as u32
            })
            .collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels: self.table.clone(),
            implied_table: true,
        }
    }

    /// Allocation-free path: identical deterministic rounding to
    /// [`quantize`], writing into `out`'s reused buffers.
    fn quantize_into(
        &mut self,
        v: &[f32],
        _rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        let norm = super::norm_and_signs_into(v, &mut out.negative);
        out.norm = norm;
        let scale = (FULL_PRECISION_LEVELS - 1) as f32;
        out.indices.clear();
        for &x in v {
            let ri = super::normalized_magnitude(x, norm);
            out.indices.push((ri * scale + 0.5).clamp(0.0, scale) as u32);
        }
        out.levels.clear();
        out.levels.extend_from_slice(&self.table);
        out.implied_table = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{l2_norm, sq_dist};

    #[test]
    fn near_lossless() {
        let mut q = FullPrecision::new();
        let mut rng = Rng::new(0);
        let v: Vec<f32> =
            (0..5000).map(|_| rng.normal_ms(0.0, 3.0) as f32).collect();
        let dq = q.quantize(&v, &mut rng).dequantize();
        // uniform grid step 1/(s-1): expected normalized distortion
        // ~ d * step^2 / 12 ≈ 1.6e-6 at d = 5000
        let rel = sq_dist(&dq, &v) / l2_norm(&v).powi(2);
        assert!(rel < 1e-5, "relative distortion {rel}");
    }

    #[test]
    fn bits_match_paper_accounting() {
        let mut q = FullPrecision::new();
        let mut rng = Rng::new(0);
        let v = vec![1.0f32; 100];
        let qv = q.quantize(&v, &mut rng);
        // 14 index bits + 1 sign bit per element + 32-bit norm
        assert_eq!(qv.paper_bits(), 100 * 14 + 100 + 32);
    }
}
