//! Lloyd-Max quantizer (paper §III-C, Algorithm 1) — the LM-DFL quantizer.
//!
//! Deterministic, distortion-minimizing scalar quantizer applied to the
//! normalized magnitudes r_i = |v_i|/‖v‖ ∈ [0, 1]:
//!
//! * levels ℓ_j = centroid of φ(r) over bin j              (Eq. 17)
//! * boundaries b_j = (ℓ_j + ℓ_{j+1}) / 2                  (Eq. 16)
//!
//! iterated to a fixed point. The "probability density function
//! constructed from the statistics of the differential model parameters"
//! (Algorithm 2 step 7) is an empirical histogram: each call builds an
//! `HIST_BINS`-bin histogram of r (counts + per-bin sums) and runs the
//! Lloyd iterations on it — O(d + iters·HIST_BINS) instead of O(iters·d).
//! Levels warm-start from the previous call (the gradient distribution
//! drifts slowly across rounds), so few iterations are needed.
//!
//! Quantization is deterministic nearest-level assignment — Table I's
//! "Deterministic" row — and unbiased *with respect to the constructed
//! density* (Theorem 1): the centroid condition makes E[q(r)] = E[r] under
//! φ, unlike QSGD-style per-element stochastic unbiasedness.

use super::{decompose, QuantizedVector, Quantizer};
use crate::util::rng::Rng;

/// Histogram resolution for the empirical density φ(r).
const HIST_BINS: usize = 8192;

#[derive(Clone, Debug)]
pub struct LloydMaxQuantizer {
    s: usize,
    iters: usize,
    /// current level table (warm start between calls)
    levels: Vec<f32>,
    /// boundaries b_0..b_s (b_0 = 0, b_s = top of the fitted range)
    boundaries: Vec<f32>,
    /// top of the fitted range — max |r| observed in the last fit. For
    /// high-dimensional vectors the normalized magnitudes concentrate near
    /// 1/√d, so fitting the histogram over [0, r_max] instead of [0, 1]
    /// keeps full resolution regardless of d.
    r_max: f32,
    /// scratch histogram (counts, sums) reused across calls
    hist_cnt: Vec<f64>,
    hist_sum: Vec<f64>,
    /// histogram-bin → first-candidate level index (assignment LUT):
    /// lut[b] = #\{interior boundaries < b·w\}. Per-element assignment is
    /// then O(1) amortized — a LUT load plus at most a couple of compares —
    /// instead of an O(log s) binary search (DESIGN.md §Perf).
    lut: Vec<u32>,
    /// scratch for the normalized magnitudes r (reused by `quantize_into`
    /// so the hot path performs no per-call allocation)
    r_scratch: Vec<f32>,
}

impl LloydMaxQuantizer {
    pub fn new(s: usize, iters: usize) -> Self {
        assert!(s >= 2);
        let mut q = LloydMaxQuantizer {
            s,
            iters: iters.max(1),
            levels: Vec::new(),
            boundaries: Vec::new(),
            r_max: 1.0,
            hist_cnt: vec![0.0; HIST_BINS],
            hist_sum: vec![0.0; HIST_BINS],
            lut: Vec::new(),
            r_scratch: Vec::new(),
        };
        q.reset_uniform(1.0);
        q.rebuild_lut();
        q
    }

    /// Rebuild the bin→index LUT from the current boundaries.
    fn rebuild_lut(&mut self) {
        super::kernels::build_count_lut(
            &self.boundaries[1..self.s],
            self.r_max,
            HIST_BINS,
            &mut self.lut,
        );
    }

    fn reset_uniform(&mut self, r_max: f32) {
        let s = self.s;
        self.r_max = r_max;
        self.boundaries =
            (0..=s).map(|j| j as f32 / s as f32 * r_max).collect();
        self.levels = (0..s)
            .map(|j| (j as f32 + 0.5) / s as f32 * r_max)
            .collect();
    }

    /// Current level table (normalized, ascending).
    pub fn level_table(&self) -> &[f32] {
        &self.levels
    }

    /// Current boundaries (len s+1).
    pub fn boundary_table(&self) -> &[f32] {
        &self.boundaries
    }

    /// Build the empirical histogram of r over [0, r_max].
    fn build_histogram(&mut self, r: &[f32]) {
        self.hist_cnt.iter_mut().for_each(|x| *x = 0.0);
        self.hist_sum.iter_mut().for_each(|x| *x = 0.0);
        let scale = HIST_BINS as f32 / self.r_max;
        for &ri in r {
            let b = ((ri * scale) as usize).min(HIST_BINS - 1);
            self.hist_cnt[b] += 1.0;
            self.hist_sum[b] += ri as f64;
        }
    }

    /// One Lloyd iteration on the histogram:
    /// levels <- centroids(boundaries), boundaries <- midpoints(levels).
    fn lloyd_iteration(&mut self) {
        let s = self.s;
        let scale = HIST_BINS as f32 / self.r_max;
        // centroid of each [b_{j-1}, b_j] from histogram mass
        let mut hb = 0usize; // histogram cursor
        for j in 0..s {
            let hi_edge = self.boundaries[j + 1];
            let hb_end = if j + 1 == s {
                HIST_BINS
            } else {
                ((hi_edge * scale) as usize).min(HIST_BINS)
            };
            let mut cnt = 0.0;
            let mut sum = 0.0;
            while hb < hb_end {
                cnt += self.hist_cnt[hb];
                sum += self.hist_sum[hb];
                hb += 1;
            }
            self.levels[j] = if cnt > 0.0 {
                (sum / cnt) as f32
            } else {
                // empty bin: keep the midpoint so the sequence stays sorted
                0.5 * (self.boundaries[j] + self.boundaries[j + 1])
            };
        }
        // midpoints
        for j in 1..s {
            self.boundaries[j] = 0.5 * (self.levels[j - 1] + self.levels[j]);
        }
        self.boundaries[0] = 0.0;
        self.boundaries[s] = self.r_max;
    }

    /// Fit levels to the empirical distribution of `r` (Algorithm 1).
    pub fn fit(&mut self, r: &[f32]) {
        if r.is_empty() {
            return;
        }
        let r_max = r.iter().cloned().fold(0.0f32, f32::max);
        if r_max <= 0.0 {
            return;
        }
        // warm-start only while the data range is comparable; re-init the
        // tables when it shifts (new level count, different vector scale)
        let ratio = r_max / self.r_max;
        if !(0.5..=2.0).contains(&ratio) {
            self.reset_uniform(r_max);
        } else {
            self.r_max = r_max;
            self.boundaries[self.s] = r_max;
        }
        self.build_histogram(r);
        for _ in 0..self.iters {
            self.lloyd_iteration();
        }
        // enforce strict monotonicity for the binary search
        for j in 1..self.s {
            if self.levels[j] <= self.levels[j - 1] {
                self.levels[j] = self.levels[j - 1] + f32::EPSILON;
            }
        }
        for j in 1..=self.s {
            let prev = self.boundaries[j - 1];
            if self.boundaries[j] <= prev {
                self.boundaries[j] = prev + f32::EPSILON;
            }
        }
        self.rebuild_lut();
    }

    /// LUT-accelerated assignment — exact same result as [`assign`].
    #[inline]
    fn assign_fast(&self, ri: f32) -> u32 {
        let scale = HIST_BINS as f32 / self.r_max;
        let b = ((ri * scale) as usize).min(HIST_BINS - 1);
        let mut j = self.lut[b] as usize;
        let inner = &self.boundaries[1..self.s];
        // at most the boundaries that fall inside this histogram bin
        while j < inner.len() && inner[j] < ri {
            j += 1;
        }
        j as u32
    }

    /// Deterministic bin assignment: r ∈ (b_{j-1}, b_j] → j-1 (0-based).
    #[inline]
    pub fn assign(&self, ri: f32) -> u32 {
        // branchless-ish binary search over interior boundaries
        let inner = &self.boundaries[1..self.s];
        let mut lo = 0usize;
        let mut len = inner.len();
        while len > 0 {
            let half = len / 2;
            let mid = lo + half;
            // count of interior boundaries strictly below ri
            if inner[mid] < ri {
                lo = mid + 1;
                len -= half + 1;
            } else {
                len = half;
            }
        }
        lo as u32
    }
}

impl Quantizer for LloydMaxQuantizer {
    fn name(&self) -> &'static str {
        "lloyd_max"
    }

    fn levels(&self) -> usize {
        self.s
    }

    fn set_levels(&mut self, s: usize) {
        assert!(s >= 2);
        if s != self.s {
            self.s = s;
            let r_max = self.r_max;
            self.reset_uniform(r_max);
        }
    }

    fn quantize(&mut self, v: &[f32], _rng: &mut Rng) -> QuantizedVector {
        let (norm, negative, r) = decompose(v);
        self.fit(&r);
        let indices: Vec<u32> =
            r.iter().map(|&ri| self.assign_fast(ri)).collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels: self.levels.clone(),
            implied_table: false,
        }
    }

    /// Allocation-free batch path: identical math to [`quantize`] (same
    /// norm, same fit, same LUT assignment), but run as slice kernels —
    /// the vectorized magnitude prepass plus the batch LUT walk of
    /// [`super::kernels::assign_lut_slice`] — writing into `out`'s
    /// reused buffers and the internal `r` scratch. [`quantize`] stays
    /// the per-element reference this path is property-tested against.
    fn quantize_into(
        &mut self,
        v: &[f32],
        _rng: &mut Rng,
        out: &mut QuantizedVector,
    ) {
        let norm = super::norm_and_signs_into(v, &mut out.negative);
        out.norm = norm;
        // take the scratch out so `fit(&r)` can borrow self mutably
        let mut r = std::mem::take(&mut self.r_scratch);
        super::kernels::normalized_magnitudes_into(v, norm, &mut r);
        self.fit(&r);
        super::kernels::assign_lut_slice(
            &self.boundaries[1..self.s],
            &self.lut,
            HIST_BINS as f32 / self.r_max,
            &r,
            &mut out.indices,
        );
        self.r_scratch = r;
        out.levels.clear();
        out.levels.extend_from_slice(&self.levels);
        out.implied_table = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::stats::{l2_norm, sq_dist};

    fn normalized_distortion(v: &[f32], dq: &[f32]) -> f64 {
        sq_dist(dq, v) / l2_norm(v).powi(2)
    }

    #[test]
    fn uniform_init_tables() {
        let q = LloydMaxQuantizer::new(4, 1);
        assert_eq!(q.boundary_table(), &[0.0, 0.25, 0.5, 0.75, 1.0]);
        assert_eq!(q.level_table(), &[0.125, 0.375, 0.625, 0.875]);
    }

    #[test]
    fn assign_fast_matches_binary_search() {
        let mut q = LloydMaxQuantizer::new(16, 10);
        let mut rng = Rng::new(77);
        let v: Vec<f32> = (0..4096).map(|_| rng.laplace(0.3) as f32).collect();
        let _ = q.quantize(&v, &mut rng);
        for i in 0..5000 {
            let ri = i as f32 / 5000.0 * q.r_max;
            assert_eq!(q.assign_fast(ri), q.assign(ri), "ri={ri}");
        }
    }

    #[test]
    fn assign_matches_linear_scan() {
        let mut q = LloydMaxQuantizer::new(8, 5);
        let mut rng = Rng::new(0);
        let v: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let _ = q.quantize(&v, &mut rng);
        for i in 0..200 {
            let ri = i as f32 / 199.0;
            let fast = q.assign(ri);
            let slow = q.boundaries[1..q.s]
                .iter()
                .filter(|&&b| b < ri)
                .count() as u32;
            assert_eq!(fast, slow, "ri={ri}");
        }
    }

    #[test]
    fn deterministic_same_input_same_output() {
        let mut q1 = LloydMaxQuantizer::new(16, 8);
        let mut q2 = LloydMaxQuantizer::new(16, 8);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999); // rng must not matter
        let v: Vec<f32> = (0..300).map(|i| ((i * 31 % 97) as f32) - 48.0).collect();
        assert_eq!(q1.quantize(&v, &mut r1), q2.quantize(&v, &mut r2));
    }

    #[test]
    fn beats_uniform_grid_on_gaussian() {
        // Lloyd-Max fits the density; on non-uniform data it must beat the
        // same-s uniform deterministic grid.
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
        let s = 16;

        let mut lm = LloydMaxQuantizer::new(s, 30);
        let dq_lm = lm.quantize(&v, &mut rng).dequantize();
        let lm_dist = normalized_distortion(&v, &dq_lm);

        // deterministic uniform grid at the same s
        let (norm, neg, r) = super::super::decompose(&v);
        let grid: Vec<f32> =
            (0..s).map(|j| (j as f32 + 0.5) / s as f32).collect();
        let dq_grid: Vec<f32> = r
            .iter()
            .zip(&neg)
            .map(|(&ri, &n)| {
                let j = ((ri * s as f32) as usize).min(s - 1);
                let mag = norm * grid[j];
                if n { -mag } else { mag }
            })
            .collect();
        let grid_dist = normalized_distortion(&v, &dq_grid);
        assert!(
            lm_dist < grid_dist,
            "lm {lm_dist} should beat uniform {grid_dist}"
        );
    }

    #[test]
    fn distortion_within_theorem2_bound() {
        // Theorem 2: E||Q(x)-x||^2 <= d/(12 s^2) ||x||^2. The histogram
        // approximation adds resolution error; allow modest slack.
        check("lm distortion d/12s^2", 25, |g| {
            let v = g.vec_normal(200..4000, 1.0);
            let s = *g.pick(&[4usize, 8, 16, 32]);
            let mut q = LloydMaxQuantizer::new(s, 25);
            let mut rng = Rng::new(g.seed);
            let dq = q.quantize(&v, &mut rng).dequantize();
            let d = v.len() as f64;
            let bound = d / (12.0 * (s * s) as f64);
            let nd = normalized_distortion(&v, &dq);
            assert!(nd <= bound * 1.5 + 1e-6, "nd={nd} bound={bound} s={s}");
        });
    }

    #[test]
    fn iterations_reduce_distortion() {
        let mut rng = Rng::new(11);
        let v: Vec<f32> = (0..10_000)
            .map(|_| (rng.laplace(0.2)) as f32)
            .collect();
        let mut prev = f64::INFINITY;
        for iters in [1usize, 3, 10, 30] {
            let mut q = LloydMaxQuantizer::new(8, iters);
            let dq = q.quantize(&v, &mut rng).dequantize();
            let nd = normalized_distortion(&v, &dq);
            assert!(nd <= prev * 1.05, "iters={iters}: {nd} > {prev}");
            prev = nd;
        }
    }

    #[test]
    fn warm_start_consistent_across_calls() {
        // second call on same distribution should not be worse
        let mut rng = Rng::new(13);
        let v1: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let v2: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let mut q = LloydMaxQuantizer::new(16, 5);
        let _ = q.quantize(&v1, &mut rng);
        let dq2 = q.quantize(&v2, &mut rng).dequantize();
        let nd = normalized_distortion(&v2, &dq2);
        let bound = 5000.0 / (12.0 * 256.0);
        assert!(nd <= bound * 1.5, "warm nd={nd}");
    }

    #[test]
    fn levels_sorted_and_boundaries_interleave() {
        check("lm tables monotone", 30, |g| {
            let v = g.vec_laplace(50..3000, 0.5);
            if l2_norm(&v) == 0.0 {
                return;
            }
            let s = *g.pick(&[2usize, 4, 16, 50]);
            let mut q = LloydMaxQuantizer::new(s, 10);
            let mut rng = Rng::new(g.seed);
            let _ = q.quantize(&v, &mut rng);
            let lev = q.level_table();
            let bnd = q.boundary_table();
            for w in lev.windows(2) {
                assert!(w[0] < w[1], "levels not sorted: {lev:?}");
            }
            for j in 0..s {
                assert!(bnd[j] <= lev[j] + 1e-6 && lev[j] <= bnd[j + 1] + 1e-6,
                    "level {j} outside its bin");
            }
        });
    }

    #[test]
    fn handles_degenerate_inputs() {
        let mut q = LloydMaxQuantizer::new(4, 5);
        let mut rng = Rng::new(0);
        // zero vector
        let qv = q.quantize(&[0.0f32; 8], &mut rng);
        assert!(qv.dequantize().iter().all(|&x| x == 0.0));
        // single element (r = 1 exactly)
        let qv = q.quantize(&[5.0f32], &mut rng);
        let dq = qv.dequantize();
        assert!((dq[0] - 5.0).abs() < 0.2, "{dq:?}");
        // constant vector
        let qv = q.quantize(&[1.0f32; 16], &mut rng);
        for x in qv.dequantize() {
            assert!((x - 1.0).abs() < 0.05, "{x}");
        }
    }

    #[test]
    fn set_levels_resets() {
        let mut q = LloydMaxQuantizer::new(4, 5);
        q.set_levels(9);
        assert_eq!(q.levels(), 9);
        assert_eq!(q.level_table().len(), 9);
        assert_eq!(q.boundary_table().len(), 10);
    }
}
