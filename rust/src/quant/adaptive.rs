//! Doubly-adaptive level-count controller (paper §V, Eq. 36-37).
//!
//! The optimal number of quantization levels grows as training loss falls:
//!
//!   s_k ≈ √(F(u₁)/F(u_k)) · s₁
//!
//! Intuition (paper): early training has fast loss descent — coarse
//! quantization suffices; near convergence fine quantization is needed for
//! the remaining small gradient steps. Each node evaluates s_k from its
//! *local* loss (Algorithm 3 step 8) since the global F(u_k) is not
//! observable in a decentralized system.

/// Per-node ascending-s controller.
#[derive(Clone, Debug)]
pub struct AdaptiveLevels {
    /// initial level count s₁
    pub s1: usize,
    /// cap (memory/bit-width guard)
    pub s_max: usize,
    /// F_i(x₁) — loss at the first round, set on first observation
    f1: Option<f64>,
    /// monotone guard: s_k never decreases (ascending schedule)
    last_s: usize,
}

impl AdaptiveLevels {
    pub fn new(s1: usize, s_max: usize) -> Self {
        assert!(s1 >= 2 && s_max >= s1);
        AdaptiveLevels { s1, s_max, f1: None, last_s: s1 }
    }

    /// Observe the current loss and return s_k (Eq. 37). The first call
    /// pins F₁ and returns s₁.
    pub fn update(&mut self, loss: f64) -> usize {
        let loss = loss.max(1e-12);
        let f1 = *self.f1.get_or_insert(loss);
        let ratio = (f1 / loss).max(0.0).sqrt();
        let s = (self.s1 as f64 * ratio).round() as usize;
        let s = s.clamp(self.s1, self.s_max);
        // ascending schedule: loss is noisy, never step s back down
        self.last_s = self.last_s.max(s);
        self.last_s
    }

    /// Current s without observing a new loss.
    pub fn current(&self) -> usize {
        self.last_s
    }

    /// Reset (new run).
    pub fn reset(&mut self) {
        self.f1 = None;
        self.last_s = self.s1;
    }
}

/// A fixed or scripted schedule — used by the Fig. 4 ablation to compare
/// ascending vs fixed vs descending level counts.
#[derive(Clone, Debug)]
pub enum LevelSchedule {
    Fixed(usize),
    /// Adaptive per Eq. 37.
    Ascending(AdaptiveLevels),
    /// Inverse of the adaptive rule (ablation: starts fine, gets coarse).
    Descending { s1: usize, s_min: usize, f1: Option<f64> },
}

impl LevelSchedule {
    pub fn next(&mut self, loss: f64) -> usize {
        match self {
            LevelSchedule::Fixed(s) => *s,
            LevelSchedule::Ascending(a) => a.update(loss),
            LevelSchedule::Descending { s1, s_min, f1 } => {
                let loss = loss.max(1e-12);
                let f1v = *f1.get_or_insert(loss);
                let ratio = (loss / f1v).sqrt(); // inverse of Eq. 37
                (((*s1 as f64) * ratio).round() as usize)
                    .clamp(*s_min, *s1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_call_returns_s1() {
        let mut a = AdaptiveLevels::new(4, 1024);
        assert_eq!(a.update(2.3), 4);
        assert_eq!(a.current(), 4);
    }

    #[test]
    fn follows_sqrt_rule() {
        let mut a = AdaptiveLevels::new(4, 1 << 20);
        a.update(1.0);
        // loss 1/4 => sqrt(4) = 2x levels
        assert_eq!(a.update(0.25), 8);
        // loss 1/100 => 10x
        assert_eq!(a.update(0.01), 40);
    }

    #[test]
    fn ascending_guard_never_decreases() {
        let mut a = AdaptiveLevels::new(4, 1024);
        a.update(1.0);
        let s_low = a.update(0.0625); // 16
        assert_eq!(s_low, 16);
        // noisy loss spike must not reduce s
        assert_eq!(a.update(0.5), 16);
        assert!(a.update(0.01) >= 16);
    }

    #[test]
    fn capped_at_s_max() {
        let mut a = AdaptiveLevels::new(4, 32);
        a.update(1.0);
        assert_eq!(a.update(1e-9), 32);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut a = AdaptiveLevels::new(4, 64);
        a.update(1.0);
        a.update(0.01);
        a.reset();
        assert_eq!(a.update(5.0), 4);
    }

    #[test]
    fn descending_schedule_inverse() {
        let mut d = LevelSchedule::Descending { s1: 64, s_min: 2, f1: None };
        assert_eq!(d.next(1.0), 64);
        assert_eq!(d.next(0.25), 32);
        assert_eq!(d.next(1e-9), 2);
    }

    #[test]
    fn fixed_schedule_constant() {
        let mut f = LevelSchedule::Fixed(16);
        assert_eq!(f.next(9.0), 16);
        assert_eq!(f.next(0.001), 16);
    }
}
