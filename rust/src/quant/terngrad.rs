//! TernGrad [11] extension baseline (paper §I): ternary {−1, 0, +1}
//! stochastic quantization — 2 bits/element, no convergence guarantee in
//! the original paper. Expressed in the (norm, sign, level) wire format
//! with s = 2 levels {0, 1} scaled by max |v_i|/‖v‖ rather than 1, i.e.
//! h(v_i) = s_max · sign(v_i) · b_i with b_i ~ Bernoulli(|v_i|/max|v|).

use super::{QuantizedVector, Quantizer};
use crate::util::rng::Rng;
use crate::util::stats::l2_norm;

#[derive(Clone, Debug, Default)]
pub struct TernGradQuantizer;

impl TernGradQuantizer {
    pub fn new() -> Self {
        TernGradQuantizer
    }
}

impl Quantizer for TernGradQuantizer {
    fn name(&self) -> &'static str {
        "terngrad"
    }

    fn levels(&self) -> usize {
        2
    }

    fn quantize(&mut self, v: &[f32], rng: &mut Rng) -> QuantizedVector {
        let norm = l2_norm(v) as f32;
        let vmax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let (levels, indices) = if norm > 0.0 && vmax > 0.0 {
            // level table normalized by ||v||: {0, vmax/||v||}
            let top = vmax / norm;
            let idx = v
                .iter()
                .map(|&x| {
                    let p = x.abs() / vmax;
                    (rng.uniform_f32() < p) as u32
                })
                .collect();
            (vec![0.0, top], idx)
        } else {
            (vec![0.0, 1.0], vec![0u32; v.len()])
        };
        // a coordinate rounded to zero carries no sign: emit the
        // canonical index-0/positive-sign slot so the codec's sparse
        // body applies when it is the smaller form
        let negative: Vec<bool> = v
            .iter()
            .zip(&indices)
            .map(|(&x, &i)| i != 0 && x < 0.0)
            .collect();
        QuantizedVector {
            norm,
            negative,
            indices,
            levels,
            implied_table: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_in_expectation() {
        let mut q = TernGradQuantizer::new();
        let mut rng = Rng::new(1);
        let v = vec![0.5f32, -0.25, 0.1, -0.9];
        let n = 30_000;
        let mut acc = vec![0.0f64; v.len()];
        for _ in 0..n {
            for (a, x) in acc.iter_mut().zip(q.quantize(&v, &mut rng).dequantize()) {
                *a += x as f64;
            }
        }
        for (a, &want) in acc.iter().zip(&v) {
            let mean = a / n as f64;
            assert!((mean - want as f64).abs() < 0.02, "{mean} vs {want}");
        }
    }

    #[test]
    fn output_is_ternary() {
        let mut q = TernGradQuantizer::new();
        let mut rng = Rng::new(2);
        let v: Vec<f32> = (0..500).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let dq = q.quantize(&v, &mut rng).dequantize();
        let vmax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for x in dq {
            assert!(
                x == 0.0 || (x.abs() - vmax).abs() < 1e-3,
                "non-ternary value {x}"
            );
        }
    }

    #[test]
    fn two_bits_per_element_accounting() {
        let mut q = TernGradQuantizer::new();
        let mut rng = Rng::new(3);
        let v = vec![1.0f32; 100];
        let msg = q.quantize(&v, &mut rng);
        // 1 index bit + 1 sign bit per element + 32-bit norm
        assert_eq!(msg.paper_bits(), 100 + 100 + 32);
    }

    #[test]
    fn zero_vector_ok() {
        let mut q = TernGradQuantizer::new();
        let mut rng = Rng::new(4);
        let dq = q.quantize(&[0.0f32; 8], &mut rng).dequantize();
        assert!(dq.iter().all(|&x| x == 0.0));
    }
}
