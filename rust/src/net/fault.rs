//! Fault injection over any transport: a [`Delivery`] wrapper applying
//! a simnet [`LinkModel`]'s drop probability and latency/jitter in real
//! time.
//!
//! Drops happen on the send side: the payload is replaced by an
//! empty-bytes tombstone with the envelope key intact, so receivers
//! never deadlock on a slot that will never arrive and the byte meter
//! still counts the full payload (a lost message occupied the link —
//! the same accounting the simnet fabric uses). Latency and jitter are
//! applied on the receive side by holding arrived frames in a min-heap
//! until their due time.
//!
//! Two deliberate divergences from the simnet clock: bandwidth shaping
//! is *not* applied (serialization delay on localhost is what it is —
//! modeling it is the virtual clock's job), and jitter reordering
//! depends on real OS timing, so lossy/jittery wall-clock runs are not
//! bit-reproducible the way virtual-clock runs are. The drop pattern
//! *is* deterministic for a given rng seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use crate::error::LmdflError;
use crate::simnet::LinkModel;
use crate::util::rng::Rng;

use super::{Delivery, Frame};

/// A frame held until its jittered delivery time. Ordered by (due,
/// arrival sequence) so equal due-times keep arrival order.
struct Held {
    due: Instant,
    seq: u64,
    frame: Frame,
}

impl PartialEq for Held {
    fn eq(&self, other: &Held) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}

impl Eq for Held {}

impl PartialOrd for Held {
    fn partial_cmp(&self, other: &Held) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Held {
    fn cmp(&self, other: &Held) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

/// The fault-injecting wrapper. Compose it around any inner transport:
/// `FaultDelivery::new(Box::new(inner), link, rng)`.
pub struct FaultDelivery {
    inner: Box<dyn Delivery>,
    link: LinkModel,
    rng: Rng,
    held: BinaryHeap<Reverse<Held>>,
    seq: u64,
    sent: u64,
}

impl FaultDelivery {
    pub fn new(
        inner: Box<dyn Delivery>,
        link: LinkModel,
        rng: Rng,
    ) -> FaultDelivery {
        FaultDelivery {
            inner,
            link,
            rng,
            held: BinaryHeap::new(),
            seq: 0,
            sent: 0,
        }
    }

    fn delayed(&self) -> bool {
        self.link.latency_s > 0.0 || self.link.jitter_s > 0.0
    }

    fn hold(&mut self, frame: Frame) {
        let mut secs = self.link.latency_s;
        if self.link.jitter_s > 0.0 {
            secs += self.rng.uniform() * self.link.jitter_s;
        }
        self.held.push(Reverse(Held {
            due: Instant::now() + Duration::from_secs_f64(secs),
            seq: self.seq,
            frame,
        }));
        self.seq += 1;
    }
}

impl Delivery for FaultDelivery {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), LmdflError> {
        // the wrapper's meter is the authoritative one: full payload
        // bytes, dropped or not (the link was occupied either way);
        // the inner transport's own meter sees only what survives
        self.sent += frame.bytes.len() as u64;
        if self.link.dropped(&mut self.rng) {
            crate::obs::counter("fault_drop", "total", 1);
            let t = Frame::tombstone(frame.from, frame.round, frame.phase);
            self.inner.send(to, t)
        } else {
            self.inner.send(to, frame)
        }
    }

    fn recv(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Frame>, LmdflError> {
        if !self.delayed() {
            return self.inner.recv(timeout);
        }
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            // earliest held frame that is already due wins
            if let Some(Reverse(head)) = self.held.peek() {
                if head.due <= now {
                    let Reverse(h) =
                        self.held.pop().expect("peeked head");
                    return Ok(Some(h.frame));
                }
            }
            // wait for new arrivals until the head is due (or the
            // caller's deadline, whichever is sooner)
            let until = match self.held.peek() {
                Some(Reverse(head)) => head.due.min(deadline),
                None => deadline,
            };
            if until <= now {
                if self.held.is_empty() {
                    return Ok(None); // caller's timeout, nothing held
                }
                continue; // head became due while computing
            }
            if let Some(f) = self.inner.recv(until - now)? {
                self.hold(f);
            } else if self
                .held
                .peek()
                .map(|Reverse(h)| h.due > deadline)
                .unwrap_or(true)
            {
                // inner timed out and nothing matures before the
                // caller's deadline
                return Ok(None);
            }
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.sent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{channel_mesh, Frame};
    use std::sync::Arc;

    fn frame(from: usize, round: u32, len: usize) -> Frame {
        Frame::new(from, round, 0, Arc::from(vec![0x5A; len]))
    }

    #[test]
    fn drop_prob_one_tombstones_everything_but_meters_fully() {
        let mut mesh = channel_mesh(2);
        let receiver = mesh.pop().unwrap();
        let sender = mesh.pop().unwrap();
        let mut lossy = FaultDelivery::new(
            Box::new(sender),
            LinkModel::lossy(1.0),
            Rng::new(7),
        );
        for k in 0..4 {
            lossy.send(1, frame(0, k, 25)).unwrap();
        }
        // outer meter counts every payload in full
        assert_eq!(lossy.wire_bytes(), 100);
        let mut rx = receiver;
        for k in 0..4 {
            let f = rx.recv(Duration::from_secs(1)).unwrap().unwrap();
            assert!(f.is_tombstone());
            assert_eq!((f.from, f.round), (0, k));
        }
    }

    #[test]
    fn lossless_link_passes_frames_through_unchanged() {
        let mut mesh = channel_mesh(2);
        let receiver = mesh.pop().unwrap();
        let sender = mesh.pop().unwrap();
        let mut ideal = FaultDelivery::new(
            Box::new(sender),
            LinkModel::ideal(),
            Rng::new(7),
        );
        ideal.send(1, frame(0, 3, 9)).unwrap();
        let mut wrapped_rx = FaultDelivery::new(
            Box::new(receiver),
            LinkModel::ideal(),
            Rng::new(8),
        );
        let f = wrapped_rx
            .recv(Duration::from_secs(1))
            .unwrap()
            .unwrap();
        assert_eq!((f.from, f.round, f.bytes.len()), (0, 3, 9));
        assert_eq!(ideal.wire_bytes(), 9);
    }

    #[test]
    fn latency_holds_then_delivers_all() {
        let mut mesh = channel_mesh(2);
        let receiver = mesh.pop().unwrap();
        let mut sender = mesh.pop().unwrap();
        for k in 0..3 {
            sender.send(1, frame(0, k, 5)).unwrap();
        }
        let link = LinkModel {
            latency_s: 0.02,
            jitter_s: 0.02,
            ..LinkModel::ideal()
        };
        let mut delayed = FaultDelivery::new(
            Box::new(receiver),
            link,
            Rng::new(42),
        );
        let t0 = Instant::now();
        let mut rounds: Vec<u32> = Vec::new();
        for _ in 0..3 {
            let f = delayed
                .recv(Duration::from_secs(2))
                .unwrap()
                .unwrap();
            rounds.push(f.round);
        }
        // everything arrives (possibly reordered by jitter), and not
        // before the base latency elapsed
        rounds.sort_unstable();
        assert_eq!(rounds, vec![0, 1, 2]);
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // an exhausted queue times out cleanly
        assert!(delayed
            .recv(Duration::from_millis(10))
            .unwrap()
            .is_none());
    }
}
