//! Multi-process TCP transport: `WireMessage` frames over localhost
//! sockets.
//!
//! Node `i` listens on `base_port + i`; an accept loop hands each
//! inbound connection to a blocking reader thread that parses
//! length-prefixed envelopes ([`crate::quant::wire::read_frame`]) and
//! funnels frames into one mpsc queue. Outbound connections open
//! lazily on first send and reconnect with exponential backoff inside
//! a per-send deadline, so a peer process that restarts (the
//! kill-one-and-resume case) is transparently re-dialed — undelivered
//! frames from the dead connection are retried whole, because `send`
//! never reports success until `write_frame` returned.
//!
//! Localhost and trusted-LAN use only: there is no auth or encryption,
//! and the frame parser's hostile-length caps are the only input
//! validation.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::error::LmdflError;
use crate::quant::wire;

use super::{Delivery, Frame};

/// TCP endpoint parameters (the `transport:` config section's fields).
#[derive(Clone, Debug, PartialEq)]
pub struct TcpOptions {
    /// interface the listeners bind and peers are dialed on
    pub host: String,
    /// node `i` listens on `base_port + i`
    pub base_port: u16,
    /// total budget for reaching a peer — initial dial at startup and
    /// each send's reconnect loop both give up after this long
    pub connect_timeout_s: f64,
    /// initial retry sleep; doubles per attempt, capped at 1 s
    pub retry_backoff_s: f64,
}

impl Default for TcpOptions {
    fn default() -> TcpOptions {
        TcpOptions {
            host: "127.0.0.1".to_string(),
            base_port: 7600,
            connect_timeout_s: 10.0,
            retry_backoff_s: 0.05,
        }
    }
}

impl TcpOptions {
    /// The port node `node` listens on.
    pub fn port_of(&self, node: usize) -> Result<u16, LmdflError> {
        let p = self.base_port as usize + node;
        if p > 65535 {
            return Err(LmdflError::transport(
                node,
                format!("port {p} for node {node} exceeds 65535"),
            ));
        }
        Ok(p as u16)
    }

    fn backoff_base(&self) -> Duration {
        Duration::from_secs_f64(self.retry_backoff_s.max(1e-3))
    }

    fn connect_budget(&self) -> Duration {
        Duration::from_secs_f64(self.connect_timeout_s.max(1e-3))
    }
}

/// Dial `host:port`, retrying with exponential backoff until the
/// options' connect budget runs out. Used for gossip links and for the
/// report plane of a multi-process run.
pub fn connect_retry(
    opts: &TcpOptions,
    port: u16,
) -> Result<TcpStream, LmdflError> {
    let deadline = Instant::now() + opts.connect_budget();
    let mut backoff = opts.backoff_base();
    let addr = format!("{}:{port}", opts.host);
    loop {
        // short per-attempt timeout so a dead peer doesn't eat the
        // whole budget in one OS-level connect
        let per_try = Duration::from_millis(250)
            .min(deadline.saturating_duration_since(Instant::now()));
        let attempt = std::net::ToSocketAddrs::to_socket_addrs(&*addr)
            .map_err(LmdflError::from)
            .and_then(|mut it| {
                it.next().ok_or_else(|| {
                    LmdflError::transport(
                        None,
                        format!("address {addr} resolved to nothing"),
                    )
                })
            })
            .and_then(|sock| {
                TcpStream::connect_timeout(&sock, per_try.max(
                    Duration::from_millis(1),
                ))
                .map_err(LmdflError::from)
            });
        match attempt {
            Ok(stream) => {
                // small frames on a latency-sensitive protocol: never
                // let Nagle batch them
                let _ = stream.set_nodelay(true);
                return Ok(stream);
            }
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    return Err(LmdflError::transport(
                        None,
                        format!(
                            "could not connect to {addr} within \
                             {:.1}s: {e}",
                            opts.connect_timeout_s
                        ),
                    ));
                }
                thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
        }
    }
}

/// The socket transport. One instance per node process (or per node
/// thread when bound in-process for parity testing).
pub struct TcpDelivery {
    node: usize,
    opts: TcpOptions,
    rx: Receiver<Frame>,
    /// keeps `rx` connected even while no reader thread holds a clone
    _tx_keepalive: Sender<Frame>,
    shutdown: Arc<AtomicBool>,
    /// lazily dialed outbound connections, one per peer
    outs: HashMap<usize, TcpStream>,
    sent: u64,
}

impl TcpDelivery {
    /// Bind this node's listener and start the accept loop. Fails fast
    /// if the port is taken (a stale run or a rank collision).
    pub fn bind(
        node: usize,
        opts: TcpOptions,
    ) -> Result<TcpDelivery, LmdflError> {
        let port = opts.port_of(node)?;
        let addr = format!("{}:{port}", opts.host);
        let listener = TcpListener::bind(&addr).map_err(|e| {
            LmdflError::transport(
                node,
                format!("could not bind {addr}: {e}"),
            )
        })?;
        listener.set_nonblocking(true)?;
        let (tx, rx) = channel::<Frame>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let accept_tx = tx.clone();
        thread::Builder::new()
            .name(format!("lmdfl-accept-{node}"))
            .spawn(move || accept_loop(listener, accept_tx, flag))
            .map_err(LmdflError::from)?;
        Ok(TcpDelivery {
            node,
            opts,
            rx,
            _tx_keepalive: tx,
            shutdown,
            outs: HashMap::new(),
            sent: 0,
        })
    }

    /// The dial options this endpoint was built with.
    pub fn options(&self) -> &TcpOptions {
        &self.opts
    }

    fn connect_to(&self, to: usize) -> Result<TcpStream, LmdflError> {
        let port = self.opts.port_of(to)?;
        connect_retry(&self.opts, port).map_err(|e| match e {
            LmdflError::Transport { detail, .. } => {
                LmdflError::transport(to, detail)
            }
            other => other,
        })
    }
}

fn accept_loop(
    listener: TcpListener,
    tx: Sender<Frame>,
    shutdown: Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // the reader blocks; only the accept loop polls
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let reader_tx = tx.clone();
                let _ = thread::Builder::new()
                    .name("lmdfl-read".to_string())
                    .spawn(move || read_loop(stream, reader_tx));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn read_loop(mut stream: TcpStream, tx: Sender<Frame>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(env)) => {
                let frame = Frame {
                    from: env.from as usize,
                    round: env.round,
                    phase: env.phase,
                    bytes: env.payload.into(),
                };
                if tx.send(frame).is_err() {
                    return; // endpoint dropped — stop reading
                }
            }
            // clean EOF (peer closed) or a poisoned stream: either way
            // this connection is done; the peer re-dials if it has more
            Ok(None) | Err(_) => return,
        }
    }
}

impl Delivery for TcpDelivery {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), LmdflError> {
        // meter at entry — the byte-accounting contract counts every
        // payload offered to the link
        self.sent += frame.bytes.len() as u64;
        crate::obs::counter("frame_send", "tcp", 1);
        if frame.is_tombstone() {
            crate::obs::counter("frame_tombstone", "tcp", 1);
        }
        let deadline = Instant::now() + self.opts.connect_budget();
        let mut backoff = self.opts.backoff_base();
        loop {
            if !self.outs.contains_key(&to) {
                let stream = self.connect_to(to)?;
                self.outs.insert(to, stream);
            }
            let stream = self.outs.get_mut(&to).expect("just inserted");
            let wrote = wire::write_frame(
                stream,
                self.node as u32,
                frame.round,
                frame.phase,
                &frame.bytes,
            );
            match wrote {
                Ok(()) => return Ok(()),
                Err(e) => {
                    // broken pipe / reset: drop the connection and
                    // retry the whole frame on a fresh dial
                    self.outs.remove(&to);
                    if crate::obs::active() {
                        crate::obs::counter(
                            "tcp_reconnect",
                            &to.to_string(),
                            1,
                        );
                        crate::obs::hist(
                            "tcp_backoff_ns",
                            backoff.as_nanos() as u64,
                        );
                    }
                    if Instant::now() + backoff >= deadline {
                        return Err(LmdflError::transport(
                            to,
                            format!("send failed after retries: {e}"),
                        ));
                    }
                    thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_secs(1));
                }
            }
        }
    }

    fn recv(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Frame>, LmdflError> {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => {
                crate::obs::counter("frame_recv", "tcp", 1);
                Ok(Some(f))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // unreachable while _tx_keepalive lives, but total anyway
            Err(RecvTimeoutError::Disconnected) => Err(
                LmdflError::transport(self.node, "receive queue closed"),
            ),
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.sent
    }
}

impl Drop for TcpDelivery {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for (_, stream) in self.outs.drain() {
            let _ = stream.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(base_port: u16) -> TcpOptions {
        TcpOptions {
            base_port,
            connect_timeout_s: 5.0,
            retry_backoff_s: 0.01,
            ..TcpOptions::default()
        }
    }

    #[test]
    fn frames_cross_a_socket_pair() {
        let o = opts(17910);
        let mut a = TcpDelivery::bind(0, o.clone()).unwrap();
        let mut b = TcpDelivery::bind(1, o).unwrap();
        let payload: Arc<[u8]> = Arc::from(vec![0xAB; 37]);
        a.send(1, Frame::new(0, 3, 2, Arc::clone(&payload))).unwrap();
        a.send(1, Frame::tombstone(0, 4, 0)).unwrap();
        let f1 = b.recv(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!((f1.from, f1.round, f1.phase), (0, 3, 2));
        assert_eq!(&f1.bytes[..], &payload[..]);
        let f2 = b.recv(Duration::from_secs(5)).unwrap().unwrap();
        assert!(f2.is_tombstone());
        assert_eq!(f2.round, 4);
        // meter counts payload bytes only (tombstone adds zero)
        assert_eq!(a.wire_bytes(), 37);
        assert_eq!(b.wire_bytes(), 0);
        // reply crosses the reverse direction on its own connection
        b.send(0, Frame::new(1, 3, 2, Arc::from(vec![1u8, 2])))
            .unwrap();
        let back = a.recv(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(back.from, 1);
        assert_eq!(&back.bytes[..], &[1, 2]);
    }

    #[test]
    fn unreachable_peer_is_a_typed_error() {
        let mut o = opts(17920);
        o.connect_timeout_s = 0.2;
        let mut a = TcpDelivery::bind(0, o).unwrap();
        let err = a
            .send(7, Frame::tombstone(0, 0, 0))
            .unwrap_err();
        assert!(matches!(
            err,
            LmdflError::Transport { peer: Some(7), .. }
        ));
        // the meter still counted the attempt's payload (0 here) and
        // the endpoint stays usable
        assert_eq!(a.wire_bytes(), 0);
    }

    #[test]
    fn port_of_overflow_rejected() {
        let mut o = opts(65530);
        o.connect_timeout_s = 0.1;
        assert!(o.port_of(5).is_ok());
        assert!(o.port_of(6).is_err());
    }
}
