//! Pluggable delivery transports: how encoded wire frames physically
//! move between gossip nodes.
//!
//! The gossip core ([`crate::dfl::net`]) speaks only to the [`Delivery`]
//! trait — send one addressed [`Frame`], drain arrivals, report the
//! measured byte meter — so the protocol logic is identical whether the
//! bytes cross an in-process channel, a localhost TCP socket, or a
//! fault-injecting wrapper (the pheromessage idiom: gossip logic over a
//! swappable delivery layer). Implementations:
//!
//! * [`ChannelDelivery`] — the in-process mpsc mesh the threaded
//!   runtime has always used, now as one impl instead of a bespoke
//!   engine fork ([`channel_mesh`] builds a full n-node mesh).
//! * [`TcpDelivery`] — multi-process transport framing wire bytes over
//!   TCP sockets ([`crate::quant::wire::write_frame`] envelopes) with
//!   per-peer lazy connect, reconnect, and exponential backoff.
//! * [`FaultDelivery`] — wraps any inner transport with a simnet
//!   [`LinkModel`](crate::simnet::LinkModel)'s drop/latency/jitter in
//!   real time.
//!
//! # Byte accounting contract
//!
//! `wire_bytes()` meters the *payload* length of every frame offered to
//! `send`, including frames a fault wrapper later drops (a lost message
//! still occupied the link) and excluding envelope overhead — so the
//! meter equals the sum of encoded `WireMessage` lengths exactly, the
//! same contract the simnet fabric asserts.
//!
//! Select a transport via the `transport:` config section
//! ([`TransportConfig`]) or `lmdfl train --threaded --transport
//! channel|tcp`; `lmdfl node --rank R` launches one node of a
//! multi-process TCP run.

mod fault;
mod tcp;

pub use fault::FaultDelivery;
pub use tcp::{connect_retry, TcpDelivery, TcpOptions};

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::json::Json;
use crate::config::ConfigError;
use crate::error::LmdflError;

/// One addressed transport frame: the envelope key (sender, protocol
/// round, phase) plus the encoded `WireMessage` payload. An empty
/// payload is the drop tombstone — receivers must get *something* for
/// every broadcast slot or they would block forever, so fault wrappers
/// replace dropped payloads with an empty one, envelope intact.
#[derive(Clone, Debug)]
pub struct Frame {
    pub from: usize,
    pub round: u32,
    pub phase: u8,
    /// shared across every receiver of a broadcast (one allocation)
    pub bytes: Arc<[u8]>,
}

impl Frame {
    pub fn new(
        from: usize,
        round: u32,
        phase: u8,
        bytes: Arc<[u8]>,
    ) -> Frame {
        Frame { from, round, phase, bytes }
    }

    /// The empty-payload drop marker for this envelope key.
    pub fn tombstone(from: usize, round: u32, phase: u8) -> Frame {
        Frame { from, round, phase, bytes: Arc::from(&[][..]) }
    }

    pub fn is_tombstone(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// How frames move between nodes. Contract:
///
/// * `send` queues one frame toward node `to` and returns without
///   waiting for delivery. Delivery is reliable and per-link FIFO
///   unless a fault wrapper injects loss or jitter reordering.
/// * `recv` blocks up to `timeout` for the next arrival from *any*
///   sender; `Ok(None)` means nothing arrived in time.
/// * `wire_bytes` is the cumulative payload-byte meter over every frame
///   offered to `send` (see the module docs for the exact contract).
pub trait Delivery: Send {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), LmdflError>;

    fn recv(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Frame>, LmdflError>;

    fn wire_bytes(&self) -> u64;
}

/// In-process transport: one mpsc receiver per node, sender handles
/// cloned per peer. This is the threaded runtime's original channel
/// fabric behind the [`Delivery`] trait.
pub struct ChannelDelivery {
    peers: Vec<Sender<Frame>>,
    rx: Receiver<Frame>,
    sent: u64,
}

/// Build the full n-node channel mesh; element `i` is node `i`'s
/// endpoint. Every endpoint holds a sender to every node (including
/// itself, which also keeps its own receiver connected while the node
/// lives).
pub fn channel_mesh(n: usize) -> Vec<ChannelDelivery> {
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Frame>();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .map(|rx| ChannelDelivery { peers: txs.clone(), rx, sent: 0 })
        .collect()
}

impl Delivery for ChannelDelivery {
    fn send(&mut self, to: usize, frame: Frame) -> Result<(), LmdflError> {
        self.sent += frame.bytes.len() as u64;
        crate::obs::counter("frame_send", "channel", 1);
        if frame.is_tombstone() {
            crate::obs::counter("frame_tombstone", "channel", 1);
        }
        let tx = self.peers.get(to).ok_or_else(|| {
            LmdflError::transport(
                to,
                format!("unknown peer {to} ({} in mesh)", self.peers.len()),
            )
        })?;
        // best-effort enqueue: a peer that already exited (its receiver
        // dropped) simply stops hearing us — the original runtime's
        // semantics; the failure surfaces at *its* neighbors' recv
        // deadlines, not at every sender
        let _ = tx.send(frame);
        Ok(())
    }

    fn recv(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<Frame>, LmdflError> {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => {
                crate::obs::counter("frame_recv", "channel", 1);
                Ok(Some(f))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // unreachable while this endpoint lives (it holds its own
            // sender), but total anyway
            Err(RecvTimeoutError::Disconnected) => Err(
                LmdflError::transport(None, "all peer endpoints closed"),
            ),
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.sent
    }
}

/// Buffered matcher over any [`Delivery`]: returns the frame for a
/// specific (from, round, phase) key, stashing out-of-order arrivals.
/// Payloads are shared `Arc`s, so stashing moves a handle, never the
/// bytes. This is what lets fast neighbors run ahead a round without
/// corrupting a slow receiver — on any transport.
pub struct Mailbox {
    delivery: Box<dyn Delivery>,
    stash: HashMap<(usize, u32, u8), VecDeque<Arc<[u8]>>>,
}

impl Mailbox {
    pub fn new(delivery: Box<dyn Delivery>) -> Mailbox {
        Mailbox { delivery, stash: HashMap::new() }
    }

    /// Send passthrough to the underlying transport.
    pub fn send(
        &mut self,
        to: usize,
        frame: Frame,
    ) -> Result<(), LmdflError> {
        self.delivery.send(to, frame)
    }

    /// The underlying transport's payload byte meter.
    pub fn wire_bytes(&self) -> u64 {
        self.delivery.wire_bytes()
    }

    /// Block until the frame keyed (from, round, phase) arrives,
    /// stashing everything else; `deadline` bounds the total wait (a
    /// dead peer becomes a typed transport error, not a hang).
    pub fn recv(
        &mut self,
        from: usize,
        round: u32,
        phase: u8,
        deadline: Duration,
    ) -> Result<Arc<[u8]>, LmdflError> {
        let key = (from, round, phase);
        let until = Instant::now() + deadline;
        loop {
            if let Some(q) = self.stash.get_mut(&key) {
                if let Some(bytes) = q.pop_front() {
                    return Ok(bytes);
                }
            }
            let now = Instant::now();
            if now >= until {
                return Err(LmdflError::transport(
                    from,
                    format!(
                        "timed out waiting for frame (round {round}, \
                         phase {phase})"
                    ),
                ));
            }
            if let Some(f) = self.delivery.recv(until - now)? {
                let k = (f.from, f.round, f.phase);
                if k == key {
                    return Ok(f.bytes);
                }
                self.stash.entry(k).or_default().push_back(f.bytes);
            }
        }
    }
}

/// Which [`Delivery`] implementation a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// in-process mpsc mesh (one OS thread per node)
    #[default]
    Channel,
    /// TCP sockets — one process per node via `lmdfl node --rank R`,
    /// or bound in-process for parity testing
    Tcp,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }

    pub fn parse_str(text: &str) -> Result<Self, ConfigError> {
        match text {
            "channel" => Ok(TransportKind::Channel),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(ConfigError(format!(
                "transport.kind must be 'channel' or 'tcp', got '{other}'"
            ))),
        }
    }
}

/// The `transport:` config section: which delivery backend the threaded
/// runtime uses, plus the TCP endpoint parameters (ignored for
/// `channel`). Node `i` listens on `base_port + i`; a multi-process
/// run's report/eval plane listens on `base_port + nodes`.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TransportConfig {
    pub kind: TransportKind,
    pub tcp: TcpOptions,
}

impl TransportConfig {
    /// TCP transport with default endpoint options.
    pub fn tcp_default() -> TransportConfig {
        TransportConfig {
            kind: TransportKind::Tcp,
            tcp: TcpOptions::default(),
        }
    }

    pub fn validate(&self, nodes: usize) -> Result<(), ConfigError> {
        let t = &self.tcp;
        if t.host.is_empty() {
            return Err(ConfigError("transport.host is empty".into()));
        }
        // node ports plus the report plane must fit in the port space
        if t.base_port as usize + nodes + 1 > 65535 {
            return Err(ConfigError(format!(
                "transport.base_port {} + {nodes} nodes + report port \
                 exceeds 65535",
                t.base_port
            )));
        }
        if !(t.connect_timeout_s > 0.0 && t.connect_timeout_s.is_finite())
        {
            return Err(ConfigError(
                "transport.connect_timeout_s must be finite and > 0"
                    .into(),
            ));
        }
        if !(t.retry_backoff_s > 0.0 && t.retry_backoff_s.is_finite()) {
            return Err(ConfigError(
                "transport.retry_backoff_s must be finite and > 0".into(),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("host", Json::str(&self.tcp.host)),
            ("base_port", Json::num(self.tcp.base_port as f64)),
            (
                "connect_timeout_s",
                Json::num(self.tcp.connect_timeout_s),
            ),
            ("retry_backoff_s", Json::num(self.tcp.retry_backoff_s)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let d = TcpOptions::default();
        let kind = match j.get_str("kind") {
            Some(k) => TransportKind::parse_str(k)?,
            None => TransportKind::default(),
        };
        let base_port = match j.get_usize("base_port") {
            Some(p) if (1..=65535).contains(&p) => p as u16,
            Some(p) => {
                return Err(ConfigError(format!(
                    "transport.base_port {p} outside 1..=65535"
                )))
            }
            None => d.base_port,
        };
        Ok(TransportConfig {
            kind,
            tcp: TcpOptions {
                host: j
                    .get_str("host")
                    .unwrap_or(&d.host)
                    .to_string(),
                base_port,
                connect_timeout_s: j
                    .get_f64("connect_timeout_s")
                    .unwrap_or(d.connect_timeout_s),
                retry_backoff_s: j
                    .get_f64("retry_backoff_s")
                    .unwrap_or(d.retry_backoff_s),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(from: usize, round: u32, phase: u8, byte: u8) -> Frame {
        Frame::new(from, round, phase, Arc::from(vec![byte; 4]))
    }

    #[test]
    fn channel_mesh_routes_and_meters() {
        let mut mesh = channel_mesh(3);
        let mut n2 = mesh.pop().unwrap();
        let mut n1 = mesh.pop().unwrap();
        let mut n0 = mesh.pop().unwrap();
        n0.send(1, frame(0, 0, 0, 7)).unwrap();
        n0.send(2, frame(0, 0, 0, 7)).unwrap();
        n2.send(1, frame(2, 0, 2, 9)).unwrap();
        assert_eq!(n0.wire_bytes(), 8);
        assert_eq!(n2.wire_bytes(), 4);
        let a = n1.recv(Duration::from_secs(1)).unwrap().unwrap();
        let b = n1.recv(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!((a.from, b.from), (0, 2));
        assert!(n1
            .recv(Duration::from_millis(5))
            .unwrap()
            .is_none());
        // unknown peer is a typed transport error
        assert!(matches!(
            n0.send(9, frame(0, 0, 0, 1)),
            Err(LmdflError::Transport { peer: Some(9), .. })
        ));
    }

    #[test]
    fn mailbox_stashes_out_of_order_arrivals() {
        let mut mesh = channel_mesh(2);
        let mut sender = mesh.pop().unwrap();
        let receiver = mesh.pop().unwrap();
        // arrive out of order: round 1 before round 0
        sender.send(0, frame(1, 1, 0, 11)).unwrap();
        sender.send(0, frame(1, 0, 0, 10)).unwrap();
        let mut mb = Mailbox::new(Box::new(receiver));
        let r0 = mb.recv(1, 0, 0, Duration::from_secs(1)).unwrap();
        assert_eq!(r0[0], 10);
        let r1 = mb.recv(1, 1, 0, Duration::from_secs(1)).unwrap();
        assert_eq!(r1[0], 11);
        // a missing frame times out with a typed error, not a hang
        let err = mb
            .recv(1, 2, 0, Duration::from_millis(10))
            .unwrap_err();
        assert!(matches!(
            err,
            LmdflError::Transport { peer: Some(1), .. }
        ));
    }

    #[test]
    fn transport_config_json_roundtrip_and_validation() {
        let cfg = TransportConfig::tcp_default();
        let back =
            TransportConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        assert!(cfg.validate(16).is_ok());
        // port-space overflow rejected
        let mut high = cfg.clone();
        high.tcp.base_port = 65530;
        assert!(high.validate(16).is_err());
        // bad kinds / ports rejected
        assert!(TransportKind::parse_str("carrier-pigeon").is_err());
        let j = Json::parse(r#"{"kind": "tcp", "base_port": 0}"#)
            .unwrap();
        assert!(TransportConfig::from_json(&j).is_err());
    }
}
