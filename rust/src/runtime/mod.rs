//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! request path with zero Python.
//!
//! Pipeline (see /opt/xla-example/load_hlo/): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format — jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.

pub mod manifest;

pub use manifest::{ArtifactInfo, Manifest, TensorSpec};

use std::path::{Path, PathBuf};

use crate::dfl::backend::LocalUpdate;
use crate::util::rng::Rng;
// Resolves to the in-crate PJRT stand-in (see `crate::xla`); when the real
// bindings are wired back in, this import is the only line that changes.
use crate::xla;

/// Artifact directory: $LMDFL_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LMDFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if the manifest exists — used by tests/benches to skip gracefully
/// when `make artifacts` has not run.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// A compiled HLO executable plus its I/O contract.
pub struct HloExecutor {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl HloExecutor {
    /// Compile `info.file` on the given client.
    pub fn compile(
        client: &xla::PjRtClient,
        info: ArtifactInfo,
    ) -> anyhow::Result<HloExecutor> {
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .map_err(|e| {
                anyhow::anyhow!("loading {}: {e:?}", info.file.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", info.name))?;
        Ok(HloExecutor { info, exe })
    }

    /// Execute with literal inputs; returns the flattened tuple outputs.
    pub fn run(
        &self,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.info.inputs.len(),
            "{} expects {} inputs, got {}",
            self.info.name,
            self.info.inputs.len(),
            inputs.len()
        );
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.info.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e:?}", self.info.name))?;
        // aot.py lowers with return_tuple=True
        tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.info.name))
    }
}

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let expect: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(
        data.len() == expect,
        "literal shape {shape:?} wants {expect} elements, got {}",
        data.len()
    );
    let lit = xla::Literal::vec1(data);
    if shape.is_empty() {
        // scalar
        return lit
            .reshape(&[])
            .map_err(|e| anyhow::anyhow!("reshape scalar: {e:?}"));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
}

/// Build an i32 literal of the given logical shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
}

/// The PJRT-backed [`LocalUpdate`] implementation for classifier models.
///
/// Loads `<artifact>_step` and `<artifact>_eval` (e.g. `mlp_mnist_step`).
/// The artifacts bake a fixed batch B; batches smaller than B are padded by
/// cycling rows (sampling with replacement), larger ones are processed in
/// chunks.
pub struct HloBackend {
    client: xla::PjRtClient,
    step_exe: HloExecutor,
    eval_exe: HloExecutor,
    param_count: usize,
    batch: usize,
    features: usize,
    /// parameter tensor layout for bias-zeroing at init
    tensors: Vec<TensorSpec>,
    /// padded-batch scratch (reused per step/eval chunk — the per-call
    /// feature/label Vec allocations were the runtime's hot-path leak)
    xb_scratch: Vec<f32>,
    yb_scratch: Vec<i32>,
}

impl HloBackend {
    /// Load and compile the step/eval artifacts for `artifact` from `dir`.
    pub fn load(
        dir: &Path,
        artifact: &str,
        expect_features: usize,
        _classes: usize,
    ) -> anyhow::Result<HloBackend> {
        let manifest = Manifest::load(dir)?;
        let step_info = manifest.get(&format!("{artifact}_step"))?.clone();
        let eval_info = manifest.get(&format!("{artifact}_eval"))?.clone();
        let features = step_info
            .features
            .ok_or_else(|| anyhow::anyhow!("{artifact}_step: no features"))?;
        anyhow::ensure!(
            features == expect_features,
            "artifact {artifact} expects feature dim {features}, dataset \
             provides {expect_features}"
        );
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        let param_count = step_info
            .params
            .ok_or_else(|| anyhow::anyhow!("{artifact}_step: no params"))?;
        let batch = step_info
            .batch
            .ok_or_else(|| anyhow::anyhow!("{artifact}_step: no batch"))?;
        let tensors = step_info.tensors.clone();
        let step_exe = HloExecutor::compile(&client, step_info)?;
        let eval_exe = HloExecutor::compile(&client, eval_info)?;
        Ok(HloBackend {
            client,
            step_exe,
            eval_exe,
            param_count,
            batch,
            features,
            tensors,
            xb_scratch: Vec::new(),
            yb_scratch: Vec::new(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Pad (by cycling) or keep a batch to exactly `self.batch` rows,
    /// filling the reused scratch buffers (no per-call allocation).
    fn fill_batch(&mut self, x: &[f32], y: &[u32]) {
        let n = y.len();
        let f = self.features;
        self.xb_scratch.clear();
        self.xb_scratch.reserve(self.batch * f);
        self.yb_scratch.clear();
        self.yb_scratch.reserve(self.batch);
        for bi in 0..self.batch {
            let src = bi % n;
            self.xb_scratch
                .extend_from_slice(&x[src * f..(src + 1) * f]);
            self.yb_scratch.push(y[src] as i32);
        }
    }
}

impl LocalUpdate for HloBackend {
    fn param_count(&self) -> usize {
        self.param_count
    }

    fn input_dim(&self) -> usize {
        self.features
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut p = vec![0.0f32; self.param_count];
        rng.fill_normal(&mut p, 0.0, 0.05);
        // zero bias tensors (names ending ".b"), mirroring the rust MLP
        let mut off = 0usize;
        for t in &self.tensors {
            let sz = t.elements();
            if t.name.ends_with(".b") {
                p[off..off + sz].iter_mut().for_each(|v| *v = 0.0);
            }
            off += sz;
        }
        p
    }

    fn step(
        &mut self,
        params: &mut [f32],
        x: &[f32],
        y: &[u32],
        lr: f32,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!y.is_empty(), "empty batch");
        self.fill_batch(x, y);
        let inputs = vec![
            literal_f32(params, &[self.param_count])?,
            literal_f32(&self.xb_scratch, &[self.batch, self.features])?,
            literal_i32(&self.yb_scratch, &[self.batch])?,
            literal_f32(&[lr], &[])?,
        ];
        let outs = self.step_exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 2, "step returns (params, loss)");
        let new_params = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("params out: {e:?}"))?;
        params.copy_from_slice(&new_params);
        let loss = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss out: {e:?}"))?[0];
        Ok(loss as f64)
    }

    fn evaluate(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[u32],
    ) -> anyhow::Result<(f64, usize)> {
        anyhow::ensure!(!y.is_empty(), "empty eval set");
        let n = y.len();
        let params_lit = literal_f32(params, &[self.param_count])?;
        let mut weighted_loss = 0.0f64;
        let mut correct_est = 0.0f64;
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(self.batch);
            self.fill_batch(
                &x[done * self.features..(done + take) * self.features],
                &y[done..done + take],
            );
            let inputs = vec![
                params_lit.clone(),
                literal_f32(&self.xb_scratch, &[self.batch, self.features])?,
                literal_i32(&self.yb_scratch, &[self.batch])?,
            ];
            let outs = self.eval_exe.run(&inputs)?;
            let loss = outs[0].to_vec::<f32>().map_err(
                |e| anyhow::anyhow!("eval loss: {e:?}"))?[0] as f64;
            let correct = outs[1].to_vec::<f32>().map_err(
                |e| anyhow::anyhow!("eval correct: {e:?}"))?[0] as f64;
            // the padded tail duplicates rows; rescale both stats by the
            // real fraction of the chunk
            let frac = take as f64 / self.batch as f64;
            weighted_loss += loss * take as f64;
            correct_est += correct * frac;
            done += take;
        }
        Ok((weighted_loss / n as f64, correct_est.round() as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_validate_shapes() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_f32(&[0.5], &[]).is_ok());
    }

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("LMDFL_ARTIFACTS", "/tmp/xyz");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/xyz"));
        std::env::remove_var("LMDFL_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }
}
