//! artifacts/manifest.json reader — the contract between the python AOT
//! compile path and the Rust runtime. Describes every artifact's file and
//! I/O shapes so buffers can be bound with zero Python at run time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let name = j
            .get_str("name")
            .ok_or_else(|| anyhow::anyhow!("tensor missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("tensor missing shape"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let dtype = j.get_str("dtype").unwrap_or("float32").to_string();
        Ok(TensorSpec { name, shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// flat parameter count (step/eval/grad artifacts)
    pub params: Option<usize>,
    /// baked batch size
    pub batch: Option<usize>,
    /// feature dim of one input row
    pub features: Option<usize>,
    /// parameter tensor layout (name, shape) — lets the runtime zero
    /// biases at init like the python models do
    pub tensors: Vec<TensorSpec>,
}

impl ArtifactInfo {
    pub fn input(&self, name: &str) -> Option<&TensorSpec> {
        self.inputs.iter().find(|t| t.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        let mut artifacts = BTreeMap::new();
        for (name, aj) in arts {
            let file = dir.join(
                aj.get_str("file")
                    .ok_or_else(|| anyhow::anyhow!("{name}: no file"))?,
            );
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                aj.get(key)
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file,
                    kind: aj.get_str("kind").unwrap_or("").to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    params: aj.get_usize("params"),
                    batch: aj.get_usize("batch"),
                    features: aj.get_usize("features"),
                    tensors: parse_specs("tensors")?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactInfo> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest ({} known: {:?})",
                self.artifacts.len(),
                self.artifacts.keys().take(8).collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let text = r#"{
 "artifacts": {
  "toy_step": {
   "file": "toy_step.hlo.txt",
   "kind": "step",
   "params": 10,
   "batch": 4,
   "features": 3,
   "inputs": [
    {"name": "params", "shape": [10], "dtype": "float32"},
    {"name": "x", "shape": [4, 3], "dtype": "float32"},
    {"name": "y", "shape": [4], "dtype": "int32"},
    {"name": "lr", "shape": [], "dtype": "float32"}
   ],
   "outputs": [
    {"name": "params", "shape": [10], "dtype": "float32"},
    {"name": "loss", "shape": [], "dtype": "float32"}
   ],
   "tensors": [
    {"name": "l0.w", "shape": [3, 2]},
    {"name": "l0.b", "shape": [2]}
   ]
  }
 }
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join("lmdfl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy_step").unwrap();
        assert_eq!(a.kind, "step");
        assert_eq!(a.params, Some(10));
        assert_eq!(a.batch, Some(4));
        assert_eq!(a.input("x").unwrap().shape, vec![4, 3]);
        assert_eq!(a.input("x").unwrap().elements(), 12);
        assert_eq!(a.tensors.len(), 2);
        assert!(m.get("nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent-lmdfl"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
