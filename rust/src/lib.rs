//! # lmdfl — Communication-Efficient Quantized Decentralized Federated Learning
//!
//! Production-grade reproduction of *"Communication-Efficient Design for
//! Quantized Decentralized Federated Learning"* (Chen, Liu, Chen, Wang —
//! 2023): LM-DFL (Lloyd-Max quantized gossip learning) and doubly-adaptive
//! DFL (ascending quantization-level schedule), with the QSGD / natural
//! compression / ALQ baselines, on a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! * **L3 (this crate)** — the decentralized training coordinator: topology,
//!   gossip rounds, quantizers, wire codec, adaptive level control, metrics.
//! * **L2/L1 (python/, build-time only)** — jax models + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed from [`runtime`] via
//!   PJRT. Python never runs on the training path.
//!
//! Quick start (see `examples/quickstart.rs`):
//! ```no_run
//! use lmdfl::config::ExperimentConfig;
//! use lmdfl::dfl::Trainer;
//!
//! let cfg = ExperimentConfig::default();
//! let log = Trainer::build(&cfg).unwrap().run().unwrap();
//! println!("final loss = {:?}", log.last_loss());
//! ```

pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod dfl;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod models;
pub mod quant;
pub mod runtime;
pub mod topology;
pub mod util;
