//! # lmdfl — Communication-Efficient Quantized Decentralized Federated Learning
//!
//! Production-grade reproduction of *"Communication-Efficient Design for
//! Quantized Decentralized Federated Learning"* (Chen, Liu, Chen, Wang —
//! 2023): LM-DFL (Lloyd-Max quantized gossip learning) and doubly-adaptive
//! DFL (ascending quantization-level schedule), with the QSGD / natural
//! compression / ALQ baselines, on a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! * **L3 (this crate)** — the decentralized training coordinator: topology,
//!   gossip rounds, quantizers, wire codec, adaptive level control, metrics.
//! * **L2/L1 (python/, build-time only)** — jax models + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed from [`runtime`] via
//!   PJRT. Python never runs on the training path.
//!
//! Quick start (see `examples/quickstart.rs`):
//! ```no_run
//! use lmdfl::prelude::*;
//!
//! let cfg = ExperimentConfig::default();
//! let log = Trainer::build(&cfg).unwrap().run().unwrap();
//! println!("final loss = {:?}", log.last_loss());
//! ```
//!
//! The supported public surface is curated in [`prelude`]; everything
//! else is implementation detail that may change between releases.
//!
//! ## Parallel round execution
//!
//! The matrix engine partitions its per-node round phases across a
//! persistent parked worker pool ([`util::pool`]; spawned once per
//! engine, woken per phase) sized by the `parallelism` config knob —
//! `"auto"` (default: one worker per hardware thread), `"off"`
//! (sequential), or a fixed worker count; on the CLI: `lmdfl train
//! --parallelism auto|off|N`. The per-element inner loops run as the
//! batch kernels of [`quant::kernels`] (autovectorized, with
//! runtime-gated AVX2 fast paths). Both are **bit-identical** to the
//! sequential/scalar reference for a fixed seed (node-partitioned
//! work, node-order reductions, IEEE-exact kernels; enforced by
//! `rust/tests/engine_parallel.rs`), so they are purely throughput
//! knobs — `cargo bench --bench micro_runtime` and `--bench
//! micro_quant` report the speedups.
//!
//! ## Virtual-time simulation (simnet)
//!
//! [`simnet`] is a deterministic discrete-event fabric simulator:
//! heterogeneous links (latency / bandwidth / jitter / drop), per-node
//! compute models with stragglers, and topology churn that rebuilds the
//! Metropolis confusion matrix mid-run. `DflEngine::run_simulated`
//! wraps training rounds in a [`simnet::Fabric`], filling the
//! `virtual_secs` / `straggler_wait_secs` metrics columns so `RunLog`
//! can emit the paper's loss-vs-time series; `lmdfl fig-time --preset
//! torus-16` compares LM-DFL / QSGD / doubly-adaptive on a
//! bandwidth-constrained torus. Configure via the `network:` config
//! section or the `--net-*` CLI flags. The fabric scales to
//! 10 000-node fleets: sparse O(degree) mixing state
//! ([`topology::SparseTopology`], power-iteration ζ), multiplexed
//! node groups over the worker pool, arena-recycled events, and
//! streamed run output (`--stream-csv`, presets
//! `random-regular-4096` / `torus-10k` and their `async-` variants).
//!
//! ## Asynchronous gossip (agossip)
//!
//! [`agossip`] removes the global round barrier: each node is a state
//! machine driven directly by simnet events — it trains as soon as its
//! own compute finishes, broadcasts one damped quantized differential
//! per local round, and mixes as soon as a configurable neighborhood
//! quorum (`wait_for: all | quorum | staleness`, plus a per-node
//! quorum timer) of fresh neighbor messages has arrived, using
//! staleness-weighted Metropolis mixing rows (row-stochastic for every
//! arrival order). Same quantizer stack, same determinism contract
//! (byte-identical event digests per seed). Enable with `mode:
//! "async"` / `lmdfl train --mode async`; `lmdfl fig-time --preset
//! async-torus-16` compares sync vs async under a straggler-heavy
//! torus.
//!
//! ## Pluggable transports ([`net`])
//!
//! The threaded runtime's byte movement sits behind the
//! [`net::Delivery`] trait: in-process channels (default), real
//! localhost TCP sockets (`transport: {"kind": "tcp"}` or `lmdfl node
//! --rank R` for one process per node), and a fault-injecting wrapper
//! that applies a simnet [`simnet::LinkModel`]'s drop/latency/jitter
//! to any inner transport in real time. All transports share one byte
//! accounting contract: measured `wire_bytes` equals the sum of
//! encoded `WireMessage` lengths. Errors at this boundary are the
//! typed [`error::LmdflError`] (truncation vs version-mismatch vs io),
//! never strings or panics.
//!
//! ## The wire format ([`quant::wire`])
//!
//! Every broadcast — matrix engine, async engine, threaded runtime —
//! is a versioned wire message: a 12-byte header (version, quantizer
//! tag, phase, index bit-width, sender, round) followed by the packed
//! sign/index codec body. With `encoding: "bitstream"` (the default)
//! engines transmit the encoded bytes and reconstruct estimates
//! exclusively by decoding them, and every byte-accounting figure is
//! the measured encoded length (fabric meters count one copy per
//! transmitted link); `encoding: "matrix"` keeps the legacy
//! in-memory exchange, bit-identical by contract. The byte stream is
//! pinned by golden fixtures (`rust/tests/wire_conformance.rs`);
//! format changes must bump `WIRE_VERSION` and re-bless them.
//!
//! ## Observability ([`obs`])
//!
//! An in-tree, zero-dependency tracing and telemetry layer: scoped
//! wall spans, virtual-clock spans, monotonic counters, and log2
//! histograms across every layer (engine round phases, agossip state
//! transitions, simnet event dispatch, every transport at frame
//! granularity). Off by default — one relaxed atomic load per probe —
//! and enabled with the `observe:` config section or `--trace-out` /
//! `--chrome-out`; sinks are a JSONL trace (schema `lmdfl-trace-v1`,
//! summarized by `lmdfl trace`) and a Chrome `trace_event` file for
//! `about:tracing` / Perfetto. Tracing never perturbs the determinism
//! contract: traced simnet runs produce byte-identical event digests.
//!
//! ## Sweeps & analysis ([`sweep`])
//!
//! `lmdfl sweep` expands a grid (quantizer × topology × network
//! regime × engine mode × seed repeats) over a base config and runs
//! every cell through the existing `train` paths with tracing always
//! on. Each cell runs as a subprocess in its own content-addressed
//! directory (`cells/<config-hash>/`, FNV-1a over the config's
//! identity JSON), sampled at a fixed cadence via `/proc` (CPU% and
//! RSS to `resources.jsonl`, schema `lmdfl-resources-v1`); completed
//! cells are skipped on re-run, so interrupted sweeps resume. One
//! `manifest.json` (schema `lmdfl-sweep-v1`) records axes, per-cell
//! outcomes, artifact paths and timings. `lmdfl analyse
//! <manifest.json>` rolls every cell's trace up with
//! [`obs::aggregate`] into four tidy CSVs (cells / spans / counters /
//! histograms), and `lmdfl fig-time --from-sweep <manifest.json>`
//! rebuilds the loss-vs-virtual-time tables straight from sweep
//! artifacts without re-running anything.
//!
//! ## Bench reports
//!
//! Bench targets print a criterion-like text table and, when
//! `LMDFL_BENCH_JSON=<dir>` is set, also write a machine-readable
//! `BENCH_<target>.json` (schema `lmdfl-bench-v1`, see [`bench`]) that CI
//! archives to track the perf trajectory across PRs.
//!
//! ## Offline build notes
//!
//! The workspace builds with zero registry dependencies: `anyhow` is a
//! vendored minimal implementation (`vendor/anyhow`), and the PJRT/XLA
//! bindings are an inert API-compatible stand-in ([`xla`]) — HLO-backend
//! runs fail fast with a clear message until a real toolchain is wired
//! back in; everything else (matrix engine, threaded runtime, quantizers,
//! figure drivers) is pure Rust.

pub mod agossip;
pub mod bench;
pub mod cli;
pub mod config;
pub(crate) mod data;
pub mod dfl;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub(crate) mod models;
pub mod net;
pub mod obs;
pub mod prelude;
pub mod quant;
pub mod runtime;
pub mod simnet;
pub mod sweep;
pub mod topology;
pub mod util;
pub mod xla;
