//! fig-robust: honest training loss vs *measured wire bytes* under a
//! Byzantine minority — the adversarial companion to `fig-time`.
//!
//! The preset trains the `fig-time` torus-16 fleet (bitstream wire,
//! 2 Mbps heterogeneous links) with the first `f = 2` nodes running the
//! sign-flip attack: each broadcasts `Q(−(x − x̂))`, the exact negation
//! of its honest differential, so the corruption is energy-matched and
//! invisible to any magnitude filter. Three curves differ only in the
//! mixing rule: plain Metropolis, trimmed-Metropolis, and coordinate
//! median. Loss is evaluated on the HONEST nodes' average (an attacker
//! parks its parameters wherever it likes; averaging them in would
//! grade the defender on the adversary's weights).
//!
//! Expected shape: plain Metropolis keeps folding the flipped
//! differentials into every honest estimate and stalls well above the
//! robust curves; the trimmed and median rules discard the
//! coordinate-wise extremes and keep descending at the same wire-byte
//! budget.

use super::{Curve, Scale};
use crate::config::{
    AttackConfig, AttackKind, ExperimentConfig, MixingKind,
};
use crate::metrics::{fnum, Table};
use crate::simnet::NetworkConfig;

/// Number of Byzantine nodes in the preset (nodes `0..BYZANTINE_F`).
pub const BYZANTINE_F: usize = 2;

/// The preset's training config: the fig-time torus-16 setup with an
/// `f = 2` sign-flip minority (mixing is filled per curve).
pub fn robust_config(scale: Scale) -> ExperimentConfig {
    let mut cfg = super::fig_time::torus16_config(scale);
    cfg.name = "fig-robust-torus-16".into();
    cfg.attack = Some(AttackConfig {
        kind: AttackKind::SignFlip,
        f: BYZANTINE_F,
    });
    cfg
}

/// The preset's fabric: identical to the fig-time torus-16 fabric, so
/// the byte axis is comparable across the two figures.
pub fn robust_network() -> NetworkConfig {
    super::fig_time::torus16_network()
}

/// The three mixing-rule curves the robustness comparison plots.
///
/// The trim parameter is the per-NEIGHBORHOOD tolerance, not the
/// global `f`: attackers 0 and 1 share no honest neighbor on the 4×4
/// torus, so every honest row sees at most one Byzantine column and
/// `trimmed(1)` suffices (while `trimmed(2)` would over-trim the
/// degree-4 rows down to self-only, discarding mixing entirely).
pub fn curve_set() -> Vec<(&'static str, MixingKind)> {
    vec![
        ("plain metropolis", MixingKind::Metropolis),
        ("trimmed metropolis", MixingKind::Trimmed { f: 1 }),
        ("coordinate median", MixingKind::Median),
    ]
}

/// The honest node ids of a config (everything past the attacked
/// prefix; the whole fleet when no `attack:` section is present).
pub fn honest_nodes(cfg: &ExperimentConfig) -> Vec<usize> {
    let f = cfg.attack.as_ref().map_or(0, |a| a.f);
    (f..cfg.nodes).collect()
}

/// Run one attacked config on its own identically-seeded fabric,
/// evaluating loss on the honest subset only.
pub fn run_attacked_labeled(
    cfg: ExperimentConfig,
    net: &NetworkConfig,
    label: &str,
) -> anyhow::Result<Curve> {
    let topo = crate::topology::Topology::build(
        &cfg.topology,
        cfg.nodes,
        cfg.seed,
    );
    let mut fabric = crate::simnet::Fabric::new(net, &topo, cfg.seed);
    let mut trainer = crate::dfl::Trainer::build(&cfg)?;
    trainer
        .engine_mut()
        .set_eval_nodes(Some(honest_nodes(&cfg)));
    let log = trainer.engine_mut().run_simulated(&mut fabric)?;
    Ok(Curve { label: label.to_string(), log })
}

/// Run every mixing curve of the preset: same fleet, same adversary,
/// same fabric seed — only the aggregation rule differs.
pub fn run(
    base: ExperimentConfig,
    net: NetworkConfig,
) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, mixing) in curve_set() {
        let mut cfg = base.clone();
        cfg.name = label.to_string();
        cfg.mixing = mixing;
        curves.push(run_attacked_labeled(cfg, &net, label)?);
    }
    Ok(curves)
}

/// Panel: honest training loss at cumulative measured wire MB, per
/// mixing rule.
pub fn render_loss_vs_bytes(curves: &[Curve]) -> String {
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(curves.iter().map(|c| {
            let r = &c.log.records[k];
            format!(
                "{}@{:.3}MB",
                fnum(r.loss),
                r.wire_bytes as f64 / 1e6
            )
        }));
        t.row(row);
    }
    let mut out = String::from(
        "panel: honest training loss @ cumulative wire MB \
         (f=2 sign-flip)\n",
    );
    out.push_str(&t.render());
    out
}

/// Summary: measured wire MB each mixing rule had spent when its
/// honest loss first reached `target` (the robustness analogue of
/// fig-time's time-to-target table).
pub fn bytes_to_target(curves: &[Curve], target: f64) -> String {
    let mut t = Table::new(&[
        "mixing rule",
        "target loss",
        "wire MB",
        "final loss",
    ]);
    for c in curves {
        let hit = c.log.record_at_loss(target);
        let wire = hit
            .map(|r| format!("{:.3}", r.wire_bytes as f64 / 1e6))
            .unwrap_or_else(|| "not reached".into());
        t.row(vec![
            c.label.clone(),
            fnum(target),
            wire,
            fnum(c.log.last_loss().unwrap_or(f64::NAN)),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    /// Shrunk preset: the full torus-16 geometry and adversary, tiny
    /// data so the three curves run in CI time.
    fn tiny() -> (ExperimentConfig, NetworkConfig) {
        let mut cfg = robust_config(Scale::Quick);
        cfg.rounds = 12;
        cfg.dataset = DatasetKind::Blobs {
            train: 480,
            test: 120,
            dim: 10,
            classes: 4,
        };
        (cfg, robust_network())
    }

    #[test]
    fn preset_config_is_valid_and_attacked() {
        let cfg = robust_config(Scale::Quick);
        cfg.validate().unwrap();
        assert_eq!(cfg.nodes, 16);
        let atk = cfg.attack.as_ref().unwrap();
        assert_eq!(atk.f, BYZANTINE_F);
        assert_eq!(atk.kind, AttackKind::SignFlip);
        assert_eq!(honest_nodes(&cfg), (2..16).collect::<Vec<_>>());
    }

    #[test]
    fn robust_mixing_beats_plain_under_sign_flip() {
        // the acceptance scenario: f=2 sign-flip on the torus-16
        // preset. The trimmed rule must reach a loss the plain
        // Metropolis row never touches at any point of its run.
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        assert_eq!(curves.len(), 3);
        let plain = &curves[0].log;
        let trimmed = &curves[1].log;
        // both runs stayed finite
        for c in &curves {
            for r in &c.log.records {
                assert!(r.loss.is_finite(), "{} diverged", c.label);
            }
        }
        // the trimmed curve actually learned
        let t_first = trimmed.records.first().unwrap().loss;
        let t_last = trimmed.last_loss().unwrap();
        assert!(t_last < t_first, "trimmed: {t_first} -> {t_last}");
        // target: just above the trimmed rule's final honest loss —
        // trimmed reaches it by construction, plain must not at ANY
        // round of an equally long run
        let target = t_last * 1.05;
        assert!(trimmed.record_at_loss(target).is_some());
        let plain_best = plain
            .records
            .iter()
            .map(|r| r.loss)
            .fold(f64::INFINITY, f64::min);
        assert!(
            plain.record_at_loss(target).is_none(),
            "plain metropolis reached {target} (best {plain_best}) \
             despite the sign-flip minority"
        );
    }

    #[test]
    fn median_survives_the_attack_too() {
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        let median = &curves[2].log;
        let first = median.records.first().unwrap().loss;
        let last = median.last_loss().unwrap();
        assert!(last.is_finite() && last < first, "{first} -> {last}");
    }

    #[test]
    fn curves_share_the_byte_axis() {
        // same quantizer, same fleet, same fabric: every curve ships
        // the same measured bytes per round, so the byte axis aligns
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        let base: Vec<u64> = curves[0]
            .log
            .records
            .iter()
            .map(|r| r.wire_bytes)
            .collect();
        for c in &curves[1..] {
            let bytes: Vec<u64> =
                c.log.records.iter().map(|r| r.wire_bytes).collect();
            assert_eq!(base, bytes, "{} bytes diverged", c.label);
        }
    }

    #[test]
    fn renders_nonempty() {
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        assert!(render_loss_vs_bytes(&curves).contains("panel:"));
        assert!(
            bytes_to_target(&curves, 1.0).contains("mixing rule")
        );
    }
}
