//! fig-time: training loss vs *virtual time* on a simulated fabric —
//! the paper's time-progression comparison (§VI) generalized from
//! "bits ÷ 100 Mbps" to a discrete-event network with heterogeneous
//! links and stragglers.
//!
//! The flagship preset (`torus-16`) trains 16 nodes on a 2D torus over
//! bandwidth-constrained (2 Mbps), 5 ms links with heterogeneous node
//! speeds and a 10% straggler tail, and compares LM-DFL against QSGD
//! and the doubly-adaptive schedule. Expected shape: the coarse/adaptive
//! quantizers buy wall-clock, not just bits — message serialization
//! makes the 8-bit baselines pay for every extra level.
//!
//! The `async-torus-16` preset holds the quantizer fixed (LM-DFL) and
//! varies the *engine* instead: the synchronous round barrier vs the
//! asynchronous event-driven engine ([`crate::agossip`]) on a
//! straggler-heavy torus (25% straggler probability, 8× slowdown).
//! Expected shape: the sync engine pays the slowest node's straggle
//! every round (P ≈ 1 − 0.75¹⁶ ≈ 99% of rounds stall at the barrier),
//! while async nodes proceed on a neighborhood quorum — same
//! quantizer, same per-message byte budget, less virtual time to the
//! same loss.

use super::{Curve, Scale};
use crate::agossip::{AsyncConfig, WaitPolicy};
use crate::config::{
    BackendKind, DatasetKind, EngineMode, ExperimentConfig, LrSchedule,
    QuantizerKind, TopologyKind,
};
use crate::metrics::{fnum, Table};
use crate::simnet::{ComputeModel, LinkModel, NetworkConfig};

/// Named scenario presets for the `fig-time` CLI.
pub fn preset(
    name: &str,
    scale: Scale,
) -> anyhow::Result<(ExperimentConfig, NetworkConfig)> {
    match name {
        "torus-16" => Ok((torus16_config(scale), torus16_network())),
        "async-torus-16" => {
            Ok((async_torus16_config(scale), async_torus16_network()))
        }
        "random-regular-4096" => Ok((
            scale_config(name, 4096, false, scale),
            scale_network(),
        )),
        "torus-10k" => {
            Ok((scale_config(name, 10_000, false, scale), scale_network()))
        }
        "async-random-regular-4096" => Ok((
            scale_config(name, 4096, true, scale),
            scale_network(),
        )),
        "async-torus-10k" => {
            Ok((scale_config(name, 10_000, true, scale), scale_network()))
        }
        other => anyhow::bail!(
            "unknown fig-time preset '{other}' \
             (have: torus-16, async-torus-16, random-regular-4096, \
             torus-10k, async-random-regular-4096, async-torus-10k)"
        ),
    }
}

/// Run an already-built preset: quantizer curves for `torus-16`,
/// engine (sync vs async) curves for `async-torus-16`. Takes the
/// `(cfg, net)` pair [`preset`] returned so CLI-level tweaks to either
/// are honored by the run.
pub fn run_preset(
    name: &str,
    cfg: ExperimentConfig,
    net: NetworkConfig,
) -> anyhow::Result<Vec<Curve>> {
    match name {
        "async-torus-16" => run_sync_vs_async(cfg, net),
        "torus-16" => run(cfg, net),
        "random-regular-4096"
        | "torus-10k"
        | "async-random-regular-4096"
        | "async-torus-10k" => run_scale(cfg, net),
        other => anyhow::bail!(
            "unknown fig-time preset '{other}' \
             (have: torus-16, async-torus-16, random-regular-4096, \
             torus-10k, async-random-regular-4096, async-torus-10k)"
        ),
    }
}

/// 16-node torus training config (quantizer is filled per curve).
pub fn torus16_config(scale: Scale) -> ExperimentConfig {
    let (train, test, rounds) = match scale {
        Scale::Quick => (480, 160, 20),
        Scale::Full => (3200, 800, 80),
    };
    ExperimentConfig {
        name: "fig-time-torus-16".into(),
        seed: 17,
        nodes: 16,
        tau: 4,
        rounds,
        batch_size: 32,
        lr: LrSchedule::fixed(0.02),
        topology: TopologyKind::Torus,
        quantizer: QuantizerKind::LloydMax { s: 16, iters: 12 },
        dataset: DatasetKind::SynthMnist { train, test },
        backend: BackendKind::RustMlp { hidden: vec![64] },
        noniid_fraction: 0.5,
        link_bps: 2e6,
        eval_every: 1,
        parallelism: crate::config::Parallelism::Auto,
        network: None, // filled by the driver per curve
        mode: EngineMode::Sync,
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

/// Bandwidth-constrained heterogeneous fabric for the torus-16 preset.
pub fn torus16_network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.005,
            bandwidth_bps: 2e6,
            jitter_s: 0.001,
            drop_prob: 0.0,
        },
        link_hetero_spread: 0.5,
        compute: ComputeModel {
            base_step_s: 2e-3,
            hetero_spread: 0.5,
            straggler_prob: 0.1,
            straggler_slowdown: 4.0,
        },
        churn: Default::default(),
    }
}

/// 16-node torus config for the sync-vs-async comparison (engine mode
/// is filled per curve; the quantizer is held fixed at LM-DFL).
pub fn async_torus16_config(scale: Scale) -> ExperimentConfig {
    let mut cfg = torus16_config(scale);
    cfg.name = "fig-time-async-torus-16".into();
    cfg
}

/// Straggler-heavy fabric for the async preset: the same
/// bandwidth-constrained heterogeneous torus, but every node straggles
/// 25% of its rounds at 8× slowdown — the regime where the global
/// barrier wastes the most virtual time.
pub fn async_torus16_network() -> NetworkConfig {
    let mut net = torus16_network();
    net.compute.straggler_prob = 0.25;
    net.compute.straggler_slowdown = 8.0;
    net
}

/// The asynchronous engine settings of the `async-torus-16` preset.
pub fn async_torus16_policy() -> AsyncConfig {
    AsyncConfig {
        wait_for: WaitPolicy::Quorum { k: 2 },
        staleness_lambda: 0.5,
        quorum_timeout_s: 0.5,
    }
}

/// Large-fleet scale preset config: `nodes` machines on a sparse
/// constant-degree graph (random 4-regular, or the 100×100 torus), a
/// tiny model and dataset, and a sparse eval cadence — what these
/// presets measure is the *fabric* (events per second, resident
/// memory, mixing throughput), not learning quality.
/// `rust/tests/simnet_determinism.rs` pins their event digests and the
/// bench suite gates their throughput and peak RSS.
pub fn scale_config(
    name: &str,
    nodes: usize,
    async_mode: bool,
    scale: Scale,
) -> ExperimentConfig {
    let (train_per_node, rounds) = match scale {
        Scale::Quick => (2, 8),
        Scale::Full => (8, 32),
    };
    ExperimentConfig {
        name: format!("fig-time-{name}"),
        seed: 29,
        nodes,
        tau: 2,
        rounds,
        batch_size: 8,
        lr: LrSchedule::fixed(0.05),
        topology: if name.contains("torus") {
            TopologyKind::Torus
        } else {
            TopologyKind::RandomRegular { k: 4 }
        },
        quantizer: QuantizerKind::LloydMax { s: 8, iters: 4 },
        dataset: DatasetKind::Blobs {
            train: nodes * train_per_node,
            test: (nodes / 8).max(64),
            dim: 10,
            classes: 4,
        },
        backend: BackendKind::RustMlp { hidden: vec![8] },
        // uniform shards: at 1-2 samples per node the label-skewed
        // split would leave most of a 10k fleet empty
        noniid_fraction: 0.0,
        link_bps: 1e8,
        eval_every: 8,
        parallelism: crate::config::Parallelism::Auto,
        network: None, // filled by the driver
        mode: if async_mode {
            EngineMode::Async
        } else {
            EngineMode::Sync
        },
        encoding: Default::default(),
        agossip: if async_mode {
            Some(async_torus16_policy())
        } else {
            None
        },
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

/// Fast, mildly heterogeneous fabric for the scale presets: event
/// volume comes from the fleet size, so links are quick and stragglers
/// rare — the regime where events-per-second is the binding metric.
pub fn scale_network() -> NetworkConfig {
    NetworkConfig {
        link: LinkModel {
            latency_s: 0.001,
            bandwidth_bps: 1e8,
            jitter_s: 1e-4,
            drop_prob: 0.0,
        },
        link_hetero_spread: 0.2,
        compute: ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.2,
            straggler_prob: 0.05,
            straggler_slowdown: 4.0,
        },
        churn: Default::default(),
    }
}

/// Run a scale preset: one curve, the engine picked by the preset's
/// `mode:` (the async variants carry their `agossip:` policy).
pub fn run_scale(
    mut cfg: ExperimentConfig,
    net: NetworkConfig,
) -> anyhow::Result<Vec<Curve>> {
    cfg.network = Some(net);
    let label = cfg.name.clone();
    Ok(vec![run_simulated_labeled(cfg, &label)?])
}

/// The two engine curves of the async preset: identical quantizer,
/// identical fabric seed (same links, same straggler draws feeding the
/// compute models), only the execution model differs.
pub fn run_sync_vs_async(
    base: ExperimentConfig,
    net: NetworkConfig,
) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, mode) in [
        ("sync LM-DFL", EngineMode::Sync),
        ("async LM-DFL", EngineMode::Async),
    ] {
        let mut cfg = base.clone();
        cfg.name = label.to_string();
        cfg.network = Some(net.clone());
        cfg.mode = mode;
        if mode == EngineMode::Async {
            cfg.agossip = Some(async_torus16_policy());
        }
        curves.push(run_simulated_labeled(cfg, label)?);
    }
    Ok(curves)
}

/// The three quantizer curves the time comparison plots.
pub fn curve_set() -> Vec<(&'static str, QuantizerKind)> {
    vec![
        ("LM-DFL", QuantizerKind::LloydMax { s: 16, iters: 12 }),
        ("QSGD", QuantizerKind::Qsgd { s: 16 }),
        (
            "doubly-adaptive",
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 12, s_max: 1024 },
        ),
    ]
}

/// Run every curve of the preset under its own (identically seeded)
/// fabric: same links, same stragglers, same churn trajectory — only
/// the quantizer differs, exactly like the paper's per-figure setups.
pub fn run(
    base: ExperimentConfig,
    net: NetworkConfig,
) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, quant) in curve_set() {
        let mut cfg = base.clone();
        cfg.name = label.to_string();
        cfg.quantizer = quant;
        cfg.network = Some(net.clone());
        curves.push(run_simulated_labeled(cfg, label)?);
    }
    Ok(curves)
}

/// Run a simulated training (via [`crate::dfl::Trainer::run_simulated`])
/// and stamp the curve label.
pub fn run_simulated_labeled(
    cfg: ExperimentConfig,
    label: &str,
) -> anyhow::Result<Curve> {
    let log = crate::dfl::Trainer::run_simulated(&cfg)?;
    Ok(Curve { label: label.to_string(), log })
}

/// Rebuild fig-time curves from a sweep's per-cell round CSVs
/// instead of re-running anything: one curve per completed cell,
/// labeled by cell id. Artifact paths in the manifest are relative
/// to its directory.
pub fn curves_from_sweep(
    manifest: &std::path::Path,
) -> anyhow::Result<Vec<Curve>> {
    let m = crate::sweep::SweepManifest::load(manifest)?;
    let dir = manifest
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."));
    let mut curves = Vec::new();
    for cell in &m.cells {
        if !cell.ok() {
            continue;
        }
        let path = dir.join(&cell.rounds_csv);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", path.display())
        })?;
        curves.push(Curve {
            label: cell.id.clone(),
            log: crate::metrics::RunLog::from_csv(&cell.id, &text)?,
        });
    }
    anyhow::ensure!(
        !curves.is_empty(),
        "sweep manifest {} has no completed cells",
        manifest.display()
    );
    Ok(curves)
}

/// Panel: training loss at cumulative virtual seconds, per curve.
pub fn render_loss_vs_time(curves: &[Curve]) -> String {
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(curves.iter().map(|c| {
            let r = &c.log.records[k];
            format!("{}@{:.2}s", fnum(r.loss), r.virtual_secs)
        }));
        t.row(row);
    }
    let mut out = String::from(
        "panel: training loss @ cumulative virtual seconds\n",
    );
    out.push_str(&t.render());
    out
}

/// Summary: virtual seconds (and straggler wait share) to a target
/// loss, plus the MEASURED bytes the fabric had carried by that same
/// record (the sum of encoded wire-message lengths over every
/// transmitted link copy up to the round the target was reached).
pub fn time_to_target(curves: &[Curve], target: f64) -> String {
    let mut t = Table::new(&[
        "curve",
        "target loss",
        "virtual secs",
        "mean straggler wait",
        "wire MB",
    ]);
    for c in curves {
        // secs and bytes come from the SAME record — the first one at
        // or below the target — so the byte column answers "what did
        // reaching the target cost", not "what did the whole run cost"
        let hit = c.log.record_at_loss(target);
        let secs = hit
            .map(|r| format!("{:.2}", r.virtual_secs))
            .unwrap_or_else(|| "not reached".into());
        let wire = hit
            .map(|r| format!("{:.3}", r.wire_bytes as f64 / 1e6))
            .unwrap_or_else(|| "-".into());
        let wait = c
            .log
            .records
            .iter()
            .map(|r| r.straggler_wait_secs)
            .sum::<f64>()
            / c.log.records.len().max(1) as f64;
        t.row(vec![
            c.label.clone(),
            fnum(target),
            secs,
            format!("{wait:.3}s"),
            wire,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (ExperimentConfig, NetworkConfig) {
        let mut cfg = torus16_config(Scale::Quick);
        cfg.nodes = 8;
        cfg.rounds = 8;
        cfg.dataset = DatasetKind::Blobs {
            train: 240,
            test: 80,
            dim: 10,
            classes: 4,
        };
        (cfg, torus16_network())
    }

    #[test]
    fn preset_lookup() {
        assert!(preset("torus-16", Scale::Quick).is_ok());
        assert!(preset("async-torus-16", Scale::Quick).is_ok());
        assert!(preset("nope", Scale::Quick).is_err());
        let (cfg, net) = preset("torus-16", Scale::Quick).unwrap();
        assert!(run_preset("nope", cfg, net).is_err());
    }

    #[test]
    fn scale_presets_build() {
        for name in [
            "random-regular-4096",
            "torus-10k",
            "async-random-regular-4096",
            "async-torus-10k",
        ] {
            let (cfg, _net) = preset(name, Scale::Quick).unwrap();
            cfg.validate().unwrap();
            assert_eq!(
                cfg.mode == EngineMode::Async,
                name.starts_with("async-"),
                "{name}: wrong engine mode"
            );
            assert_eq!(cfg.agossip.is_some(), name.starts_with("async-"));
            if name.contains("torus") {
                assert_eq!(cfg.nodes, 10_000);
                assert!(matches!(cfg.topology, TopologyKind::Torus));
            } else {
                assert_eq!(cfg.nodes, 4096);
                assert!(matches!(
                    cfg.topology,
                    TopologyKind::RandomRegular { k: 4 }
                ));
            }
            // the fabric metric presets evaluate sparsely
            assert!(cfg.eval_every > 1);
        }
    }

    #[test]
    fn shrunk_scale_preset_runs_both_engines() {
        // the full fleets belong to the bench suite; smoke-shrink the
        // preset to 64 nodes and drive both engine paths through
        // run_preset's dispatch
        for name in ["random-regular-4096", "async-random-regular-4096"]
        {
            let (mut cfg, net) = preset(name, Scale::Quick).unwrap();
            cfg.nodes = 64;
            cfg.rounds = 4;
            cfg.dataset = DatasetKind::Blobs {
                train: 128,
                test: 64,
                dim: 10,
                classes: 4,
            };
            let curves = run_preset(name, cfg, net).unwrap();
            assert_eq!(curves.len(), 1, "{name}");
            assert_eq!(curves[0].log.records.len(), 4, "{name}");
        }
    }

    #[test]
    fn async_beats_sync_to_target_loss_under_stragglers() {
        // tiny version of the async-torus-16 acceptance scenario: same
        // quantizer and per-message byte budget, straggler-heavy torus
        // — the async engine must reach the preset's target loss in
        // less virtual time than the synchronous round barrier
        let mut cfg = async_torus16_config(Scale::Quick);
        cfg.nodes = 8;
        cfg.rounds = 10;
        cfg.dataset = DatasetKind::Blobs {
            train: 240,
            test: 80,
            dim: 10,
            classes: 4,
        };
        let curves =
            run_sync_vs_async(cfg, async_torus16_network()).unwrap();
        assert_eq!(curves.len(), 2);
        let sync = &curves[0].log;
        let asyn = &curves[1].log;
        // the preset's target: just above the worse of the two final
        // losses, so both curves reach it
        let target = sync
            .last_loss()
            .unwrap()
            .max(asyn.last_loss().unwrap())
            * 1.1;
        let t_sync = sync.virtual_secs_to_loss(target).unwrap();
        let t_async = asyn.virtual_secs_to_loss(target).unwrap();
        assert!(
            t_async < t_sync,
            "async {t_async}s !< sync {t_sync}s to loss {target}"
        );
        // both engines actually learned
        assert!(
            sync.last_loss().unwrap()
                < sync.records.first().unwrap().loss
        );
        assert!(
            asyn.last_loss().unwrap()
                < asyn.records.first().unwrap().loss
        );
    }

    #[test]
    fn torus16_bitstream_byte_accounting_is_exact() {
        // acceptance: with encoding: bitstream, simnet byte accounting
        // equals the sum of encoded WireMessage lengths exactly
        let (mut cfg, net) = preset("torus-16", Scale::Quick).unwrap();
        cfg.rounds = 4;
        cfg.dataset = DatasetKind::Blobs {
            train: 320,
            test: 80,
            dim: 8,
            classes: 4,
        };
        cfg.network = Some(net.clone());
        assert_eq!(
            cfg.encoding,
            crate::config::WireEncoding::Bitstream
        );
        let topo = crate::topology::Topology::build(
            &cfg.topology,
            cfg.nodes,
            cfg.seed,
        );
        let mut fabric =
            crate::simnet::Fabric::new(&net, &topo, cfg.seed);
        let mut trainer = crate::dfl::Trainer::build(&cfg).unwrap();
        let log =
            trainer.engine_mut().run_simulated(&mut fabric).unwrap();
        // the 16-node torus is 4-regular and this preset has no churn,
        // drops or offline nodes: every broadcast went out on exactly
        // 4 links, so the fabric's independent byte meter must equal
        // 4 × the engine's summed encoded message lengths, byte for byte
        let sent: u64 =
            trainer.engine().node_wire_bytes().iter().sum();
        assert!(sent > 0);
        assert_eq!(fabric.bytes_on_wire(), sent * 4);
        assert_eq!(
            log.records.last().unwrap().wire_bytes,
            fabric.bytes_on_wire()
        );
    }

    #[test]
    fn async_torus16_bitstream_byte_accounting_is_exact() {
        // the async half of the acceptance criterion, on the async
        // preset's straggler-heavy fabric
        let (mut cfg, net) =
            preset("async-torus-16", Scale::Quick).unwrap();
        cfg.rounds = 4;
        cfg.dataset = DatasetKind::Blobs {
            train: 320,
            test: 80,
            dim: 8,
            classes: 4,
        };
        cfg.network = Some(net);
        cfg.mode = EngineMode::Async;
        cfg.agossip = Some(async_torus16_policy());
        let log = crate::agossip::AsyncGossipEngine::new(&cfg)
            .unwrap()
            .run()
            .unwrap();
        // engine-side per-copy count == the substrate's meter
        assert_eq!(log.link_bytes, log.fabric_link_bytes);
        // every broadcast produced one node record carrying its size
        let sent: u64 = log.nodes.iter().map(|r| r.wire_bytes).sum();
        assert!(sent > 0);
        assert_eq!(sent, log.wire_bytes);
        // 4-regular torus, no churn/offline: 4 copies per broadcast
        assert_eq!(log.link_bytes, log.wire_bytes * 4);
    }

    #[test]
    fn three_curves_with_monotone_virtual_time() {
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        assert_eq!(curves.len(), 3);
        for c in &curves {
            let mut prev = 0.0;
            for r in &c.log.records {
                assert!(
                    r.virtual_secs > prev,
                    "{}: clock not monotone",
                    c.label
                );
                prev = r.virtual_secs;
            }
        }
        // curves are distinct series (different quantizers -> different
        // losses and different on-wire message sizes -> different clocks)
        let final_losses: Vec<u64> = curves
            .iter()
            .map(|c| c.log.last_loss().unwrap().to_bits())
            .collect();
        assert!(
            final_losses[0] != final_losses[1]
                || final_losses[1] != final_losses[2],
            "all curves identical"
        );
    }

    #[test]
    fn coarser_quantizer_runs_faster_in_virtual_time() {
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        let by_label = |l: &str| {
            curves
                .iter()
                .find(|c| c.label == l)
                .unwrap()
                .log
                .records
                .last()
                .unwrap()
                .virtual_secs
        };
        // doubly-adaptive starts at s1=4 (2-bit messages) — on a
        // bandwidth-bound fabric it must finish its rounds sooner than
        // the fixed 4-bit baselines
        assert!(
            by_label("doubly-adaptive") < by_label("QSGD"),
            "adaptive {} !< qsgd {}",
            by_label("doubly-adaptive"),
            by_label("QSGD")
        );
    }

    #[test]
    fn renders_nonempty() {
        let (cfg, net) = tiny();
        let curves = run(cfg, net).unwrap();
        assert!(render_loss_vs_time(&curves).contains("panel:"));
        assert!(time_to_target(&curves, 1.0).contains("virtual secs"));
    }
}
