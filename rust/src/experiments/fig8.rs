//! Fig. 8: doubly-adaptive DFL vs fixed-level QSGD (2/4/8-bit), under both
//! a fixed learning rate and the paper's variable rate (−20% / 10 iters),
//! plus the bits-per-element schedule ⌈log₂ s_k⌉ (panels c/f).
//!
//! Expected shape (§VI-B3): doubly-adaptive reaches any target loss with
//! the fewest communicated bits; its bits-per-element start low (s₁) and
//! ascend as the loss falls (Eq. 37).

use super::{Curve, Scale};
use crate::config::{ExperimentConfig, LrSchedule, QuantizerKind};
use crate::metrics::{fnum, Table};

/// Fig. 8 curve set: QSGD at s = 4/16/256 (2/4/8 bits) + doubly-adaptive.
pub fn curve_set() -> Vec<(&'static str, QuantizerKind)> {
    vec![
        ("QSGD-2bit", QuantizerKind::Qsgd { s: 4 }),
        ("QSGD-4bit", QuantizerKind::Qsgd { s: 16 }),
        ("QSGD-8bit", QuantizerKind::Qsgd { s: 256 }),
        (
            "doubly-adaptive",
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 12, s_max: 4096 },
        ),
    ]
}

/// Run one dataset config under fixed or variable learning rate.
pub fn run(
    base: ExperimentConfig,
    variable_lr: bool,
) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, quant) in curve_set() {
        let mut cfg = base.clone();
        cfg.quantizer = quant;
        if variable_lr {
            cfg.lr = LrSchedule {
                base: cfg.lr.base,
                decay: 0.8,
                decay_every: 10,
            };
        }
        let tag = if variable_lr { "var-lr" } else { "fixed-lr" };
        curves.push(super::run_labeled(cfg, &format!("{label}/{tag}"))?);
    }
    Ok(curves)
}

pub fn run_mnist(scale: Scale, variable_lr: bool) -> anyhow::Result<Vec<Curve>> {
    run(super::paper_base_config(scale), variable_lr)
}

pub fn run_cifar(scale: Scale, variable_lr: bool) -> anyhow::Result<Vec<Curve>> {
    run(super::paper_cifar_config(scale), variable_lr)
}

/// Panels a/b/d/e: training loss vs communicated bits.
pub fn render_loss_vs_bits(curves: &[Curve]) -> String {
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(curves.iter().map(|c| {
            let r = &c.log.records[k];
            format!("{}@{}b", fnum(r.loss), r.bits_per_link)
        }));
        t.row(row);
    }
    let mut out =
        String::from("panel: training loss @ cumulative bits per link\n");
    out.push_str(&t.render());
    out
}

/// Panels c/f: quantized bits per element ⌈log₂ s_k⌉ vs iteration.
pub fn render_bits_per_element(curves: &[Curve]) -> String {
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(curves.iter().map(|c| {
            let s = c.log.records[k].levels;
            format!("{}", crate::quant::bits::bits_per_element(s))
        }));
        t.row(row);
    }
    let mut out = String::from(
        "panel: quantized bits per element (ceil log2 s_k) vs iteration\n",
    );
    out.push_str(&t.render());
    out
}

/// Measured-transport summary: paper-accounting bits per link beside
/// the exact bytes of the encoded wire messages each curve broadcast
/// (header + level table + packed sign/index payload — what the fabric
/// actually carried).
pub fn render_wire_totals(curves: &[Curve]) -> String {
    let mut t = Table::new(&[
        "curve",
        "paper bits/link",
        "measured wire bytes",
    ]);
    for c in curves {
        let wire = c.log.records.last().map_or(0, |r| r.wire_bytes);
        t.row(vec![
            c.label.clone(),
            c.log.total_bits().to_string(),
            wire.to_string(),
        ]);
    }
    let mut out = String::from(
        "summary: paper bit accounting vs measured wire bytes\n",
    );
    out.push_str(&t.render());
    out
}

/// Communication-efficiency summary: bits needed to reach a target loss.
pub fn bits_to_target(curves: &[Curve], target: f64) -> String {
    let mut t = Table::new(&["curve", "target loss", "bits per link"]);
    for c in curves {
        let bits = c
            .log
            .bits_to_loss(target)
            .map(|b| b.to_string())
            .unwrap_or_else(|| "not reached".into());
        t.row(vec![c.label.clone(), fnum(target), bits]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = super::super::paper_base_config(Scale::Quick);
        cfg.nodes = 4;
        cfg.rounds = 16;
        cfg.dataset =
            DatasetKind::Blobs { train: 240, test: 80, dim: 10, classes: 4 };
        cfg
    }

    #[test]
    fn doubly_adaptive_most_bit_efficient_to_target() {
        let curves = run(tiny_base(), false).unwrap();
        // pick a mid-training target everyone eventually reaches
        let target = curves
            .iter()
            .map(|c| c.log.records.last().unwrap().loss)
            .fold(f64::MIN, f64::max)
            * 1.15;
        let bits = |label: &str| {
            curves
                .iter()
                .find(|c| c.label.starts_with(label))
                .unwrap()
                .log
                .bits_to_loss(target)
        };
        let da = bits("doubly-adaptive");
        let q8 = bits("QSGD-8bit");
        if let (Some(da), Some(q8)) = (da, q8) {
            assert!(
                da < q8,
                "doubly-adaptive {da} bits should beat 8-bit QSGD {q8}"
            );
        }
    }

    #[test]
    fn adaptive_bits_per_element_ascend() {
        let curves = run(tiny_base(), false).unwrap();
        let da = curves
            .iter()
            .find(|c| c.label.starts_with("doubly-adaptive"))
            .unwrap();
        let first = da.log.records.first().unwrap().levels;
        let last = da.log.records.last().unwrap().levels;
        assert_eq!(first, 4);
        assert!(last >= first);
        // fixed QSGD stays fixed
        let q4 = curves
            .iter()
            .find(|c| c.label.starts_with("QSGD-4bit"))
            .unwrap();
        assert!(q4
            .log
            .records
            .iter()
            .all(|r| r.levels == 16));
    }

    #[test]
    fn variable_lr_runs_and_decays() {
        let curves = run(tiny_base(), true).unwrap();
        let r = &curves[0].log.records;
        assert!(r.last().unwrap().lr < r.first().unwrap().lr);
    }

    #[test]
    fn renders_nonempty() {
        let curves = run(tiny_base(), false).unwrap();
        assert!(render_loss_vs_bits(&curves).contains("panel:"));
        assert!(render_bits_per_element(&curves).contains("panel:"));
        assert!(bits_to_target(&curves, 1.0).contains("target"));
    }
}
