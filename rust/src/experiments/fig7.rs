//! Fig. 7: impact of network topology — testing accuracy vs iteration for
//! ζ ∈ {0, 0.87, 1} (fully-connected / ring / disconnected).
//!
//! Expected shape (Remark 3): accuracy(ζ=0) ≥ accuracy(ζ=0.87) ≥
//! accuracy(ζ=1); sparser topology ⇒ worse convergence.

use super::{Curve, Scale};
use crate::config::TopologyKind;
use crate::metrics::{fnum, Table};
use crate::topology::Topology;

pub const TOPOLOGIES: [(&str, TopologyKind); 3] = [
    ("full (zeta=0)", TopologyKind::Full),
    ("ring (zeta~0.87)", TopologyKind::Ring),
    ("disconnected (zeta=1)", TopologyKind::Disconnected),
];

pub fn run(scale: Scale) -> anyhow::Result<Vec<Curve>> {
    let base = super::paper_base_config(scale);
    let mut curves = Vec::new();
    for (label, topo) in TOPOLOGIES {
        let mut cfg = base.clone();
        cfg.topology = topo;
        curves.push(super::run_labeled(cfg, label)?);
    }
    Ok(curves)
}

/// The measured ζ values for the three topologies at N nodes.
pub fn zetas(n: usize) -> Vec<(String, f64)> {
    TOPOLOGIES
        .iter()
        .map(|(label, kind)| {
            (label.to_string(), Topology::build(kind, n, 0).zeta)
        })
        .collect()
}

pub fn render(curves: &[Curve]) -> String {
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(
            curves.iter().map(|c| fnum(c.log.records[k].accuracy)));
        t.row(row);
    }
    let mut out = String::from("panel: test accuracy vs iteration\n");
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    #[test]
    fn zeta_values_match_paper_setup() {
        let z = zetas(10);
        assert!(z[0].1.abs() < 1e-9);
        assert!((z[1].1 - 0.87).abs() < 0.01);
        assert!((z[2].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn denser_topology_no_worse() {
        // tiny workload: full topology should reach accuracy >= disconnected
        let mut base = super::super::paper_base_config(Scale::Quick);
        base.nodes = 4;
        base.rounds = 15;
        base.noniid_fraction = 0.8; // make topology matter
        base.dataset =
            DatasetKind::Blobs { train: 240, test: 120, dim: 10, classes: 4 };
        let mut accs = Vec::new();
        for (label, topo) in TOPOLOGIES {
            let mut cfg = base.clone();
            cfg.topology = topo;
            let c = super::super::run_labeled(cfg, label).unwrap();
            accs.push(c.log.final_accuracy().unwrap());
        }
        assert!(
            accs[0] >= accs[2] - 0.05,
            "full {} vs disconnected {}",
            accs[0],
            accs[2]
        );
    }
}
