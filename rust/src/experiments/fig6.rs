//! Fig. 6: LM-DFL vs baselines on synth-MNIST (a-d) and synth-CIFAR (e-h).
//!
//! Four curves per dataset — DFL without quantization, LM-DFL, DFL+ALQ,
//! DFL+QSGD — and four panels: training loss vs iteration, training loss vs
//! time progression (bits / 100 Mbps), test accuracy vs iteration, and
//! quantization distortion vs iteration.
//!
//! Expected shape (paper §VI-B1): no-quant best per-iteration; LM-DFL ≤
//! ALQ ≤ QSGD per-iteration among quantized; LM-DFL best per-bit (its
//! time-progression curve is left-most); LM distortion lowest.

use super::{Curve, Scale};
use crate::config::{ExperimentConfig, QuantizerKind};
use crate::metrics::{fnum, Table};

/// The four Fig. 6 configurations at the paper's s for the dataset.
pub fn curve_set(base: &ExperimentConfig, s: usize) -> Vec<(String, QuantizerKind)> {
    let set: Vec<(&str, QuantizerKind)> = vec![
        ("no-quant", QuantizerKind::Full),
        ("LM-DFL", QuantizerKind::LloydMax { s, iters: 12 }),
        ("ALQ", QuantizerKind::Alq { s }),
        ("QSGD", QuantizerKind::Qsgd { s }),
    ];
    set.into_iter()
        .map(|(l, q)| (format!("{}/{}", base.name, l), q))
        .collect()
}

/// Run the full figure for one dataset config.
pub fn run(base: ExperimentConfig, s: usize) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, quant) in curve_set(&base, s) {
        let mut cfg = base.clone();
        cfg.quantizer = quant;
        curves.push(super::run_labeled(cfg, &label)?);
    }
    Ok(curves)
}

/// MNIST panels (Fig. 6a-d).
pub fn run_mnist(scale: Scale) -> anyhow::Result<Vec<Curve>> {
    run(super::paper_base_config(scale), 50)
}

/// CIFAR panels (Fig. 6e-h).
pub fn run_cifar(scale: Scale) -> anyhow::Result<Vec<Curve>> {
    run(super::paper_cifar_config(scale), 100)
}

/// Render the four panels as aligned tables (what the bench prints).
pub fn render_panels(curves: &[Curve], link_bps: f64) -> String {
    let mut out = String::new();
    let rounds = curves
        .iter()
        .map(|c| c.log.records.len())
        .min()
        .unwrap_or(0);
    let stride = (rounds / 12).max(1);

    // panel 1: loss vs iteration
    let mut headers: Vec<String> = vec!["iter".into()];
    headers.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(
            curves.iter().map(|c| fnum(c.log.records[k].loss)));
        t.row(row);
    }
    out.push_str("panel: training loss vs iteration\n");
    out.push_str(&t.render());

    // panel 2: loss vs time progression (ms at link rate)
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(curves.iter().map(|c| {
            let r = &c.log.records[k];
            let ms = r.bits_per_link as f64 / link_bps * 1e3;
            format!("{}@{:.1}ms", fnum(r.loss), ms)
        }));
        t.row(row);
    }
    out.push_str("\npanel: training loss @ time progression (100 Mbps)\n");
    out.push_str(&t.render());

    // panel 3: accuracy vs iteration
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(
            curves.iter().map(|c| fnum(c.log.records[k].accuracy)));
        t.row(row);
    }
    out.push_str("\npanel: test accuracy vs iteration\n");
    out.push_str(&t.render());

    // panel 4: distortion vs iteration
    let mut t = Table::new(&hdr);
    for k in (0..rounds).step_by(stride) {
        let mut row = vec![format!("{}", k + 1)];
        row.extend(
            curves.iter().map(|c| fnum(c.log.records[k].distortion)));
        t.row(row);
    }
    out.push_str("\npanel: quantization distortion vs iteration\n");
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = super::super::paper_base_config(Scale::Quick);
        cfg.nodes = 4;
        cfg.rounds = 10;
        cfg.dataset =
            DatasetKind::Blobs { train: 200, test: 60, dim: 10, classes: 4 };
        cfg
    }

    #[test]
    fn fig6_shape_holds_on_tiny_workload() {
        let curves = run(tiny_base(), 16).unwrap();
        assert_eq!(curves.len(), 4);
        let last = |label: &str| {
            curves
                .iter()
                .find(|c| c.label.ends_with(label))
                .unwrap()
                .log
                .records
                .last()
                .unwrap()
                .clone()
        };
        // distortion ordering: LM lowest among quantized (the headline)
        let lm = last("LM-DFL");
        let qsgd = last("QSGD");
        let noq = last("no-quant");
        assert!(lm.distortion < qsgd.distortion,
                "LM {} !< QSGD {}", lm.distortion, qsgd.distortion);
        assert!(noq.distortion < 1e-6);
        // everything converged somewhat
        for c in &curves {
            let f = c.log.records.first().unwrap().loss;
            let l = c.log.records.last().unwrap().loss;
            assert!(l < f, "{}: {f} -> {l}", c.label);
        }
        // per-bit: quantized methods spend far fewer bits than no-quant
        assert!(lm.bits_per_link < noq.bits_per_link / 2);
    }

    #[test]
    fn render_has_four_panels() {
        let curves = run(tiny_base(), 8).unwrap();
        let s = render_panels(&curves, 100e6);
        assert_eq!(s.matches("panel:").count(), 4);
    }
}
