//! Fig. 4: training loss vs communicated bits under adaptive vs fixed s —
//! the motivating ablation for doubly-adaptive DFL (§V).
//!
//! Curves: fixed s ∈ {4, 16, 256}, ascending s (Eq. 37), and the inverse
//! (descending) schedule as a falsification check. Expected shape:
//! ascending reaches any target loss with the fewest bits; descending is
//! the worst of the adaptive schedules.

use super::{Curve, Scale};
use crate::config::{ExperimentConfig, QuantizerKind};

/// Schedule variants for the ablation.
pub fn curve_set() -> Vec<(&'static str, QuantizerKind)> {
    vec![
        ("fixed-s4", QuantizerKind::LloydMax { s: 4, iters: 12 }),
        ("fixed-s16", QuantizerKind::LloydMax { s: 16, iters: 12 }),
        ("fixed-s256", QuantizerKind::LloydMax { s: 256, iters: 12 }),
        (
            "ascending",
            QuantizerKind::DoublyAdaptive { s1: 4, iters: 12, s_max: 4096 },
        ),
    ]
}

pub fn run(base: ExperimentConfig) -> anyhow::Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for (label, quant) in curve_set() {
        let mut cfg = base.clone();
        cfg.quantizer = quant;
        curves.push(super::run_labeled(cfg, label)?);
    }
    // descending ablation: run a custom engine loop driving set_levels
    curves.push(run_descending(base)?);
    Ok(curves)
}

/// Descending-s ablation (the paper's Fig. 4 "descending" curve): start at
/// s = 256 and halve toward 4 as loss falls — implemented by driving the
/// engine round-by-round.
pub fn run_descending(mut base: ExperimentConfig) -> anyhow::Result<Curve> {
    use crate::dfl::Trainer;
    base.name = "descending".into();
    // engine quantizer starts at the high end
    base.quantizer = QuantizerKind::LloydMax { s: 256, iters: 12 };
    let mut trainer = Trainer::build(&base)?;
    let mut log = crate::metrics::RunLog::new("descending");
    let mut cum = 0u64;
    let rounds = base.rounds;
    let mut f1: Option<f64> = None;
    for k in 0..rounds {
        let mut rec = trainer.engine_mut().round(k)?;
        cum += rec.bits_per_link;
        rec.bits_per_link = cum;
        if rec.loss.is_finite() {
            let f1v = *f1.get_or_insert(rec.loss.max(1e-9));
            let ratio = (rec.loss.max(1e-9) / f1v).sqrt();
            let s = ((256.0 * ratio).round() as usize).clamp(4, 256);
            // drive all node quantizers down
            trainer.engine_mut().set_all_levels(s);
        }
        log.push(rec);
    }
    Ok(Curve { label: "descending".into(), log })
}

pub fn run_mnist(scale: Scale) -> anyhow::Result<Vec<Curve>> {
    run(super::paper_base_config(scale))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    fn tiny() -> ExperimentConfig {
        let mut cfg = super::super::paper_base_config(Scale::Quick);
        cfg.nodes = 4;
        cfg.rounds = 14;
        cfg.dataset =
            DatasetKind::Blobs { train: 240, test: 80, dim: 10, classes: 4 };
        cfg
    }

    #[test]
    fn ascending_beats_fixed_256_per_bit() {
        let curves = run(tiny()).unwrap();
        let target = curves
            .iter()
            .map(|c| c.log.records.last().unwrap().loss)
            .fold(f64::MIN, f64::max)
            * 1.15;
        let bits = |label: &str| {
            curves
                .iter()
                .find(|c| c.label == label)
                .unwrap()
                .log
                .bits_to_loss(target)
        };
        if let (Some(asc), Some(f256)) = (bits("ascending"), bits("fixed-s256"))
        {
            assert!(asc <= f256, "ascending {asc} !<= fixed-s256 {f256}");
        }
    }

    #[test]
    fn descending_schedule_descends() {
        let c = run_descending(tiny()).unwrap();
        let first = c.log.records.first().unwrap().levels;
        let last = c.log.records.last().unwrap().levels;
        assert!(first >= last, "levels should descend: {first} -> {last}");
    }
}
