//! Paper-experiment drivers: one function per table/figure.
//!
//! Each driver builds the paper's configuration, runs the DFL engine for
//! every curve in the figure, and returns named [`RunLog`]s; the bench
//! targets (rust/benches/) print them as the series the paper plots, and
//! the examples write CSVs. `Scale` shrinks workloads for CI / quick runs.

pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig_robust;
pub mod fig_time;
pub mod table1;

use crate::config::{
    BackendKind, DatasetKind, ExperimentConfig, LrSchedule, QuantizerKind,
    TopologyKind,
};
use crate::metrics::RunLog;

/// Workload scale for the experiment drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// seconds-fast: tiny data, few rounds (CI, `cargo bench` smoke)
    Quick,
    /// the defaults used for EXPERIMENTS.md numbers
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        if std::env::var("LMDFL_FULL").is_ok() {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    pub fn rounds(&self, quick: usize, full: usize) -> usize {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

/// A named experiment result.
pub struct Curve {
    pub label: String,
    pub log: RunLog,
}

/// The paper's base experimental setup (§VI-A): N = 10 nodes, ring-like
/// topology with ζ ≈ 0.87, τ = 4 local updates, non-IID half split.
pub fn paper_base_config(scale: Scale) -> ExperimentConfig {
    let (train, test, rounds) = match scale {
        Scale::Quick => (600, 200, 30),
        Scale::Full => (4000, 1000, 120),
    };
    ExperimentConfig {
        name: "paper-base".into(),
        seed: 7,
        nodes: 10,
        tau: 4,
        rounds,
        batch_size: 32,
        // the paper trains CNNs with η = 0.002; our MLP sweep model uses a
        // slightly larger rate for comparable descent per round
        lr: LrSchedule::fixed(0.02),
        topology: TopologyKind::Ring, // ζ ≈ 0.8727 at N = 10
        quantizer: QuantizerKind::LloydMax { s: 50, iters: 12 },
        dataset: DatasetKind::SynthMnist { train, test },
        backend: BackendKind::RustMlp { hidden: vec![64] },
        noniid_fraction: 0.5,
        link_bps: 100e6,
        eval_every: 1,
        parallelism: crate::config::Parallelism::Auto,
        network: None,
        mode: Default::default(),
        encoding: Default::default(),
        agossip: None,
        transport: None,
        observe: None,
        attack: None,
        mixing: Default::default(),
    }
}

/// CIFAR-variant of the base config (paper: η = 0.001, s = 100).
pub fn paper_cifar_config(scale: Scale) -> ExperimentConfig {
    let mut cfg = paper_base_config(scale);
    let (train, test) = match scale {
        Scale::Quick => (400, 150),
        Scale::Full => (3000, 800),
    };
    cfg.name = "paper-cifar".into();
    cfg.dataset = DatasetKind::SynthCifar { train, test };
    cfg.lr = LrSchedule::fixed(0.01);
    cfg.quantizer = QuantizerKind::LloydMax { s: 100, iters: 12 };
    cfg.backend = BackendKind::RustMlp { hidden: vec![64] };
    cfg
}

/// Run a config, stamping the label.
pub fn run_labeled(
    mut cfg: ExperimentConfig,
    label: &str,
) -> anyhow::Result<Curve> {
    cfg.name = label.to_string();
    let log = crate::dfl::Trainer::build(&cfg)?.run()?;
    Ok(Curve { label: label.to_string(), log })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_configs_valid() {
        paper_base_config(Scale::Quick).validate().unwrap();
        paper_base_config(Scale::Full).validate().unwrap();
        paper_cifar_config(Scale::Quick).validate().unwrap();
    }

    #[test]
    fn scale_rounds() {
        assert_eq!(Scale::Quick.rounds(5, 50), 5);
        assert_eq!(Scale::Full.rounds(5, 50), 50);
    }
}
