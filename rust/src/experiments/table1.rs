//! Table I: quantization distortion comparison across quantizers.
//!
//! Measures the empirical normalized distortion E‖Q(v)−v‖²/‖v‖² of each
//! quantizer on gaussian / laplace / real-gradient-like vectors and prints
//! it beside the paper's analytical bound. Expected shape: LM ≪ QSGD and
//! ALQ at equal s; natural compression floors at 1/8.

use crate::metrics::{fnum, Table};
use crate::quant::distortion::{
    alq_bound, lm_bound, natural_bound, normalized_distortion, qsgd_bound,
};
use crate::quant::{
    AlqQuantizer, LloydMaxQuantizer, NaturalQuantizer, QsgdQuantizer,
    Quantizer,
};
use crate::util::rng::Rng;

/// One measured row of Table I.
#[derive(Clone, Debug)]
pub struct DistortionRow {
    pub quantizer: &'static str,
    pub dist_name: &'static str,
    pub d: usize,
    pub s: usize,
    pub measured: f64,
    pub bound: f64,
    /// measured bytes of one encoded wire message at (d, s) — the real
    /// transport cost next to the paper's C_s bit accounting
    pub wire_bytes: u64,
}

/// Generate a test vector of the named distribution.
pub fn test_vector(dist: &str, d: usize, rng: &mut Rng) -> Vec<f32> {
    match dist {
        "gaussian" => (0..d).map(|_| rng.normal() as f32).collect(),
        "laplace" => (0..d).map(|_| rng.laplace(0.5) as f32).collect(),
        // "gradient": sparse-ish heavy-tailed values like real model deltas
        "gradient" => (0..d)
            .map(|_| {
                let mag = rng.laplace(0.1) as f32;
                if rng.uniform() < 0.7 {
                    mag * 0.05
                } else {
                    mag
                }
            })
            .collect(),
        other => panic!("unknown distribution {other}"),
    }
}

/// Measure all quantizers at (d, s) on `dist`, averaged over `trials`.
pub fn measure(
    d: usize,
    s: usize,
    dist: &'static str,
    trials: usize,
    seed: u64,
) -> Vec<DistortionRow> {
    let mut rng = Rng::new(seed);
    let mut quantizers: Vec<(Box<dyn Quantizer>, Box<dyn Fn(&[f32]) -> f64>)> = vec![
        (
            Box::new(QsgdQuantizer::new(s)),
            Box::new(move |_: &[f32]| qsgd_bound(d, s)),
        ),
        (
            Box::new(NaturalQuantizer::new(s)),
            Box::new(move |_: &[f32]| natural_bound(d, s)),
        ),
        (
            Box::new(AlqQuantizer::new(s)),
            Box::new(move |levels: &[f32]| alq_bound(levels)),
        ),
        (
            Box::new(LloydMaxQuantizer::new(s, 20)),
            Box::new(move |_: &[f32]| lm_bound(d, s)),
        ),
    ];
    let mut rows = Vec::new();
    for (q, bound_fn) in quantizers.iter_mut() {
        let tag = crate::quant::wire::QuantTag::from_name(q.name())
            .expect("table quantizers all have wire tags");
        let mut acc = 0.0;
        let mut bound = 0.0;
        let mut wire_bytes = 0u64;
        for t in 0..trials {
            let v = test_vector(dist, d, &mut rng.split(t as u64));
            let msg = q.quantize(&v, &mut rng);
            if t + 1 == trials {
                // measure the encoded transport frame, not a formula
                // (once per row — the size depends only on (d, s))
                let header = crate::quant::wire::WireHeader::new(
                    tag,
                    0,
                    0,
                    t as u32,
                    msg.s(),
                );
                wire_bytes = crate::quant::wire::encode(&header, &msg)
                    .len() as u64;
            }
            let dq = msg.dequantize();
            acc += normalized_distortion(&v, &dq);
            bound = bound_fn(&msg.levels);
        }
        rows.push(DistortionRow {
            quantizer: match q.name() {
                "qsgd" => "QSGD",
                "natural" => "Natural",
                "alq" => "ALQ",
                "lloyd_max" => "LM-DFL",
                other => Box::leak(other.to_string().into_boxed_str()),
            },
            dist_name: dist,
            d,
            s,
            measured: acc / trials as f64,
            bound,
            wire_bytes,
        });
    }
    rows
}

/// Render the full table (the bench prints this).
pub fn render(rows: &[DistortionRow]) -> String {
    let mut t = Table::new(&[
        "quantizer", "distribution", "d", "s", "measured", "paper bound",
        "wire bytes",
    ]);
    for r in rows {
        t.row(vec![
            r.quantizer.to_string(),
            r.dist_name.to_string(),
            r.d.to_string(),
            r.s.to_string(),
            fnum(r.measured),
            fnum(r.bound),
            r.wire_bytes.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_beats_qsgd_and_alq_on_all_distributions() {
        for dist in ["gaussian", "laplace", "gradient"] {
            let rows = measure(2000, 16, dist, 3, 42);
            let get = |name: &str| {
                rows.iter().find(|r| r.quantizer == name).unwrap().measured
            };
            let lm = get("LM-DFL");
            assert!(
                lm < get("QSGD"),
                "{dist}: LM {lm} !< QSGD {}",
                get("QSGD")
            );
            assert!(
                lm < get("ALQ") * 1.05,
                "{dist}: LM {lm} !< ALQ {}",
                get("ALQ")
            );
        }
    }

    #[test]
    fn measured_within_bounds() {
        // stochastic quantizers measured on a single draw can exceed the
        // expectation bound slightly; allow 3x
        let rows = measure(4000, 16, "gaussian", 3, 1);
        for r in &rows {
            assert!(
                r.measured <= r.bound * 3.0 + 0.01,
                "{}: measured {} bound {}",
                r.quantizer,
                r.measured,
                r.bound
            );
        }
    }

    #[test]
    fn wire_bytes_measured_per_quantizer() {
        let rows = measure(500, 8, "gaussian", 1, 2);
        let get = |name: &str| {
            rows.iter()
                .find(|r| r.quantizer == name)
                .unwrap()
                .wire_bytes
        };
        // QSGD implies its grid: the measured frame matches the exact
        // size formula for an implied-table message
        assert_eq!(
            get("QSGD"),
            crate::quant::wire::encoded_len(500, 8, true) as u64
        );
        // table-shipping quantizers pay for their adapted levels
        assert!(get("LM-DFL") > get("QSGD"));
        assert!(get("ALQ") > get("QSGD"));
    }

    #[test]
    fn render_contains_all_quantizers() {
        let rows = measure(500, 8, "gaussian", 1, 2);
        let s = render(&rows);
        for name in ["QSGD", "Natural", "ALQ", "LM-DFL"] {
            assert!(s.contains(name), "{s}");
        }
    }
}
