//! Deflated power iteration for ζ on large sparse confusion matrices.
//!
//! The dense path computes ζ = max(|λ₂|, |λ_N|) by full Jacobi
//! eigendecomposition — O(n³) per sweep with an O(n²) matrix, which is
//! the first thing that stops scaling past a few hundred nodes. For a
//! symmetric doubly-stochastic C the Perron eigenpair is known exactly
//! (λ₁ = 1 with the all-ones eigenvector), so the second-largest
//! *absolute* eigenvalue is the dominant eigenvalue of C restricted to
//! the mean-zero subspace: project the ones-component out of the
//! iterate each step and the plain power method converges to ζ using
//! nothing but matvecs — O(edges) per iteration on a sparse graph.
//!
//! The caller supplies the matvec, so this module stays independent of
//! any particular sparse layout ([`crate::topology::SparseTopology`]
//! wraps it as `zeta_power`). Everything here is a fixed sequence of
//! f64 operations from a fixed seed: the estimate is deterministic,
//! which the simnet digest contract requires of anything that feeds
//! engine state. Agreement with the dense oracle
//! ([`super::eigen::second_largest_abs_eigenvalue`]) within 1e-6 on
//! arbitrary Metropolis graphs n ≤ 64 is property-tested in
//! `util/proptest.rs`.

use crate::util::rng::Rng;

/// Iteration budget for [`power_iteration_zeta`].
///
/// `HOT` is the production budget used when (re)building topologies at
/// scale: ζ only feeds the damping schedule there, and the norm ratio
/// is already inside ~1e-9 of the limit for well-separated spectra.
/// `ORACLE` is the verification budget the property tests run with —
/// large enough that even a 1e-5 spectral gap between |λ₂| and |λ₃|
/// leaves less than 1e-6 of contamination in the estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerBudget {
    Hot,
    Oracle,
}

impl PowerBudget {
    fn max_iters(self) -> usize {
        match self {
            PowerBudget::Hot => 512,
            PowerBudget::Oracle => 300_000,
        }
    }

    fn tol(self) -> f64 {
        match self {
            PowerBudget::Hot => 1e-10,
            PowerBudget::Oracle => 1e-15,
        }
    }
}

/// ζ = max(|λ₂|, |λ_N|) of a symmetric doubly-stochastic matrix given
/// only its matvec `y = C x` (written into `y`, both length `n`).
///
/// Deflates the Perron component (subtracts the mean each step) and
/// tracks the norm ratio ‖Cx‖/‖x‖, which converges monotonically in
/// magnitude to the dominant remaining |eigenvalue| — exactly the
/// paper's ζ. Stops at `budget` iterations or when the ratio moves
/// less than the budget's tolerance between steps.
pub fn power_iteration_zeta<F>(
    n: usize,
    budget: PowerBudget,
    mut matvec: F,
) -> f64
where
    F: FnMut(&[f64], &mut [f64]),
{
    if n <= 1 {
        // a 1x1 doubly-stochastic matrix is [1]; no second eigenvalue
        // (the dense oracle returns 0 there too)
        return 0.0;
    }
    // deterministic start vector: fixed-seed uniform noise so the
    // iterate overlaps every eigenvector with probability 1
    let mut rng = Rng::new(0x9E1A_5EED ^ n as u64);
    let mut x: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
    deflate_ones(&mut x);
    let norm = l2(&x);
    if norm < 1e-300 {
        return 0.0;
    }
    scale(&mut x, 1.0 / norm);

    let mut y = vec![0.0f64; n];
    let mut prev_ratio = f64::INFINITY;
    let mut ratio = 0.0;
    for _ in 0..budget.max_iters() {
        matvec(&x, &mut y);
        deflate_ones(&mut y);
        ratio = l2(&y);
        if ratio < 1e-300 {
            // C annihilates the mean-zero subspace (e.g. C = J): ζ = 0
            return 0.0;
        }
        // renormalize into the next iterate
        for (xi, &yi) in x.iter_mut().zip(&y) {
            *xi = yi / ratio;
        }
        if (ratio - prev_ratio).abs() <= budget.tol() {
            break;
        }
        prev_ratio = ratio;
    }
    ratio
}

/// Remove the component along the all-ones Perron eigenvector.
fn deflate_ones(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn l2(x: &[f64]) -> f64 {
    x.iter().map(|&v| v * v).sum::<f64>().sqrt()
}

fn scale(x: &mut [f64], s: f64) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::second_largest_abs_eigenvalue;
    use crate::linalg::Matrix;

    fn zeta_of(m: &Matrix, budget: PowerBudget) -> f64 {
        power_iteration_zeta(m.rows, budget, |x, y| {
            let out = m.matvec(x);
            y.copy_from_slice(&out);
        })
    }

    #[test]
    fn consensus_matrix_gives_zero() {
        let j = Matrix::consensus(6);
        assert!(zeta_of(&j, PowerBudget::Oracle).abs() < 1e-9);
    }

    #[test]
    fn identity_gives_one() {
        let i = Matrix::identity(5);
        let z = zeta_of(&i, PowerBudget::Oracle);
        assert!((z - 1.0).abs() < 1e-9, "zeta(I)={z}");
    }

    #[test]
    fn ring_matches_closed_form_and_jacobi() {
        // uniform ring averaging: zeta = (1 + 2cos(2*pi/n)) / 3
        let n = 10;
        let mut c = Matrix::zeros(n, n);
        for i in 0..n {
            c[(i, i)] = 1.0 / 3.0;
            c[(i, (i + 1) % n)] = 1.0 / 3.0;
            c[(i, (i + n - 1) % n)] = 1.0 / 3.0;
        }
        let expect = (1.0
            + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos())
            / 3.0;
        let z = zeta_of(&c, PowerBudget::Oracle);
        assert!((z - expect).abs() < 1e-9, "{z} vs {expect}");
        let jac = second_largest_abs_eigenvalue(&c);
        assert!((z - jac).abs() < 1e-9, "{z} vs jacobi {jac}");
    }

    #[test]
    fn negative_dominant_eigenvalue_is_found() {
        // two nodes swapping everything: C = [[0,1],[1,0]] has spectrum
        // {1, -1}; zeta must be |−1| = 1, not 0
        let c = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let z = zeta_of(&c, PowerBudget::Oracle);
        assert!((z - 1.0).abs() < 1e-9, "zeta={z}");
    }

    #[test]
    fn deterministic_across_calls() {
        let n = 12;
        let mut c = Matrix::zeros(n, n);
        for i in 0..n {
            c[(i, i)] = 0.5;
            c[(i, (i + 1) % n)] = 0.25;
            c[(i, (i + n - 1) % n)] = 0.25;
        }
        let a = zeta_of(&c, PowerBudget::Hot);
        let b = zeta_of(&c, PowerBudget::Hot);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn tiny_sizes_are_defined() {
        assert_eq!(zeta_of(&Matrix::identity(1), PowerBudget::Hot), 0.0);
        assert_eq!(zeta_of(&Matrix::zeros(0, 0), PowerBudget::Hot), 0.0);
    }
}
