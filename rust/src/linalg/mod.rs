//! Dense linear-algebra substrate (no external crates offline).
//!
//! Only what the DFL simulator needs: a small row-major `Matrix` with the
//! handful of ops used by the topology/confusion-matrix machinery, plus a
//! cyclic Jacobi eigensolver (`eigen`) to compute the second-largest
//! absolute eigenvalue ζ of the (symmetric, doubly-stochastic) confusion
//! matrix — the quantity the paper's convergence bounds are written in.
//! At scale the dense eigensolver is replaced by deflated power
//! iteration over sparse matvecs (`power`); the Jacobi path stays as
//! the small-n bit-identity oracle.

pub mod eigen;
pub mod power;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// The consensus matrix J = 11^T / n (paper notation).
    pub fn consensus(n: usize) -> Self {
        Matrix { rows: n, cols: n, data: vec![1.0 / n as f64; n * n] }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let dst =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (d, &b) in dst.iter_mut().zip(orow) {
                    *d += a * b;
                }
            }
        }
        out
    }

    /// `self * v` for a column vector.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(&a, &b)| a * b).sum())
            .collect()
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Doubly stochastic: rows and columns sum to 1, entries >= 0.
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        let n = self.rows;
        for i in 0..n {
            let rsum: f64 = self.row(i).iter().sum();
            if (rsum - 1.0).abs() > tol {
                return false;
            }
            let csum: f64 = (0..n).map(|r| self[(r, i)]).sum();
            if (csum - 1.0).abs() > tol {
                return false;
            }
        }
        self.data.iter().all(|&x| x >= -tol)
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let i3 = Matrix::identity(3);
        let m = Matrix::from_rows(&[
            &[1.0, 2.0, 3.0],
            &[4.0, 5.0, 6.0],
            &[7.0, 8.0, 9.0],
        ]);
        assert_eq!(i3.matmul(&m), m);
        assert_eq!(m.matmul(&i3), m);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn consensus_is_doubly_stochastic_and_idempotent() {
        let j = Matrix::consensus(5);
        assert!(j.is_doubly_stochastic(1e-12));
        assert!(j.matmul(&j).max_abs_diff(&j) < 1e-12);
    }

    #[test]
    fn symmetry_check() {
        let s = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 3.0]]);
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[0.0, 3.0]]);
        assert!(s.is_symmetric(1e-12));
        assert!(!a.is_symmetric(1e-12));
    }
}
