//! Cyclic Jacobi eigensolver for real symmetric matrices.
//!
//! Used to compute the full spectrum of the confusion matrix `C`; the paper
//! characterizes topologies by ζ = max(|λ₂|, |λ_N|), the second-largest
//! absolute eigenvalue (Assumption 1.5), which drives the convergence bound
//! through α = ζ²/(1-ζ²) + ζ/(1-ζ)².

use super::Matrix;

/// Eigenvalues of a symmetric matrix, sorted descending.
///
/// Cyclic Jacobi sweeps; O(n³) per sweep, converges quadratically. The
/// confusion matrices here are small (N ≲ a few hundred nodes), so this is
/// more than fast enough and numerically robust.
pub fn symmetric_eigenvalues(m: &Matrix) -> Vec<f64> {
    assert_eq!(m.rows, m.cols, "eigenvalues need a square matrix");
    debug_assert!(m.is_symmetric(1e-9), "matrix must be symmetric");
    let n = m.rows;
    if n == 0 {
        return vec![];
    }
    let mut a = m.clone();
    let max_sweeps = 100;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + a.frobenius_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tangent of the rotation angle
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- G^T A G with Givens rotation G in plane (p, q)
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut evals: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    evals.sort_by(|x, y| y.partial_cmp(x).unwrap());
    evals
}

/// ζ = max(|λ₂|, |λ_N|) for a doubly-stochastic symmetric matrix whose
/// leading eigenvalue is 1 (Assumption 1.5). The eigenvalue closest to 1
/// is treated as λ₁ and excluded.
pub fn second_largest_abs_eigenvalue(c: &Matrix) -> f64 {
    let evals = symmetric_eigenvalues(c);
    assert!(!evals.is_empty());
    if evals.len() == 1 {
        return 0.0;
    }
    // drop one eigenvalue closest to 1 (the Perron root)
    let mut idx = 0;
    let mut best = f64::INFINITY;
    for (i, &e) in evals.iter().enumerate() {
        let d = (e - 1.0).abs();
        if d < best {
            best = d;
            idx = i;
        }
    }
    evals
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != idx)
        .map(|(_, &e)| e.abs())
        .fold(0.0, f64::max)
}

/// α(ζ) = ζ²/(1-ζ²) + ζ/(1-ζ)² from Lemma 2 — the topology term of the
/// convergence bound. Returns +inf at ζ = 1 (disconnected network).
pub fn alpha_of_zeta(zeta: f64) -> f64 {
    if zeta >= 1.0 {
        return f64::INFINITY;
    }
    zeta * zeta / (1.0 - zeta * zeta) + zeta / ((1.0 - zeta) * (1.0 - zeta))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut m = Matrix::zeros(3, 3);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = -1.0;
        m[(2, 2)] = 2.0;
        let e = symmetric_eigenvalues(&m);
        assert!((e[0] - 3.0).abs() < 1e-10);
        assert!((e[1] - 2.0).abs() < 1e-10);
        assert!((e[2] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let m = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = symmetric_eigenvalues(&m);
        assert!((e[0] - 3.0).abs() < 1e-10);
        assert!((e[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn trace_preserved() {
        let m = Matrix::from_rows(&[
            &[4.0, 1.0, 0.5],
            &[1.0, 3.0, -0.25],
            &[0.5, -0.25, 1.0],
        ]);
        let e = symmetric_eigenvalues(&m);
        let trace = 4.0 + 3.0 + 1.0;
        assert!((e.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn consensus_matrix_zeta_zero() {
        let j = Matrix::consensus(6);
        let z = second_largest_abs_eigenvalue(&j);
        assert!(z.abs() < 1e-10, "zeta(J)={z}");
    }

    #[test]
    fn identity_zeta_one() {
        let i = Matrix::identity(5);
        let z = second_largest_abs_eigenvalue(&i);
        assert!((z - 1.0).abs() < 1e-10);
    }

    #[test]
    fn ring_eigenvalues_match_closed_form() {
        // Uniform ring averaging over self + 2 neighbours:
        // eigenvalues are (1 + 2cos(2*pi*k/n)) / 3.
        let n = 8;
        let mut c = Matrix::zeros(n, n);
        for i in 0..n {
            c[(i, i)] = 1.0 / 3.0;
            c[(i, (i + 1) % n)] = 1.0 / 3.0;
            c[(i, (i + n - 1) % n)] = 1.0 / 3.0;
        }
        let mut expect: Vec<f64> = (0..n)
            .map(|k| {
                (1.0 + 2.0 * (2.0 * std::f64::consts::PI * k as f64
                    / n as f64)
                    .cos())
                    / 3.0
            })
            .collect();
        expect.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let got = symmetric_eigenvalues(&c);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-9, "{g} vs {e}");
        }
    }

    #[test]
    fn alpha_monotone_in_zeta() {
        let mut prev = alpha_of_zeta(0.0);
        assert_eq!(prev, 0.0);
        for i in 1..10 {
            let z = i as f64 * 0.1;
            let a = alpha_of_zeta(z);
            assert!(a > prev);
            prev = a;
        }
        assert!(alpha_of_zeta(1.0).is_infinite());
    }
}
