//! Cross-run telemetry aggregation: sweep manifest in, tidy CSVs out.
//!
//! `lmdfl analyse <manifest.json>` loads every completed cell's trace
//! through [`crate::obs::export::parse_trace`] and rolls it up with
//! the same [`crate::obs::aggregate`] tables the `trace` summary
//! prints, then writes four tidy (one observation per row) CSVs:
//!
//! * `cells.csv`    — one row per cell: axes, outcome, resources
//! * `spans.csv`    — one row per (cell, span name, clock)
//! * `counters.csv` — one row per (cell, counter, key)
//! * `hists.csv`    — one row per (cell, histogram): count, mean,
//!   p50/p90/p99 upper bucket edges
//!
//! Axis columns come from the manifest's ordered `axes` listing, so
//! every sweep's `cells.csv` leads with the same
//! `quantizer,topology,net,mode,seed` block regardless of which axes
//! actually varied — downstream tooling can group on them blindly.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::obs::aggregate;
use crate::obs::export::parse_trace;

use super::{CellResult, SweepManifest};

/// Axis names in the manifest's declared order.
fn axis_names(m: &SweepManifest) -> Vec<String> {
    m.axes
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|a| a.get_str("axis").map(str::to_string))
        .collect()
}

/// One cell's value on one axis, rendered for CSV (seed is numeric
/// in the manifest; everything else is a string).
fn axis_value(cell: &CellResult, name: &str) -> String {
    match cell.axes.get(name) {
        Some(v) => match v.as_str() {
            Some(s) => s.to_string(),
            None => v.to_string(),
        },
        None => String::new(),
    }
}

/// Aggregate `manifest` into the four tidy CSVs under `out_dir`
/// (created if needed). Returns the written paths in a fixed order:
/// cells, spans, counters, hists.
pub fn analyse(
    manifest_path: &Path,
    out_dir: &Path,
) -> anyhow::Result<Vec<PathBuf>> {
    let m = SweepManifest::load(manifest_path)?;
    let base = manifest_path
        .parent()
        .unwrap_or_else(|| Path::new("."));
    let axes = axis_names(&m);

    let mut cells_csv = String::from("cell,hash,");
    for a in &axes {
        let _ = write!(cells_csv, "{a},");
    }
    cells_csv.push_str(
        "status,rounds,last_loss,final_accuracy,virtual_secs,\
         wire_bytes,wall_secs,peak_rss_bytes,cpu_percent\n",
    );
    let mut spans_csv = String::from(
        "cell,hash,span,clock,count,total_ns,mean_ns\n",
    );
    let mut counters_csv =
        String::from("cell,hash,counter,key,value\n");
    let mut hists_csv = String::from(
        "cell,hash,histogram,count,mean,p50_le,p90_le,p99_le\n",
    );

    for cell in &m.cells {
        let _ = write!(cells_csv, "{},{},", cell.id, cell.hash);
        for a in &axes {
            let _ = write!(cells_csv, "{},", axis_value(cell, a));
        }
        let _ = writeln!(
            cells_csv,
            "{},{},{},{},{},{},{},{},{}",
            cell.status,
            cell.rounds,
            cell.last_loss,
            cell.final_accuracy,
            cell.virtual_secs,
            cell.wire_bytes,
            cell.timing.wall_secs,
            cell.timing.peak_rss_bytes,
            cell.timing.cpu_percent,
        );
        if !cell.ok() {
            continue; // failed cells have no trace to aggregate
        }
        let trace_path = base.join(&cell.trace);
        let text =
            std::fs::read_to_string(&trace_path).map_err(|e| {
                anyhow::anyhow!(
                    "reading {}: {e}",
                    trace_path.display()
                )
            })?;
        let tf = parse_trace(&text)?;
        for s in aggregate::spans(&tf) {
            let _ = writeln!(
                spans_csv,
                "{},{},{},{},{},{},{}",
                cell.id,
                cell.hash,
                s.name,
                s.clock(),
                s.count,
                s.total_ns,
                s.mean_ns(),
            );
        }
        for c in aggregate::counters(&tf) {
            let _ = writeln!(
                counters_csv,
                "{},{},{},{},{}",
                cell.id, cell.hash, c.name, c.key, c.value,
            );
        }
        for h in aggregate::hists(&tf) {
            let _ = writeln!(
                hists_csv,
                "{},{},{},{},{},{},{},{}",
                cell.id,
                cell.hash,
                h.name,
                h.hist.count,
                h.hist.mean(),
                h.p50(),
                h.p90(),
                h.p99(),
            );
        }
    }

    std::fs::create_dir_all(out_dir).map_err(|e| {
        anyhow::anyhow!("creating {}: {e}", out_dir.display())
    })?;
    let mut written = Vec::new();
    for (file, text) in [
        ("cells.csv", &cells_csv),
        ("spans.csv", &spans_csv),
        ("counters.csv", &counters_csv),
        ("hists.csv", &hists_csv),
    ] {
        let path = out_dir.join(file);
        std::fs::write(&path, text).map_err(|e| {
            anyhow::anyhow!("writing {}: {e}", path.display())
        })?;
        written.push(path);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;
    use crate::config::ExperimentConfig;
    use crate::sweep::{CellTiming, Grid, SWEEP_SCHEMA};

    #[test]
    fn axis_columns_follow_manifest_order() {
        let base = ExperimentConfig::default();
        let grid = Grid::from_base(&base);
        let m = SweepManifest {
            schema: SWEEP_SCHEMA.to_string(),
            name: "t".into(),
            axes: grid.axes_json(),
            base: base.identity_json(),
            cells: Vec::new(),
        };
        assert_eq!(
            axis_names(&m),
            vec!["quantizer", "topology", "net", "mode", "seed"]
        );
    }

    #[test]
    fn axis_value_renders_strings_and_numbers() {
        let cell = CellResult {
            id: "x".into(),
            hash: "0".into(),
            axes: Json::obj(vec![
                ("quantizer", Json::str("qsgd")),
                ("seed", Json::num(7.0)),
            ]),
            status: "ok".into(),
            dir: String::new(),
            rounds_csv: String::new(),
            trace: String::new(),
            resources: String::new(),
            rounds: 0,
            last_loss: 0.0,
            final_accuracy: 0.0,
            virtual_secs: 0.0,
            wire_bytes: 0,
            timing: CellTiming::default(),
        };
        assert_eq!(axis_value(&cell, "quantizer"), "qsgd");
        assert_eq!(axis_value(&cell, "seed"), "7");
        assert_eq!(axis_value(&cell, "missing"), "");
    }
}
