//! Sweep orchestration: grids of experiments, run to one manifest.
//!
//! `lmdfl sweep` expands a [`Grid`] (quantizer × topology × network
//! regime × engine mode × seed) over a base config and runs every
//! cell through the existing `train` paths, with `observe:` tracing
//! always on. Each cell lives in `out/cells/<config-hash>/`:
//!
//! ```text
//! out/
//!   manifest.json            schema lmdfl-sweep-v1 (this module)
//!   cells/<hash>/
//!     config.json            the cell's full experiment config
//!     rounds.csv             per-round records (CSV_HEADER schema)
//!     trace.jsonl            lmdfl-trace-v1 spans/counters/hists
//!     resources.jsonl        lmdfl-resources-v1 CPU/RSS samples
//!     run.log                the cell's stdout+stderr
//!     cell.json              the cell's manifest entry (resume unit)
//! ```
//!
//! The hash is FNV-1a over [`ExperimentConfig::identity_json`] — the
//! config minus its `observe:` section — so a cell's directory name
//! is a pure function of what it computes, and re-running a sweep
//! into the same `--out` skips every cell whose `cell.json` says it
//! already completed with its artifacts intact (resume).
//!
//! Cells run as *subprocesses* of the `lmdfl` binary, not in-process
//! threads: the obs recorder is process-global (one trace per
//! process), and `/proc/<pid>` sampling ([`ProcessMonitor`]) needs a
//! real pid whose RSS is the cell's alone. A bounded worker pool
//! (`--slots`, default the machine's parallelism) keeps concurrent
//! cells from thrashing each other's timings.

pub mod analyse;
pub mod grid;
pub mod monitor;

pub use grid::{AttackRegime, Cell, Grid, NetRegime};
pub use monitor::{ProcessMonitor, ResourceUsage};

use std::collections::{BTreeSet, VecDeque};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::Mutex;
use std::time::Duration;

use crate::config::json::Json;
use crate::config::ExperimentConfig;
use crate::metrics::RunLog;
use crate::obs::ObserveConfig;

/// Schema identifier of `manifest.json`. Any change to the cell
/// record or axis encoding must bump this.
pub const SWEEP_SCHEMA: &str = "lmdfl-sweep-v1";

/// FNV-1a (64-bit) over the config's identity JSON — the cell
/// directory name. The `observe:` section is excluded
/// ([`ExperimentConfig::identity_json`]), so turning tracing on or
/// moving the sweep directory never invalidates completed cells.
pub fn config_hash(cfg: &ExperimentConfig) -> String {
    let text = cfg.identity_json().to_string();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("{h:016x}")
}

/// The non-deterministic (timing) half of a cell's outcome, kept
/// separate so manifests can be compared modulo timing
/// ([`SweepManifest::determinism_key`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CellTiming {
    /// child wall-clock, seconds
    pub wall_secs: f64,
    /// child peak RSS (`VmHWM` via `/proc`), bytes
    pub peak_rss_bytes: u64,
    /// mean child CPU utilization, percent of one core
    pub cpu_percent: f64,
    /// true when resume found the cell already complete
    pub cached: bool,
}

impl CellTiming {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wall_secs", Json::num(self.wall_secs)),
            (
                "peak_rss_bytes",
                Json::num(self.peak_rss_bytes as f64),
            ),
            ("cpu_percent", Json::num(self.cpu_percent)),
            ("cached", Json::Bool(self.cached)),
        ])
    }

    pub fn from_json(j: &Json) -> CellTiming {
        CellTiming {
            wall_secs: j.get_f64("wall_secs").unwrap_or(0.0),
            peak_rss_bytes: j
                .get_f64("peak_rss_bytes")
                .unwrap_or(0.0) as u64,
            cpu_percent: j.get_f64("cpu_percent").unwrap_or(0.0),
            cached: j
                .get("cached")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        }
    }
}

/// One cell's manifest entry: identity, outcome, artifact paths
/// (relative to the manifest's directory), and timing.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// human-readable id: `quantizer/topology/net/mode/seed`
    pub id: String,
    /// [`config_hash`] of the cell's config (the directory name)
    pub hash: String,
    /// this cell's axis assignments ([`Cell::axes_json`])
    pub axes: Json,
    /// `"ok"` or `"failed"`
    pub status: String,
    /// cell directory, relative to the manifest
    pub dir: String,
    pub rounds_csv: String,
    pub trace: String,
    pub resources: String,
    /// rounds recorded in `rounds.csv`
    pub rounds: usize,
    pub last_loss: f64,
    pub final_accuracy: f64,
    /// virtual clock of the last round (simnet cells)
    pub virtual_secs: f64,
    /// cumulative wire bytes of the last round
    pub wire_bytes: u64,
    pub timing: CellTiming,
}

impl CellResult {
    pub fn ok(&self) -> bool {
        self.status == "ok"
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("hash", Json::str(&self.hash)),
            ("axes", self.axes.clone()),
            ("status", Json::str(&self.status)),
            ("dir", Json::str(&self.dir)),
            ("rounds_csv", Json::str(&self.rounds_csv)),
            ("trace", Json::str(&self.trace)),
            ("resources", Json::str(&self.resources)),
            ("rounds", Json::num(self.rounds as f64)),
            ("last_loss", Json::num(self.last_loss)),
            ("final_accuracy", Json::num(self.final_accuracy)),
            ("virtual_secs", Json::num(self.virtual_secs)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
            ("timing", self.timing.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CellResult> {
        let req = |key: &str| -> anyhow::Result<String> {
            j.get_str(key).map(str::to_string).ok_or_else(|| {
                anyhow::anyhow!("cell record missing '{key}'")
            })
        };
        Ok(CellResult {
            id: req("id")?,
            hash: req("hash")?,
            axes: j
                .get("axes")
                .cloned()
                .unwrap_or(Json::obj(Vec::new())),
            status: req("status")?,
            dir: req("dir")?,
            rounds_csv: req("rounds_csv")?,
            trace: req("trace")?,
            resources: req("resources")?,
            rounds: j.get_usize("rounds").unwrap_or(0),
            // Json::num(NaN) serializes to null, so a failed cell's
            // losses read back as missing — keep them NaN
            last_loss: j.get_f64("last_loss").unwrap_or(f64::NAN),
            final_accuracy: j
                .get_f64("final_accuracy")
                .unwrap_or(f64::NAN),
            virtual_secs: j.get_f64("virtual_secs").unwrap_or(0.0),
            wire_bytes: j.get_f64("wire_bytes").unwrap_or(0.0) as u64,
            timing: j
                .get("timing")
                .map(CellTiming::from_json)
                .unwrap_or_default(),
        })
    }
}

/// The sweep's one output document: grid axes, base identity, and
/// every cell's outcome, in grid expansion order.
#[derive(Clone, Debug)]
pub struct SweepManifest {
    pub schema: String,
    /// the base config's name
    pub name: String,
    /// ordered axis listing ([`Grid::axes_json`])
    pub axes: Json,
    /// the base config's identity JSON
    pub base: Json,
    pub cells: Vec<CellResult>,
}

impl SweepManifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(&self.schema)),
            ("name", Json::str(&self.name)),
            ("axes", self.axes.clone()),
            ("base", self.base.clone()),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(CellResult::to_json)
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SweepManifest> {
        let schema = j.get_str("schema").unwrap_or("");
        anyhow::ensure!(
            schema == SWEEP_SCHEMA,
            "manifest schema '{schema}' != expected '{SWEEP_SCHEMA}'"
        );
        let cells = j
            .get("cells")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(CellResult::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(SweepManifest {
            schema: schema.to_string(),
            name: j.get_str("name").unwrap_or("sweep").to_string(),
            axes: j
                .get("axes")
                .cloned()
                .unwrap_or(Json::Arr(Vec::new())),
            base: j
                .get("base")
                .cloned()
                .unwrap_or(Json::obj(Vec::new())),
            cells,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<SweepManifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", path.display())
        })?;
        let j = Json::parse(&text).map_err(|e| {
            anyhow::anyhow!("parsing {}: {e}", path.display())
        })?;
        SweepManifest::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_pretty()).map_err(
            |e| anyhow::anyhow!("writing {}: {e}", path.display()),
        )
    }

    /// The manifest with every cell's timing zeroed, rendered
    /// compactly — equal across runs of the same sweep
    /// (`rust/tests/sweep_manifest.rs` pins this).
    pub fn determinism_key(&self) -> String {
        let mut m = self.clone();
        for cell in &mut m.cells {
            cell.timing = CellTiming::default();
        }
        m.to_json().to_string()
    }
}

/// Knobs of [`run_sweep`].
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// sweep output directory (manifest + `cells/`)
    pub out_dir: PathBuf,
    /// concurrent cells; 0 = the machine's available parallelism
    pub slots: usize,
    /// skip cells whose `cell.json` says they completed
    pub resume: bool,
    /// resource sampling cadence
    pub sample_every: Duration,
    /// the `lmdfl` binary to spawn; `None` = `current_exe()`
    pub binary: Option<PathBuf>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            out_dir: PathBuf::from("sweep-out"),
            slots: 0,
            resume: true,
            sample_every: Duration::from_millis(50),
            binary: None,
        }
    }
}

/// Expand `grid` over `base`, run every cell, write
/// `out_dir/manifest.json`, and return the manifest. Failed cells
/// are recorded with `status: "failed"` (the sweep keeps going); the
/// caller decides whether partial success is an error.
pub fn run_sweep(
    base: &ExperimentConfig,
    grid: &Grid,
    opts: &SweepOptions,
) -> anyhow::Result<SweepManifest> {
    let bin = match &opts.binary {
        Some(p) => p.clone(),
        None => std::env::current_exe()?,
    };
    let cells_dir = opts.out_dir.join("cells");
    std::fs::create_dir_all(&cells_dir).map_err(|e| {
        anyhow::anyhow!("creating {}: {e}", cells_dir.display())
    })?;

    // prepare every cell up front: config, hash, uniqueness
    let mut prepped = Vec::new();
    let mut seen = BTreeSet::new();
    for cell in grid.cells() {
        let mut cfg = cell.apply_to(base);
        let hash = config_hash(&cfg);
        anyhow::ensure!(
            seen.insert(hash.clone()),
            "duplicate cell {} (hash {hash}): two grid points \
             expand to the same config",
            cell.id()
        );
        // tracing is always on in a sweep; the path is relative to
        // the cell directory (the child's working directory)
        cfg.observe = Some(ObserveConfig {
            trace_path: Some("trace.jsonl".into()),
            chrome_path: None,
        });
        prepped.push((cell, cfg, hash));
    }

    let slots = match opts.slots {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
    .min(prepped.len().max(1));
    eprintln!(
        "sweep '{}': {} cells, {} slot(s) -> {}",
        base.name,
        prepped.len(),
        slots,
        opts.out_dir.display()
    );

    let queue: Mutex<VecDeque<usize>> =
        Mutex::new((0..prepped.len()).collect());
    let results: Mutex<Vec<Option<CellResult>>> =
        Mutex::new(vec![None; prepped.len()]);
    std::thread::scope(|scope| {
        for _ in 0..slots {
            scope.spawn(|| loop {
                let Some(idx) = queue.lock().unwrap().pop_front()
                else {
                    return;
                };
                let (cell, cfg, hash) = &prepped[idx];
                let res =
                    run_cell(&bin, &cells_dir, cell, cfg, hash, opts);
                let result = match res {
                    Ok(r) => {
                        eprintln!(
                            "sweep: {} {} ({:.1}s{})",
                            r.id,
                            r.status,
                            r.timing.wall_secs,
                            if r.timing.cached {
                                ", cached"
                            } else {
                                ""
                            }
                        );
                        r
                    }
                    Err(e) => {
                        eprintln!(
                            "sweep: cell {} failed: {e:#}",
                            cell.id()
                        );
                        failed_cell(cell, hash)
                    }
                };
                results.lock().unwrap()[idx] = Some(result);
            });
        }
    });

    let cells: Vec<CellResult> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every queued cell produces a result"))
        .collect();
    let manifest = SweepManifest {
        schema: SWEEP_SCHEMA.to_string(),
        name: base.name.clone(),
        axes: grid.axes_json(),
        base: base.identity_json(),
        cells,
    };
    manifest.save(&opts.out_dir.join("manifest.json"))?;
    Ok(manifest)
}

/// The manifest entry of a cell that errored before producing
/// artifacts.
fn failed_cell(cell: &Cell, hash: &str) -> CellResult {
    CellResult {
        id: cell.id(),
        hash: hash.to_string(),
        axes: cell.axes_json(),
        status: "failed".to_string(),
        dir: format!("cells/{hash}"),
        rounds_csv: format!("cells/{hash}/rounds.csv"),
        trace: format!("cells/{hash}/trace.jsonl"),
        resources: format!("cells/{hash}/resources.jsonl"),
        rounds: 0,
        last_loss: f64::NAN,
        final_accuracy: f64::NAN,
        virtual_secs: 0.0,
        wire_bytes: 0,
        timing: CellTiming::default(),
    }
}

/// Run one cell: spawn `lmdfl train` in `cells/<hash>/`, sample its
/// `/proc` entries until exit, then fold artifacts into a
/// [`CellResult`] and persist it as `cell.json`.
fn run_cell(
    bin: &Path,
    cells_dir: &Path,
    cell: &Cell,
    cfg: &ExperimentConfig,
    hash: &str,
    opts: &SweepOptions,
) -> anyhow::Result<CellResult> {
    let dir = cells_dir.join(hash);
    let cell_json = dir.join("cell.json");
    if opts.resume {
        if let Some(mut done) = load_completed(&cell_json, hash) {
            done.timing.cached = true;
            return Ok(done);
        }
    }
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("config.json"),
        cfg.to_json().to_pretty(),
    )?;

    // async runs buffer a merged log (--csv); sync runs stream
    let stream_flag =
        if cfg.mode == crate::config::EngineMode::Async {
            "--csv"
        } else {
            "--stream-csv"
        };
    let log_file = std::fs::File::create(dir.join("run.log"))?;
    let log_err = log_file.try_clone()?;
    let mut child = Command::new(bin)
        .current_dir(&dir)
        .args([
            "train",
            "--config",
            "config.json",
            stream_flag,
            "rounds.csv",
            "--quiet",
        ])
        .stdin(Stdio::null())
        .stdout(log_file)
        .stderr(log_err)
        .spawn()
        .map_err(|e| {
            anyhow::anyhow!("spawning {}: {e}", bin.display())
        })?;

    let mut mon =
        ProcessMonitor::new(child.id(), &dir.join("resources.jsonl"))?;
    let status = loop {
        mon.sample();
        match child.try_wait()? {
            Some(status) => break status,
            None => std::thread::sleep(opts.sample_every),
        }
    };
    let usage = mon.finish();
    anyhow::ensure!(
        status.success(),
        "cell {} exited with {status} (see {})",
        cell.id(),
        dir.join("run.log").display()
    );

    let csv = std::fs::read_to_string(dir.join("rounds.csv"))?;
    let log = RunLog::from_csv(&cell.id(), &csv)?;
    let last = log.records.last().ok_or_else(|| {
        anyhow::anyhow!("cell {} produced no rounds", cell.id())
    })?;
    let trace_text =
        std::fs::read_to_string(dir.join("trace.jsonl"))?;
    let tf = crate::obs::export::parse_trace(&trace_text)?;
    crate::obs::summary::check(&tf)?;

    let rel = |file: &str| format!("cells/{hash}/{file}");
    let result = CellResult {
        id: cell.id(),
        hash: hash.to_string(),
        axes: cell.axes_json(),
        status: "ok".to_string(),
        dir: format!("cells/{hash}"),
        rounds_csv: rel("rounds.csv"),
        trace: rel("trace.jsonl"),
        resources: rel("resources.jsonl"),
        rounds: log.records.len(),
        last_loss: log.last_loss().unwrap_or(f64::NAN),
        final_accuracy: log.final_accuracy().unwrap_or(f64::NAN),
        virtual_secs: last.virtual_secs,
        wire_bytes: last.wire_bytes,
        timing: CellTiming {
            wall_secs: usage.wall_secs,
            peak_rss_bytes: usage.peak_rss_bytes,
            cpu_percent: usage.cpu_percent,
            cached: false,
        },
    };
    std::fs::write(&cell_json, result.to_json().to_pretty())?;
    Ok(result)
}

/// A completed prior run of this cell, if its `cell.json` matches the
/// hash, says `ok`, and all three artifacts still exist.
fn load_completed(cell_json: &Path, hash: &str) -> Option<CellResult> {
    let text = std::fs::read_to_string(cell_json).ok()?;
    let j = Json::parse(&text).ok()?;
    let res = CellResult::from_json(&j).ok()?;
    if res.hash != hash || !res.ok() {
        return None;
    }
    let dir = cell_json.parent()?;
    for artifact in ["rounds.csv", "trace.jsonl", "resources.jsonl"] {
        if !dir.join(artifact).exists() {
            return None;
        }
    }
    Some(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantizerKind;

    #[test]
    fn config_hash_is_stable_and_observe_invariant() {
        let cfg = ExperimentConfig::default();
        let h1 = config_hash(&cfg);
        let h2 = config_hash(&cfg);
        assert_eq!(h1, h2);
        assert_eq!(h1.len(), 16);

        let mut traced = cfg.clone();
        traced.observe = Some(ObserveConfig {
            trace_path: Some("/tmp/elsewhere.jsonl".into()),
            chrome_path: None,
        });
        assert_eq!(config_hash(&traced), h1);

        let mut other = cfg.clone();
        other.quantizer = QuantizerKind::Qsgd { s: 16 };
        assert_ne!(config_hash(&other), h1);
        let mut renamed = cfg.clone();
        renamed.name = "something-else".into();
        assert_ne!(config_hash(&renamed), h1);
    }

    fn sample_cell() -> CellResult {
        CellResult {
            id: "qsgd/ring/base/sync/base/7".into(),
            hash: "00deadbeef001234".into(),
            axes: Json::obj(vec![(
                "quantizer",
                Json::str("qsgd"),
            )]),
            status: "ok".into(),
            dir: "cells/00deadbeef001234".into(),
            rounds_csv: "cells/00deadbeef001234/rounds.csv".into(),
            trace: "cells/00deadbeef001234/trace.jsonl".into(),
            resources: "cells/00deadbeef001234/resources.jsonl"
                .into(),
            rounds: 12,
            last_loss: 0.25,
            final_accuracy: 0.875,
            virtual_secs: 3.5,
            wire_bytes: 123_456,
            timing: CellTiming {
                wall_secs: 1.25,
                peak_rss_bytes: 7 << 20,
                cpu_percent: 93.5,
                cached: false,
            },
        }
    }

    #[test]
    fn cell_result_roundtrips_through_json() {
        let cell = sample_cell();
        let back =
            CellResult::from_json(&cell.to_json()).unwrap();
        assert_eq!(back.id, cell.id);
        assert_eq!(back.hash, cell.hash);
        assert_eq!(back.status, cell.status);
        assert_eq!(back.rounds, cell.rounds);
        assert_eq!(back.last_loss, cell.last_loss);
        assert_eq!(back.wire_bytes, cell.wire_bytes);
        assert_eq!(back.timing, cell.timing);
    }

    #[test]
    fn failed_cell_losses_roundtrip_as_nan() {
        let mut cell = sample_cell();
        cell.status = "failed".into();
        cell.last_loss = f64::NAN;
        cell.final_accuracy = f64::NAN;
        let back =
            CellResult::from_json(&cell.to_json()).unwrap();
        assert!(back.last_loss.is_nan());
        assert!(back.final_accuracy.is_nan());
    }

    #[test]
    fn manifest_roundtrips_and_key_ignores_timing() {
        let base = ExperimentConfig::default();
        let grid = Grid::from_base(&base);
        let manifest = SweepManifest {
            schema: SWEEP_SCHEMA.to_string(),
            name: base.name.clone(),
            axes: grid.axes_json(),
            base: base.identity_json(),
            cells: vec![sample_cell()],
        };
        let back =
            SweepManifest::from_json(&manifest.to_json()).unwrap();
        assert_eq!(back.cells.len(), 1);
        assert_eq!(back.name, manifest.name);

        let mut slower = manifest.clone();
        slower.cells[0].timing.wall_secs = 99.0;
        slower.cells[0].timing.peak_rss_bytes = 1 << 30;
        assert_eq!(
            slower.determinism_key(),
            manifest.determinism_key()
        );
        let mut different = manifest.clone();
        different.cells[0].last_loss = 0.5;
        assert_ne!(
            different.determinism_key(),
            manifest.determinism_key()
        );
    }

    #[test]
    fn wrong_schema_is_rejected() {
        let j = Json::obj(vec![(
            "schema",
            Json::str("lmdfl-sweep-v0"),
        )]);
        assert!(SweepManifest::from_json(&j).is_err());
    }
}
