//! Sweep grids: the cartesian product of experiment axes.
//!
//! A [`Grid`] holds one list of values per axis — quantizer, topology,
//! network regime, engine mode, seed — in that fixed order. An axis
//! not set explicitly holds exactly one value taken from the base
//! config, so a fresh grid is the base experiment itself.
//! [`Grid::cells`] expands the product row-major (the last axis, seed,
//! varies fastest); [`Cell::apply_to`] stamps one cell onto the base
//! config.

use crate::config::json::Json;
use crate::config::{
    AttackConfig, AttackKind, EngineMode, ExperimentConfig,
    QuantizerKind, TopologyKind,
};
use crate::experiments::fig_time;

/// Which simnet fabric a sweep cell runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetRegime {
    /// keep the base config's `network:` section (possibly none)
    Base,
    /// strip the section: the ideal instantaneous network
    Ideal,
    /// the bandwidth-constrained heterogeneous torus-16 fabric
    Torus16,
    /// the straggler-heavy fabric of the async-torus-16 preset
    Straggler,
    /// the fast, mildly heterogeneous large-fleet fabric
    Scale,
}

impl NetRegime {
    pub fn name(&self) -> &'static str {
        match self {
            NetRegime::Base => "base",
            NetRegime::Ideal => "ideal",
            NetRegime::Torus16 => "torus16",
            NetRegime::Straggler => "straggler",
            NetRegime::Scale => "scale",
        }
    }

    pub fn parse_str(text: &str) -> anyhow::Result<Self> {
        Ok(match text {
            "base" => NetRegime::Base,
            "ideal" => NetRegime::Ideal,
            "torus16" => NetRegime::Torus16,
            "straggler" => NetRegime::Straggler,
            "scale" => NetRegime::Scale,
            other => anyhow::bail!(
                "unknown net regime '{other}' \
                 (have: base, ideal, torus16, straggler, scale)"
            ),
        })
    }

    /// Materialize the regime over `cfg.network`.
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        match self {
            NetRegime::Base => {}
            NetRegime::Ideal => cfg.network = None,
            NetRegime::Torus16 => {
                cfg.network = Some(fig_time::torus16_network());
            }
            NetRegime::Straggler => {
                cfg.network = Some(fig_time::async_torus16_network());
            }
            NetRegime::Scale => {
                cfg.network = Some(fig_time::scale_network());
            }
        }
    }
}

/// Which adversary a sweep cell faces (the `attack` regime axis).
/// The Byzantine regimes run the fig-robust preset's adversary: the
/// first `f = 2` node ids corrupted, scale factor −4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackRegime {
    /// keep the base config's `attack:` section (possibly none)
    Base,
    /// strip the section: every sender honest
    Honest,
    /// f=2 sign-flip senders
    SignFlip,
    /// f=2 scaled-gradient senders (factor −4)
    Scale,
    /// f=2 random-message senders
    Random,
}

impl AttackRegime {
    pub fn name(&self) -> &'static str {
        match self {
            AttackRegime::Base => "base",
            AttackRegime::Honest => "none",
            AttackRegime::SignFlip => "sign_flip",
            AttackRegime::Scale => "scale",
            AttackRegime::Random => "random",
        }
    }

    pub fn parse_str(text: &str) -> anyhow::Result<Self> {
        Ok(match text {
            "base" => AttackRegime::Base,
            "none" => AttackRegime::Honest,
            "sign_flip" => AttackRegime::SignFlip,
            "scale" => AttackRegime::Scale,
            "random" => AttackRegime::Random,
            other => anyhow::bail!(
                "unknown attack regime '{other}' \
                 (have: base, none, sign_flip, scale, random)"
            ),
        })
    }

    /// Materialize the regime over `cfg.attack`.
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        let kind = match self {
            AttackRegime::Base => return,
            AttackRegime::Honest => {
                cfg.attack = None;
                return;
            }
            AttackRegime::SignFlip => AttackKind::SignFlip,
            AttackRegime::Scale => AttackKind::Scale { factor: -4.0 },
            AttackRegime::Random => AttackKind::Random,
        };
        cfg.attack = Some(AttackConfig { kind, f: 2 });
    }
}

/// Parse one quantizer axis value by name (the CLI's `lm` / `da`
/// aliases included), with the crate's default parameters per kind.
pub fn quantizer_from_name(
    name: &str,
) -> anyhow::Result<QuantizerKind> {
    Ok(match name {
        "full" => QuantizerKind::Full,
        "qsgd" => QuantizerKind::Qsgd { s: 16 },
        "natural" => QuantizerKind::Natural { s: 16 },
        "alq" => QuantizerKind::Alq { s: 16 },
        "lloyd_max" | "lm" => {
            QuantizerKind::LloydMax { s: 16, iters: 12 }
        }
        "doubly_adaptive" | "da" => QuantizerKind::DoublyAdaptive {
            s1: 4,
            iters: 12,
            s_max: 4096,
        },
        "terngrad" => QuantizerKind::TernGrad,
        "topk" => QuantizerKind::TopK { keep: 0.1 },
        other => anyhow::bail!("unknown quantizer '{other}'"),
    })
}

/// Parse one topology axis value by name (parameterized kinds get
/// their CLI defaults: `random` p=0.4, `random_regular` k=4).
pub fn topology_from_name(name: &str) -> anyhow::Result<TopologyKind> {
    Ok(match name {
        "full" => TopologyKind::Full,
        "ring" => TopologyKind::Ring,
        "disconnected" => TopologyKind::Disconnected,
        "star" => TopologyKind::Star,
        "torus" => TopologyKind::Torus,
        "random" => TopologyKind::Random { p: 0.4 },
        "random_regular" => TopologyKind::RandomRegular { k: 4 },
        other => anyhow::bail!("unknown topology '{other}'"),
    })
}

/// One expansion cell: a concrete value per axis.
#[derive(Clone, Debug)]
pub struct Cell {
    pub quantizer: QuantizerKind,
    pub topology: TopologyKind,
    pub net: NetRegime,
    pub mode: EngineMode,
    pub attack: AttackRegime,
    pub seed: u64,
}

impl Cell {
    /// The stable human-readable cell id:
    /// `quantizer/topology/net/mode/attack/seed`.
    pub fn id(&self) -> String {
        format!(
            "{}/{}/{}/{}/{}/{}",
            self.quantizer.name(),
            self.topology.name(),
            self.net.name(),
            self.mode.name(),
            self.attack.name(),
            self.seed
        )
    }

    /// The axis assignments of this cell (seed stays numeric).
    pub fn axes_json(&self) -> Json {
        Json::obj(vec![
            ("quantizer", Json::str(self.quantizer.name())),
            ("topology", Json::str(self.topology.name())),
            ("net", Json::str(self.net.name())),
            ("mode", Json::str(self.mode.name())),
            ("attack", Json::str(self.attack.name())),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    /// Stamp this cell onto a copy of the base config. The cell id
    /// becomes the config name; async cells without an `async:`
    /// section inherit the async-torus-16 preset policy so engine
    /// mode is the only difference against their sync siblings.
    pub fn apply_to(&self, base: &ExperimentConfig) -> ExperimentConfig {
        let mut cfg = base.clone();
        cfg.name = self.id();
        cfg.quantizer = self.quantizer.clone();
        cfg.topology = self.topology.clone();
        cfg.mode = self.mode;
        cfg.seed = self.seed;
        self.net.apply(&mut cfg);
        self.attack.apply(&mut cfg);
        if cfg.mode == EngineMode::Async && cfg.agossip.is_none() {
            cfg.agossip = Some(fig_time::async_torus16_policy());
        }
        cfg
    }
}

/// The sweep's axis lists, in the fixed expansion order.
#[derive(Clone, Debug)]
pub struct Grid {
    pub quantizers: Vec<QuantizerKind>,
    pub topologies: Vec<TopologyKind>,
    pub nets: Vec<NetRegime>,
    pub modes: Vec<EngineMode>,
    pub attacks: Vec<AttackRegime>,
    pub seeds: Vec<u64>,
}

fn split(list: &str) -> impl Iterator<Item = &str> {
    list.split(',').map(str::trim).filter(|s| !s.is_empty())
}

impl Grid {
    /// A 1-cell grid: every axis pinned to the base config's value.
    pub fn from_base(base: &ExperimentConfig) -> Grid {
        Grid {
            quantizers: vec![base.quantizer.clone()],
            topologies: vec![base.topology.clone()],
            nets: vec![NetRegime::Base],
            modes: vec![base.mode],
            attacks: vec![AttackRegime::Base],
            seeds: vec![base.seed],
        }
    }

    pub fn set_quantizers(&mut self, list: &str) -> anyhow::Result<()> {
        self.quantizers = split(list)
            .map(quantizer_from_name)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !self.quantizers.is_empty(),
            "--quantizers list is empty"
        );
        Ok(())
    }

    pub fn set_topologies(&mut self, list: &str) -> anyhow::Result<()> {
        self.topologies = split(list)
            .map(topology_from_name)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !self.topologies.is_empty(),
            "--topologies list is empty"
        );
        Ok(())
    }

    pub fn set_nets(&mut self, list: &str) -> anyhow::Result<()> {
        self.nets = split(list)
            .map(NetRegime::parse_str)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!self.nets.is_empty(), "--nets list is empty");
        Ok(())
    }

    pub fn set_modes(&mut self, list: &str) -> anyhow::Result<()> {
        self.modes = split(list)
            .map(|m| EngineMode::parse_str(m).map_err(Into::into))
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!self.modes.is_empty(), "--modes list is empty");
        Ok(())
    }

    pub fn set_attacks(&mut self, list: &str) -> anyhow::Result<()> {
        self.attacks = split(list)
            .map(AttackRegime::parse_str)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !self.attacks.is_empty(),
            "--attacks list is empty"
        );
        Ok(())
    }

    /// Seed repeats: `base, base+1, ..., base+repeats-1`.
    pub fn set_seed_repeats(&mut self, base: u64, repeats: usize) {
        self.seeds =
            (0..repeats.max(1) as u64).map(|i| base + i).collect();
    }

    pub fn set_seed_list(&mut self, list: &str) -> anyhow::Result<()> {
        self.seeds = split(list)
            .map(|s| {
                s.parse::<u64>().map_err(|_| {
                    anyhow::anyhow!("bad seed '{s}' in --seed-list")
                })
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            !self.seeds.is_empty(),
            "--seed-list is empty"
        );
        Ok(())
    }

    /// Number of cells in the product.
    pub fn len(&self) -> usize {
        self.quantizers.len()
            * self.topologies.len()
            * self.nets.len()
            * self.modes.len()
            * self.attacks.len()
            * self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the product row-major: quantizer outermost, seed
    /// innermost (the manifest's cell order).
    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::with_capacity(self.len());
        for q in &self.quantizers {
            for t in &self.topologies {
                for n in &self.nets {
                    for m in &self.modes {
                        for a in &self.attacks {
                            for &s in &self.seeds {
                                out.push(Cell {
                                    quantizer: q.clone(),
                                    topology: t.clone(),
                                    net: *n,
                                    mode: *m,
                                    attack: *a,
                                    seed: s,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The manifest's ordered axis listing. An array of per-axis
    /// objects rather than one object: JSON objects here are
    /// `BTreeMap`s and would alphabetize the declared axis order.
    pub fn axes_json(&self) -> Json {
        fn axis(name: &str, values: Vec<Json>) -> Json {
            Json::obj(vec![
                ("axis", Json::str(name)),
                ("values", Json::Arr(values)),
            ])
        }
        Json::Arr(vec![
            axis(
                "quantizer",
                self.quantizers
                    .iter()
                    .map(|q| Json::str(q.name()))
                    .collect(),
            ),
            axis(
                "topology",
                self.topologies
                    .iter()
                    .map(|t| Json::str(t.name()))
                    .collect(),
            ),
            axis(
                "net",
                self.nets
                    .iter()
                    .map(|n| Json::str(n.name()))
                    .collect(),
            ),
            axis(
                "mode",
                self.modes
                    .iter()
                    .map(|m| Json::str(m.name()))
                    .collect(),
            ),
            axis(
                "attack",
                self.attacks
                    .iter()
                    .map(|a| Json::str(a.name()))
                    .collect(),
            ),
            axis(
                "seed",
                self.seeds
                    .iter()
                    .map(|&s| Json::num(s as f64))
                    .collect(),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_grid_is_the_base_experiment() {
        let base = ExperimentConfig::default();
        let grid = Grid::from_base(&base);
        assert_eq!(grid.len(), 1);
        let cells = grid.cells();
        let cfg = cells[0].apply_to(&base);
        assert_eq!(cfg.quantizer, base.quantizer);
        assert_eq!(cfg.topology, base.topology);
        assert_eq!(cfg.seed, base.seed);
        assert_eq!(cfg.name, "lloyd_max/ring/base/sync/base/0");
        // the default attack regime keeps the base section (none here)
        assert!(cfg.attack.is_none());
    }

    #[test]
    fn expansion_is_row_major_with_seed_fastest() {
        let base = ExperimentConfig::default();
        let mut grid = Grid::from_base(&base);
        grid.set_quantizers("lloyd_max,qsgd").unwrap();
        grid.set_modes("sync,async").unwrap();
        grid.set_seed_repeats(5, 2);
        assert_eq!(grid.len(), 8);
        let ids: Vec<String> =
            grid.cells().iter().map(Cell::id).collect();
        assert_eq!(ids[0], "lloyd_max/ring/base/sync/base/5");
        assert_eq!(ids[1], "lloyd_max/ring/base/sync/base/6");
        assert_eq!(ids[2], "lloyd_max/ring/base/async/base/5");
        assert_eq!(ids[4], "lloyd_max/ring/base/sync/base/5".replace(
            "lloyd_max", "qsgd"));
        assert_eq!(ids[7], "qsgd/ring/base/async/base/6");
    }

    #[test]
    fn async_cells_inherit_the_preset_policy() {
        let base = ExperimentConfig::default();
        assert!(base.agossip.is_none());
        let mut grid = Grid::from_base(&base);
        grid.set_modes("async").unwrap();
        let cfg = grid.cells()[0].apply_to(&base);
        assert_eq!(cfg.mode, EngineMode::Async);
        assert!(cfg.agossip.is_some());
    }

    #[test]
    fn net_regimes_materialize_fabrics() {
        let mut base = ExperimentConfig::default();
        base.network =
            Some(crate::simnet::NetworkConfig::default());
        let mut grid = Grid::from_base(&base);
        grid.set_nets("ideal,torus16,straggler").unwrap();
        let cells = grid.cells();
        assert!(cells[0].apply_to(&base).network.is_none());
        let torus = cells[1].apply_to(&base).network.unwrap();
        assert_eq!(torus.link.bandwidth_bps, 2e6);
        let strag = cells[2].apply_to(&base).network.unwrap();
        assert_eq!(strag.compute.straggler_slowdown, 8.0);
    }

    #[test]
    fn axes_json_preserves_declaration_order() {
        let base = ExperimentConfig::default();
        let mut grid = Grid::from_base(&base);
        grid.set_quantizers("qsgd,lm").unwrap();
        let axes = grid.axes_json();
        let arr = axes.as_arr().unwrap();
        let order: Vec<&str> = arr
            .iter()
            .filter_map(|a| a.get_str("axis"))
            .collect();
        assert_eq!(
            order,
            vec![
                "quantizer", "topology", "net", "mode", "attack",
                "seed"
            ]
        );
        // list order inside an axis is preserved too (qsgd first)
        let qs = arr[0].get("values").unwrap().as_arr().unwrap();
        assert_eq!(qs[0].as_str(), Some("qsgd"));
        assert_eq!(qs[1].as_str(), Some("lloyd_max"));
    }

    #[test]
    fn bad_axis_values_are_rejected() {
        let base = ExperimentConfig::default();
        let mut grid = Grid::from_base(&base);
        assert!(grid.set_quantizers("qsgd,telepathy").is_err());
        assert!(grid.set_topologies("moebius").is_err());
        assert!(grid.set_nets("underwater").is_err());
        assert!(grid.set_modes("both").is_err());
        assert!(grid.set_attacks("polite").is_err());
        assert!(grid.set_seed_list("1,two").is_err());
    }

    #[test]
    fn attack_regimes_materialize_adversaries() {
        let mut base = ExperimentConfig::default();
        base.attack = Some(AttackConfig {
            kind: AttackKind::SignFlip,
            f: 3,
        });
        let mut grid = Grid::from_base(&base);
        grid.set_attacks("base,none,sign_flip,scale,random").unwrap();
        let cells = grid.cells();
        // `base` keeps the config's own section, f and all
        let kept = cells[0].apply_to(&base).attack.unwrap();
        assert_eq!(kept.f, 3);
        // `none` strips it
        assert!(cells[1].apply_to(&base).attack.is_none());
        // the Byzantine regimes pin the fig-robust adversary (f = 2)
        let sf = cells[2].apply_to(&base).attack.unwrap();
        assert_eq!(sf.kind, AttackKind::SignFlip);
        assert_eq!(sf.f, 2);
        let sc = cells[3].apply_to(&base).attack.unwrap();
        assert_eq!(sc.kind, AttackKind::Scale { factor: -4.0 });
        let rn = cells[4].apply_to(&base).attack.unwrap();
        assert_eq!(rn.kind, AttackKind::Random);
        // ids carry the regime segment
        assert_eq!(
            cells[2].id(),
            "lloyd_max/ring/base/sync/sign_flip/0"
        );
        // every materialized config stays valid
        for c in &cells {
            c.apply_to(&base).validate().unwrap();
        }
    }

    #[test]
    fn sparse_quantizer_axis_values_parse() {
        assert_eq!(
            quantizer_from_name("terngrad").unwrap(),
            QuantizerKind::TernGrad
        );
        assert!(matches!(
            quantizer_from_name("topk").unwrap(),
            QuantizerKind::TopK { .. }
        ));
    }
}
