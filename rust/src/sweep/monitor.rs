//! Per-run resource monitoring via `/proc`.
//!
//! A [`ProcessMonitor`] samples one child process at a fixed cadence:
//! resident set (`VmRSS`), peak resident set (`VmHWM`) and cumulative
//! CPU time (`utime + stime` from `/proc/<pid>/stat`). Every sample is
//! appended as one JSON line to a `resources.jsonl` file next to the
//! run's trace, and [`ProcessMonitor::finish`] folds the series into a
//! [`ResourceUsage`] summary (peak RSS, CPU seconds, mean CPU%). The
//! sweep runner owns the sampling loop — it polls the child's exit
//! status between samples, so monitoring costs no extra thread.
//!
//! Off-Linux (no `/proc`) the monitor degrades gracefully: samples
//! read nothing, the summary reports zeros, and the JSONL holds only
//! its header line.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

/// Kernel clock ticks per second for `utime`/`stime` (the universal
/// Linux value; `sysconf(_SC_CLK_TCK)` without libc).
const CLK_TCK: f64 = 100.0;

/// Folded resource series of one monitored run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResourceUsage {
    /// samples taken (0 when `/proc` was unavailable)
    pub samples: u64,
    /// max `VmHWM` observed, bytes
    pub peak_rss_bytes: u64,
    /// cumulative `utime + stime` at the last sample, seconds
    pub cpu_secs: f64,
    /// wall-clock monitor lifetime, seconds
    pub wall_secs: f64,
    /// mean utilization: `100 * cpu_secs / wall_secs`
    pub cpu_percent: f64,
}

/// Samples one pid's `/proc` entries and streams them to JSONL.
pub struct ProcessMonitor {
    pid: u32,
    started: Instant,
    samples: u64,
    peak_rss_bytes: u64,
    cpu_secs: f64,
    sink: BufWriter<File>,
}

impl ProcessMonitor {
    /// Open the JSONL sink and write its header line
    /// (`{"schema":"lmdfl-resources-v1","pid":N}`).
    pub fn new(pid: u32, jsonl: &Path) -> anyhow::Result<Self> {
        let file = File::create(jsonl).map_err(|e| {
            anyhow::anyhow!("creating {}: {e}", jsonl.display())
        })?;
        let mut sink = BufWriter::new(file);
        writeln!(
            sink,
            "{{\"schema\":\"lmdfl-resources-v1\",\"pid\":{pid}}}"
        )?;
        Ok(ProcessMonitor {
            pid,
            started: Instant::now(),
            samples: 0,
            peak_rss_bytes: 0,
            cpu_secs: 0.0,
            sink,
        })
    }

    /// Take one sample. Returns `false` once the pid's `/proc` entry
    /// is gone (process exited) — the caller's cue to stop sampling.
    pub fn sample(&mut self) -> bool {
        let Some((rss, hwm)) = read_status(self.pid) else {
            return false;
        };
        let cpu = read_cpu_secs(self.pid).unwrap_or(self.cpu_secs);
        self.peak_rss_bytes = self.peak_rss_bytes.max(hwm);
        self.cpu_secs = self.cpu_secs.max(cpu);
        self.samples += 1;
        let t = self.started.elapsed().as_secs_f64();
        let _ = writeln!(
            self.sink,
            "{{\"t_secs\":{t},\"rss_bytes\":{rss},\
             \"vm_hwm_bytes\":{hwm},\"cpu_secs\":{cpu}}}"
        );
        true
    }

    /// Flush the JSONL and fold the series into a summary.
    pub fn finish(mut self) -> ResourceUsage {
        let _ = self.sink.flush();
        let wall = self.started.elapsed().as_secs_f64();
        ResourceUsage {
            samples: self.samples,
            peak_rss_bytes: self.peak_rss_bytes,
            cpu_secs: self.cpu_secs,
            wall_secs: wall,
            cpu_percent: if wall > 0.0 {
                100.0 * self.cpu_secs / wall
            } else {
                0.0
            },
        }
    }
}

/// `VmRSS` and `VmHWM` from `/proc/<pid>/status`, in bytes.
fn read_status(pid: u32) -> Option<(u64, u64)> {
    let text =
        std::fs::read_to_string(format!("/proc/{pid}/status")).ok()?;
    let kb = |l: &str| -> Option<u64> {
        l.split_whitespace().nth(1).and_then(|v| v.parse().ok())
    };
    let mut rss = 0u64;
    let mut hwm = 0u64;
    for line in text.lines() {
        if line.starts_with("VmRSS:") {
            rss = kb(line)? * 1024;
        } else if line.starts_with("VmHWM:") {
            hwm = kb(line)? * 1024;
        }
    }
    Some((rss, hwm))
}

/// Cumulative `utime + stime` from `/proc/<pid>/stat`, in seconds.
/// The comm field may contain spaces, so tokens count from the last
/// `)`: utime and stime are fields 14 and 15 of the stat line, i.e.
/// whitespace tokens 11 and 12 after the closing paren.
fn read_cpu_secs(pid: u32) -> Option<f64> {
    let text =
        std::fs::read_to_string(format!("/proc/{pid}/stat")).ok()?;
    let rest = &text[text.rfind(')')? + 1..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some((utime + stime) as f64 / CLK_TCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::Json;

    #[test]
    fn monitors_own_process_and_streams_jsonl() {
        if !Path::new("/proc/self/status").exists() {
            return; // no procfs on this platform
        }
        let dir = std::env::temp_dir().join(format!(
            "lmdfl-monitor-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let jsonl = dir.join("resources.jsonl");
        let mut mon =
            ProcessMonitor::new(std::process::id(), &jsonl).unwrap();
        // burn a little CPU between samples so cpu_secs can move
        let mut acc = 0u64;
        for round in 0..3 {
            for i in 0..200_000u64 {
                acc = acc.wrapping_mul(31).wrapping_add(i ^ round);
            }
            assert!(mon.sample());
        }
        assert!(acc != 42); // keep the loop alive
        let usage = mon.finish();
        assert_eq!(usage.samples, 3);
        assert!(usage.peak_rss_bytes > 0);
        assert!(usage.wall_secs > 0.0);

        let text = std::fs::read_to_string(&jsonl).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 samples
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(
            header.get_str("schema"),
            Some("lmdfl-resources-v1")
        );
        for line in &lines[1..] {
            let doc = Json::parse(line).unwrap();
            assert!(doc.get_f64("rss_bytes").unwrap() > 0.0);
            assert!(doc.get_f64("t_secs").unwrap() >= 0.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_pid_reports_gone() {
        // pid 0 never has a /proc entry visible this way
        assert!(read_status(0).is_none());
    }
}
