//! Deterministic discrete-event clock: a binary-heap event queue over
//! integer virtual nanoseconds.
//!
//! Determinism contract: events are ordered by `(time, seq)` where `seq`
//! is the insertion sequence number, so simultaneous events pop in the
//! exact order they were scheduled — the queue is a stable priority
//! queue. Payloads never participate in the ordering (no `Ord` bound),
//! and virtual time is integral (nanoseconds), so two runs that schedule
//! the same events produce byte-identical pop sequences on any platform.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds since simulation start.
pub type VirtualTime = u64;

/// Convert (non-negative, finite) seconds to virtual nanoseconds,
/// rounding to the nearest integer so link/compute durations derived
/// from `f64` models stay platform-independent.
#[inline]
pub fn secs_to_ns(secs: f64) -> VirtualTime {
    debug_assert!(secs >= 0.0 && secs.is_finite(), "bad duration {secs}");
    (secs * 1e9).round() as VirtualTime
}

/// Convert virtual nanoseconds back to seconds (reporting only).
#[inline]
pub fn ns_to_secs(ns: VirtualTime) -> f64 {
    ns as f64 / 1e9
}

/// One scheduled event. Heap entries compare on `(time, seq)` only.
struct Entry<P> {
    time: VirtualTime,
    seq: u64,
    payload: P,
}

impl<P> PartialEq for Entry<P> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<P> Eq for Entry<P> {}

impl<P> PartialOrd for Entry<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<P> Ord for Entry<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Stable min-priority event queue with a monotonic virtual clock.
pub struct EventQueue<P> {
    heap: BinaryHeap<Entry<P>>,
    next_seq: u64,
    now: VirtualTime,
    /// total events popped over the queue's lifetime (bench/report metric)
    processed: u64,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: 0,
            processed: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> VirtualTime {
        self.now
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Lifetime count of popped events.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute virtual time `at`. Scheduling in
    /// the past is a logic error; the check is unconditional (not a
    /// `debug_assert`) so debug and release builds can never diverge on
    /// the replay contract.
    pub fn schedule(&mut self, at: VirtualTime, payload: P) {
        assert!(at >= self.now, "event scheduled in the past");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time: at, seq, payload });
    }

    /// Schedule `payload` `delay` nanoseconds after the current time.
    pub fn schedule_in(&mut self, delay: VirtualTime, payload: P) {
        self.schedule(self.now.saturating_add(delay), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(VirtualTime, P)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.payload))
    }

    /// Reset the clock to a new epoch without clearing statistics. Only
    /// valid when no events are pending (between simulation rounds).
    pub fn rebase(&mut self, now: VirtualTime) {
        assert!(self.heap.is_empty(), "rebase with pending events");
        self.now = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(7, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5, ());
        q.schedule(5, ());
        q.schedule(9, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 9);
    }

    #[test]
    fn schedule_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.schedule(100, 0u8);
        q.pop();
        q.schedule_in(50, 1u8);
        assert_eq!(q.pop(), Some((150, 1u8)));
    }

    #[test]
    fn rebase_moves_epoch() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.schedule(10, 0);
        q.pop();
        q.rebase(1000);
        q.schedule_in(5, 1);
        assert_eq!(q.pop(), Some((1005, 1)));
    }

    #[test]
    fn secs_ns_roundtrip() {
        assert_eq!(secs_to_ns(0.0), 0);
        assert_eq!(secs_to_ns(1.0), 1_000_000_000);
        assert_eq!(secs_to_ns(0.005), 5_000_000);
        assert!((ns_to_secs(secs_to_ns(2.5)) - 2.5).abs() < 1e-12);
    }
}
