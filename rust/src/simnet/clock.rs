//! Deterministic discrete-event clock: a binary-heap event queue over
//! integer virtual nanoseconds.
//!
//! Determinism contract: events are ordered by `(time, seq)` where `seq`
//! is the insertion sequence number, so simultaneous events pop in the
//! exact order they were scheduled — the queue is a stable priority
//! queue. Payloads never participate in the ordering (no `Ord` bound),
//! and virtual time is integral (nanoseconds), so two runs that schedule
//! the same events produce byte-identical pop sequences on any platform.
//!
//! Allocation contract (the 10k-node scale-up): payloads live in a
//! slab arena recycled through a free list, and the heap holds only
//! small plain-data `(time, seq, slot)` entries. Once the maximum
//! number of *concurrently pending* events has been seen, schedule/pop
//! cycles allocate nothing — the heap keeps its capacity across pops
//! and every slab slot is reused — so the steady-state event loop runs
//! at arena speed regardless of how many million events pass through.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds since simulation start.
pub type VirtualTime = u64;

/// Convert (non-negative, finite) seconds to virtual nanoseconds,
/// rounding to the nearest integer so link/compute durations derived
/// from `f64` models stay platform-independent.
#[inline]
pub fn secs_to_ns(secs: f64) -> VirtualTime {
    debug_assert!(secs >= 0.0 && secs.is_finite(), "bad duration {secs}");
    (secs * 1e9).round() as VirtualTime
}

/// Convert virtual nanoseconds back to seconds (reporting only).
#[inline]
pub fn ns_to_secs(ns: VirtualTime) -> f64 {
    ns as f64 / 1e9
}

/// One scheduled event: ordering key + arena slot of the payload.
/// Heap entries compare on `(time, seq)` only.
#[derive(Clone, Copy)]
struct Entry {
    time: VirtualTime,
    seq: u64,
    slot: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Stable min-priority event queue with a monotonic virtual clock and
/// arena-allocated payloads.
pub struct EventQueue<P> {
    heap: BinaryHeap<Entry>,
    /// payload arena; `None` slots are parked on `free`
    slab: Vec<Option<P>>,
    /// recycled slab slots
    free: Vec<u32>,
    next_seq: u64,
    now: VirtualTime,
    /// total events popped over the queue's lifetime (bench/report metric)
    processed: u64,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            next_seq: 0,
            now: 0,
            processed: 0,
        }
    }

    /// Pre-size the arena and heap for `cap` concurrently pending
    /// events so even the warm-up phase allocates nothing.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            slab: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            next_seq: 0,
            now: 0,
            processed: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> VirtualTime {
        self.now
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Lifetime count of popped events.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of payload slots the arena has ever grown to — the peak
    /// concurrent-event watermark (steady state allocates no new ones).
    pub fn arena_slots(&self) -> usize {
        self.slab.len()
    }

    /// Schedule `payload` at absolute virtual time `at`. Scheduling in
    /// the past is a logic error; the check is unconditional (not a
    /// `debug_assert`) so debug and release builds can never diverge on
    /// the replay contract.
    pub fn schedule(&mut self, at: VirtualTime, payload: P) {
        assert!(at >= self.now, "event scheduled in the past");
        let seq = self.next_seq;
        self.next_seq += 1;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(payload);
                s
            }
            None => {
                let s = self.slab.len();
                assert!(s <= u32::MAX as usize, "event arena overflow");
                self.slab.push(Some(payload));
                s as u32
            }
        };
        self.heap.push(Entry { time: at, seq, slot });
    }

    /// Schedule `payload` `delay` nanoseconds after the current time.
    pub fn schedule_in(&mut self, delay: VirtualTime, payload: P) {
        self.schedule(self.now.saturating_add(delay), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(VirtualTime, P)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        let payload = self.slab[e.slot as usize]
            .take()
            .expect("arena slot empty on pop");
        self.free.push(e.slot);
        Some((e.time, payload))
    }

    /// Reset the clock to a new epoch without clearing statistics. Only
    /// valid when no events are pending (between simulation rounds).
    pub fn rebase(&mut self, now: VirtualTime) {
        assert!(self.heap.is_empty(), "rebase with pending events");
        self.now = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(7, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5, ());
        q.schedule(5, ());
        q.schedule(9, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 9);
    }

    #[test]
    fn schedule_in_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.schedule(100, 0u8);
        q.pop();
        q.schedule_in(50, 1u8);
        assert_eq!(q.pop(), Some((150, 1u8)));
    }

    #[test]
    fn rebase_moves_epoch() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.schedule(10, 0);
        q.pop();
        q.rebase(1000);
        q.schedule_in(5, 1);
        assert_eq!(q.pop(), Some((1005, 1)));
    }

    #[test]
    fn arena_stops_growing_at_peak_pending() {
        // peak concurrency 8: after warm-up, a million schedule/pop
        // cycles must not grow the arena — slots are recycled
        let mut q = EventQueue::new();
        let mut t = 0;
        for _ in 0..8 {
            t += 1;
            q.schedule(t, t);
        }
        let peak = q.arena_slots();
        assert_eq!(peak, 8);
        for _ in 0..100_000 {
            let (now, _) = q.pop().unwrap();
            t = t.max(now) + 1;
            q.schedule(t, t);
            assert_eq!(q.arena_slots(), peak, "arena grew in steady state");
        }
        assert_eq!(q.len(), 8);
    }

    #[test]
    fn arena_reuse_preserves_payloads_and_order() {
        // interleave boxed payloads through recycled slots and check
        // values are never crossed
        let mut q = EventQueue::new();
        for round in 0u64..50 {
            for i in 0..4 {
                q.schedule(round * 10 + i, Box::new(round * 10 + i));
            }
            for i in 0..4 {
                let (time, v) = q.pop().unwrap();
                assert_eq!(time, round * 10 + i);
                assert_eq!(*v, time);
            }
        }
        assert!(q.arena_slots() <= 4);
    }

    #[test]
    fn with_capacity_preallocates() {
        let mut q: EventQueue<u64> = EventQueue::with_capacity(16);
        for i in 0..16 {
            q.schedule(i, i);
        }
        assert_eq!(q.arena_slots(), 16);
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 16);
    }

    #[test]
    fn secs_ns_roundtrip() {
        assert_eq!(secs_to_ns(0.0), 0);
        assert_eq!(secs_to_ns(1.0), 1_000_000_000);
        assert_eq!(secs_to_ns(0.005), 5_000_000);
        assert!((ns_to_secs(secs_to_ns(2.5)) - 2.5).abs() < 1e-12);
    }
}
