//! Topology churn: nodes leave/return and links fail/heal mid-run.
//!
//! Every `interval_rounds` rounds the churn layer re-draws the fault
//! state of the base graph and rebuilds the confusion matrix with
//! Metropolis–Hastings weights over the surviving edges (the standard
//! construction — stays symmetric doubly stochastic for any subgraph,
//! isolated nodes degenerate to self-weight 1), then recomputes ζ so the
//! engine's spectral bookkeeping (α(ζ), Lemma 2) tracks the live graph
//! instead of the stale build-time one.
//!
//! Determinism: the fault coins come from a dedicated rng stream and are
//! drawn in sorted edge / node order, so the churn trajectory is a pure
//! function of (seed, base graph, config).

use std::collections::BTreeSet;

use crate::linalg::eigen::second_largest_abs_eigenvalue;
use crate::linalg::power::PowerBudget;
use crate::topology::{
    metropolis_weights, SparseTopology, Topology, DENSE_ORACLE_MAX,
};
use crate::util::rng::Rng;

/// Churn process parameters. All probabilities are per churn epoch
/// (every `interval_rounds` rounds); `interval_rounds == 0` disables
/// churn entirely.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnConfig {
    /// re-draw faults every this many rounds (0 = never)
    pub interval_rounds: usize,
    /// probability an up link fails this epoch
    pub link_fail_prob: f64,
    /// probability a failed link heals this epoch
    pub link_heal_prob: f64,
    /// probability an online node leaves this epoch
    pub node_leave_prob: f64,
    /// probability an offline node returns this epoch
    pub node_return_prob: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            interval_rounds: 0,
            link_fail_prob: 0.0,
            link_heal_prob: 0.5,
            node_leave_prob: 0.0,
            node_return_prob: 0.5,
        }
    }
}

impl ChurnConfig {
    pub fn enabled(&self) -> bool {
        self.interval_rounds > 0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("link_fail_prob", self.link_fail_prob),
            ("link_heal_prob", self.link_heal_prob),
            ("node_leave_prob", self.node_leave_prob),
            ("node_return_prob", self.node_return_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("churn {name} must be in [0, 1]"));
            }
        }
        Ok(())
    }
}

/// Live churn state over a fixed base graph.
#[derive(Clone, Debug)]
pub struct ChurnState {
    cfg: ChurnConfig,
    /// undirected base edges, sorted, as (min, max) pairs
    base_edges: Vec<(usize, usize)>,
    n: usize,
    failed_links: BTreeSet<(usize, usize)>,
    offline_nodes: BTreeSet<usize>,
    rng: Rng,
    /// base-graph neighbors per node (for dirty-set expansion)
    base_adj: Vec<Vec<usize>>,
    /// last rebuilt live graph (large-n incremental path only)
    live: Option<(Vec<Vec<usize>>, SparseTopology)>,
}

impl ChurnState {
    /// Capture the base graph from the build-time topology.
    pub fn new(cfg: ChurnConfig, base: &Topology, rng: Rng) -> Self {
        let mut base_edges = Vec::new();
        for (i, nbrs) in base.adj.iter().enumerate() {
            for &j in nbrs {
                if i < j {
                    base_edges.push((i, j));
                }
            }
        }
        base_edges.sort_unstable();
        ChurnState {
            cfg,
            base_edges,
            n: base.n,
            failed_links: BTreeSet::new(),
            offline_nodes: BTreeSet::new(),
            rng,
            base_adj: base.adj.clone(),
            live: None,
        }
    }

    /// Nodes currently offline (for the fabric's compute scheduling).
    pub fn offline(&self) -> &BTreeSet<usize> {
        &self.offline_nodes
    }

    /// Whether the undirected link {i, j} currently carries traffic.
    pub fn link_up(&self, i: usize, j: usize) -> bool {
        let key = (i.min(j), i.max(j));
        !self.failed_links.contains(&key)
            && !self.offline_nodes.contains(&i)
            && !self.offline_nodes.contains(&j)
    }

    /// Maybe re-draw faults before round `k`; returns the rebuilt
    /// topology when the live graph changed. Round 0 uses the pristine
    /// base graph.
    pub fn pre_round(&mut self, k: usize) -> Option<Topology> {
        if !self.cfg.enabled() || k == 0 || k % self.cfg.interval_rounds != 0
        {
            return None;
        }
        let mut changed = false;
        // nodes whose incident-edge liveness toggled this epoch — the
        // seeds of the incremental dirty set
        let mut touched = BTreeSet::new();
        // links first, then nodes — both in sorted order (determinism)
        for &edge in &self.base_edges {
            if self.failed_links.contains(&edge) {
                if self.cfg.link_heal_prob > 0.0
                    && self.rng.uniform() < self.cfg.link_heal_prob
                {
                    self.failed_links.remove(&edge);
                    changed = true;
                    touched.insert(edge.0);
                    touched.insert(edge.1);
                }
            } else if self.cfg.link_fail_prob > 0.0
                && self.rng.uniform() < self.cfg.link_fail_prob
            {
                self.failed_links.insert(edge);
                changed = true;
                touched.insert(edge.0);
                touched.insert(edge.1);
            }
        }
        for i in 0..self.n {
            let toggled = if self.offline_nodes.contains(&i) {
                self.cfg.node_return_prob > 0.0
                    && self.rng.uniform() < self.cfg.node_return_prob
                    && self.offline_nodes.remove(&i)
            } else if self.cfg.node_leave_prob > 0.0
                && self.rng.uniform() < self.cfg.node_leave_prob
            {
                self.offline_nodes.insert(i)
            } else {
                false
            };
            if toggled {
                changed = true;
                // every incident base edge changes liveness
                touched.insert(i);
                for &j in &self.base_adj[i] {
                    touched.insert(j);
                }
            }
        }
        if changed {
            Some(self.rebuild_touched(&touched))
        } else {
            None
        }
    }

    /// Surviving-edge adjacency of the current fault state.
    fn live_adj(&self) -> Vec<Vec<usize>> {
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); self.n];
        for &(i, j) in &self.base_edges {
            if self.link_up(i, j) {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
        adj
    }

    /// Build the live topology: surviving edges, Metropolis weights,
    /// fresh ζ. Isolated / offline nodes keep self-weight 1, so C stays
    /// symmetric doubly stochastic no matter what failed.
    ///
    /// Small graphs (n ≤ [`DENSE_ORACLE_MAX`]) rebuild the dense matrix
    /// from scratch — the historical path, byte-identical digests.
    pub fn rebuild(&self) -> Topology {
        let adj = self.live_adj();
        if self.n <= DENSE_ORACLE_MAX {
            let c = metropolis_weights(&adj);
            let zeta = second_largest_abs_eigenvalue(&c);
            let sparse = SparseTopology::from_dense(&c);
            Topology { n: self.n, adj, sparse, c: Some(c), zeta }
        } else {
            let sparse = SparseTopology::metropolis(&adj);
            let zeta = sparse.zeta_power(PowerBudget::Hot);
            Topology { n: self.n, adj, sparse, c: None, zeta }
        }
    }

    /// Incremental large-n rebuild: recompute only the Metropolis rows
    /// whose weights can have changed — the touched nodes plus their
    /// one-hop neighborhoods under the previous *and* the new live
    /// graph (a degree change at a node moves the weights of every
    /// incident edge, which moves its neighbors' diagonals too). Rows
    /// are recomputed whole, so the result is exactly equal to a
    /// from-scratch build (tested below); ζ comes from power iteration
    /// either way.
    fn rebuild_touched(&mut self, touched: &BTreeSet<usize>) -> Topology {
        if self.n <= DENSE_ORACLE_MAX {
            return self.rebuild();
        }
        let adj = self.live_adj();
        let (sparse, zeta) = match self.live.take() {
            Some((old_adj, mut sp)) => {
                let mut dirty = BTreeSet::new();
                for &t in touched {
                    dirty.insert(t);
                    dirty.extend(old_adj[t].iter().copied());
                    dirty.extend(adj[t].iter().copied());
                }
                sp.rebuild_rows(&adj, dirty.into_iter());
                let zeta = sp.zeta_power(PowerBudget::Hot);
                (sp, zeta)
            }
            None => {
                let sp = SparseTopology::metropolis(&adj);
                let zeta = sp.zeta_power(PowerBudget::Hot);
                (sp, zeta)
            }
        };
        self.live = Some((adj.clone(), sparse.clone()));
        Topology { n: self.n, adj, sparse, c: None, zeta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    fn churny(interval: usize) -> ChurnConfig {
        ChurnConfig {
            interval_rounds: interval,
            link_fail_prob: 0.4,
            link_heal_prob: 0.5,
            node_leave_prob: 0.2,
            node_return_prob: 0.5,
        }
    }

    #[test]
    fn disabled_churn_never_fires() {
        let base = Topology::build(&TopologyKind::Ring, 8, 0);
        let mut st =
            ChurnState::new(ChurnConfig::default(), &base, Rng::new(1));
        for k in 0..50 {
            assert!(st.pre_round(k).is_none());
        }
    }

    #[test]
    fn rebuilt_matrix_stays_symmetric_doubly_stochastic() {
        let base = Topology::build(&TopologyKind::Torus, 16, 3);
        let mut st = ChurnState::new(churny(1), &base, Rng::new(9));
        let mut rebuilds = 0;
        for k in 1..40 {
            if let Some(t) = st.pre_round(k) {
                rebuilds += 1;
                assert!(
                    t.dense().is_symmetric(1e-12),
                    "round {k}: asymmetric"
                );
                assert!(
                    t.dense().is_doubly_stochastic(1e-9),
                    "round {k}: not doubly stochastic"
                );
                assert!(t.zeta >= -1e-12 && t.zeta <= 1.0 + 1e-9);
                // adjacency stays a subgraph of the base torus
                for (i, nbrs) in t.adj.iter().enumerate() {
                    for &j in nbrs {
                        assert!(base.adj[i].contains(&j));
                    }
                }
            }
        }
        assert!(rebuilds > 5, "churn too quiet: {rebuilds} rebuilds");
    }

    #[test]
    fn deterministic_trajectory() {
        let base = Topology::build(&TopologyKind::Ring, 10, 0);
        let run = |seed| {
            let mut st = ChurnState::new(churny(2), &base, Rng::new(seed));
            let mut trace = Vec::new();
            for k in 0..30 {
                if let Some(t) = st.pre_round(k) {
                    trace.push((k, t.directed_links(), t.zeta.to_bits()));
                }
            }
            trace
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn offline_node_loses_all_links() {
        let base = Topology::build(&TopologyKind::Full, 5, 0);
        let cfg = ChurnConfig {
            interval_rounds: 1,
            node_leave_prob: 1.0,
            node_return_prob: 0.0,
            link_fail_prob: 0.0,
            link_heal_prob: 0.0,
        };
        let mut st = ChurnState::new(cfg, &base, Rng::new(0));
        let t = st.pre_round(1).unwrap();
        // everyone left: fully disconnected, C = I, zeta = 1
        assert!(t.adj.iter().all(|a| a.is_empty()));
        for i in 0..5 {
            assert!((t.weight(i, i) - 1.0).abs() < 1e-12);
        }
        assert!((t.zeta - 1.0).abs() < 1e-9);
    }

    #[test]
    fn incremental_rebuild_matches_from_scratch_at_scale() {
        // n = 100 takes the sparse incremental path; every rebuilt
        // topology must exactly equal a from-scratch Metropolis build
        // of the same fault state (rows are recomputed whole, so this
        // is equality, not approximation)
        let base = Topology::build(&TopologyKind::Torus, 100, 5);
        let mut st = ChurnState::new(churny(1), &base, Rng::new(11));
        let mut rebuilds = 0;
        for k in 1..20 {
            if let Some(t) = st.pre_round(k) {
                rebuilds += 1;
                assert!(t.c.is_none(), "large churn rebuilt dense C");
                let oracle = st.rebuild();
                assert_eq!(
                    t.sparse, oracle.sparse,
                    "round {k}: incremental != full"
                );
                assert_eq!(t.zeta.to_bits(), oracle.zeta.to_bits());
            }
        }
        assert!(rebuilds > 5, "churn too quiet: {rebuilds} rebuilds");
    }
}
