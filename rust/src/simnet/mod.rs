//! simnet — a deterministic discrete-event communication-fabric
//! simulator for DFL training runs.
//!
//! The paper's headline claim is communication efficiency measured in
//! bits *and* in time progression; counting bits over ideal static links
//! only covers the first axis. This subsystem models the second:
//!
//! * [`clock`] — binary-heap event queue over integer virtual
//!   nanoseconds with stable `(time, seq)` ordering;
//! * [`link`] — per-directed-link latency + bandwidth + jitter + drop
//!   models, with message serialization per link;
//! * [`compute`] — heterogeneous per-node τ-step SGD durations and
//!   transient stragglers;
//! * [`churn`] — nodes leave/return and links fail/heal, rebuilding the
//!   Metropolis confusion matrix (and ζ) on the live subgraph;
//! * [`substrate`] — the shared live state (links, compute fleet,
//!   offline set, churn, rng) every virtual-clock engine drives;
//! * [`fabric`] — ties them together for the synchronous round barrier:
//!   one [`Fabric`] per run, one [`fabric::RoundTiming`] per round.
//!
//! Entry points: [`crate::dfl::DflEngine::run_simulated`] wraps the
//! matrix engine's rounds in a fabric (filling the
//! `virtual_secs` / `straggler_wait_secs` metrics columns), the
//! asynchronous event-driven engine ([`crate::agossip`]) drives a
//! [`Substrate`] from its own per-node state machines (no round
//! barrier), and the `fig-time` CLI / `experiments::fig_time` driver
//! reproduces the paper's loss-vs-time comparison on a
//! bandwidth-constrained torus. Everything is a pure function of
//! (seed, config): two identical runs produce byte-identical logs and
//! event digests (`rust/tests/simnet_determinism.rs`).

pub mod churn;
pub mod clock;
pub mod compute;
pub mod fabric;
pub mod link;
pub mod substrate;

pub use churn::{ChurnConfig, ChurnState};
pub use clock::{ns_to_secs, secs_to_ns, EventQueue, VirtualTime};
pub use compute::{ComputeModel, NodeCompute};
pub use fabric::{Fabric, RoundTiming};
pub use link::{Link, LinkModel};
pub use substrate::Substrate;

use crate::config::json::Json;
use crate::config::ConfigError;

/// The `network:` config section: everything the fabric needs. Absent
/// section = ideal instantaneous network (the pre-simnet behavior).
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    /// base model applied to every directed link
    pub link: LinkModel,
    /// per-link bandwidth divisor is uniform in [1, 1 + spread]
    /// (heterogeneous links; 0 = uniform fabric)
    pub link_hetero_spread: f64,
    pub compute: ComputeModel,
    pub churn: ChurnConfig,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            link: LinkModel::ideal(),
            link_hetero_spread: 0.0,
            compute: ComputeModel::default(),
            churn: ChurnConfig::default(),
        }
    }
}

impl NetworkConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| ConfigError(format!("network: {m}"));
        self.link.validate().map_err(err)?;
        if !(self.link_hetero_spread >= 0.0
            && self.link_hetero_spread.is_finite())
        {
            return Err(err(
                "link_hetero_spread must be finite and >= 0".into(),
            ));
        }
        self.compute.validate().map_err(err)?;
        self.churn.validate().map_err(err)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("latency_s", Json::num(self.link.latency_s)),
            ("bandwidth_bps", Json::num(self.link.bandwidth_bps)),
            ("jitter_s", Json::num(self.link.jitter_s)),
            ("drop_prob", Json::num(self.link.drop_prob)),
            ("link_hetero_spread", Json::num(self.link_hetero_spread)),
            (
                "compute",
                Json::obj(vec![
                    ("base_step_s", Json::num(self.compute.base_step_s)),
                    (
                        "hetero_spread",
                        Json::num(self.compute.hetero_spread),
                    ),
                    (
                        "straggler_prob",
                        Json::num(self.compute.straggler_prob),
                    ),
                    (
                        "straggler_slowdown",
                        Json::num(self.compute.straggler_slowdown),
                    ),
                ]),
            ),
            (
                "churn",
                Json::obj(vec![
                    (
                        "interval_rounds",
                        Json::num(self.churn.interval_rounds as f64),
                    ),
                    (
                        "link_fail_prob",
                        Json::num(self.churn.link_fail_prob),
                    ),
                    (
                        "link_heal_prob",
                        Json::num(self.churn.link_heal_prob),
                    ),
                    (
                        "node_leave_prob",
                        Json::num(self.churn.node_leave_prob),
                    ),
                    (
                        "node_return_prob",
                        Json::num(self.churn.node_return_prob),
                    ),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let d = NetworkConfig::default();
        let link = LinkModel {
            latency_s: j.get_f64("latency_s").unwrap_or(d.link.latency_s),
            bandwidth_bps: j
                .get_f64("bandwidth_bps")
                .unwrap_or(d.link.bandwidth_bps),
            jitter_s: j.get_f64("jitter_s").unwrap_or(d.link.jitter_s),
            drop_prob: j.get_f64("drop_prob").unwrap_or(d.link.drop_prob),
        };
        let compute = match j.get("compute") {
            Some(cj) => ComputeModel {
                base_step_s: cj
                    .get_f64("base_step_s")
                    .unwrap_or(d.compute.base_step_s),
                hetero_spread: cj
                    .get_f64("hetero_spread")
                    .unwrap_or(d.compute.hetero_spread),
                straggler_prob: cj
                    .get_f64("straggler_prob")
                    .unwrap_or(d.compute.straggler_prob),
                straggler_slowdown: cj
                    .get_f64("straggler_slowdown")
                    .unwrap_or(d.compute.straggler_slowdown),
            },
            None => d.compute.clone(),
        };
        let churn = match j.get("churn") {
            Some(cj) => ChurnConfig {
                interval_rounds: cj
                    .get_usize("interval_rounds")
                    .unwrap_or(d.churn.interval_rounds),
                link_fail_prob: cj
                    .get_f64("link_fail_prob")
                    .unwrap_or(d.churn.link_fail_prob),
                link_heal_prob: cj
                    .get_f64("link_heal_prob")
                    .unwrap_or(d.churn.link_heal_prob),
                node_leave_prob: cj
                    .get_f64("node_leave_prob")
                    .unwrap_or(d.churn.node_leave_prob),
                node_return_prob: cj
                    .get_f64("node_return_prob")
                    .unwrap_or(d.churn.node_return_prob),
            },
            None => d.churn.clone(),
        };
        let cfg = NetworkConfig {
            link,
            link_hetero_spread: j
                .get_f64("link_hetero_spread")
                .unwrap_or(d.link_hetero_spread),
            compute,
            churn,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_ideal() {
        let d = NetworkConfig::default();
        d.validate().unwrap();
        assert_eq!(d.link, LinkModel::ideal());
        assert!(!d.churn.enabled());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = NetworkConfig {
            link: LinkModel {
                latency_s: 0.005,
                bandwidth_bps: 2e6,
                jitter_s: 0.001,
                drop_prob: 0.05,
            },
            link_hetero_spread: 0.5,
            compute: ComputeModel {
                base_step_s: 2e-3,
                hetero_spread: 0.4,
                straggler_prob: 0.1,
                straggler_slowdown: 6.0,
            },
            churn: ChurnConfig {
                interval_rounds: 5,
                link_fail_prob: 0.1,
                link_heal_prob: 0.6,
                node_leave_prob: 0.02,
                node_return_prob: 0.7,
            },
        };
        let text = cfg.to_json().to_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = NetworkConfig::from_json(&parsed).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"bandwidth_bps": 1000000.0}"#).unwrap();
        let cfg = NetworkConfig::from_json(&j).unwrap();
        assert_eq!(cfg.link.bandwidth_bps, 1e6);
        assert_eq!(cfg.link.latency_s, 0.0);
        assert_eq!(cfg.compute, ComputeModel::default());
    }

    #[test]
    fn invalid_sections_rejected() {
        let j = Json::parse(r#"{"drop_prob": 2.0}"#).unwrap();
        assert!(NetworkConfig::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"compute": {"straggler_slowdown": 0.1}}"#,
        )
        .unwrap();
        assert!(NetworkConfig::from_json(&j).is_err());
        let j =
            Json::parse(r#"{"churn": {"link_fail_prob": -0.5}}"#).unwrap();
        assert!(NetworkConfig::from_json(&j).is_err());
    }
}
