//! Per-directed-link transmission models.
//!
//! A [`LinkModel`] turns a message size into an occupancy interval: a
//! message of `bytes` wire bytes holds the link for
//! `latency + bytes·8/bandwidth (+ jitter)` virtual seconds, and links
//! serialize — a second message queued on the same directed link waits
//! for the first to clear ([`Link::transmit`] tracks `busy_until`). This
//! is the store-and-forward fabric the paper's "time progression" axis
//! assumes, generalized to heterogeneous rates and lossy links (the old
//! `drop_prob` knob of `dfl::net` is one field of this model now).

use super::clock::{secs_to_ns, VirtualTime};
use crate::util::rng::Rng;

/// A directed link's quality-of-service parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// one-way propagation delay in seconds
    pub latency_s: f64,
    /// serialization rate in bits per second
    pub bandwidth_bps: f64,
    /// uniform extra delay in [0, jitter_s) drawn per message
    pub jitter_s: f64,
    /// probability a message is lost (it still occupies the link)
    pub drop_prob: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        Self::ideal()
    }
}

impl LinkModel {
    /// Zero-latency, paper-rate (100 Mbps), lossless link.
    pub fn ideal() -> Self {
        LinkModel {
            latency_s: 0.0,
            bandwidth_bps: 100e6,
            jitter_s: 0.0,
            drop_prob: 0.0,
        }
    }

    /// Ideal link with a drop probability — the exact semantics of the
    /// old `NetOptions::drop_prob` knob.
    pub fn lossy(drop_prob: f64) -> Self {
        LinkModel { drop_prob, ..Self::ideal() }
    }

    /// Transmission duration for `bytes` wire bytes, drawing jitter from
    /// `rng` (one uniform per message when jitter is enabled, none
    /// otherwise — keeps lossless/jitterless runs on the same rng
    /// stream as before).
    pub fn transfer_ns(&self, bytes: u64, rng: &mut Rng) -> VirtualTime {
        let mut secs = self.latency_s + bytes as f64 * 8.0 / self.bandwidth_bps;
        if self.jitter_s > 0.0 {
            secs += rng.uniform() * self.jitter_s;
        }
        secs_to_ns(secs)
    }

    /// Draw the per-message loss coin (no rng consumed when lossless).
    pub fn dropped(&self, rng: &mut Rng) -> bool {
        self.drop_prob > 0.0 && rng.uniform() < self.drop_prob
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.latency_s >= 0.0 && self.latency_s.is_finite()) {
            return Err("link latency_s must be finite and >= 0".into());
        }
        if !(self.bandwidth_bps > 0.0 && self.bandwidth_bps.is_finite()) {
            return Err("link bandwidth_bps must be finite and > 0".into());
        }
        if !(self.jitter_s >= 0.0 && self.jitter_s.is_finite()) {
            return Err("link jitter_s must be finite and >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.drop_prob) {
            return Err("link drop_prob must be in [0, 1]".into());
        }
        Ok(())
    }
}

/// One directed link's live state inside the fabric.
#[derive(Clone, Debug)]
pub struct Link {
    pub model: LinkModel,
    /// the link is transmitting until this virtual time
    pub busy_until: VirtualTime,
    /// whether churn has (temporarily) failed this link
    pub up: bool,
}

impl Link {
    pub fn new(model: LinkModel) -> Self {
        Link { model, busy_until: 0, up: true }
    }

    /// Queue a message of `bytes` at earliest-start `ready`; returns the
    /// arrival time and whether the message was lost in flight. Lost
    /// messages still occupy the link (the sender transmitted them).
    pub fn transmit(
        &mut self,
        ready: VirtualTime,
        bytes: u64,
        rng: &mut Rng,
    ) -> (VirtualTime, bool) {
        let start = ready.max(self.busy_until);
        let arrive = start + self.model.transfer_ns(bytes, rng);
        self.busy_until = arrive;
        let lost = self.model.dropped(rng);
        (arrive, lost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let m = LinkModel {
            latency_s: 0.010,
            bandwidth_bps: 1e6,
            jitter_s: 0.0,
            drop_prob: 0.0,
        };
        let mut rng = Rng::new(0);
        // 12_500 bytes = 100_000 bits = 0.1 s at 1 Mbps, + 10 ms latency
        let ns = m.transfer_ns(12_500, &mut rng);
        assert_eq!(ns, secs_to_ns(0.110));
    }

    #[test]
    fn links_serialize_back_to_back_messages() {
        let mut link = Link::new(LinkModel {
            latency_s: 0.0,
            bandwidth_bps: 8e6, // 1 byte per microsecond
            jitter_s: 0.0,
            drop_prob: 0.0,
        });
        let mut rng = Rng::new(1);
        let (a1, _) = link.transmit(0, 1000, &mut rng);
        let (a2, _) = link.transmit(0, 1000, &mut rng);
        assert_eq!(a1, secs_to_ns(1000e-6));
        // second message waits for the first to clear the link
        assert_eq!(a2, 2 * a1);
        assert_eq!(link.busy_until, a2);
    }

    #[test]
    fn jitter_draws_are_bounded_and_deterministic() {
        let m = LinkModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            jitter_s: 0.001,
            drop_prob: 0.0,
        };
        let base = {
            let mut rng = Rng::new(7);
            m.transfer_ns(100, &mut rng)
        };
        let again = {
            let mut rng = Rng::new(7);
            m.transfer_ns(100, &mut rng)
        };
        assert_eq!(base, again, "same seed, same jitter");
        let floor = secs_to_ns(100.0 * 8.0 / 1e9);
        assert!(base >= floor && base <= floor + secs_to_ns(0.001));
    }

    #[test]
    fn drop_probability_extremes() {
        let mut rng = Rng::new(3);
        assert!(!LinkModel::ideal().dropped(&mut rng));
        let always = LinkModel::lossy(1.0);
        for _ in 0..16 {
            assert!(always.dropped(&mut rng));
        }
    }

    #[test]
    fn validate_catches_bad_fields() {
        assert!(LinkModel::ideal().validate().is_ok());
        assert!(
            LinkModel { bandwidth_bps: 0.0, ..LinkModel::ideal() }
                .validate()
                .is_err()
        );
        assert!(
            LinkModel { drop_prob: 1.5, ..LinkModel::ideal() }
                .validate()
                .is_err()
        );
        assert!(
            LinkModel { latency_s: -1.0, ..LinkModel::ideal() }
                .validate()
                .is_err()
        );
    }
}
