//! The fabric: a virtual-time model of one DFL deployment under the
//! *synchronous* round barrier.
//!
//! [`Fabric::simulate_round`] replays one communication round of the
//! gossip protocol on the event queue: at round start every node
//! broadcasts its mixing delta q2 (one message per up directed link,
//! links serialize), runs its τ local steps on its own compute model,
//! then broadcasts the local-update delta q1; a node is done when its
//! own compute finished AND every surviving inbound message arrived, and
//! the round closes at the straggler barrier — the latest node-done
//! time. The engine keeps producing the learning dynamics; the fabric
//! produces *when* each round happens, which is exactly the decomposition
//! the paper's time-progression axis assumes (bits → seconds), extended
//! to heterogeneous links, stragglers, and churn.
//!
//! The live link/compute/churn state lives in the shared
//! [`Substrate`] so the asynchronous engine
//! ([`crate::agossip::AsyncGossipEngine`]) can drive the exact same
//! deployment model from its own event loop, without the round barrier.
//!
//! Loss semantics: the fabric's per-link drop coins shape the timeline
//! (a lost message still occupies its link — the sender transmitted it —
//! but lands nowhere, so no arrival barrier); the *learning-level*
//! effect of loss in the matrix engine stays broadcast-level
//! (`EngineOptions::drop_prob`, which
//! [`DflEngine::run_simulated`](crate::dfl::DflEngine::run_simulated)
//! seeds from this fabric's link model), because the matrix form keeps
//! one globally consistent estimate — the two layers draw independent
//! coins at the same rate. An engine-dropped broadcast is still charged
//! to the links (run_simulated substitutes the same-sized q1 message),
//! so lossier networks never get *faster* timelines. The threaded
//! runtime (`dfl::net`) drops per link for real.
//!
//! Byte semantics: a zero entry in `q2_bytes`/`q1_bytes` means "nothing
//! was put on the wire at all" — an offline or engine-suppressed
//! sender. It can NEVER mean "an empty quantized message": the wire
//! format always ships a header, so a legitimately empty (full-zero)
//! delta still encodes to at least
//! [`crate::quant::wire::MIN_ENCODED_BYTES`] and still occupies its
//! links. [`Fabric::simulate_round`] asserts that distinction so a
//! caller passing sub-header "sizes" fails loudly instead of silently
//! skewing the timeline.

use super::clock::{ns_to_secs, EventQueue, VirtualTime};
use super::substrate::{fold_event, Substrate, DIGEST_OFFSET};
use super::NetworkConfig;
use crate::topology::Topology;

/// Timing record of one simulated round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTiming {
    /// this round's duration in virtual seconds
    pub round_secs: f64,
    /// cumulative virtual clock at the end of the round
    pub virtual_secs: f64,
    /// mean time online nodes idled at the round barrier
    pub straggler_wait_secs: f64,
    /// nodes whose compute straggled this round
    pub stragglers: usize,
    /// messages lost in flight this round
    pub messages_lost: u64,
}

/// Simulation events: a node finishing its τ local steps, or a message
/// (phase 0 = q2 mixing delta, phase 1 = q1 local-update delta) landing.
#[derive(Clone, Copy, Debug)]
enum Ev {
    ComputeDone { node: usize },
    Arrive { to: usize, phase: u8 },
}

/// A deployment's communication fabric in virtual time.
pub struct Fabric {
    /// shared link/compute/churn state (see [`Substrate`])
    sub: Substrate,
    queue: EventQueue<Ev>,
    /// FNV-1a hash over the popped (time, kind, node) stream — the
    /// deterministic-replay fingerprint the simnet tests compare
    digest: u64,
    /// per-round scratch: each node's done time
    node_done: Vec<VirtualTime>,
}

impl Fabric {
    /// Assemble the fabric for `topo` (see [`Substrate::new`] for the
    /// deterministic build contract).
    pub fn new(cfg: &NetworkConfig, topo: &Topology, seed: u64) -> Fabric {
        let sub = Substrate::new(cfg, topo, seed);
        let n = sub.n();
        Fabric {
            sub,
            queue: EventQueue::new(),
            digest: DIGEST_OFFSET,
            node_done: vec![0; n],
        }
    }

    /// Loss probability the engine's broadcast-level fault injection
    /// should inherit (the old `drop_prob` knob, subsumed).
    pub fn link_drop_prob(&self) -> f64 {
        self.sub.link_drop_prob()
    }

    /// Lifetime count of processed simulation events.
    pub fn events_processed(&self) -> u64 {
        self.queue.processed()
    }

    /// Deterministic fingerprint of the full event stream so far.
    pub fn event_digest(&self) -> u64 {
        self.digest
    }

    /// Lifetime bytes put on links (every transmitted copy, dropped
    /// in-flight included) — the fabric-side byte meter that must equal
    /// the sum of the engines' encoded wire-message lengths.
    pub fn bytes_on_wire(&self) -> u64 {
        self.sub.bytes_on_wire()
    }

    /// Current virtual time in seconds.
    pub fn virtual_secs(&self) -> f64 {
        ns_to_secs(self.queue.now())
    }

    /// Run the churn process before round `k`; when the live graph
    /// changed, returns the rebuilt topology (Metropolis weights, fresh
    /// ζ) the engine must mix with from now on.
    pub fn pre_round(&mut self, k: usize) -> Option<Topology> {
        self.sub.pre_round(k)
    }

    /// Simulate round `k`'s timeline. `q2_bytes[i]` / `q1_bytes[i]` are
    /// node i's wire bytes for the two broadcast messages this round.
    /// 0 = that broadcast never went on the wire (offline / suppressed
    /// sender); a real message — even a full-zero delta — is at least a
    /// wire header long (asserted; see the module docs). Advances the
    /// virtual clock to the round barrier and returns the timing record.
    pub fn simulate_round(
        &mut self,
        tau: usize,
        q2_bytes: &[u64],
        q1_bytes: &[u64],
    ) -> RoundTiming {
        let n = self.node_done.len();
        assert_eq!(q2_bytes.len(), n, "one q2 size per node");
        assert_eq!(q1_bytes.len(), n, "one q1 size per node");
        let floor = crate::quant::wire::MIN_ENCODED_BYTES as u64;
        for &b in q2_bytes.iter().chain(q1_bytes) {
            assert!(
                b == 0 || b >= floor,
                "{b}-byte message is below the {floor}-byte wire \
                 minimum: 0 means 'nothing transmitted', an empty \
                 quantized message still ships a header"
            );
        }
        let t0 = self.queue.now();
        let mut lost = 0u64;
        let mut stragglers = 0usize;
        self.node_done.iter_mut().for_each(|d| *d = t0);

        // round start: q2 broadcasts depart and local compute begins
        for i in 0..n {
            if self.sub.is_offline(i) {
                continue;
            }
            if q2_bytes[i] > 0 {
                lost += self.broadcast(i, t0, q2_bytes[i], 0);
            }
            let (dur, straggled) = self.sub.local_update_ns(i, tau);
            stragglers += usize::from(straggled);
            self.queue.schedule(t0 + dur, Ev::ComputeDone { node: i });
            // the completion is scheduled ahead of time, so the whole
            // virtual compute interval is known right here
            crate::obs::vspan("compute", i, t0, t0 + dur);
        }

        // drain the queue: compute-done events trigger the q1 broadcast
        while let Some((t, ev)) = self.queue.pop() {
            match ev {
                Ev::ComputeDone { node } => {
                    fold_event(&mut self.digest, t, 1, node as u64);
                    self.node_done[node] = self.node_done[node].max(t);
                    if q1_bytes[node] > 0 {
                        lost += self.broadcast(node, t, q1_bytes[node], 1);
                    }
                }
                Ev::Arrive { to, phase } => {
                    fold_event(
                        &mut self.digest,
                        t,
                        2 + phase as u64,
                        to as u64,
                    );
                    self.node_done[to] = self.node_done[to].max(t);
                }
            }
        }

        let round_end = self
            .node_done
            .iter()
            .copied()
            .max()
            .unwrap_or(t0)
            .max(t0);
        let online: usize =
            (0..n).filter(|&i| !self.sub.is_offline(i)).count();
        let wait_ns: u64 = self
            .node_done
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.sub.is_offline(*i))
            .map(|(_, &d)| round_end - d)
            .sum();
        self.queue.rebase(round_end);
        if crate::obs::active() {
            for (i, &d) in self.node_done.iter().enumerate() {
                if !self.sub.is_offline(i) {
                    crate::obs::hist(
                        "straggler_wait_ns",
                        round_end - d,
                    );
                }
            }
            if lost > 0 {
                crate::obs::counter(
                    "sim_messages_lost",
                    "total",
                    lost,
                );
            }
        }
        RoundTiming {
            round_secs: ns_to_secs(round_end - t0),
            virtual_secs: ns_to_secs(round_end),
            straggler_wait_secs: if online > 0 {
                ns_to_secs(wait_ns) / online as f64
            } else {
                0.0
            },
            stragglers,
            messages_lost: lost,
        }
    }

    /// Send `bytes` from node `i` to every up neighbor starting at
    /// `ready`; schedules arrivals for surviving messages and returns
    /// how many were lost in flight.
    fn broadcast(
        &mut self,
        i: usize,
        ready: VirtualTime,
        bytes: u64,
        phase: u8,
    ) -> u64 {
        let mut lost = 0u64;
        // adjacency lists are neighbor-sorted per Topology::build, so the
        // rng draw order is deterministic
        for ni in 0..self.sub.neighbors(i).len() {
            let j = self.sub.neighbors(i)[ni];
            let Some((arrive, dropped)) =
                self.sub.transmit_on(i, j, ready, bytes)
            else {
                continue; // no link / link down / receiver offline
            };
            if dropped {
                lost += 1;
            } else {
                self.queue.schedule(arrive, Ev::Arrive { to: j, phase });
            }
        }
        lost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::simnet::compute::ComputeModel;
    use crate::simnet::link::LinkModel;

    fn net(bw: f64) -> NetworkConfig {
        NetworkConfig {
            link: LinkModel {
                latency_s: 0.001,
                bandwidth_bps: bw,
                jitter_s: 0.0,
                drop_prob: 0.0,
            },
            link_hetero_spread: 0.0,
            compute: ComputeModel {
                base_step_s: 1e-3,
                ..Default::default()
            },
            churn: Default::default(),
        }
    }

    fn fabric(bw: f64, n: usize) -> Fabric {
        let topo = Topology::build(&TopologyKind::Ring, n, 0);
        Fabric::new(&net(bw), &topo, 7)
    }

    #[test]
    fn round_time_has_compute_and_transfer_floors() {
        let mut f = fabric(1e6, 4);
        let bytes = vec![12_500u64; 4]; // 0.1 s serialization at 1 Mbps
        let t = f.simulate_round(4, &bytes, &bytes);
        // per node: 4 ms compute; per link: two 0.1 s + 1 ms messages,
        // q1 serializes behind q2 on the shared directed link
        assert!(t.round_secs >= 0.2, "round {}", t.round_secs);
        assert!(t.round_secs < 1.0);
        assert_eq!(t.virtual_secs, t.round_secs);
        assert_eq!(t.messages_lost, 0);
    }

    #[test]
    fn clock_accumulates_across_rounds() {
        let mut f = fabric(1e8, 6);
        let bytes = vec![1000u64; 6];
        let t1 = f.simulate_round(2, &bytes, &bytes);
        let t2 = f.simulate_round(2, &bytes, &bytes);
        assert!(t2.virtual_secs > t1.virtual_secs);
        assert!(
            (t2.virtual_secs - (t1.virtual_secs + t2.round_secs)).abs()
                < 1e-12
        );
        assert!(f.events_processed() > 0);
    }

    #[test]
    fn narrower_links_make_slower_rounds() {
        let bytes = vec![50_000u64; 8];
        let fast = fabric(1e8, 8).simulate_round(2, &bytes, &bytes);
        let slow = fabric(1e6, 8).simulate_round(2, &bytes, &bytes);
        assert!(
            slow.round_secs > 2.0 * fast.round_secs,
            "slow {} fast {}",
            slow.round_secs,
            fast.round_secs
        );
    }

    #[test]
    fn stragglers_create_barrier_wait() {
        let topo = Topology::build(&TopologyKind::Ring, 8, 0);
        let mut cfg = net(1e9);
        cfg.compute = ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.0,
            straggler_prob: 0.3,
            straggler_slowdown: 20.0,
        };
        let mut f = Fabric::new(&cfg, &topo, 11);
        let bytes = vec![100u64; 8];
        let mut waited = 0.0;
        let mut straggled = 0;
        for _ in 0..20 {
            let t = f.simulate_round(4, &bytes, &bytes);
            waited += t.straggler_wait_secs;
            straggled += t.stragglers;
        }
        assert!(straggled > 10, "stragglers never fired: {straggled}");
        assert!(waited > 0.0, "stragglers caused no barrier wait");
    }

    #[test]
    fn replay_is_bit_identical() {
        let bytes = vec![4096u64; 8];
        let run = || {
            let topo = Topology::build(&TopologyKind::Torus, 8, 0);
            let mut cfg = net(1e6);
            cfg.link.jitter_s = 0.002;
            cfg.link.drop_prob = 0.1;
            cfg.compute.hetero_spread = 0.7;
            cfg.compute.straggler_prob = 0.2;
            let mut f = Fabric::new(&cfg, &topo, 99);
            let mut out = Vec::new();
            for _ in 0..10 {
                let t = f.simulate_round(4, &bytes, &bytes);
                out.push((
                    t.virtual_secs.to_bits(),
                    t.straggler_wait_secs.to_bits(),
                    t.messages_lost,
                ));
            }
            (out, f.event_digest(), f.events_processed())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn suppressed_broadcasts_send_nothing() {
        let mut f = fabric(1e6, 4);
        let silent = vec![0u64; 4];
        let t = f.simulate_round(1, &silent, &silent);
        // only compute events: round = the 1 ms local step
        assert!((t.round_secs - 1e-3).abs() < 1e-9, "{}", t.round_secs);
        assert_eq!(f.events_processed(), 4);
    }

    #[test]
    fn zero_delta_messages_still_occupy_links() {
        // offline (0 bytes) vs "legitimately empty quantized message":
        // a full-zero delta encodes to a header-sized frame and must
        // pay link serialization, unlike a suppressed broadcast
        let hdr = crate::quant::wire::MIN_ENCODED_BYTES as u64;
        let mut live_fab = fabric(1e4, 4);
        let live = vec![hdr; 4];
        let live_t = live_fab.simulate_round(1, &live, &live);
        let mut silent_fab = fabric(1e4, 4);
        let silent = vec![0u64; 4];
        let silent_t = silent_fab.simulate_round(1, &silent, &silent);
        assert!(
            live_t.round_secs > silent_t.round_secs,
            "header-only messages cost no time: {} !> {}",
            live_t.round_secs,
            silent_t.round_secs
        );
        assert!(live_fab.bytes_on_wire() > 0);
        assert_eq!(silent_fab.bytes_on_wire(), 0);
    }

    #[test]
    fn empty_sparse_topk_messages_still_occupy_links() {
        // ISSUE 10 regression: a top-k message that kept NOTHING
        // (k = 0) encodes to a sparse frame, not to zero bytes — the
        // fabric must charge it link time like any other live message,
        // keeping "sent an empty update" distinct from "offline"
        use crate::quant::wire::{self, QuantTag, WireHeader};
        use crate::quant::QuantizedVector;
        let qv = QuantizedVector {
            norm: 0.0,
            negative: vec![false; 512],
            indices: vec![0; 512],
            levels: vec![0.0],
            implied_table: false,
        };
        let header = WireHeader::new(QuantTag::TopK, 0, 0, 1, 1);
        let frame = wire::encode(&header, &qv).len() as u64;
        assert!(frame >= wire::MIN_ENCODED_BYTES as u64);
        // ... and far below the dense form of a 512-dim message
        assert!(frame < 512 / 8, "k=0 frame is not sparse: {frame}");
        let mut live_fab = fabric(1e4, 4);
        let live = vec![frame; 4];
        let live_t = live_fab.simulate_round(1, &live, &live);
        let mut silent_fab = fabric(1e4, 4);
        let silent = vec![0u64; 4];
        let silent_t = silent_fab.simulate_round(1, &silent, &silent);
        assert!(
            live_t.round_secs > silent_t.round_secs,
            "k=0 sparse frames cost no time: {} !> {}",
            live_t.round_secs,
            silent_t.round_secs
        );
        // ring of 4: 2 out-links per node, 2 broadcasts per node/round
        assert_eq!(live_fab.bytes_on_wire(), frame * 2 * 2 * 4);
        assert_eq!(silent_fab.bytes_on_wire(), 0);
    }

    #[test]
    #[should_panic(expected = "wire minimum")]
    fn sub_header_sizes_are_rejected() {
        // nothing between 0 (offline) and a full header is encodable
        let mut f = fabric(1e6, 4);
        let bogus = vec![5u64; 4];
        let _ = f.simulate_round(1, &bogus, &bogus);
    }

    #[test]
    fn byte_meter_counts_every_transmitted_copy() {
        // ring of 4: 2 out-links per node, 2 broadcasts per node/round
        let mut f = fabric(1e8, 4);
        let sizes = vec![1000u64; 4];
        let _ = f.simulate_round(2, &sizes, &sizes);
        assert_eq!(f.bytes_on_wire(), 1000 * 2 * 2 * 4);
        let _ = f.simulate_round(2, &sizes, &sizes);
        assert_eq!(f.bytes_on_wire(), 2 * 1000 * 2 * 2 * 4);
    }

    #[test]
    fn churned_fabric_reports_topology_changes() {
        let topo = Topology::build(&TopologyKind::Torus, 16, 1);
        let mut cfg = net(1e8);
        cfg.churn = crate::simnet::ChurnConfig {
            interval_rounds: 2,
            link_fail_prob: 0.5,
            link_heal_prob: 0.5,
            node_leave_prob: 0.1,
            node_return_prob: 0.5,
        };
        let mut f = Fabric::new(&cfg, &topo, 5);
        let bytes = vec![1000u64; 16];
        let mut changes = 0;
        for k in 0..20 {
            if let Some(t) = f.pre_round(k) {
                changes += 1;
                assert!(t.dense().is_doubly_stochastic(1e-9));
            }
            let _ = f.simulate_round(2, &bytes, &bytes);
        }
        assert!(changes > 3, "churn produced only {changes} changes");
    }
}
