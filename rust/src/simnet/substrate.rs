//! The fabric substrate: per-link / per-node / churn state shared by
//! every engine that runs on the simnet virtual clock.
//!
//! [`Fabric`](super::Fabric) (the synchronous round-barrier replay) and
//! the asynchronous event-driven engine
//! ([`crate::agossip::AsyncGossipEngine`]) need exactly the same live
//! state — directed [`Link`]s with serialization, heterogeneous
//! [`NodeCompute`] models, the offline set, and the churn process — but
//! drive completely different event loops over it. The substrate owns
//! that state plus the single rng stream the two consumers draw from, so
//! both engines inherit the same determinism contract: state transitions
//! and rng draws are a pure function of the (deterministic) order in
//! which the owning engine calls in.
//!
//! Construction is bit-compatible with the pre-extraction `Fabric::new`:
//! the same seed and config produce the same per-link bandwidth draws,
//! compute fleet, and churn trajectory, so the synchronous replay
//! digests recorded by `rust/tests/simnet_determinism.rs` are unchanged.

use std::collections::BTreeMap;

use super::churn::ChurnState;
use super::clock::VirtualTime;
use super::compute::NodeCompute;
use super::link::Link;
use super::NetworkConfig;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// FNV-1a offset basis — the shared seed of every event-stream digest.
pub const DIGEST_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold one popped event `(time, kind, node)` into an FNV-1a digest.
/// Both the synchronous fabric and the async engine fingerprint their
/// event streams with this exact fold, so "byte-identical event digest"
/// means the same thing for every engine on the virtual clock.
#[inline]
pub fn fold_event(digest: &mut u64, t: VirtualTime, kind: u64, node: u64) {
    const PRIME: u64 = 0x100_0000_01b3;
    for x in [t, kind, node] {
        *digest = (*digest ^ x).wrapping_mul(PRIME);
    }
}

/// Live deployment state under an engine-owned event loop.
pub struct Substrate {
    cfg: NetworkConfig,
    /// per-directed-link live state, keyed (from, to) over the base graph
    links: BTreeMap<(usize, usize), Link>,
    /// current adjacency (changes under churn)
    adj: Vec<Vec<usize>>,
    /// nodes currently offline (empty without churn)
    offline: Vec<bool>,
    compute: Vec<NodeCompute>,
    churn: Option<ChurnState>,
    rng: Rng,
    /// lifetime bytes put on links (every transmitted copy, dropped
    /// in-flight included — the sender still occupied the link). This
    /// is the fabric-side byte-accounting truth the engines' measured
    /// wire sizes are cross-checked against.
    bytes_tx: u64,
}

impl Substrate {
    /// Assemble the substrate for `topo` with per-link models drawn from
    /// the config (a dedicated rng stream per concern keeps the build
    /// deterministic and independent of call order).
    pub fn new(cfg: &NetworkConfig, topo: &Topology, seed: u64) -> Substrate {
        let mut root = Rng::new(seed ^ 0x51A7_ABBE);
        let mut build_rng = root.split(1);
        let n = topo.n;
        let mut links = BTreeMap::new();
        // BTreeMap iteration and sorted insertion keep per-link draws in
        // (from, to) order regardless of adjacency-list layout
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for (i, nbrs) in topo.adj.iter().enumerate() {
            for &j in nbrs {
                edges.push((i, j));
            }
        }
        edges.sort_unstable();
        for (i, j) in edges {
            let mut model = cfg.link.clone();
            if cfg.link_hetero_spread > 0.0 {
                let factor =
                    1.0 + cfg.link_hetero_spread * build_rng.uniform();
                model.bandwidth_bps /= factor;
            }
            links.insert((i, j), Link::new(model));
        }
        let compute =
            NodeCompute::fleet(&cfg.compute, n, &mut root.split(2));
        let churn = if cfg.churn.enabled() {
            Some(ChurnState::new(cfg.churn.clone(), topo, root.split(3)))
        } else {
            None
        };
        Substrate {
            cfg: cfg.clone(),
            links,
            adj: topo.adj.clone(),
            offline: vec![false; n],
            compute,
            churn,
            rng: root.split(4),
            bytes_tx: 0,
        }
    }

    /// Lifetime bytes transmitted on links (see the field docs).
    pub fn bytes_on_wire(&self) -> u64 {
        self.bytes_tx
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.offline.len()
    }

    /// Loss probability the engine's broadcast-level fault injection
    /// should inherit (the old `drop_prob` knob, subsumed).
    pub fn link_drop_prob(&self) -> f64 {
        self.cfg.link.drop_prob
    }

    /// Current (churned) neighbors of node `i`.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Whether churn currently has node `i` offline.
    pub fn is_offline(&self, i: usize) -> bool {
        self.offline[i]
    }

    /// Whether the directed link i→j exists and currently carries
    /// traffic (false for never-built links and churn-failed ones).
    pub fn link_up(&self, i: usize, j: usize) -> bool {
        self.links.get(&(i, j)).is_some_and(|l| l.up)
    }

    /// Run the churn process before epoch `k`; when the live graph
    /// changed, returns the rebuilt topology (Metropolis weights, fresh
    /// ζ) the owning engine must mix with from now on.
    pub fn pre_round(&mut self, k: usize) -> Option<Topology> {
        let churn = self.churn.as_mut()?;
        let topo = churn.pre_round(k)?;
        self.adj = topo.adj.clone();
        for (&(i, j), link) in self.links.iter_mut() {
            link.up = churn.link_up(i, j);
        }
        for (i, off) in self.offline.iter_mut().enumerate() {
            *off = churn.offline().contains(&i);
        }
        Some(topo)
    }

    /// Queue `bytes` on the directed link i→j starting no earlier than
    /// `ready`. Returns `None` when nothing was transmitted at all (no
    /// such link, link down, or receiver offline — no rng consumed), or
    /// `Some((arrival, dropped))`; a dropped message still occupied the
    /// link (the sender transmitted it) but lands nowhere.
    pub fn transmit_on(
        &mut self,
        i: usize,
        j: usize,
        ready: VirtualTime,
        bytes: u64,
    ) -> Option<(VirtualTime, bool)> {
        if self.offline[j] {
            return None;
        }
        let link = self.links.get_mut(&(i, j))?;
        if !link.up {
            return None;
        }
        self.bytes_tx += bytes;
        let out = link.transmit(ready, bytes, &mut self.rng);
        // observation only — the transmit above already drew its rng,
        // so tracing can never perturb the event stream. Keys aggregate
        // per *sender* node, not per directed link: at 10k nodes the
        // per-link scheme minted ~80k strings per counter name; the
        // recorder additionally caps distinct keys per name.
        if crate::obs::active() {
            let key = format!("{i}");
            crate::obs::counter("link_send", &key, 1);
            crate::obs::counter("link_bytes", &key, bytes);
            if out.1 {
                crate::obs::counter("link_drop", &key, 1);
            }
        }
        Some(out)
    }

    /// Virtual duration of node `i`'s τ local steps this round; returns
    /// the duration and whether the node straggled.
    pub fn local_update_ns(
        &mut self,
        i: usize,
        tau: usize,
    ) -> (VirtualTime, bool) {
        self.compute[i].local_update_ns(
            &self.cfg.compute,
            tau,
            &mut self.rng,
        )
    }
}
