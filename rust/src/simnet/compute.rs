//! Per-node compute models: heterogeneous τ-step SGD durations and
//! transient stragglers.
//!
//! Every node gets a fixed speed factor drawn once at fabric build time
//! (hardware heterogeneity), and each round independently becomes a
//! straggler with `straggler_prob`, multiplying that round's local-update
//! time by `straggler_slowdown` (GC pauses, co-tenant interference,
//! thermal throttling — the transient tail DAdaQuant-style schedules
//! have to survive).

use super::clock::{secs_to_ns, VirtualTime};
use crate::util::rng::Rng;

/// Fabric-wide compute distribution parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeModel {
    /// seconds one local SGD step takes on the fastest node
    pub base_step_s: f64,
    /// per-node speed factor is uniform in [1, 1 + hetero_spread]
    pub hetero_spread: f64,
    /// per-round probability a node straggles
    pub straggler_prob: f64,
    /// multiplier applied to a straggling node's round compute time
    pub straggler_slowdown: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel {
            base_step_s: 1e-3,
            hetero_spread: 0.0,
            straggler_prob: 0.0,
            straggler_slowdown: 4.0,
        }
    }
}

impl ComputeModel {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.base_step_s >= 0.0 && self.base_step_s.is_finite()) {
            return Err("compute base_step_s must be finite and >= 0".into());
        }
        if !(self.hetero_spread >= 0.0 && self.hetero_spread.is_finite()) {
            return Err("compute hetero_spread must be finite and >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err("compute straggler_prob must be in [0, 1]".into());
        }
        if !(self.straggler_slowdown >= 1.0
            && self.straggler_slowdown.is_finite())
        {
            return Err("compute straggler_slowdown must be >= 1".into());
        }
        Ok(())
    }
}

/// One node's resolved compute state.
#[derive(Clone, Debug)]
pub struct NodeCompute {
    /// fixed hardware speed factor (>= 1; 1 = fastest)
    pub speed: f64,
}

impl NodeCompute {
    /// Draw the per-node fleet for `n` nodes from a dedicated rng stream.
    pub fn fleet(model: &ComputeModel, n: usize, rng: &mut Rng) -> Vec<Self> {
        (0..n)
            .map(|_| {
                let u = if model.hetero_spread > 0.0 {
                    rng.uniform()
                } else {
                    0.0
                };
                NodeCompute { speed: 1.0 + model.hetero_spread * u }
            })
            .collect()
    }

    /// Virtual duration of this round's τ local steps; returns the
    /// duration and whether the node straggled. One uniform is drawn per
    /// round when straggling is enabled (none otherwise).
    pub fn local_update_ns(
        &self,
        model: &ComputeModel,
        tau: usize,
        rng: &mut Rng,
    ) -> (VirtualTime, bool) {
        let mut secs = model.base_step_s * tau as f64 * self.speed;
        let straggled = model.straggler_prob > 0.0
            && rng.uniform() < model.straggler_prob;
        if straggled {
            secs *= model.straggler_slowdown;
        }
        (secs_to_ns(secs), straggled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_fleet_all_unit_speed() {
        let m = ComputeModel::default();
        let mut rng = Rng::new(0);
        let fleet = NodeCompute::fleet(&m, 8, &mut rng);
        assert!(fleet.iter().all(|c| c.speed == 1.0));
        let (ns, s) = fleet[0].local_update_ns(&m, 4, &mut rng);
        assert_eq!(ns, secs_to_ns(4e-3));
        assert!(!s);
    }

    #[test]
    fn heterogeneous_fleet_spreads_speeds() {
        let m = ComputeModel { hetero_spread: 1.0, ..Default::default() };
        let mut rng = Rng::new(5);
        let fleet = NodeCompute::fleet(&m, 32, &mut rng);
        assert!(fleet.iter().all(|c| (1.0..=2.0).contains(&c.speed)));
        let min = fleet.iter().map(|c| c.speed).fold(f64::MAX, f64::min);
        let max = fleet.iter().map(|c| c.speed).fold(f64::MIN, f64::max);
        assert!(max - min > 0.2, "no spread: {min}..{max}");
    }

    #[test]
    fn stragglers_slow_the_round() {
        let m = ComputeModel {
            straggler_prob: 1.0,
            straggler_slowdown: 10.0,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let node = NodeCompute { speed: 1.0 };
        let (ns, straggled) = node.local_update_ns(&m, 2, &mut rng);
        assert!(straggled);
        assert_eq!(ns, secs_to_ns(2e-3 * 10.0));
    }

    #[test]
    fn validate_catches_bad_fields() {
        assert!(ComputeModel::default().validate().is_ok());
        assert!(
            ComputeModel { straggler_prob: -0.1, ..Default::default() }
                .validate()
                .is_err()
        );
        assert!(
            ComputeModel { straggler_slowdown: 0.5, ..Default::default() }
                .validate()
                .is_err()
        );
        assert!(
            ComputeModel { base_step_s: f64::NAN, ..Default::default() }
                .validate()
                .is_err()
        );
    }
}
