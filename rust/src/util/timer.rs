//! Wall-clock timing helpers for metrics and the bench harness.

use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.elapsed_secs() * 1e9
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
