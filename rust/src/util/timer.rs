//! Wall-clock timing helpers for metrics and the bench harness.

use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_ns(&self) -> f64 {
        // NOT elapsed_secs() * 1e9: the f64 seconds round-trip loses
        // nanosecond resolution once runs last minutes (2^52 ns ~ 52
        // days, but the secs path already rounds at microseconds)
        self.start.elapsed().as_nanos() as f64
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn elapsed_ns_keeps_nanosecond_resolution() {
        // regression: the old implementation computed
        // elapsed_secs() * 1e9, so a ~1 µs interval came back rounded
        // through an f64 of *seconds*; integer nanoseconds from
        // Instant::elapsed().as_nanos() must agree with the secs view
        // at microsecond scale and be exact at nanosecond scale
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_micros(500));
        let ns = t.elapsed_ns();
        let secs = t.elapsed_secs();
        assert!(ns >= 500_000.0, "slept 500µs but measured {ns}ns");
        // an f64 holds integers exactly to 2^53: any ns count a test
        // can reach converts without rounding, so the value must be a
        // whole number of nanoseconds
        assert_eq!(ns.fract(), 0.0);
        // the two clocks agree (ns was measured first, so it is the
        // smaller of the two)
        assert!(secs * 1e9 >= ns);
        assert!(secs * 1e9 - ns < 50_000_000.0, "clocks diverged");
    }
}
