//! Small statistics helpers shared by metrics, benches and tests.

/// Running summary of a stream of f64 samples (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample set (linear interpolation); `q` in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return 0.0; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// l2 norm of an f32 slice (f64 accumulation).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Squared l2 distance between two f32 slices.
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.var() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 30.0);
        assert!((percentile(&xs, 0.5) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn l2_and_sq_dist() {
        let a = [3.0f32, 4.0];
        let b = [0.0f32, 0.0];
        assert!((l2_norm(&a) - 5.0).abs() < 1e-12);
        assert!((sq_dist(&a, &b) - 25.0).abs() < 1e-6);
    }
}
